"""Serving API v2 (ISSUE 5): continuous-batching scheduler.

Pillars:
  * token parity — greedy tokens from the continuous `Scheduler` equal
    the static-batch engine's, per request, on staggered arrivals with
    mixed prompt lengths and budgets, for decode-SLA on AND off (the
    per-request (1, bucket) prefill + slot scatter is bit-equivalent to
    a row of the aligned batch; drift-threshold extremes 0.0/1.0 where
    per-slot decisions must coincide with the group decision);
  * slot turnover — admission counters, occupancy accounting, and the
    acceptance claim: continuous occupancy > static occupancy on a
    heterogeneous-budget workload (deterministic — the counters depend
    only on slot bookkeeping, not wall time);
  * state scatter — after crossing block boundaries in a slot, the
    slot's incremental decode plan rows and H/Z running state equal a
    scalar-pos decode chain's (the decode suite's ground truth);
  * streaming — event ordering (start < tokens < finish per rid,
    monotone times, indices dense) and sampling-policy behavior
    (stop tokens, temperature determinism);
  * `SLAConfig.validate()` — the satellite's loud-failure matrix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import SLAConfig
from repro.models import transformer as tfm
from repro.serving.api import (RequestState, SamplingParams, Scheduler,
                               StreamEvent)
from repro.serving.engine import Request, ServingEngine

LENS = (32, 20, 32, 24)
BUDGETS = (6, 20, 4, 12)


def _arch(kh=1.0, kl=0.0, decode=False, drift=None):
    cfg = get_arch("qwen3-1.7b").smoke()
    sla = cfg.sla.replace(kh_frac=kh, kl_frac=kl)
    if decode:
        sla = sla.replace(decode_mode="sla")
    if drift is not None:
        sla = sla.replace(plan_drift_threshold=drift)
    return dataclasses.replace(cfg, sla=sla)


def _params(cfg, proj_scale=0.3):
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) \
        * proj_scale
    return params


def _prompts(cfg, lens=LENS, seed=0):
    rs = np.random.default_rng(seed)
    return [rs.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def _requests(cfg):
    return [Request(rid=i, prompt=p, max_new_tokens=BUDGETS[i])
            for i, p in enumerate(_prompts(cfg))]


def _staggered_drain(sched, prompts, budgets, stagger=3):
    """Submit the first two requests, decode a few steps, then submit
    the rest mid-flight — the arrival pattern the static engine cannot
    express."""
    events = []
    for p, b in zip(prompts[:2], budgets[:2]):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    for _ in range(stagger):
        events.extend(sched.step())
    for p, b in zip(prompts[2:], budgets[2:]):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    while sched.has_work:
        events.extend(sched.step())
    return sched.drain(), events


# ---------------------------------------------------------------------------
# token parity vs the static-batch engine
# ---------------------------------------------------------------------------
def test_continuous_matches_static_dense():
    """Greedy parity on the dense-decode path, staggered arrivals,
    mixed prompt lengths and budgets."""
    cfg = _arch()
    params = _params(cfg)
    static = ServingEngine(cfg, params, batch_size=2, max_len=96)
    a = static.run(_requests(cfg))
    # per-group plen is 32 for both engine groups; pin the scheduler's
    # bucket to it so left-padding (and therefore numerics) match
    sched = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=32)
    done, events = _staggered_drain(sched, _prompts(cfg), BUDGETS)
    assert len(done) == len(a)
    for ra, rb in zip(a, done):
        assert ra.rid == rb.rid
        assert ra.tokens_out == rb.tokens_out, f"rid {ra.rid}"
        assert rb.state is RequestState.FINISHED
    assert sched.stats.admissions == len(a)
    # acceptance: continuous slots turn over, lockstep ones do not
    assert sched.stats.occupancy() > static.stats.occupancy()


@pytest.mark.parametrize("kh,drift", [
    (1.0, None),   # saturating: inherit == fresh, decision irrelevant
    (0.25, 0.0),   # always-replan: per-slot == per-group decision
    (0.25, 1.0),   # never-replan: pure inheritance on both paths
])
def test_continuous_matches_static_decode_sla(kh, drift):
    """Greedy parity with decode-time SLA state scattered per slot."""
    cfg = _arch(kh=kh, decode=True, drift=drift)
    params = _params(cfg)
    static = ServingEngine(cfg, params, batch_size=2, max_len=96,
                           decode_sla=True)
    a = static.run(_requests(cfg))
    sched = Scheduler(cfg, params, num_slots=2, max_len=96,
                      decode_sla=True, prefill_bucket=32)
    done, _ = _staggered_drain(sched, _prompts(cfg), BUDGETS)
    for ra, rb in zip(a, done):
        assert ra.tokens_out == rb.tokens_out, f"rid {ra.rid}"
    st = sched.stats
    assert st.decode_plan_builds == cfg.num_layers * len(a)
    assert st.decode_plan_extends > 0  # budgets cross block boundaries
    assert st.occupancy() > static.stats.occupancy()


@pytest.mark.slow
def test_engine_continuous_wrapper_matches_static():
    """ServingEngine(scheduler='continuous').run() — the v1 compat
    wrapper — reproduces the static path's tokens and fills metrics."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    a = ServingEngine(cfg, params, batch_size=2, max_len=96,
                      decode_sla=True).run(_requests(cfg))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=96,
                        decode_sla=True, scheduler="continuous")
    b = eng.run(_requests(cfg))
    for ra, rb in zip(a, b):
        assert ra.tokens_out == rb.tokens_out
        assert rb.metrics is not None
        assert rb.metrics.ttft_s > 0.0
        assert rb.latency_s == rb.metrics.latency_s >= rb.metrics.ttft_s
    assert eng.stats.admissions == len(b)


# ---------------------------------------------------------------------------
# slot turnover + admission counters
# ---------------------------------------------------------------------------
def test_slot_turnover_and_counters():
    cfg = _arch()
    params = _params(cfg)
    sched = Scheduler(cfg, params, num_slots=2, max_len=80,
                      prefill_bucket=32)
    prompts = _prompts(cfg, lens=(16, 16, 16, 16, 16))
    budgets = (3, 9, 3, 3, 5)
    for p, b in zip(prompts, budgets):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    done = sched.drain()
    st = sched.stats
    assert st.admissions == 5          # every request got a slot
    assert len(done) == 5
    for r, b in zip(done, budgets):
        assert len(r.tokens_out) == b
        assert r.metrics.decode_tokens == b
        assert r.state is RequestState.FINISHED
        assert r.metrics.latency_s >= r.metrics.ttft_s > 0.0
    # 5 admissions through 2 slots == slots were recycled mid-stream
    assert st.admissions > sched.num_slots
    assert 0.0 < st.occupancy() <= 1.0
    assert st.slot_steps_total % sched.num_slots == 0
    # later submissions waited for a free slot -> queue time is real
    assert done[4].metrics.queue_s > 0.0


def test_static_engine_per_request_metrics():
    """Satellite: the static engine no longer assigns every request the
    cumulative engine time."""
    cfg = _arch()
    params = _params(cfg)
    eng = ServingEngine(cfg, params, batch_size=2, max_len=96)
    done = eng.run(_requests(cfg))
    lats = [r.latency_s for r in done]
    assert all(l > 0.0 for l in lats)
    # within group 0, rid 0 (6 tokens) finishes before rid 1 (20 tokens)
    assert done[0].metrics.finish_t < done[1].metrics.finish_t
    assert done[0].latency_s < done[1].latency_s
    for r in done:
        assert r.metrics.first_token_t >= r.metrics.admit_t
        assert r.latency_s == r.metrics.latency_s
        assert r.metrics.decode_tokens == r.max_new_tokens
    # cumulative engine seconds is NOT a per-request latency any more
    total = eng.stats.prefill_s + eng.stats.decode_s
    assert any(abs(l - total) > 1e-9 for l in lats)


# ---------------------------------------------------------------------------
# decode-SLA state scatter correctness
# ---------------------------------------------------------------------------
def test_slot_state_matches_scalar_decode_chain():
    """A request decoded through a scheduler slot carries exactly the
    state a scalar-pos decode chain (the decode suite's ground truth)
    would have: same tokens, same incremental plan rows, same H/Z."""
    cfg = _arch(kh=0.5, decode=True)
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(32,))[0]
    budget = 20  # crosses the pos-32 and pos-48 block boundaries

    # ground truth: batch-1 scalar-pos chain
    import functools
    last, cache = tfm.prefill(params, cfg, jnp.asarray(prompt[None, :]),
                              decode_max_len=96)
    step = jax.jit(functools.partial(tfm.decode_step, params, cfg))
    from repro.models.common import logits_from_hidden
    tok = jnp.argmax(logits_from_hidden(params, last), -1) \
        .astype(jnp.int32)
    ref_tokens = [int(tok[0])]
    for _ in range(budget - 1):
        logits, cache = step(tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ref_tokens.append(int(tok[0]))

    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      decode_sla=True, prefill_bucket=32)
    sched.submit(prompt, SamplingParams(max_new_tokens=budget))
    done = sched.drain()
    assert done[0].tokens_out == ref_tokens

    live, ref = sched._live["sla"], cache["sla"]
    # the scheduler ran one extra decode step's worth of state for the
    # final sampled token? no: both chains decoded budget-1 steps after
    # the prefill token, so the slot state must match exactly
    np.testing.assert_array_equal(np.asarray(live["plan"].mc[:, 0]),
                                  np.asarray(ref["plan"].mc[:, 0]))
    np.testing.assert_array_equal(np.asarray(live["rows"][0]),
                                  np.asarray(ref["rows"]))
    np.testing.assert_array_equal(np.asarray(live["hblk"][:, 0]),
                                  np.asarray(ref["hblk"][:, 0]))
    np.testing.assert_array_equal(np.asarray(live["htot"][:, 0]),
                                  np.asarray(ref["htot"][:, 0]))
    np.testing.assert_array_equal(np.asarray(live["ztot"][:, 0]),
                                  np.asarray(ref["ztot"][:, 0]))
    # per-slot counters equal the scalar chain's per-layer counters
    np.testing.assert_array_equal(np.asarray(live["extends"][:, 0]),
                                  np.asarray(ref["extends"]))


def test_insert_slot_rejects_mismatched_caches():
    cfg = _arch(decode=True)
    params = _params(cfg)
    live = tfm.make_cache(cfg, 2, 64, decode_sla=True, per_slot=True)
    toks = jnp.asarray(_prompts(cfg, lens=(16,))[0][None, :])
    _, single = tfm.prefill(params, cfg, toks, decode_max_len=48)
    with pytest.raises(ValueError, match="length mismatch"):
        tfm.insert_slot(live, single, 0)
    _, plain = tfm.prefill(params, cfg, toks)
    with pytest.raises(ValueError, match="sla"):
        tfm.insert_slot(live, dict(plain), 0)


# ---------------------------------------------------------------------------
# streaming events + sampling policies
# ---------------------------------------------------------------------------
def test_stream_event_ordering():
    cfg = _arch()
    params = _params(cfg)
    sched = Scheduler(cfg, params, num_slots=2, max_len=80,
                      prefill_bucket=32)
    done, events = _staggered_drain(
        sched, _prompts(cfg, lens=(16, 24, 16)), (4, 7, 5), stagger=2)
    assert all(isinstance(e, StreamEvent) for e in events)
    times = [e.t for e in events]
    assert times == sorted(times)
    by_rid = {r.rid: [e for e in events if e.rid == r.rid] for r in done}
    for r in done:
        evs = by_rid[r.rid]
        assert [e.kind for e in evs] == \
            ["start"] + ["token"] * len(r.tokens_out) + ["finish"]
        toks = [e for e in evs if e.kind == "token"]
        assert [e.index for e in toks] == list(range(len(r.tokens_out)))
        assert [e.token for e in toks] == r.tokens_out


def test_stream_generator_and_stop_tokens():
    cfg = _arch()
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(16,))[0]
    probe = Scheduler(cfg, params, num_slots=1, max_len=64,
                      prefill_bucket=16)
    probe.submit(prompt, SamplingParams(max_new_tokens=6))
    greedy = probe.drain()[0].tokens_out

    # stop on the first token value whose first occurrence is mid-stream
    # (greedy chains repeat heavily; a repeated value would stop early)
    stop_idx = next((i for i in range(1, len(greedy))
                     if greedy[i] not in greedy[:i]), 0)
    sched = Scheduler(cfg, params, num_slots=1, max_len=64,
                      prefill_bucket=16)
    sched.submit(prompt, SamplingParams(max_new_tokens=6,
                                        stop_tokens=(greedy[stop_idx],)))
    events = list(sched.stream())
    r = sched.drain()[0]
    # stopped at (and kept) the stop token, under budget
    assert r.tokens_out == greedy[:stop_idx + 1]
    assert events[-1].kind == "finish"
    assert not sched.has_work


def test_temperature_sampling_deterministic():
    cfg = _arch()
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(16,))[0]

    def run_once():
        s = Scheduler(cfg, params, num_slots=1, max_len=64,
                      prefill_bucket=16)
        s.submit(prompt, SamplingParams(max_new_tokens=5,
                                        temperature=1.0, seed=3))
        return s.drain()[0].tokens_out

    a, b = run_once(), run_once()
    assert a == b
    assert len(a) == 5


def test_submit_validation():
    cfg = _arch()
    params = _params(cfg)
    sched = Scheduler(cfg, params, num_slots=1, max_len=48)
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0).validate()
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-1.0).validate()
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(np.arange(40, dtype=np.int32),
                     SamplingParams(max_new_tokens=32))
    with pytest.raises(ValueError, match="empty"):
        sched.submit(np.zeros((0,), np.int32))


def test_bucket_growth_cannot_overrun_max_len():
    """A long prompt grows the SHARED prefill bucket; a shorter queued
    request that fit at submit time may no longer fit (its decode would
    run past max_len into clamped — silently corrupting — cache
    writes). Both submit() and admission must fail loudly instead."""
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(80, 16))
    # submitted AFTER the long prompt was admitted: submit() checks
    # against the grown shared bucket
    sched = Scheduler(cfg, params, num_slots=1, max_len=96)
    sched.submit(prompts[0], SamplingParams(max_new_tokens=1))
    sched.drain()  # admits the long prompt -> shared bucket is now 80
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(prompts[1], SamplingParams(max_new_tokens=48))
    # queued BEFORE the long prompt was admitted (submit-time check
    # could not see the growth): the admission re-check catches it
    sched2 = Scheduler(cfg, params, num_slots=1, max_len=96)
    sched2.submit(prompts[0], SamplingParams(max_new_tokens=1))
    sched2.submit(prompts[1], SamplingParams(max_new_tokens=48))  # fits now
    with pytest.raises(ValueError, match="bucket grew"):
        sched2.drain()


def test_scheduler_rejects_incapable_family():
    cfg = get_arch("rwkv6-7b").smoke()
    with pytest.raises(ValueError, match="continuous|slot"):
        Scheduler(cfg, params=None)


# ---------------------------------------------------------------------------
# SLAConfig.validate (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("field,value", [
    ("mode", "slaa"), ("phi", "gelu"), ("routing_mode", "leraned"),
    ("plan_refresh_mode", "sometimes"), ("decode_mode", "sparse"),
])
def test_sla_config_validate_rejects_typos(field, value):
    cfg = SLAConfig(**{field: value})
    with pytest.raises(ValueError, match=field):
        cfg.validate()


def test_sla_config_validate_rejects_bad_combos():
    with pytest.raises(ValueError, match="window"):
        SLAConfig(window=64, decode_mode="sla").validate()
    with pytest.raises(ValueError, match="block"):
        SLAConfig(block_q=0).validate()
    with pytest.raises(ValueError, match="block_q == block_kv"):
        SLAConfig(block_q=32, block_kv=64, decode_mode="sla").validate()
    with pytest.raises(ValueError, match="kh_frac"):
        SLAConfig(kh_frac=1.5).validate()
    with pytest.raises(ValueError, match="plan_refresh_interval"):
        SLAConfig(plan_refresh_interval=0).validate()
    # chaining: a valid config returns itself
    cfg = SLAConfig()
    assert cfg.validate() is cfg


def test_validate_called_at_entry_points():
    """Engine, scheduler, and plan entry points all reject a typo'd
    mode up front instead of deep inside a trace."""
    from repro.core.plan import plan_attention

    cfg = get_arch("qwen3-1.7b").smoke()
    bad = dataclasses.replace(cfg, sla=cfg.sla.replace(mode="topk"))
    with pytest.raises(ValueError, match="mode"):
        ServingEngine(bad, params=None)
    with pytest.raises(ValueError, match="mode"):
        Scheduler(bad, params=None)
    q = jnp.zeros((1, 2, 32, 16))
    with pytest.raises(ValueError, match="mode"):
        plan_attention(q, q, bad.sla)


def test_request_metrics_unset_return_none():
    """Derived metrics are None until their gating event happens —
    clamping to 0.0 silently reported in-flight requests as
    instantaneous (the ISSUE 7 latency bug)."""
    from repro.serving.api import RequestMetrics

    m = RequestMetrics(submit_t=100.0)
    assert m.queue_s is None
    assert m.ttft_s is None
    assert m.latency_s is None
    m.admit_t = 100.5
    assert m.queue_s == pytest.approx(0.5)
    assert m.ttft_s is None and m.latency_s is None
    m.first_token_t = 101.0
    assert m.ttft_s == pytest.approx(1.0)
    assert m.latency_s is None  # still decoding: NOT 0.0
    m.finish_t = 103.0
    assert m.latency_s == pytest.approx(3.0)


def test_scheduler_inflight_metrics_are_none():
    """A decoding request has ttft_s but no latency_s; a queued request
    has neither."""
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32, 24))
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=32)
    for p in prompts:
        sched.submit(p, SamplingParams(max_new_tokens=8))
    sched.step()  # admits request 0, decodes one token
    decoding, queued = sched._requests
    assert decoding.metrics.queue_s is not None
    assert decoding.metrics.ttft_s is not None
    assert decoding.metrics.latency_s is None
    assert queued.metrics.queue_s is None
    assert queued.metrics.ttft_s is None
    assert queued.metrics.latency_s is None
    done = sched.drain()
    assert all(r.metrics.latency_s >= r.metrics.ttft_s > 0.0
               for r in done)


def test_percentile_nearest_rank():
    """Nearest-rank percentile: rank = ceil(p*n), 1-indexed. The old
    `int(p * n)` indexing read one element HIGH (p95 of 20 returned
    sorted[19] — the max — instead of sorted[18])."""
    from repro.serving.api import percentile

    xs10 = [9.0, 1.0, 5.0, 3.0, 7.0, 0.0, 8.0, 2.0, 6.0, 4.0]
    # p50 of 10 -> rank ceil(5.0) = 5 -> sorted[4]
    assert percentile(xs10, 0.5) == sorted(xs10)[4] == 4.0
    xs20 = [float(v) for v in range(20, 0, -1)]
    # p95 of 20 -> rank ceil(19.0) = 19 -> sorted[18], NOT sorted[19]
    assert percentile(xs20, 0.95) == sorted(xs20)[18] == 19.0
    assert percentile(xs20, 1.0) == 20.0    # rank clamps to n
    assert percentile(xs20, 0.0) == 1.0     # rank clamps to 1
    assert percentile([3.0], 0.5) == 3.0
    with pytest.raises(ValueError, match="empty"):
        percentile([], 0.5)


# ---------------------------------------------------------------------------
# chunked admission prefill (tentpole)
# ---------------------------------------------------------------------------
def _chunk_arch(decode=False, kh=0.25):
    """Chunk-eligible smoke config: per-row critical sets only
    (col_capacity_factor=None — the column-capacity demotion pass
    couples rows, see transformer.check_chunked_prefill)."""
    cfg = get_arch("qwen3-1.7b").smoke()
    sla = cfg.sla.replace(kh_frac=kh, kl_frac=0.0,
                          col_capacity_factor=None)
    if decode:
        sla = sla.replace(decode_mode="sla")
    return dataclasses.replace(cfg, sla=sla)


def _step_until_tokens(sched, n, limit=200):
    """step() until `n` token events were emitted; returns all events."""
    events, toks = [], 0
    for _ in range(limit):
        if toks >= n:
            break
        new = sched.step()
        events.extend(new)
        toks += sum(1 for e in new if e.kind == "token")
    assert toks >= n, f"only {toks} tokens after {limit} ticks"
    return events


@pytest.mark.parametrize("backend,decode_sla", [
    ("gather", False), ("gather", True),
    ("kernel", False), ("kernel", True),
])
def test_chunked_matches_blocking_bitwise(backend, decode_sla):
    """The tentpole bar: chunked admission produces the SAME greedy
    tokens as blocking admission (mixed lengths, slot turnover) AND a
    mid-decode slot's cache leaves are bitwise equal — for gather and
    fused-kernel execution, decode-SLA on and off."""
    cfg = _chunk_arch(decode=decode_sla)
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(64, 24, 48), seed=4)
    budgets = (6, 8, 5)

    def make(chunk):
        return Scheduler(cfg, params, num_slots=2, max_len=96,
                         prefill_bucket=64, decode_sla=decode_sla,
                         backend=backend, paged=True,
                         prefill_chunk_blocks=chunk)

    def run(chunk):
        s = make(chunk)
        for p, b in zip(prompts, budgets):
            s.submit(p, SamplingParams(max_new_tokens=b))
        return [list(r.tokens_out) for r in s.drain()]

    assert run(None) == run(1)

    # cache-leaf parity mid-decode: one request in each scheduler,
    # stopped after the same number of emitted tokens
    from repro.models.transformer import paged_dense_view
    live = {}
    for chunk in (None, 1):
        s = make(chunk)
        s.submit(prompts[0], SamplingParams(max_new_tokens=8))
        _step_until_tokens(s, 4)
        live[chunk] = (s._live, paged_dense_view(cfg, s._live))
    (la, va), (lb, vb) = live[None], live[1]
    np.testing.assert_array_equal(np.asarray(la["pos"][0]),
                                  np.asarray(lb["pos"][0]))
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(va[key][:, 0]),
                                      np.asarray(vb[key][:, 0]),
                                      err_msg=key)
    if decode_sla:
        sa, sb = va["sla"], vb["sla"]
        for key in ("hblk", "zblk", "kpool", "htot", "ztot", "qpool",
                    "live_lut", "live_cnt", "live_marg"):
            np.testing.assert_array_equal(np.asarray(sa[key][:, 0]),
                                          np.asarray(sb[key][:, 0]),
                                          err_msg=key)
        np.testing.assert_array_equal(np.asarray(sa["rows"][0]),
                                      np.asarray(sb["rows"][0]))
        np.testing.assert_array_equal(np.asarray(sa["plan"].mc[:, 0]),
                                      np.asarray(sb["plan"].mc[:, 0]))


def test_chunked_admission_interleaves_decode():
    """Decode tokens keep flowing BETWEEN a chunked admission's start
    and its first token — the event order blocking admission cannot
    produce (its prefill dispatch stalls the whole tick)."""
    cfg = _chunk_arch()
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(16, 64), seed=2)
    sched = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=64, paged=True,
                      prefill_chunk_blocks=1)
    r0 = sched.submit(prompts[0], SamplingParams(max_new_tokens=12))
    events = _step_until_tokens(sched, 1)  # r0 is mid-decode
    r1 = sched.submit(prompts[1], SamplingParams(max_new_tokens=4))
    while sched.has_work:
        events.extend(sched.step())
    start1 = next(i for i, e in enumerate(events)
                  if e.rid == r1 and e.kind == "start")
    tok1 = next(i for i, e in enumerate(events)
                if e.rid == r1 and e.kind == "token")
    between = [e for e in events[start1:tok1]
               if e.rid == r0 and e.kind == "token"]
    # 64-token prompt = 4 one-block chunks = >= 3 ticks of interleaved
    # decode between the long request's start and its first token
    assert len(between) >= 3, len(between)
    st = sched.stats
    assert st.chunked_admissions == 2  # the 16-token prompt chunks too
    assert st.prefill_chunks == 8      # 4 chunks each, no resume
    assert st.prefill_tokens == 128    # dispatched tokens, not buckets


def test_chunked_prefix_resume_skips_chunks():
    """A second prompt sharing the first's chunk-aligned prefix resumes
    from the stored carry at the last shared chunk boundary — it
    dispatches ONE chunk, re-claims the shared pages from the intern
    index, and still decodes exactly what blocking admission decodes."""
    cfg = _chunk_arch()
    params = _params(cfg)
    rs = np.random.default_rng(5)
    shared = rs.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    pa, pb = [np.concatenate([
        shared, rs.integers(0, cfg.vocab_size, size=16).astype(np.int32)])
        for _ in range(2)]
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=64, paged=True,
                      prefill_chunk_blocks=1)
    sched.submit(pa, SamplingParams(max_new_tokens=3))
    sched.drain()
    assert sched.stats.prefill_chunks == 4
    assert sched.stats.prefill_tokens == 64
    rid_b = sched.submit(pb, SamplingParams(max_new_tokens=3))
    toks_b = [list(r.tokens_out) for r in sched.drain()
              if r.rid == rid_b]
    # resumed at chunk 3: one dispatch, 16 tokens, 3 prefix-page hits
    assert sched.stats.prefill_chunks == 5
    assert sched.stats.prefill_tokens == 80
    assert sched.stats.prefix_hits >= 3
    blocking = Scheduler(cfg, params, num_slots=1, max_len=96,
                         prefill_bucket=64, paged=True)
    blocking.submit(pb, SamplingParams(max_new_tokens=3))
    assert [list(r.tokens_out) for r in blocking.drain()] == toks_b


def test_chunked_dispatch_traces_once(monkeypatch):
    """The chunk dispatch takes its start offset as a TRACED scalar:
    every chunk index of every admission shares ONE compiled graph
    (trace-count idiom from test_compile_count.py)."""
    cfg = _chunk_arch()
    params = _params(cfg)
    calls = []
    orig = tfm.prefill_chunk

    def counted(*args, **kwargs):
        calls.append(True)
        return orig(*args, **kwargs)

    monkeypatch.setattr(tfm, "prefill_chunk", counted)
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=64, paged=True,
                      prefill_chunk_blocks=1)
    rs = np.random.default_rng(6)
    for _ in range(2):
        sched.submit(rs.integers(0, cfg.vocab_size, size=64)
                     .astype(np.int32),
                     SamplingParams(max_new_tokens=3))
    sched.drain()
    assert sched.stats.prefill_chunks == 8  # 4 chunks x 2 admissions
    assert len(calls) == 1, len(calls)


def test_chunked_requires_paged_and_eligible_config():
    cfg = _chunk_arch()
    with pytest.raises(ValueError, match="paged"):
        Scheduler(cfg, params=None, prefill_chunk_blocks=1)
    with pytest.raises(ValueError, match=">= 1"):
        Scheduler(cfg, params=None, paged=True, prefill_chunk_blocks=0)
    capped = dataclasses.replace(
        cfg, sla=cfg.sla.replace(col_capacity_factor=2.0))
    with pytest.raises(ValueError, match="col_capacity_factor"):
        Scheduler(capped, params=None, paged=True,
                  prefill_chunk_blocks=1)


def test_grow_cache_is_name_keyed():
    """_grow_cache pads exactly the leaves it names: k/v grow along the
    sequence axis with content preserved, pos passes through, and an
    UNKNOWN leaf — even one with the rank-5 shape of a KV slab — fails
    loudly instead of being silently zero-padded (the old `ndim == 5`
    rank test did exactly that)."""
    cfg = _arch()
    params = _params(cfg)
    eng = ServingEngine(cfg, params, batch_size=1, max_len=64)
    toks = jnp.asarray(_prompts(cfg, lens=(32,))[0])[None]
    _, cache = tfm.prefill(params, cfg, toks)
    grown = eng._grow_cache(cache)
    assert grown["k"].shape[3] == 64 and grown["v"].shape[3] == 64
    np.testing.assert_array_equal(np.asarray(grown["k"][..., :32, :]),
                                  np.asarray(cache["k"]))
    assert grown["pos"] is cache["pos"]
    cache["stats5d"] = jnp.zeros(cache["k"].shape)  # rank-5 impostor
    with pytest.raises(ValueError, match="stats5d"):
        eng._grow_cache(cache)
