"""Paged KV cache + copy-on-write prefix sharing (ISSUE 7 tentpole).

Pillars:
  * PagePool — refcount/intern/LRU-eviction/exhaustion unit behavior
    (host bookkeeping only; never touches device memory);
  * device-level bitwise parity — a paged cache driven through
    decode_step produces logits bitwise equal to the monolithic
    per-slot cache, for every SLA decode backend (gather / reference /
    fused kernel) AND dense decode, with an inactive scratch-backed
    slot riding along;
  * scheduler-level parity matrix — greedy tokens from the paged
    Scheduler bitwise-match the unpaged Scheduler under decode-SLA
    on/off, staggered arrivals, and slot turnover, with full
    cache-leaf equality (via paged_dense_view) checked at every step;
  * CoW prefix sharing — requests with a common prompt prefix share
    physical prefix pages (refs >= 2) that stay bitwise identical,
    while their decode pages diverge onto private CoW copies; page
    allocations scale O(prefix + sum(unique suffixes));
  * exhaustion — a pool too small for its workload raises
    PagePoolExhausted instead of silently recycling referenced pages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.serving.api import SamplingParams, Scheduler
from repro.serving.pages import (PagePool, PagePoolExhausted, ZERO_PAGE)


def _arch(kh=0.25, decode=True):
    cfg = get_arch("qwen3-1.7b").smoke()
    sla = cfg.sla.replace(kh_frac=kh, kl_frac=0.0)
    if decode:
        sla = sla.replace(decode_mode="sla")
    return dataclasses.replace(cfg, sla=sla)


def _params(cfg):
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) * 0.3
    return params


def _prompts(cfg, lens, seed=0, prefix=0):
    """`prefix` > 0 gives every prompt the same leading tokens."""
    rs = np.random.default_rng(seed)
    shared = rs.integers(0, cfg.vocab_size, size=prefix).astype(np.int32)
    out = []
    for n in lens:
        p = rs.integers(0, cfg.vocab_size, size=n - prefix) \
            .astype(np.int32)
        out.append(np.concatenate([shared, p]))
    return out


# ---------------------------------------------------------------------------
# PagePool unit behavior
# ---------------------------------------------------------------------------
def test_pool_alloc_release_refcounts():
    pool = PagePool(4)
    assert pool.refs(ZERO_PAGE) == 1  # permanently pinned
    a, b = pool.alloc(), pool.alloc()
    assert a != b and ZERO_PAGE not in (a, b)
    assert pool.refs(a) == pool.refs(b) == 1
    assert pool.in_use() == 3
    pool.retain(a)
    pool.release(a)
    assert pool.refs(a) == 1  # still held
    pool.release(a)
    assert pool.refs(a) == 0 and pool.free_pages() == 2
    with pytest.raises(ValueError, match="unreferenced"):
        pool.release(a)
    with pytest.raises(ValueError, match="unreferenced"):
        pool.retain(a)
    pool.release(ZERO_PAGE)  # no-op, never freed
    assert pool.refs(ZERO_PAGE) == 1
    with pytest.raises(ValueError, match=">= 2"):
        PagePool(1)


def test_pool_intern_lookup_and_lru_eviction():
    pool = PagePool(3)  # zero page + 2
    a = pool.alloc()
    pool.intern(b"key-a", a)
    assert pool.refs(a) == 2  # caller + index
    hit = pool.lookup(b"key-a")
    assert hit == a and pool.refs(a) == 3
    assert pool.lookup(b"missing") is None
    assert pool.stats.prefix_hits == 1 and pool.stats.prefix_misses == 1
    pool.release(a)  # lookup's ref
    pool.release(a)  # original ref -> index-only, LRU-evictable
    assert pool.refs(a) == 1
    b2 = pool.alloc()          # takes the last free page
    c = pool.alloc()           # must EVICT the index-only page a
    assert c == a and pool.stats.evictions == 1
    assert pool.lookup(b"key-a") is None  # evicted from the index
    assert pool.refs(b2) == pool.refs(c) == 1


def test_pool_exhaustion_fails_loudly():
    pool = PagePool(3)
    a = pool.alloc()
    pool.alloc()
    pool.intern(b"a", a)  # interned but still caller-referenced
    with pytest.raises(PagePoolExhausted, match="exhausted"):
        pool.alloc()


def test_pool_ensure_private_cow():
    pool = PagePool(5)
    a = pool.alloc()
    same, src = pool.ensure_private(a)
    assert same == a and src is None  # already exclusive
    pool.retain(a)  # now shared
    new, src = pool.ensure_private(a)
    assert new != a and src == a
    assert pool.refs(a) == 1 and pool.refs(new) == 1
    assert pool.stats.cow_copies == 1
    # the zero page is shared by construction: always copies
    fresh, src = pool.ensure_private(ZERO_PAGE)
    assert src == ZERO_PAGE and fresh not in (ZERO_PAGE, a, new)
    assert pool.refs(ZERO_PAGE) == 1  # release of zero page is a no-op


# ---------------------------------------------------------------------------
# device-level bitwise parity (all decode backends + dense)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["gather", "reference", "kernel"])
def test_paged_decode_bitwise_matches_monolithic(backend):
    """3 decode steps over one active slot (2 prompt pages + a decode
    page) and one inactive scratch-backed slot: logits bitwise equal to
    the monolithic per-slot cache, the zero page stays zero, and the
    inactive slot's garbage lands only in its scratch page."""
    cfg = _arch()
    params = _params(cfg)
    rs = np.random.default_rng(0)
    prompt = rs.integers(0, cfg.vocab_size, size=(1, 32)).astype(np.int32)
    _, single = tfm.prefill(params, cfg, jnp.asarray(prompt),
                            decode_max_len=96)

    mono = tfm.make_cache(cfg, 2, 96, decode_sla=True, per_slot=True)
    mono = tfm.insert_slot(mono, single, 0)
    paged = tfm.make_paged_cache(cfg, 2, 96, 20, decode_sla=True)
    paged = tfm.insert_slot_paged(paged, single, 0, jnp.asarray([3, 4]))
    pt = np.zeros((2, 6), np.int32)
    pt[0] = [3, 4, 5, 0, 0, 0]  # prompt pages + private decode page
    pt[1] = 2                   # inactive slot -> scratch page
    paged["pt"] = jnp.asarray(pt)

    tok = jnp.asarray([7, 11], jnp.int32)
    m, p = mono, paged
    for i in range(3):
        lm, m = tfm.decode_step(params, cfg, tok, m, backend=backend)
        lp, p = tfm.decode_step(params, cfg, tok, p, backend=backend)
        np.testing.assert_array_equal(np.asarray(lm[0]),
                                      np.asarray(lp[0]), err_msg=str(i))
    # zero page untouched; slot-1 garbage confined to its scratch page
    assert not np.asarray(p["kp"][:, 0]).any()
    assert not np.asarray(p["slap"]["hblk"][:, 0]).any()
    assert np.asarray(p["kp"][:, 2]).any()  # scratch absorbed the writes
    # full cache-leaf equality through the dense view
    view = tfm.paged_dense_view(cfg, p)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(m[key][:, 0, :, :35]),
                                      np.asarray(view[key][:, 0, :, :35]),
                                      err_msg=key)
    for key in ("hblk", "zblk", "kpool", "htot", "ztot"):
        np.testing.assert_array_equal(np.asarray(m["sla"][key][:, 0]),
                                      np.asarray(view["sla"][key][:, 0]),
                                      err_msg=key)


def test_paged_dense_decode_bitwise():
    """Same parity for plain dense decode (no SLA state at all)."""
    cfg = _arch(decode=False)
    params = _params(cfg)
    rs = np.random.default_rng(0)
    prompt = rs.integers(0, cfg.vocab_size, size=(1, 32)).astype(np.int32)
    _, single = tfm.prefill(params, cfg, jnp.asarray(prompt))
    mono = tfm.make_cache(cfg, 2, 96, decode_sla=False, per_slot=True)
    pad = 96 - single["k"].shape[-2]
    grown = dict(single,
                 k=jnp.pad(single["k"], [(0, 0)] * 3 + [(0, pad), (0, 0)]),
                 v=jnp.pad(single["v"], [(0, 0)] * 3 + [(0, pad), (0, 0)]))
    mono = tfm.insert_slot(mono, grown, 0)
    paged = tfm.make_paged_cache(cfg, 2, 96, 20, decode_sla=False)
    paged = tfm.insert_slot_paged(paged, single, 0, jnp.asarray([3, 4]))
    pt = np.zeros((2, 6), np.int32)
    pt[0] = [3, 4, 5, 0, 0, 0]
    pt[1] = 2
    paged["pt"] = jnp.asarray(pt)
    tok = jnp.asarray([7, 11], jnp.int32)
    m, p = mono, paged
    for i in range(3):
        lm, m = tfm.decode_step(params, cfg, tok, m)
        lp, p = tfm.decode_step(params, cfg, tok, p)
        np.testing.assert_array_equal(np.asarray(lm[0]),
                                      np.asarray(lp[0]), err_msg=str(i))


# ---------------------------------------------------------------------------
# scheduler-level parity matrix (paged vs unpaged, leaf equality)
# ---------------------------------------------------------------------------
def _compare_active_slots(cfg, un, pg):
    """Bitwise cache-leaf equality for every slot active in both."""
    view = tfm.paged_dense_view(cfg, pg._live)
    for j in range(un.num_slots):
        if un._slots[j] is None or pg._slots[j] is None:
            continue
        assert un._slots[j].rid == pg._slots[j].rid
        np.testing.assert_array_equal(
            np.asarray(un._live["pos"][j]), np.asarray(pg._live["pos"][j]))
        for key in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(un._live[key][:, j]),
                np.asarray(view[key][:, j]), err_msg=f"slot {j} {key}")
        if "sla" not in un._live:
            continue
        a, b = un._live["sla"], view["sla"]
        for key in ("hblk", "zblk", "kpool", "htot", "ztot", "qpool",
                    "live_lut", "live_cnt", "live_marg"):
            np.testing.assert_array_equal(
                np.asarray(a[key][:, j]), np.asarray(b[key][:, j]),
                err_msg=f"slot {j} {key}")
        np.testing.assert_array_equal(np.asarray(a["rows"][j]),
                                      np.asarray(b["rows"][j]))
        np.testing.assert_array_equal(np.asarray(a["plan"].mc[:, j]),
                                      np.asarray(b["plan"].mc[:, j]))


@pytest.mark.parametrize("decode_sla", [False, True])
def test_paged_scheduler_parity_matrix(decode_sla):
    """Greedy tokens AND per-step cache leaves bitwise-match the
    unpaged Scheduler: staggered arrivals, heterogeneous budgets, slot
    turnover (4 requests through 2 slots), decode-SLA on/off."""
    cfg = _arch(decode=decode_sla)
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32, 20, 32, 24), prefix=16)
    budgets = (6, 10, 4, 8)
    kw = dict(num_slots=2, max_len=96, prefill_bucket=32,
              decode_sla=decode_sla)
    un = Scheduler(cfg, params, paged=False, **kw)
    pg = Scheduler(cfg, params, paged=True, **kw)
    for s in (un, pg):
        for p, b in zip(prompts[:2], budgets[:2]):
            s.submit(p, SamplingParams(max_new_tokens=b))
    steps = 0
    while un.has_work or pg.has_work:
        un.step()
        pg.step()
        _compare_active_slots(cfg, un, pg)
        steps += 1
        if steps == 3:  # staggered arrivals, mid-flight
            for s in (un, pg):
                for p, b in zip(prompts[2:], budgets[2:]):
                    s.submit(p, SamplingParams(max_new_tokens=b))
    a, b = un.drain(), pg.drain()
    assert len(a) == len(b) == 4
    for ra, rb in zip(a, b):
        assert ra.tokens_out == rb.tokens_out, f"rid {ra.rid}"
    assert pg.stats.admissions > pg.num_slots  # slots turned over
    assert pg.stats.pages_peak > 0
    assert pg.stats.prefix_hits > 0  # 16-token shared prefix = 1 page


def test_paged_drain_parity_rolled_path():
    """drain()'s rolled multi-step dispatch (not per-token step()) also
    matches unpaged token-for-token."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32, 20, 32), prefix=0)
    budgets = (6, 9, 5)

    def run(paged):
        s = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=32, decode_sla=True, paged=paged)
        for p, b in zip(prompts, budgets):
            s.submit(p, SamplingParams(max_new_tokens=b))
        return [list(r.tokens_out) for r in s.drain()]

    assert run(False) == run(True)


def test_paged_full_prompt_snapshot_skips_prefill():
    """Identical prompts: the second admission is a full-prompt
    snapshot hit (no prefill dispatch) and still decodes the same
    greedy tokens."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(32,))[0]
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=32, decode_sla=True, paged=True)
    for _ in range(3):
        sched.submit(prompt, SamplingParams(max_new_tokens=5))
    done = sched.drain()
    toks = [list(r.tokens_out) for r in done]
    assert toks[0] == toks[1] == toks[2]
    assert sched.stats.prefix_full_hits == 2  # admissions 2 and 3


def test_snapshot_hit_leaves_dispatch_counters_unchanged():
    """A full-prompt snapshot hit admits WITHOUT a prefill dispatch, so
    the dispatch counters must not move: the old code unconditionally
    charged `decode_plan_builds += num_layers` and
    `prefill_tokens += bucket` per admission, overstating plan builds
    and prefill throughput on every cache hit."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(32,))[0]
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=32, decode_sla=True, paged=True)
    sched.submit(prompt, SamplingParams(max_new_tokens=4))
    sched.drain()
    st = sched.stats
    assert st.decode_plan_builds == cfg.num_layers
    assert st.prefill_tokens == 32
    sched.submit(prompt, SamplingParams(max_new_tokens=4))
    sched.drain()
    assert st.prefix_full_hits == 1
    assert st.admissions == 2
    # the snapshot admission dispatched nothing: both stay put
    assert st.decode_plan_builds == cfg.num_layers
    assert st.prefill_tokens == 32


# ---------------------------------------------------------------------------
# CoW prefix sharing
# ---------------------------------------------------------------------------
def test_cow_divergence_after_shared_prefix():
    """Two concurrent requests sharing a 32-token prompt prefix: the
    prefix pages are physically shared (refs >= 2, one table entry
    each), bitwise identical between the slots' views, and the decode
    pages they diverge onto are private CoW copies with different
    contents."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(48, 48), prefix=32)
    sched = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=48, decode_sla=True, paged=True)
    for p in prompts:
        sched.submit(p, SamplingParams(max_new_tokens=8))
    for _ in range(4):  # admit both + a few decode steps, still active
        sched.step()
    pt = sched._pt_host
    bkv = cfg.sla.block_kv
    npp = 48 // bkv
    # prefix pages (2 full blocks of the shared 32 tokens) are SHARED
    assert pt[0, 0] == pt[1, 0] and pt[0, 1] == pt[1, 1]
    for blk in (0, 1):
        assert sched._pool.refs(int(pt[0, blk])) >= 2
    # the unique-suffix prompt page and the decode page are private
    assert pt[0, 2] != pt[1, 2]
    assert pt[0, npp] != pt[1, npp] != ZERO_PAGE
    assert sched.stats.cow_copies >= 2  # one privatized decode page each
    view = tfm.paged_dense_view(cfg, sched._live)
    k = np.asarray(view["k"])
    # shared prefix rows bitwise equal across slots; divergent decode
    # rows differ (different suffixes -> different tokens -> different KV)
    np.testing.assert_array_equal(k[:, 0, :, :2 * bkv], k[:, 1, :, :2 * bkv])
    assert not np.array_equal(k[:, 0, :, 2 * bkv:3 * bkv],
                              k[:, 1, :, 2 * bkv:3 * bkv])
    sched.drain()
    # finish released the slots' refs; interned pages persist index-only
    assert all(r is None for r in sched._slots)
    for blk in (0, 1):
        assert sched._pool.refs(int(pt[0, blk])) == 1


def test_shared_prefix_saves_pages():
    """Acceptance: N requests with a common prefix allocate
    O(prefix + sum(unique suffixes)) pages — strictly fewer than N
    unique prompts of the same lengths."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    bkv = cfg.sla.block_kv

    def allocs(prefix):
        prompts = _prompts(cfg, lens=(48,) * 4, prefix=prefix, seed=3)
        s = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=48, decode_sla=True, paged=True)
        for p in prompts:
            s.submit(p, SamplingParams(max_new_tokens=4))
        s.drain()
        return s.stats.page_allocs, s.stats

    shared, st = allocs(prefix=32)
    unique, _ = allocs(prefix=0)
    # shared: 2 scratch + 2 prefix pages + 4 * (1 suffix + 1 decode)
    assert shared == 2 + 32 // bkv + 4 * 2
    # unique: same minus sharing -> every prompt pays all 3 pages
    assert unique == 2 + 4 * (3 + 1)
    assert shared < unique
    assert st.prefix_hits >= 2 * 3  # prefix pages hit by requests 2..4


def test_page_pool_exhaustion_fails_loudly():
    """A pool with no room for a single request's decode page raises
    PagePoolExhausted (interned prompt pages referenced by the live
    slot are NOT evictable) instead of corrupting a referenced page."""
    cfg = _arch(decode=True)
    params = _params(cfg)
    prompt = _prompts(cfg, lens=(32,))[0]
    # 4 pages: zero + scratch + exactly the 2 prompt pages -> the first
    # decode-page privatization has nothing to allocate
    sched = Scheduler(cfg, params, num_slots=1, max_len=96,
                      prefill_bucket=32, decode_sla=True, paged=True,
                      pool_pages=4)
    sched.submit(prompt, SamplingParams(max_new_tokens=4))
    with pytest.raises(PagePoolExhausted):
        sched.drain()


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_paged_rejects_adaptive_plan_reuse():
    cfg = _arch(decode=True)
    with pytest.raises(ValueError, match="adaptive"):
        Scheduler(cfg, params=None, paged=True, plan_reuse="adaptive")


def test_paged_requires_continuous_scheduler():
    from repro.serving.engine import ServingEngine

    cfg = _arch(decode=True)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(cfg, params=None, scheduler="static", paged=True)


def test_paged_config_knobs_validate():
    from repro.core import SLAConfig

    with pytest.raises(ValueError, match="page_pool_size"):
        SLAConfig(page_pool_size=1).validate()
    with pytest.raises(ValueError, match="block"):
        SLAConfig(paged=True, block_q=32, block_kv=64).validate()
    SLAConfig(paged=True, page_pool_size=8).validate()


# -- PagePool property tests (ISSUE 9 satellite) -----------------------------
# Randomized alloc/release/retain/intern/lookup/ensure_private/evict
# sequences, with PagePool.check_invariants() asserted after EVERY
# operation plus a host-side reference model of caller-held refs. Runs
# under real hypothesis when installed, else the deterministic
# fixed-sample sweep from _hypothesis_compat.
from _hypothesis_compat import given, settings, st  # noqa: E402


def _random_pool_ops(seed: int, num_ops: int = 80):
    """Drive one randomized operation sequence, cross-checking the pool
    against a reference model: `held` maps pid -> number of refs THIS
    test owns (the index's own refs are the pool's business)."""
    import random

    rnd = random.Random(seed)
    pool = PagePool(rnd.randint(3, 12))
    held: dict = {}
    interned_keys: list = []
    next_key = [0]

    def fresh_key() -> bytes:
        next_key[0] += 1
        return b"prefix-%d" % next_key[0]

    def model_refs(pid: int) -> int:
        """What the pool's refcount MUST be for a page this test can
        see: caller refs + the index's own ref if it is interned."""
        return held.get(pid, 0) + (1 if pid in pool._by_pid else 0)

    for _ in range(num_ops):
        op = rnd.choice(["alloc", "alloc", "release", "retain",
                         "intern", "lookup", "ensure_private"])
        if op == "alloc":
            try:
                pid = pool.alloc()
                assert pid != ZERO_PAGE, "alloc handed out the zero page"
                assert held.get(pid, 0) == 0, \
                    f"alloc returned page {pid} this test still holds"
                held[pid] = 1
            except PagePoolExhausted:
                # legal exactly when nothing is free or evictable
                assert pool.free_pages() == 0
        elif op == "release" and held:
            pid = rnd.choice(sorted(held))
            pool.release(pid)
            held[pid] -= 1
            if held[pid] == 0:
                del held[pid]
        elif op == "retain" and held:
            pid = rnd.choice(sorted(held))
            pool.retain(pid)
            held[pid] += 1
        elif op == "intern" and held:
            pid = rnd.choice(sorted(held))
            if pid not in pool._by_pid:
                key = fresh_key()
                pool.intern(key, pid)
                interned_keys.append(key)
        elif op == "lookup" and interned_keys:
            key = rnd.choice(interned_keys)
            pid = pool.lookup(key)
            if pid is not None:  # may have been LRU-evicted
                held[pid] = held.get(pid, 0) + 1
        elif op == "ensure_private" and held:
            pid = rnd.choice(sorted(held))
            try:
                new, src = pool.ensure_private(pid)
            except PagePoolExhausted:
                # the internal alloc() failed BEFORE the old ref was
                # released: caller state must be untouched
                assert pool.free_pages() == 0
                pool.check_invariants()
                continue
            if src is None:
                assert new == pid and pool.refs(pid) == 1
            else:
                # our ref moved from pid to the private copy
                assert src == pid
                held[pid] -= 1
                if held[pid] == 0:
                    del held[pid]
                held[new] = held.get(new, 0) + 1
                assert pool.refs(new) >= 1
        pool.check_invariants()
        assert pool.refs(ZERO_PAGE) >= 1
        for pid in held:
            assert pool.refs(pid) == model_refs(pid), \
                (f"page {pid}: pool says {pool.refs(pid)}, model says "
                 f"{model_refs(pid)}")
    # teardown: hand every ref back; the pool must survive and the
    # invariants must still hold (interned pages become LRU candidates)
    for pid, n in list(held.items()):
        for _ in range(n):
            pool.release(pid)
        pool.check_invariants()
    return pool


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_pool_random_ops_preserve_invariants(seed):
    _random_pool_ops(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_pool_eviction_only_reclaims_index_only_pages(seed):
    """Under pressure, alloc may evict — but NEVER a page a caller
    still references: drive a pool to exhaustion repeatedly and check
    evictions only ever happened when the victim's sole ref was the
    intern index's."""
    import random

    rnd = random.Random(seed)
    pool = PagePool(rnd.randint(4, 8))
    held = []
    for step in range(60):
        if rnd.random() < 0.6:
            try:
                pid = pool.alloc()
                assert pid not in held, \
                    f"evicted page {pid} still caller-referenced"
                if rnd.random() < 0.5:
                    pool.intern(b"k%d" % step, pid)
                held.append(pid)
            except PagePoolExhausted:
                assert pool.free_pages() == 0
        elif held:
            pid = held.pop(rnd.randrange(len(held)))
            pool.release(pid)
        pool.check_invariants()


def test_pool_zero_page_never_freed_or_allocated():
    """Page 0's pin survives any release storm, and ensure_private on
    it always yields a copy (fresh decode pages must start zeroed)."""
    pool = PagePool(5)  # zero page + 4: keep one free for the CoW copy
    pool.release(ZERO_PAGE)  # documented no-op
    pool.release(ZERO_PAGE)
    assert pool.refs(ZERO_PAGE) == 1
    seen = {pool.alloc() for _ in range(3)}
    assert ZERO_PAGE not in seen
    new, src = pool.ensure_private(ZERO_PAGE)
    assert new != ZERO_PAGE and src == ZERO_PAGE
    assert pool.refs(ZERO_PAGE) == 1  # pin survives the release inside CoW
    pool.release(new)
    for pid in seen:
        pool.release(pid)
    pool.check_invariants()
    assert pool.free_pages() == pool.num_pages - 1


def test_pool_intern_bijection_after_eviction_and_reuse():
    """key<->pid stays a bijection across evict + re-intern cycles."""
    pool = PagePool(4)  # zero page + 3
    pids = [pool.alloc() for _ in range(3)]
    for i, pid in enumerate(pids):
        pool.intern(b"key%d" % i, pid)
        pool.release(pid)  # index-only -> LRU candidate
    pool.check_invariants()
    fresh = pool.alloc()  # must evict the LRU (key0's page)
    assert pool.lookup(b"key0") is None
    pool.check_invariants()
    pool.intern(b"key0b", fresh)
    pool.check_invariants()
    assert pool.lookup(b"key0b") == fresh
    pool.release(fresh)
    pool.release(fresh)
    pool.check_invariants()
