"""SLA algorithm invariants: the decomposition limits, execution-path
agreement, baselines, and differentiability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLAConfig, compute_mask, plan_attention,
                        sla_attention, sla_init)
from repro.core import reference as ref
from repro.core.block_sparse_xla import sla_forward_gather
from repro.core.phi import PHI_KINDS, phi


def _qkv(seed=0, b=2, h=2, n=128, d=16, dtype=jnp.float32):
    rs = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(r, (b, h, n, d), dtype) * 1.3
                 for r in rs)


def test_all_critical_equals_full_attention():
    q, k, v = _qkv()
    for causal in (False, True):
        cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=1.0, kl_frac=0.0,
                        causal=causal, col_capacity_factor=None)
        mc = compute_mask(q, k, cfg)
        o_s, _ = ref.sparse_component(q, k, v, mc, cfg)
        full = ref.full_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o_s), np.asarray(full),
                                   atol=1e-5)


def test_all_marginal_equals_full_linear():
    q, k, v = _qkv(1)
    cfg = SLAConfig(block_q=16, block_kv=16)
    qp, kp = phi(q, "softmax"), phi(k, "softmax")
    mc = jnp.zeros((2, 2, 8, 8), jnp.int8)
    o_l, _, _ = ref.linear_component(qp, kp, v, mc, cfg)
    fl = ref.full_linear(qp, kp, v)
    np.testing.assert_allclose(np.asarray(o_l), np.asarray(fl), atol=1e-5)


def test_gather_path_matches_reference():
    q, k, v = _qkv(2)
    for causal in (False, True):
        cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25,
                        kl_frac=0.25, causal=causal)
        qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
        plan = plan_attention(q, k, cfg)
        og = sla_forward_gather(q, k, v, qp, kp, plan, cfg)
        orf = ref.sla_forward_reference(q, k, v, qp, kp, plan.mc, cfg)
        np.testing.assert_allclose(np.asarray(og[0]), np.asarray(orf[0]),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(og[1]), np.asarray(orf[1]),
                                   atol=2e-5)


@pytest.mark.parametrize("mode", ["sla", "sparse_only", "linear_only",
                                  "l_plus_s", "full"])
def test_modes_finite_and_shaped(mode):
    q, k, v = _qkv(3)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                    mode=mode)
    params = sla_init(jax.random.PRNGKey(0), 2, 16, cfg)
    out = sla_attention(params, q, k, v, cfg)
    assert out.shape == q.shape
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("kind", PHI_KINDS)
def test_phi_nonnegative(kind):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8)) * 3
    assert bool((phi(x, kind) >= 0).all())


def test_gqa_kv_heads():
    q, k, v = _qkv(4, h=4)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    params = sla_init(jax.random.PRNGKey(0), 4, 16, cfg)
    out = sla_attention(params, q, k[:, :2], v[:, :2], cfg)
    assert out.shape == q.shape
    # kv broadcast must equal explicit repetition
    out2 = sla_attention(params, q, jnp.repeat(k[:, :2], 2, 1),
                         jnp.repeat(v[:, :2], 2, 1), cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-6)


def test_gradients_flow_everywhere():
    q, k, v = _qkv(5)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    params = sla_init(jax.random.PRNGKey(0), 2, 16, cfg)

    def loss(params, q, k, v):
        return jnp.sum(sla_attention(params, q, k, v, cfg) ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(params, q, k, v)
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).sum()) > 0


def test_mask_is_gradient_stopped():
    """TopK classification must not contribute gradients (paper: the mask
    is a constant wrt the loss)."""
    q, k, v = _qkv(6)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)

    def mask_sum(q):
        return jnp.sum(compute_mask(q, k, cfg).astype(jnp.float32))

    g = jax.grad(mask_sum)(q)
    assert float(jnp.abs(g).sum()) == 0.0


def test_fixed_budget_long_context_is_constant_cost():
    cfg = SLAConfig(block_q=16, block_kv=16, fixed_budget=4)
    assert cfg.num_critical(8) == 4
    assert cfg.num_critical(1024) == 4  # O(N) sparse cost at long N
    q, k, v = _qkv(7, n=256)
    params = sla_init(jax.random.PRNGKey(0), 2, 16, cfg)
    out = sla_attention(params, q, k, v, cfg)
    assert bool(jnp.isfinite(out).all())


def test_output_decomposition_eq6():
    """O = O^s + Proj(O^l) exactly (Eq. 6)."""
    q, k, v = _qkv(8)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                    proj_init="identity")
    params = sla_init(jax.random.PRNGKey(0), 2, 16, cfg)
    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
    mc = compute_mask(q, k, cfg)
    o_s, o_l = ref.sla_forward_reference(q, k, v, qp, kp, mc, cfg)
    out = sla_attention(params, q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_s + o_l),
                               atol=1e-5)
