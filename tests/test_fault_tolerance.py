"""Straggler watchdog, NaN guard, retry wrapper, fault plan.

Direct unit coverage for the primitives the disaggregated serving
harness (tests/test_disagg.py) composes: watchdog EMA/warmup edge
cases, NaNGuard strike reset, the injectable-sleep retry contract with
its exact backoff schedule and `on_retry` callback, and FaultPlan's
deterministic due-event popping."""
import jax.numpy as jnp
import pytest

from repro.distributed.fault_tolerance import (FaultEvent, FaultPlan,
                                               NaNGuard,
                                               StragglerWatchdog,
                                               run_with_retries)


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(threshold=2.0, warmup=3)
    for _ in range(10):
        assert not wd.record(1.0)
    assert wd.record(5.0, host_id=7)  # 5x EMA -> straggler
    assert wd.flagged[-1]["host"] == 7
    # EMA not polluted by the straggler step
    assert abs(wd.ema - 1.0) < 0.05


def test_watchdog_adapts_to_regime_change():
    wd = StragglerWatchdog(threshold=2.0, warmup=2, decay=0.5)
    for _ in range(10):
        wd.record(1.0)
    for _ in range(10):
        wd.record(1.5)  # slower but below threshold -> absorbed into EMA
    assert not wd.record(2.0)


def test_nan_guard_skips_then_raises():
    g = NaNGuard(max_strikes=3)
    assert g.check(jnp.float32(1.0))
    assert not g.check(jnp.float32(float("nan")))
    assert not g.check(jnp.float32(float("inf")))
    with pytest.raises(FloatingPointError):
        g.check(jnp.float32(float("nan")))


def test_nan_guard_resets_on_healthy():
    g = NaNGuard(max_strikes=2)
    assert not g.check(jnp.float32(float("nan")))
    assert g.check(jnp.float32(0.5))
    assert not g.check(jnp.float32(float("nan")))  # strike count reset


def test_run_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective timeout")
        return "ok"

    assert run_with_retries(flaky, max_retries=3) == "ok"
    assert calls["n"] == 3


def test_run_with_retries_exhausts():
    def always_fails():
        raise RuntimeError("dead host")

    with pytest.raises(RuntimeError):
        run_with_retries(always_fails, max_retries=1)


# -- watchdog EMA / warmup edges ---------------------------------------------
def test_watchdog_warmup_absorbs_spikes():
    """Steps <= warmup NEVER flag, however slow — they seed the EMA."""
    wd = StragglerWatchdog(threshold=2.0, warmup=3)
    assert not wd.record(100.0)  # first sets ema directly
    assert not wd.record(100.0)
    assert not wd.record(100.0)
    assert wd.flagged == []
    # step 4 compares against the (spiky) warmup EMA: 100s is normal now
    assert not wd.record(100.0)
    assert wd.record(250.0)


def test_watchdog_first_record_seeds_ema_exactly():
    wd = StragglerWatchdog(threshold=2.0, warmup=5, decay=0.9)
    wd.record(4.0)
    assert wd.ema == 4.0  # ema==0 branch: seed, don't decay toward 0
    wd.record(2.0)
    assert wd.ema == pytest.approx(0.9 * 4.0 + 0.1 * 2.0)


def test_watchdog_straggler_step_leaves_ema_untouched():
    wd = StragglerWatchdog(threshold=2.0, warmup=2, decay=0.5)
    wd.record(1.0)
    wd.record(1.0)
    ema_before = wd.ema
    assert wd.record(10.0)  # flagged
    assert wd.ema == ema_before  # NOT decayed toward the straggler
    assert not wd.record(0.5)  # healthy step still updates
    assert wd.ema == pytest.approx(0.5 * ema_before + 0.5 * 0.5)


def test_watchdog_flag_record_contents():
    wd = StragglerWatchdog(threshold=2.0, warmup=1)
    wd.record(1.0)
    assert wd.record(9.0, host_id=3)
    (flag,) = wd.flagged
    assert flag["step"] == 2 and flag["host"] == 3
    assert flag["seconds"] == 9.0 and flag["ema"] == 1.0


def test_watchdog_boundary_is_strictly_greater():
    """seconds == threshold * ema is NOT a straggler (strict >)."""
    wd = StragglerWatchdog(threshold=2.0, warmup=1, decay=1.0)
    wd.record(1.0)
    assert not wd.record(2.0)  # exactly 2x: healthy
    assert wd.record(2.0 + 1e-9)


# -- NaNGuard strike reset ---------------------------------------------------
def test_nan_guard_single_strike_raises_immediately():
    g = NaNGuard(max_strikes=1)
    with pytest.raises(FloatingPointError):
        g.check(jnp.float32(float("nan")))


def test_nan_guard_interleaved_never_accumulates():
    g = NaNGuard(max_strikes=2)
    for _ in range(5):  # nan, healthy, nan, healthy... never 2 in a row
        assert not g.check(jnp.float32(float("inf")))
        assert g.check(jnp.float32(1.0))
        assert g.strikes == 0


# -- retry contract: injectable sleep, on_retry, backoff ---------------------
def test_run_with_retries_injected_sleep_backoff_schedule():
    """The backoff is min(2^attempt, 10): 1, 2, 4, 8, 10, 10, ..."""
    sleeps = []

    def always_fails():
        raise RuntimeError("transient")

    with pytest.raises(RuntimeError):
        run_with_retries(always_fails, max_retries=6,
                         sleep=sleeps.append)
    assert sleeps == [1.0, 2.0, 4.0, 8.0, 10.0, 10.0]


def test_run_with_retries_on_retry_sees_attempt_and_exception():
    seen = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"boom {calls['n']}")
        return "ok"

    out = run_with_retries(flaky, max_retries=3,
                           on_retry=lambda a, e: seen.append((a, str(e))),
                           sleep=lambda s: None)
    assert out == "ok"
    assert seen == [(0, "boom 1"), (1, "boom 2")]


def test_run_with_retries_no_sleep_after_final_failure():
    sleeps = []

    def always_fails():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        run_with_retries(always_fails, max_retries=2,
                         sleep=sleeps.append)
    assert len(sleeps) == 2  # attempts 0 and 1 back off; attempt 2 raises


def test_run_with_retries_does_not_catch_unrelated_errors():
    sleeps = []

    def typo():
        raise ValueError("not a runtime fault")

    with pytest.raises(ValueError):
        run_with_retries(typo, max_retries=5, sleep=sleeps.append)
    assert sleeps == []  # no retry path for non-transient errors


# -- FaultPlan ---------------------------------------------------------------
def test_fault_plan_pops_due_events_once_in_order():
    plan = FaultPlan([
        FaultEvent(tick=5, kind="kill", pool="decode", worker=1),
        FaultEvent(tick=2, kind="straggle", pool="decode", worker=0,
                   factor=4.0),
        FaultEvent(tick=5, kind="flake", pool="prefill", worker=0),
    ])
    assert plan.due(1) == []
    due2 = plan.due(2)
    assert [e.kind for e in due2] == ["straggle"]
    assert plan.due(2) == []  # consumed
    due5 = plan.due(5)  # multi-fault tick: (pool, worker) order
    assert [(e.pool, e.worker) for e in due5] == [("decode", 1),
                                                 ("prefill", 0)]
    assert plan.exhausted
    assert len(plan.fired) == 3


def test_fault_plan_late_due_catches_skipped_ticks():
    plan = FaultPlan([FaultEvent(tick=3, kind="kill", pool="decode",
                                 worker=0)])
    assert [e.tick for e in plan.due(10)] == [3]


def test_fault_event_validates_kind_and_pool():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(tick=1, kind="explode", pool="decode", worker=0)
    with pytest.raises(ValueError, match="unknown worker pool"):
        FaultEvent(tick=1, kind="kill", pool="gpu", worker=0)
    with pytest.raises(ValueError, match="tick must be >= 0"):
        FaultEvent(tick=-1, kind="kill", pool="decode", worker=0)
