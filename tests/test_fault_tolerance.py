"""Straggler watchdog, NaN guard, retry wrapper."""
import jax.numpy as jnp
import pytest

from repro.distributed.fault_tolerance import (NaNGuard, StragglerWatchdog,
                                               run_with_retries)


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(threshold=2.0, warmup=3)
    for _ in range(10):
        assert not wd.record(1.0)
    assert wd.record(5.0, host_id=7)  # 5x EMA -> straggler
    assert wd.flagged[-1]["host"] == 7
    # EMA not polluted by the straggler step
    assert abs(wd.ema - 1.0) < 0.05


def test_watchdog_adapts_to_regime_change():
    wd = StragglerWatchdog(threshold=2.0, warmup=2, decay=0.5)
    for _ in range(10):
        wd.record(1.0)
    for _ in range(10):
        wd.record(1.5)  # slower but below threshold -> absorbed into EMA
    assert not wd.record(2.0)


def test_nan_guard_skips_then_raises():
    g = NaNGuard(max_strikes=3)
    assert g.check(jnp.float32(1.0))
    assert not g.check(jnp.float32(float("nan")))
    assert not g.check(jnp.float32(float("inf")))
    with pytest.raises(FloatingPointError):
        g.check(jnp.float32(float("nan")))


def test_nan_guard_resets_on_healthy():
    g = NaNGuard(max_strikes=2)
    assert not g.check(jnp.float32(float("nan")))
    assert g.check(jnp.float32(0.5))
    assert not g.check(jnp.float32(float("nan")))  # strike count reset


def test_run_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective timeout")
        return "ok"

    assert run_with_retries(flaky, max_retries=3) == "ok"
    assert calls["n"] == 3


def test_run_with_retries_exhausts():
    def always_fails():
        raise RuntimeError("dead host")

    with pytest.raises(RuntimeError):
        run_with_retries(always_fails, max_retries=1)
