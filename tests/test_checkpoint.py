"""Checkpoint manager: roundtrip, atomic commit, GC, auto-resume."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    r = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(r, (8, 4)),
                       "layers": {"ln": jnp.ones((4,))}},
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    assert mgr.latest_step() == 10
    out = mgr.restore(10, jax.tree.map(lambda x: x, tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_last_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.steps() == [3, 4]


def test_partial_write_is_invisible(tmp_path):
    """A .tmp directory (crash mid-write) must not be listed as a step."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree(), blocking=True)
    fake = pathlib.Path(tmp_path) / "step_6.tmp"
    fake.mkdir()
    (fake / "junk.npy").write_bytes(b"xx")
    # also a committed-looking dir without manifest is ignored
    half = pathlib.Path(tmp_path) / "step_7"
    half.mkdir()
    assert mgr.latest_step() == 5


def test_restore_newer_template_dtype_preserved(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(3, tree, blocking=True)
    out = mgr.restore(3, tree)
    assert out["opt"]["step"].dtype == np.int32
