"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.compression import (dequantize, ef_compress_decompress,
                                     ef_init, quantize)


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                            warmup_steps=5, schedule="constant")
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(p)
        p, s, _ = adamw.update(p, g, s, cfg)
        return p, s, loss

    for _ in range(200):
        params, state, loss = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                            schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_quantize_roundtrip_error_bounded():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(rng, (1000,)) * 3.0
    codes, scale = quantize(g)
    ghat = dequantize(codes, scale, g.shape)
    err = jnp.abs(ghat - g)
    # int8 block quantization: error <= scale/2 per block
    assert float(err.max()) <= float(scale.max()) * 0.51 + 1e-6


def test_error_feedback_accumulates_residual():
    rng = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(rng, (256,)) * 0.01}
    err = ef_init(grads)
    ghat, err2, stats = ef_compress_decompress(grads, err)
    # wire format is ~3.88x smaller than f32 at this tiny size (scale
    # overhead amortizes to ~3.97x on real layers)
    assert stats["compression_x"] > 3.8
    # decompressed + residual == original (exactness of EF bookkeeping)
    np.testing.assert_allclose(
        np.asarray(ghat["w"] + err2["w"]), np.asarray(grads["w"]),
        atol=1e-6)


def test_ef_compression_preserves_convergence():
    """EF-compressed AdamW still fits the quadratic (the convergence
    property plain quantization loses)."""
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, total_steps=300,
                            warmup_steps=0, schedule="constant")
    target = jnp.array([0.5, -1.5, 2.5, 0.1])
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    err = ef_init(params)
    for _ in range(300):
        _, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        ghat, err, _ = ef_compress_decompress(g, err)
        params, state, _ = adamw.update(params, ghat, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=5e-2)


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine")
    lrs = [float(adamw.schedule_lr(cfg, jnp.int32(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert lrs[4] < 1e-6
