"""Benchmark-artifact honesty guards (ISSUE 7 satellite).

BENCH_decode.json's acceptance booleans must be recomputed from EXACTLY
the cells their names point at. An earlier revision computed
`kernel_beats_gather_32k` from the model-level cells while the name
(and the cells it shipped next to) said attention-level: the JSON
reported `true` over cells showing sla_kernel 67.33us vs sla_gather
55.88us. These tests pin every boolean to its source cells so a
payload edit (or a renamed metric) cannot drift them apart again.
"""
import json
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH = ROOT / "BENCH_decode.json"


def _payload():
    if not BENCH.exists():
        pytest.skip("BENCH_decode.json not generated")
    return json.loads(BENCH.read_text())


def test_acceptance_matches_recompute():
    """The stored acceptance block is byte-for-byte what
    recompute_acceptance derives from the stored cells."""
    from benchmarks.fig_decode import recompute_acceptance

    payload = _payload()
    assert payload["acceptance"] == recompute_acceptance(payload)


def test_each_boolean_reads_its_named_cells():
    """Independent spelling of each boolean's defining inequality,
    straight off the cells — catches a recompute_acceptance that
    quietly changes which cells a name points at."""
    payload = _payload()
    acc, cells = payload["acceptance"], payload["cells"]
    assert acc["kernel_beats_gather_32k"] == (
        cells["32768"]["sla_kernel"]["per_token_us"]
        < cells["32768"]["sla_gather"]["per_token_us"])
    assert acc["sla_beats_dense_32k"] == all(
        cells[str(n)]["dense"]["per_token_us"]
        > cells[str(n)]["sla_gather"]["per_token_us"]
        for n in payload["config"]["contexts"] if int(n) >= 32768)
    top = str(max(int(c) for c in payload["config"]["model_contexts"]))
    mk = payload["model_cells"][top]
    assert acc["model_chunk_beats_step_32k"] == (
        mk["chunk_kernel"]["per_token_us"]
        < mk["step_gather"]["per_token_us"])


def test_recompute_acceptance_is_honest_on_synthetic_cells():
    """recompute_acceptance on a hand-built payload where the kernel
    LOSES at the attention level but WINS at the model level — the
    exact shape of the original bug — reports both truths separately."""
    from benchmarks.fig_decode import recompute_acceptance

    def cell(us):
        return {"compile_s": 0.0, "per_token_us": us}

    payload = {
        "config": {"contexts": [8192, 32768],
                   "model_contexts": [8192, 32768]},
        "cells": {
            "8192": {"dense": cell(100.0), "sla_gather": cell(50.0),
                     "sla_kernel": cell(60.0)},
            "32768": {"dense": cell(400.0), "sla_gather": cell(55.0),
                      "sla_kernel": cell(67.0)},
        },
        "model_cells": {
            "8192": {"step_gather": cell(200.0),
                     "chunk_kernel": cell(30.0)},
            "32768": {"step_gather": cell(260.0),
                      "chunk_kernel": cell(28.0)},
        },
    }
    acc = recompute_acceptance(payload)
    assert acc["sla_beats_dense_32k"] is True
    assert acc["kernel_beats_gather_32k"] is False  # 67 > 55
    assert acc["model_chunk_beats_step_32k"] is True  # 28 < 260


SERVING = ROOT / "BENCH_serving.json"


def test_serving_acceptance_matches_recompute():
    """BENCH_serving.json obeys the same honesty contract: stored
    acceptance == recompute from the stored cells, and each boolean's
    inequality re-derives from the cells it names."""
    from benchmarks.fig_serving import recompute_acceptance

    if not SERVING.exists():
        pytest.skip("BENCH_serving.json not generated")
    payload = json.loads(SERVING.read_text())
    acc = payload["acceptance"]
    assert acc == recompute_acceptance(payload)
    assert acc["shared_prefix_saves_pages"] == (
        payload["paged"]["shared_prefix"]["page_allocs"]
        < payload["paged"]["unique_prompts"]["page_allocs"])
    assert acc["continuous_beats_static_occupancy"] == (
        payload["paths"]["continuous"]["occupancy"]
        > payload["paths"]["static"]["occupancy"])
    assert acc["chunked_reduces_decode_stall"] == (
        payload["stall"]["chunked"]["max_decode_gap_ms"]
        < payload["stall"]["blocking"]["max_decode_gap_ms"])
    assert acc["disagg_fault_tokens_bitwise_equal"] == (
        payload["disagg"]["faulted"]["tokens_checksum"]
        == payload["disagg"]["healthy"]["tokens_checksum"])
    assert acc["disagg_requeue_zero_lost"] == (
        payload["disagg"]["faulted"]["completed"]
        == payload["disagg"]["faulted"]["submitted"]
        and payload["disagg"]["faulted"]["kills"] >= 1
        and payload["disagg"]["faulted"]["requeues"] >= 1)


def _synthetic_serving_payload():
    """Hand-built cells where every headline claim HOLDS — the honesty
    tests then flip individual cells and watch the booleans follow."""
    return {
        "paths": {"static": {"occupancy": 0.5},
                  "continuous": {"occupancy": 0.9}},
        "paged": {"shared_prefix": {"page_allocs": 10},
                  "unique_prompts": {"page_allocs": 20}},
        "stall": {"blocking": {"max_decode_gap_ms": 5.0},
                  "chunked": {"max_decode_gap_ms": 2.0}},
        "disagg": {
            "healthy": {"submitted": 10, "completed": 10,
                        "kills": 0, "requeues": 0,
                        "tokens_checksum": "0:1,2,3;1:4,5"},
            "faulted": {"submitted": 10, "completed": 10,
                        "kills": 1, "requeues": 2,
                        "tokens_checksum": "0:1,2,3;1:4,5"},
        },
    }


def test_serving_recompute_is_honest_on_synthetic_stall_cells():
    """recompute_acceptance on hand-built stall cells where chunked
    LOSES: the boolean must report that, not the headline claim."""
    from benchmarks.fig_serving import recompute_acceptance

    payload = _synthetic_serving_payload()
    payload["stall"]["chunked"]["max_decode_gap_ms"] = 9.0
    acc = recompute_acceptance(payload)
    assert acc["chunked_reduces_decode_stall"] is False  # 9 > 5
    assert acc["continuous_beats_static_occupancy"] is True
    payload["stall"]["chunked"]["max_decode_gap_ms"] = 2.0
    assert recompute_acceptance(payload)[
        "chunked_reduces_decode_stall"] is True


def test_serving_recompute_is_honest_on_synthetic_disagg_cells():
    """The disagg booleans read exactly their named cells: mislabel a
    cell and the matching boolean — and ONLY it — must flip."""
    from benchmarks.fig_serving import recompute_acceptance

    base = _synthetic_serving_payload()
    assert recompute_acceptance(base)["disagg_completes_all_healthy"]
    assert recompute_acceptance(base)["disagg_requeue_zero_lost"]
    assert recompute_acceptance(base)["disagg_fault_tokens_bitwise_equal"]

    # a lost request in the faulted run
    p = _synthetic_serving_payload()
    p["disagg"]["faulted"]["completed"] = 9
    acc = recompute_acceptance(p)
    assert acc["disagg_requeue_zero_lost"] is False
    assert acc["disagg_completes_all_healthy"] is True

    # the kill never fired (idle worker): zero-lost proves nothing
    p = _synthetic_serving_payload()
    p["disagg"]["faulted"]["kills"] = 0
    assert recompute_acceptance(p)["disagg_requeue_zero_lost"] is False
    p = _synthetic_serving_payload()
    p["disagg"]["faulted"]["requeues"] = 0
    assert recompute_acceptance(p)["disagg_requeue_zero_lost"] is False

    # a single diverging token breaks bitwise equality
    p = _synthetic_serving_payload()
    p["disagg"]["faulted"]["tokens_checksum"] = "0:1,2,3;1:4,6"
    acc = recompute_acceptance(p)
    assert acc["disagg_fault_tokens_bitwise_equal"] is False
    assert acc["disagg_requeue_zero_lost"] is True

    # an incomplete healthy run
    p = _synthetic_serving_payload()
    p["disagg"]["healthy"]["completed"] = 0
    p["disagg"]["healthy"]["submitted"] = 0
    assert recompute_acceptance(p)[
        "disagg_completes_all_healthy"] is False


DIT_SERVING = ROOT / "BENCH_dit_serving.json"


def test_dit_serving_acceptance_matches_recompute():
    """BENCH_dit_serving.json obeys the honesty contract: stored
    acceptance == recompute from the stored cells, and each boolean's
    defining relation re-derives from the cells it names."""
    from benchmarks.fig_dit_serving import recompute_acceptance

    if not DIT_SERVING.exists():
        pytest.skip("BENCH_dit_serving.json not generated")
    payload = json.loads(DIT_SERVING.read_text())
    acc = payload["acceptance"]
    assert acc == recompute_acceptance(payload)
    assert acc["dit_batched_bitwise_equal_sequential"] == all(
        payload["parity"][b]["batched_checksum"]
        == payload["parity"][b]["sequential_checksum"]
        for b in payload["config"]["backends"])
    assert acc["plan_cache_cuts_plan_builds"] == (
        payload["plan_cache"]["cache"]["plan_builds"]
        < payload["plan_cache"]["no_cache"]["plan_builds"]
        and payload["plan_cache"]["cache"]["hits"] >= 1)


def _synthetic_dit_payload():
    """Hand-built cells where both headline claims HOLD."""
    return {
        "config": {"backends": ["reference", "gather"]},
        "parity": {
            "reference": {"batched_checksum": "aa",
                          "sequential_checksum": "aa"},
            "gather": {"batched_checksum": "bb",
                       "sequential_checksum": "bb"},
        },
        "plan_cache": {
            "no_cache": {"plan_builds": 12},
            "cache": {"plan_builds": 2, "hits": 5, "misses": 1},
        },
    }


def test_dit_recompute_is_honest_on_synthetic_parity_cells():
    """A single-backend checksum mismatch must flip the parity boolean
    — equality on the OTHER backend cannot mask it."""
    from benchmarks.fig_dit_serving import recompute_acceptance

    base = _synthetic_dit_payload()
    acc = recompute_acceptance(base)
    assert acc["dit_batched_bitwise_equal_sequential"] is True
    assert acc["plan_cache_cuts_plan_builds"] is True

    p = _synthetic_dit_payload()
    p["parity"]["reference"]["batched_checksum"] = "xx"
    acc = recompute_acceptance(p)
    assert acc["dit_batched_bitwise_equal_sequential"] is False
    assert acc["plan_cache_cuts_plan_builds"] is True  # untouched


def test_dit_recompute_is_honest_on_synthetic_cache_cells():
    """The cache boolean needs BOTH a strict build cut AND >= 1 real
    hit — fewer builds from a shorter trace alone must not pass."""
    from benchmarks.fig_dit_serving import recompute_acceptance

    p = _synthetic_dit_payload()
    p["plan_cache"]["cache"]["plan_builds"] = 12  # no cut
    assert recompute_acceptance(p)["plan_cache_cuts_plan_builds"] is False

    p = _synthetic_dit_payload()
    p["plan_cache"]["cache"]["hits"] = 0  # cut without a single hit
    assert recompute_acceptance(p)["plan_cache_cuts_plan_builds"] is False
    assert recompute_acceptance(p)[
        "dit_batched_bitwise_equal_sequential"] is True
