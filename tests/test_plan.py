"""Plan/execute split: SLAPlan pytree, backend registry, LUT reuse, and
cross-timestep plan reuse (DESIGN.md "Plan/execute split")."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLAConfig, available_backends, compute_mask,
                        execute, get_backend, plan_attention,
                        plan_from_mask, register_backend, sla_init)
from repro.core import plan as plan_lib
from repro.core.phi import phi
from repro.kernels.ops import sla_attention_core


def _qkv(seed, b=1, h=2, n=128, d=16):
    rs = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(r, (b, h, n, d)) for r in rs)


def _cfg(**kw):
    base = dict(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    base.update(kw)
    return SLAConfig(**base)


# ---------------------------------------------------------------------------
# SLAPlan pytree
# ---------------------------------------------------------------------------
def test_plan_roundtrips_through_jit():
    q, k, _ = _qkv(0)
    cfg = _cfg()
    plan = plan_attention(q, k, cfg)
    plan_jit = jax.jit(plan_attention, static_argnums=(2,))(q, k, cfg)
    for name in ("mc", "lut", "counts", "col_lut", "col_counts",
                 "marginal"):
        np.testing.assert_array_equal(np.asarray(getattr(plan, name)),
                                      np.asarray(getattr(plan_jit, name)),
                                      err_msg=name)
    # identity through jit: the dataclass is a registered pytree
    plan2 = jax.jit(lambda p: p)(plan)
    assert type(plan2) is type(plan)
    assert plan2.k_sel == plan.k_sel and plan2.w_col == plan.w_col


def test_plan_matches_mask_and_mask_derivation():
    q, k, _ = _qkv(1)
    cfg = _cfg()
    mc = compute_mask(q, k, cfg)
    plan = plan_attention(q, k, cfg)
    np.testing.assert_array_equal(np.asarray(plan.mc), np.asarray(mc))
    plan_b = plan_from_mask(mc, cfg)
    np.testing.assert_array_equal(np.asarray(plan.lut),
                                  np.asarray(plan_b.lut))
    # the marginal aggregation matrix is exactly the mc == 0 indicator
    np.testing.assert_array_equal(np.asarray(plan.marginal),
                                  np.asarray(mc == 0).astype(np.float32))
    stats = plan.stats()
    total = sum(float(stats[k_]) for k_ in
                ("critical_frac", "marginal_frac", "negligible_frac"))
    assert abs(total - 1.0) < 1e-6


def test_plan_gqa_head_broadcast():
    q, _, _ = _qkv(2, h=4)
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 128, 16))
    plan = plan_attention(q, k, _cfg())
    assert plan.mc.shape[1] == 4  # one plan row of structure per q head


# ---------------------------------------------------------------------------
# backward-pass LUT reuse (acceptance: zero build_lut calls in bwd)
# ---------------------------------------------------------------------------
def test_backward_reuses_forward_luts(monkeypatch):
    q, k, v = _qkv(3)
    cfg = _cfg()
    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
    plan = plan_attention(q, k, cfg)  # planning happens HERE, once

    calls = {"row": 0, "col": 0}
    orig_row, orig_col = plan_lib.build_lut, plan_lib.build_col_lut

    def count_row(*a, **kw):
        calls["row"] += 1
        return orig_row(*a, **kw)

    def count_col(*a, **kw):
        calls["col"] += 1
        return orig_col(*a, **kw)

    monkeypatch.setattr(plan_lib, "build_lut", count_row)
    monkeypatch.setattr(plan_lib, "build_col_lut", count_col)

    def loss(q, k, v, qp, kp):
        o_s, o_l = sla_attention_core(q, k, v, qp, kp, plan, cfg)
        return jnp.sum(o_s ** 2) + jnp.sum(o_l ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, qp, kp)
    assert all(bool(jnp.isfinite(x).all()) for x in g)
    # forward + backward consumed the precomputed plan verbatim
    assert calls == {"row": 0, "col": 0}


def test_bwd_source_has_no_lut_build():
    import inspect
    from repro.kernels import ops
    src = inspect.getsource(ops._sla_core_bwd)
    assert "build_lut" not in src and "build_col_lut" not in src


# ---------------------------------------------------------------------------
# backend registry (cross-backend *numerics* live in test_conformance.py,
# the table-driven matrix; this file keeps the registry API contract)
# ---------------------------------------------------------------------------
def test_backend_registry_api():
    assert set(available_backends()) >= {"reference", "gather", "kernel"}
    assert get_backend("kernel") is get_backend("pallas")  # legacy alias
    with pytest.raises(ValueError, match="unknown SLA backend"):
        get_backend("does-not-exist")

    seen = []

    @register_backend("_test_probe")
    def probe(plan, q, k, v, qp, kp, cfg, scale):
        seen.append(plan.k_sel)
        return get_backend("reference")(plan, q, k, v, qp, kp, cfg, scale)

    try:
        q, k, v = _qkv(6)
        cfg = _cfg()
        params = sla_init(jax.random.PRNGKey(0), 2, 16, cfg)
        out = execute(None, params, q, k, v, cfg, backend="_test_probe")
        assert out.shape == q.shape and len(seen) == 1
    finally:
        from repro.core import backends as backends_mod
        backends_mod._BACKENDS.pop("_test_probe", None)


# ---------------------------------------------------------------------------
# cross-timestep plan reuse in the DiT sampler: the rolled sampler
# (lax.scan over steps) traces planning a CONSTANT number of times —
# the step-0 build plus the lax.cond refresh branch — no matter how
# many steps run or how often the refresh fires (the per-step re-plan
# happens inside the one compiled cond branch).
# ---------------------------------------------------------------------------
def _dit_cfg(refresh=1):
    from repro.configs.base import ArchConfig
    return ArchConfig(
        name="dit-test", family="dit", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=0,
        patch_dim=8, cross_attn=False, attention_kind="sla",
        sla=SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                      plan_refresh_interval=refresh))


def test_dit_sampler_plan_traces_horizon_independent(monkeypatch):
    from repro.models import dit
    steps = 4
    cfg = _dit_cfg(refresh=steps)
    params = dit.init(jax.random.PRNGKey(0), cfg)
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))

    calls = []
    orig = plan_lib.plan_attention

    def counted(q, k, c, scale=None, routing=None):
        calls.append(q.shape)
        return orig(q, k, c, scale)

    monkeypatch.setattr(plan_lib, "plan_attention", counted)
    out = dit.sample(params, cfg, noise, num_steps=steps)
    assert out.shape == noise.shape
    # two traced planning calls total (step-0 build + the refresh
    # branch), each inside the layer scan, so every layer plans through
    # the same trace; tests/test_compile_count.py pins the same
    # contract across different horizons
    assert len(calls) == 2

    calls.clear()
    dit.sample(params, cfg, noise, num_steps=steps, refresh_interval=1)
    assert len(calls) == 2  # refresh every step: same traces, re-run


def test_dit_forward_plan_roundtrip_numerics():
    from repro.models import dit
    cfg = _dit_cfg()
    params = dit.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 8))
    t = jnp.full((2,), 0.5)
    out, plans = dit.forward(params, cfg, x, t, return_plans=True)
    assert plans.mc.shape[0] == cfg.num_layers  # stacked per layer
    out_reuse = dit.forward(params, cfg, x, t, plans=plans)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_reuse),
                               atol=1e-6)
