"""Mask prediction/classification invariants (paper Eq. 2-3 + TPU
column-capacity adaptation), including hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import SLAConfig, build_lut, build_col_lut, compute_mask, \
    predict_pc
from repro.core.masks import block_valid, classify_blocks


def _qk(seed, b=1, h=2, n=128, d=16):
    r1, r2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(r1, (b, h, n, d)),
            jax.random.normal(r2, (b, h, n, d)))


def test_pc_is_row_stochastic():
    q, k = _qk(0)
    cfg = SLAConfig(block_q=16, block_kv=16)
    pc = predict_pc(q, k, cfg)
    np.testing.assert_allclose(np.asarray(pc.sum(-1)), 1.0, rtol=1e-5)


def test_partition_three_way():
    q, k = _qk(1)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    mc = np.asarray(compute_mask(q, k, cfg))
    assert set(np.unique(mc)) <= {-1, 0, 1}
    tn = mc.shape[-1]
    crit = (mc == 1).sum(-1)
    assert (crit == cfg.num_critical(tn)).all()


def test_causal_invalid_blocks_are_skipped():
    q, k = _qk(2)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.3, kl_frac=0.2,
                    causal=True)
    mc = np.asarray(compute_mask(q, k, cfg))
    valid = np.asarray(block_valid(cfg, mc.shape[-2], mc.shape[-1]))
    assert (mc[..., ~valid] == -1).all()
    # diagonal always critical in causal mode
    tm = mc.shape[-2]
    for i in range(tm):
        assert (mc[..., i, i] == 1).all()


def test_window_constraint():
    q, k = _qk(3)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.3, kl_frac=0.1,
                    causal=True, window=32)
    mc = np.asarray(compute_mask(q, k, cfg))
    tm, tn = mc.shape[-2:]
    for i in range(tm):
        for j in range(tn):
            dist = abs(i - j) * 16
            if dist >= 32 + 16 or j > i:
                assert (mc[..., i, j] == -1).all()


def test_column_capacity_is_enforced():
    q, k = _qk(4, n=256)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                    col_capacity_factor=1.5)
    mc = np.asarray(compute_mask(q, k, cfg))
    cap = cfg.col_capacity(mc.shape[-2], mc.shape[-1])
    col_counts = (mc == 1).sum(-2)
    assert col_counts.max() <= cap
    # demoted blocks became marginal (0), never negligible
    cfg_uncapped = cfg.replace(col_capacity_factor=None)
    mc_u = np.asarray(compute_mask(q, k, cfg_uncapped))
    demoted = (mc_u == 1) & (mc == 0)
    assert ((mc[demoted] == 0).all())


def test_row_lut_matches_mask():
    q, k = _qk(5)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    mc = compute_mask(q, k, cfg)
    tn = mc.shape[-1]
    k_sel = cfg.num_critical(tn)
    lut, counts = build_lut(mc, k_sel)
    mc_np, lut_np, c_np = map(np.asarray, (mc, lut, counts))
    b, h, tm, _ = mc_np.shape
    for bi in range(b):
        for hi in range(h):
            for i in range(tm):
                live = set(lut_np[bi, hi, i, : c_np[bi, hi, i]].tolist())
                expect = set(np.nonzero(mc_np[bi, hi, i] == 1)[0].tolist())
                assert live == expect


def test_col_lut_matches_mask():
    q, k = _qk(6)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    mc = compute_mask(q, k, cfg)
    w = cfg.col_capacity(mc.shape[-2], mc.shape[-1])
    lut, counts = build_col_lut(mc, w)
    mc_np, lut_np, c_np = map(np.asarray, (mc, lut, counts))
    b, h, tm, tn = mc_np.shape
    for bi in range(b):
        for hi in range(h):
            for j in range(tn):
                live = set(lut_np[bi, hi, j, : c_np[bi, hi, j]].tolist())
                expect = set(np.nonzero(mc_np[bi, hi, :, j] == 1)[0]
                             .tolist())
                assert live == expect


@settings(max_examples=15, deadline=None)
@given(kh=st.floats(0.05, 0.9), kl=st.floats(0.0, 0.5),
       causal=st.booleans(), seed=st.integers(0, 100))
def test_property_counts_and_partition(kh, kl, causal, seed):
    if kh + kl > 0.95:
        kl = 0.95 - kh
    q, k = _qk(seed, n=64, d=8)
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=kh, kl_frac=kl,
                    causal=causal)
    mc = np.asarray(compute_mask(q, k, cfg))
    tn = mc.shape[-1]
    # every row has >= 1 critical and exactly num_critical on valid rows
    assert ((mc == 1).sum(-1) >= 1).all()
    if not causal:
        assert ((mc == 1).sum(-1) == cfg.num_critical(tn)).all()
    # column capacity always bounded
    cap = cfg.col_capacity(mc.shape[-2], tn)
    assert (mc == 1).sum(-2).max() <= cap
