"""Loop-aware HLO cost model: closed-form validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze, xla_cost_analysis
from repro.roofline.analysis import roofline_terms


def test_scan_flops_scaled_by_trip_count():
    w = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    x = jnp.ones((128, 128))
    res = analyze(jax.jit(scanned).lower(x).compile().as_text())
    expect = 8 * 2 * 128**3
    assert abs(res["flops"] - expect) / expect < 0.05
    # XLA's own analysis undercounts the same program ~8x
    xla = xla_cost_analysis(jax.jit(scanned).lower(x).compile())["flops"]
    assert res["flops"] > 6 * xla


def test_nested_scan():
    w = jnp.ones((64, 64))

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jnp.ones((64, 64))
    res = analyze(jax.jit(nested).lower(x).compile().as_text())
    expect = 12 * 2 * 64**3
    assert abs(res["flops"] - expect) / expect < 0.05


def test_bytes_accounted():
    x = jnp.ones((512, 512))
    res = analyze(jax.jit(lambda a: a @ x).lower(x).compile().as_text())
    # >= read 2 operands + write 1 result
    assert res["bytes"] >= 3 * 512 * 512 * 4


def test_cond_takes_max_branch():
    w = jnp.ones((128, 128))

    def f(x, flag):
        return jax.lax.cond(flag, lambda x: x @ w @ w, lambda x: x, x)

    x = jnp.ones((128, 128))
    res = analyze(jax.jit(f).lower(
        x, jnp.bool_(True)).compile().as_text())
    assert res["flops"] >= 2 * 2 * 128**3 * 0.9


def test_roofline_terms_pick_dominant():
    t = roofline_terms(197e12, 0.0, 0.0, 1)  # exactly 1s of compute
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t2 = roofline_terms(1e9, 819e9 * 2, 0.0, 1)
    assert t2["dominant"] == "memory_s"
    assert abs(t2["memory_s"] - 2.0) < 1e-6
    t3 = roofline_terms(0.0, 0.0, 50e9 * 3, 1)
    assert t3["dominant"] == "collective_s"


@pytest.mark.slow
def test_collectives_parsed_and_scaled(tmp_path):
    """Collective inside a scan body is multiplied by the trip count."""
    import subprocess, sys, textwrap, pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_cost import analyze
        try:
            from jax.sharding import AxisType
            mesh = jax.make_mesh((4,), ("d",),
                                 axis_types=(AxisType.Auto,))
        except ImportError:
            mesh = jax.make_mesh((4,), ("d",))
        w = jnp.ones((64, 64))
        def f(x):
            def body(c, _):
                y = c @ w
                return y, None
            out, _ = jax.lax.scan(body, x, None, length=5)
            return jnp.sum(out)
        xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P(None, "d")),
                        out_shardings=NamedSharding(mesh, P())) \\
                .lower(xs).compile()
        res = analyze(c.as_text())
        print("COLL", res["collective_bytes"], res["flops"])
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "PYTHONPATH": f"{root}/src", "HOME": "/root",
             "PATH": "/usr/bin:/bin",
             # fake-device test must never try to init a real accelerator
             # (a stripped env + installed libtpu hangs on TPU metadata;
             # host-device fakes need the cpu platform)
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    coll, flops = out.stdout.split("COLL")[1].split()
    # per-device flops: 5 matmuls of (64 x 16 x 64) after sharding
    assert float(flops) >= 5 * 2 * 64 * 16 * 64 * 0.9
