"""Degrade-gracefully shim around `hypothesis`.

The property tests use a tiny subset of hypothesis (`@given` with
floats / integers / booleans / sampled_from strategies plus
`@settings(max_examples=..., deadline=None)`). When hypothesis is
installed, this module re-exports the real thing. When it is not
(offline CI images), `@given` degrades to a deterministic fixed-sample
`pytest.mark.parametrize` sweep drawn from a seeded PRNG — weaker than
real property search, but the invariants still get exercised and the
suite collects everywhere.

Usage in tests:
    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback
    import random

    import pytest

    HAVE_HYPOTHESIS = False
    _FIXED_EXAMPLES = 10  # fixed sweep size (max_examples is best-effort)

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies` spelling
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    def settings(*args, **kwargs):
        """No-op decorator (deadline / max_examples are hypothesis-only)."""

        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        """Fixed-sample stand-in: parametrize over deterministic draws."""
        names = sorted(strategies)
        rnd = random.Random(0x51A)
        samples = [tuple(strategies[n].draw(rnd) for n in names)
                   for _ in range(_FIXED_EXAMPLES)]
        if len(names) == 1:  # parametrize wants scalars, not 1-tuples
            samples = [s[0] for s in samples]

        def deco(fn):
            return pytest.mark.parametrize(",".join(names), samples)(fn)

        return deco
