"""Shared pytest fixtures. NOTE: XLA_FLAGS / device-count overrides are
deliberately NOT set here — smoke tests and benches must see 1 device;
only launch/dryrun.py (and subprocess-based distributed tests) force
fake device counts."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
