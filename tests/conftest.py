"""Shared pytest fixtures. NOTE: XLA_FLAGS / device-count overrides are
deliberately NOT set here — smoke tests and benches must see 1 device;
only launch/dryrun.py (and subprocess-based distributed tests) force
fake device counts."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import pytest

# Modules dominated by subprocess / multi-device / end-to-end runs; the
# CI split (scripts/ci.sh) runs them after the fast numerics tier.
SLOW_MODULES = {"test_distributed", "test_system", "test_fault_tolerance"}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess / end-to-end tests (scripts/ci.sh "
        "runs them in a second pass after the fast tier)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
