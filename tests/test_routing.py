"""Learned routing (ISSUE 4): SLA2-style trainable block classification.

Four pillars:
  * init parity — identity-initialized learned routing produces
    bitwise-identical SLAPlans (mc / lut / counts / col_lut /
    col_counts / marginal) to the threshold classifier across the
    conformance matrix (dtype x causal x column-capacity x block
    size), and execution through every backend is bitwise identical;
  * decode parity — the row scorer at identity equals `predict_pc_row`
    bitwise, so decode-SLA greedy decode under learned routing at init
    matches threshold decode token-for-token (prefill and decode route
    identically);
  * gradient flow — routing parameters receive nonzero gradients
    through the straight-through marginal gates (gather AND reference
    backends; the fused kernel treats the plan as a constant by
    contract), and the end-to-end distillation fine-tune decreases the
    loss while moving the routing head off identity;
  * plumbing — FLOPs accounting, drift/refresh under the learned
    scorer, the optimizer's trainable mask, and loud failures on
    missing/unknown routing configuration.

Run standalone via `scripts/ci.sh --routing`.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (SLAConfig, classify_row, plan_attention,
                        predict_pc, predict_pc_row, predict_routing,
                        predict_routing_row, refresh_plan, routing_init,
                        sla_attention, sla_init)
from repro.core.flops import sla_decode_flops, sla_flops
from repro.models import dit
from repro.models import transformer as tfm
from repro.optim import adamw

PLAN_LEAVES = ("mc", "lut", "counts", "col_lut", "col_counts", "marginal")


def _cfgs(causal=False, col_cap=2.0, block=16, **kw):
    """(threshold_cfg, learned_cfg) differing only in routing_mode."""
    thr = SLAConfig(block_q=block, block_kv=block, kh_frac=0.25,
                    kl_frac=0.25, causal=causal,
                    col_capacity_factor=col_cap, **kw)
    return thr, thr.replace(routing_mode="learned")


def _qkv(seed, dtype=jnp.float32, b=1, h=2, n=128, d=16):
    rs = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(r, (b, h, n, d), dtype) for r in rs)


# ---------------------------------------------------------------------------
# init parity: the conformance matrix, learned-at-identity vs threshold
# ---------------------------------------------------------------------------
INIT_MATRIX = [
    pytest.param(dtype, causal, col_cap, block,
                 id=f"{name}-{'causal' if causal else 'bidir'}-"
                    f"{'colcap' if col_cap else 'nocap'}-b{block}")
    for name, dtype in (("f32", jnp.float32), ("bf16", jnp.bfloat16))
    for causal in (False, True)
    for col_cap in (None, 2.0)
    for block in (16, 32)
]


@pytest.mark.parametrize("dtype,causal,col_cap,block", INIT_MATRIX)
def test_plan_init_parity_matrix(dtype, causal, col_cap, block):
    """Identity-initialized learned routing builds a bitwise-identical
    SLAPlan on every leaf — the guarantee that lets all existing
    conformance/parity machinery apply unchanged at init."""
    thr, lrn = _cfgs(causal, col_cap, block)
    q, k, _ = _qkv(0, dtype)
    routing = routing_init(q.shape[1], q.shape[-1])
    p_t = plan_attention(q, k, thr)
    p_l = plan_attention(q, k, lrn, routing=routing)
    for leaf in PLAN_LEAVES:
        np.testing.assert_array_equal(
            np.asarray(getattr(p_l, leaf)), np.asarray(getattr(p_t, leaf)),
            err_msg=leaf)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("causal", [False, True], ids=["bidir", "causal"])
def test_predict_routing_identity_bitwise(dtype, causal):
    thr, lrn = _cfgs(causal)
    q, k, _ = _qkv(1, dtype)
    routing = routing_init(q.shape[1], q.shape[-1])
    pc_t = predict_pc(q, k, thr)
    pc_l = predict_routing(routing, q, k, lrn)
    np.testing.assert_array_equal(np.asarray(pc_l), np.asarray(pc_t))


@pytest.mark.parametrize("backend", ["reference", "gather", "kernel"])
@pytest.mark.parametrize("causal", [False, True], ids=["bidir", "causal"])
def test_execution_init_parity(backend, causal):
    """Running attention on the learned-at-init plan is bitwise the
    threshold run, for every backend (the STE soft term cancels
    exactly in the forward value)."""
    thr, lrn = _cfgs(causal, proj_init="identity")
    q, k, v = _qkv(2)
    routing = routing_init(q.shape[1], q.shape[-1])
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1], thr)
    out_t = sla_attention(params, q, k, v, thr, backend=backend,
                          plan=plan_attention(q, k, thr))
    out_l = sla_attention(params, q, k, v, lrn, backend=backend,
                          plan=plan_attention(q, k, lrn, routing=routing))
    np.testing.assert_array_equal(np.asarray(out_l), np.asarray(out_t))


def test_refresh_plan_init_parity():
    """Drift measurement + refresh decisions under the learned scorer at
    identity equal the threshold path bitwise (same retention, same
    replan flag, same refreshed plan)."""
    thr, lrn = _cfgs(causal=False)
    q0, k0, _ = _qkv(3)
    q1, k1, _ = _qkv(4)
    routing = routing_init(q0.shape[1], q0.shape[-1])
    p_t = plan_attention(q0, k0, thr)
    p_l = plan_attention(q0, k0, lrn, routing=routing)
    for threshold in (0.0, 0.05, 1.0):
        n_t, r_t, rep_t = refresh_plan(p_t, q1, k1, thr, threshold)
        n_l, r_l, rep_l = refresh_plan(p_l, q1, k1, lrn, threshold,
                                       routing=routing)
        assert float(r_t) == float(r_l)
        assert bool(rep_t) == bool(rep_l)
        np.testing.assert_array_equal(np.asarray(n_l.mc),
                                      np.asarray(n_t.mc))
        np.testing.assert_array_equal(np.asarray(n_l.marginal),
                                      np.asarray(n_t.marginal))


# ---------------------------------------------------------------------------
# decode parity: the row-local scorer routes like the full classifier
# ---------------------------------------------------------------------------
def test_routing_row_identity_bitwise():
    """predict_routing_row at identity == predict_pc_row bitwise, and the
    resulting row classification matches the full classifier row."""
    cfg = SLAConfig(block_q=16, block_kv=16, causal=True, kl_frac=0.0,
                    col_capacity_factor=None, fixed_budget=2,
                    routing_mode="learned")
    q, k, _ = _qkv(5)
    routing = routing_init(q.shape[1], q.shape[-1])
    from repro.core import pool_blocks
    qp = pool_blocks(q, cfg.block_q)
    kp = pool_blocks(k, cfg.block_kv)
    for row in range(qp.shape[-2]):
        pc_t = predict_pc_row(qp[..., row, :], kp, row, cfg)
        pc_l = predict_routing_row(routing, qp[..., row, :], kp, row, cfg)
        np.testing.assert_array_equal(np.asarray(pc_l), np.asarray(pc_t))
        np.testing.assert_array_equal(
            np.asarray(classify_row(pc_l, row, cfg)),
            np.asarray(classify_row(pc_t, row, cfg)))


def _lm_arch(routing_mode, num_layers=2):
    cfg = get_arch("qwen3-1.7b").smoke()
    return dataclasses.replace(
        cfg, num_layers=num_layers,
        sla=cfg.sla.replace(kh_frac=0.25, kl_frac=0.0, decode_mode="sla",
                            routing_mode=routing_mode))


def _lm_params(cfg, seed=0, proj_scale=0.3):
    params = tfm.init(jax.random.PRNGKey(seed), cfg)
    # nonzero Proj makes the linear branch observable in logits
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) \
        * proj_scale
    return params


def _greedy_tokens(cfg, params, toks, steps, max_len):
    last, cache = tfm.prefill(params, cfg, toks,
                              compute_dtype=jnp.float32,
                              decode_max_len=max_len)
    step = jax.jit(functools.partial(tfm.decode_step,
                                     compute_dtype=jnp.float32),
                   static_argnums=(1,))
    table = params.get("unembed", params["embed"])
    tok = jnp.argmax(jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                                table.astype(jnp.float32)), -1) \
        .astype(jnp.int32)
    out = []
    for _ in range(steps):
        out.append(np.asarray(tok))
        logits, cache = step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(out)


def test_decode_parity_learned_vs_threshold():
    """Decode-SLA greedy decode with learned routing at init equals the
    threshold run token-for-token, across block boundaries (so the
    incremental plans extend identically)."""
    cfg_t = _lm_arch("threshold")
    cfg_l = _lm_arch("learned")
    p_t = _lm_params(cfg_t)
    p_l = _lm_params(cfg_l)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg_t.vocab_size)
    g_t = _greedy_tokens(cfg_t, p_t, toks, steps=40, max_len=96)
    g_l = _greedy_tokens(cfg_l, p_l, toks, steps=40, max_len=96)
    np.testing.assert_array_equal(g_l, g_t)


def test_forward_and_prefill_plans_init_parity():
    """One-shot forward (and the per-layer prefill plan stack) is
    bitwise identical under learned-at-init routing."""
    cfg_t = _lm_arch("threshold")
    cfg_l = _lm_arch("learned")
    p_t = _lm_params(cfg_t)
    p_l = _lm_params(cfg_l)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                              cfg_t.vocab_size)
    x_t, _, plans_t = tfm.forward(p_t, cfg_t, toks,
                                  compute_dtype=jnp.float32,
                                  return_plans=True)
    x_l, _, plans_l = tfm.forward(p_l, cfg_l, toks,
                                  compute_dtype=jnp.float32,
                                  return_plans=True)
    np.testing.assert_array_equal(np.asarray(x_l), np.asarray(x_t))
    np.testing.assert_array_equal(np.asarray(plans_l.mc),
                                  np.asarray(plans_t.mc))


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "whisper-small"])
def test_other_families_init_parity(arch):
    """Hybrid (shared-attn) and enc-dec families also carry the routing
    head through their SLA layers; at identity init the training loss
    is bitwise the threshold run."""
    from repro.configs import get_shape
    from repro.models import registry
    cfg_t = get_arch(arch).smoke()
    cfg_l = dataclasses.replace(
        cfg_t, sla=cfg_t.sla.replace(routing_mode="learned"))
    mdl = registry.get_model(cfg_t)
    p_t = mdl.init(jax.random.PRNGKey(0), cfg_t)
    p_l = mdl.init(jax.random.PRNGKey(0), cfg_l)
    shape = get_shape("train_4k", smoke=True)
    batch = registry.make_concrete_batch(jax.random.PRNGKey(1), cfg_t,
                                         shape)
    assert float(mdl.loss_fn(p_l, cfg_l, batch)) == \
        float(mdl.loss_fn(p_t, cfg_t, batch))


# ---------------------------------------------------------------------------
# gradient flow: straight-through gates reach the routing parameters
# ---------------------------------------------------------------------------
def _routing_grad(backend, cfg, q, k, v):
    routing = routing_init(q.shape[1], q.shape[-1])
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1],
                      cfg.replace(proj_init="identity"))

    def loss(routing):
        plan = plan_attention(q, k, cfg, routing=routing)
        out = sla_attention(params, q, k, v, cfg, backend=backend,
                            plan=plan)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    return jax.grad(loss)(routing)


@pytest.mark.parametrize("backend", ["reference", "gather"])
def test_ste_grads_nonzero_autodiff_backends(backend):
    _, lrn = _cfgs(causal=False, proj_init="identity")
    q, k, v = _qkv(6)
    g = _routing_grad(backend, lrn, q, k, v)
    assert float(jnp.linalg.norm(g["wq"])) > 0
    assert float(jnp.linalg.norm(g["wk"])) > 0


def test_ste_grads_zero_through_kernel_backend():
    """The fused kernel's custom_vjp treats the plan as a constant — the
    documented contract is zero routing grads there (fine-tune with
    gather/reference), not an error."""
    _, lrn = _cfgs(causal=False, proj_init="identity")
    q, k, v = _qkv(6)
    g = _routing_grad("kernel", lrn, q, k, v)
    assert float(jnp.linalg.norm(g["wq"])) == 0.0


def test_qk_grads_unaffected_by_routing():
    """(q, k) stay gradient-stopped through planning: the block
    structure is a constant w.r.t. the loss exactly as in threshold
    mode (only the routing parameters see the STE path)."""
    thr, lrn = _cfgs(causal=False, proj_init="identity")
    q, k, v = _qkv(7)
    routing = routing_init(q.shape[1], q.shape[-1])
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1], thr)

    def loss(q, cfg, **kw):
        plan = plan_attention(q, k, cfg, **kw)
        out = sla_attention(params, q, k, v, cfg, backend="gather",
                            plan=plan)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    g_t = jax.grad(loss)(q, thr)
    g_l = jax.grad(loss)(q, lrn, routing=routing)
    np.testing.assert_array_equal(np.asarray(g_l), np.asarray(g_t))


def _dit_setup(routing_mode):
    """The shared toy-DiT distillation harness (same substrate as
    benchmarks/fig_routing.py — one definition, benchmarks/_toy.py)."""
    from benchmarks._toy import toy_dit_distill_setup
    return toy_dit_distill_setup(routing_mode)


def test_distill_loss_routing_grads_nonzero():
    """The acceptance-criteria gradient check: under the end-to-end
    distillation loss, routing parameters receive nonzero grads."""
    cfg, params, batch = _dit_setup("learned")
    loss, g = jax.value_and_grad(
        lambda p: dit.distill_loss_fn(p, cfg, batch,
                                      compute_dtype=jnp.float32))(params)
    assert float(loss) > 0
    assert float(jnp.linalg.norm(g["layers"]["routing"]["wq"])) > 0
    assert float(jnp.linalg.norm(g["layers"]["routing"]["wk"])) > 0


def test_distill_finetune_smoke():
    """A few fine-tuning steps training only (routing, sla_proj) at the
    fixed critical-block budget decrease the distillation loss and move
    the routing head off identity; frozen params stay bitwise put."""
    cfg, params, batch = _dit_setup("learned")
    mask = adamw.trainable_mask(params, ("routing", "sla_proj"))
    opt_cfg = adamw.AdamWConfig(lr=3e-2, total_steps=12, warmup_steps=1,
                                weight_decay=0.0)
    opt = adamw.init(params)
    frozen_before = np.asarray(params["layers"]["wq"])

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda p: dit.distill_loss_fn(p, cfg, batch,
                                          compute_dtype=jnp.float32))(p)
        p, o, _ = adamw.update(p, g, o, opt_cfg, trainable=mask)
        return p, o, loss

    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    eye = np.asarray(routing_init(cfg.num_heads, cfg.head_dim)["wq"])
    moved = np.abs(np.asarray(params["layers"]["routing"]["wq"])
                   - eye[None]).max()
    assert moved > 0, "routing head never moved off identity"
    np.testing.assert_array_equal(np.asarray(params["layers"]["wq"]),
                                  frozen_before)
    # the final gradient still reaches the routing head
    _, g = jax.value_and_grad(
        lambda p: dit.distill_loss_fn(p, cfg, batch,
                                      compute_dtype=jnp.float32))(params)
    assert float(jnp.linalg.norm(g["layers"]["routing"]["wq"])) > 0


def test_transformer_distill_grads():
    """LM variant of the distillation objective: exact-attention teacher
    on the same params, nonzero routing grads once Proj is nonzero."""
    cfg = dataclasses.replace(
        _lm_arch("learned"),
        sla=_lm_arch("learned").sla.replace(kl_frac=0.25,
                                            routing_temp=0.05))
    params = _lm_params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    loss, g = jax.value_and_grad(
        lambda p: tfm.distill_loss_fn(p, cfg, batch,
                                      compute_dtype=jnp.float32))(params)
    assert float(loss) > 0
    assert float(jnp.linalg.norm(g["layers"]["routing"]["wq"])) > 0


# ---------------------------------------------------------------------------
# plumbing: flops, optimizer mask, loud failures
# ---------------------------------------------------------------------------
def test_flops_routing_accounting():
    thr, lrn = _cfgs()
    n, d, h = 4096, 64, 8
    f_t = sla_flops(n, d, h, thr)
    f_l = sla_flops(n, d, h, lrn)
    assert f_t["routing"] == 0.0
    tm, tn = n // lrn.block_q, n // lrn.block_kv
    assert f_l["routing"] == 2.0 * (tm + tn) * d * d * h
    assert f_l["total"] == pytest.approx(f_t["total"] + f_l["routing"])
    d_t = sla_decode_flops(n, d, h, thr.replace(causal=True))
    d_l = sla_decode_flops(n, d, h, lrn.replace(causal=True))
    assert d_t["routing"] == 0.0 and d_l["routing"] > 0.0
    assert d_l["total"] == pytest.approx(d_t["total"] + d_l["routing"])


def test_trainable_mask_marks_by_path():
    cfg, params, _ = _dit_setup("learned")
    mask = adamw.trainable_mask(params, ("routing", "sla_proj"))
    assert mask["layers"]["routing"]["wq"] is True
    assert mask["layers"]["sla_proj"] is True
    assert mask["layers"]["wq"] is False
    assert mask["patch_out"] is False


def test_loud_failures():
    """Every scoring entry point — planning, classification, AND drift
    measurement — shares the one loud-failure path: learned mode
    without routing params raises instead of silently falling back to
    the threshold scorer."""
    thr, lrn = _cfgs()
    q, k, _ = _qkv(8)
    with pytest.raises(ValueError, match="routing parameters"):
        plan_attention(q, k, lrn)  # learned mode, no routing params
    with pytest.raises(ValueError, match="routing_mode"):
        # SLAConfig.validate() rejects the typo at the plan entry point
        plan_attention(q, k, thr.replace(routing_mode="psychic"))
    from repro.core.masks import compute_mask
    with pytest.raises(ValueError, match="routing parameters"):
        compute_mask(q, k, lrn)
    routing = routing_init(q.shape[1], q.shape[-1])
    plan = plan_attention(q, k, lrn, routing=routing)
    from repro.core import plan_drift
    with pytest.raises(ValueError, match="routing parameters"):
        plan_drift(plan, q, k, lrn)
    with pytest.raises(ValueError, match="routing parameters"):
        refresh_plan(plan, q, k, lrn, 0.1)


def test_train_cli_rejects_empty_train_only():
    from repro.launch import train
    with pytest.raises(ValueError, match="matches no parameters"):
        train.main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "1",
                    "--train-only", "does-not-exist"])


def test_routing_dead_point_warns_and_warm_init_clears():
    """Fresh checkpoint + --train-only routing is a dead point: routing
    gradients flow only through the linear branch, whose output is
    multiplied by the paper's ZERO-initialized sla_proj — they are all
    exactly zero. check_routing_dead_point must warn on that state and
    stay quiet once the proj is nonzero (tests assert BOTH paths)."""
    from repro.launch import train

    cfg = _lm_arch("learned")
    params = tfm.init(jax.random.PRNGKey(0), cfg)  # paper init: proj=0
    mask = adamw.trainable_mask(params, ("routing",))
    with pytest.warns(UserWarning, match="dead point"):
        assert train.check_routing_dead_point(params, mask) is True

    # warm init replaces the zero proj with an epsilon identity ...
    warm = train.routing_warm_init(params)
    proj = np.asarray(warm["layers"]["sla_proj"])
    eye = np.eye(proj.shape[-1], dtype=proj.dtype) \
        * train.ROUTING_WARM_EPS
    np.testing.assert_array_equal(
        proj, np.broadcast_to(eye, proj.shape))
    # ... and the untouched leaves are the SAME arrays, not copies
    assert warm["layers"]["wq"] is params["layers"]["wq"]
    # nonzero proj -> no warning, returns False
    assert train.check_routing_dead_point(warm, mask) is False
    # routing frozen -> not a dead point even with zero proj
    frozen = adamw.trainable_mask(params, ("sla_proj",))
    assert train.check_routing_dead_point(params, frozen) is False


@pytest.mark.slow
def test_train_cli_routing_dead_point_paths():
    """End to end through launch/train.py: --train-only routing on a
    fresh smoke checkpoint warns; adding --routing-warm-init does
    not."""
    import warnings

    from repro.launch import train

    args = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "1",
            "--routing-mode", "learned", "--train-only", "routing"]
    with pytest.warns(UserWarning, match="dead point"):
        train.main(args)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        train.main(args + ["--routing-warm-init"])
    assert not [w for w in rec if "dead point" in str(w.message)]


@pytest.mark.slow
def test_serve_cli_routing_mode_learned():
    """launch/serve.py --routing-mode learned end to end (smoke): fresh
    params serve identically under either router, so the run must
    complete and honor every request budget."""
    from repro.launch import serve
    done = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--requests",
                       "4", "--batch", "2", "--prompt-len", "32",
                       "--max-new", "4", "--routing-mode", "learned"])
    assert all(len(r.tokens_out) == r.max_new_tokens for r in done)


@pytest.mark.slow
def test_engine_decode_sla_learned_routing_parity():
    """ServingEngine with decode-SLA + learned routing at init produces
    the same tokens as the threshold engine."""
    from repro.serving.engine import Request, ServingEngine
    outs = {}
    for mode in ("threshold", "learned"):
        cfg = _lm_arch(mode)
        params = _lm_params(cfg)
        engine = ServingEngine(cfg, params, batch_size=2, max_len=128,
                               decode_sla=True)
        rs = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rs.integers(0, cfg.vocab_size, size=32)
                        .astype(np.int32), max_new_tokens=24)
                for i in range(2)]
        done = engine.run(reqs)
        outs[mode] = [r.tokens_out for r in done]
    assert outs["learned"] == outs["threshold"]
