"""Disaggregated prefill/decode pools (ISSUE 9): fault-injection parity.

Pillars:
  * bitwise token parity — the disaggregated pools (1 prefill worker,
    2 decode workers, least-loaded routing, explicit HandoffBundle
    scatter) produce per-request greedy tokens bitwise equal to a
    single-Scheduler run, for gather and kernel backends, decode-SLA
    on and off;
  * requeue determinism — killing a decode worker mid-stream requeues
    its in-flight requests from their retained bundles, and the
    replayed trajectories are STILL bitwise equal to the undisturbed
    baseline (prefill is a pure function of (padded prompt, bucket):
    plan_reuse is pinned off);
  * straggler drain — a flagged worker finishes its residents, takes
    no new admissions, and zero requests are lost;
  * loud double-fault — a request whose requeue budget is exhausted is
    returned to the QUEUE (state QUEUED, no slot, no partial tokens —
    the PR 5 no-half-admitted-limbo invariant) and the loss raises;
  * flake absorption — injected transient faults are retried under the
    exact min(2**attempt, 10) backoff with the injected sleep;
  * the slow trace-replay tier: paged + chunked prefill + decode-SLA
    with kill + straggle + flake mixed into one staggered trace, still
    bitwise equal to the baseline.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.distributed.fault_tolerance import (FaultEvent, FaultPlan,
                                               StragglerWatchdog)
from repro.models import transformer as tfm
from repro.serving import DisaggScheduler, least_loaded
from repro.serving.api import (RequestState, SamplingParams, Scheduler)

import jax

LENS = (32, 20, 24, 16)
BUDGETS = (6, 9, 4, 7)
BUCKET = 32


def _arch(decode=False, kh=1.0, kl=0.0, chunk=False):
    cfg = get_arch("qwen3-1.7b").smoke()
    sla = cfg.sla.replace(kh_frac=kh, kl_frac=kl)
    if decode:
        sla = sla.replace(decode_mode="sla")
    if chunk:
        # chunk-eligible: per-row critical sets only (the column-
        # capacity demotion pass couples rows across chunks)
        sla = sla.replace(col_capacity_factor=None)
    return dataclasses.replace(cfg, sla=sla)


def _params(cfg, proj_scale=0.3):
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) \
        * proj_scale
    return params


def _prompts(cfg, lens=LENS, seed=0):
    rs = np.random.default_rng(seed)
    return [rs.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def _baseline_tokens(cfg, params, prompts, budgets, *, backend,
                     decode_sla, max_len=96, **kw):
    """Greedy tokens from one plain Scheduler, keyed by rid."""
    sched = Scheduler(cfg, params, num_slots=2, max_len=max_len,
                      backend=backend, decode_sla=decode_sla,
                      prefill_bucket=BUCKET, plan_reuse="off", **kw)
    for p, b in zip(prompts, budgets):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    return {r.rid: list(r.tokens_out) for r in sched.drain()}


def _disagg_tokens(dis):
    return {r.rid: list(r.tokens_out) for r in dis._requests}


class TickClock:
    """Deterministic virtual clock: every call advances 0.5s, so each
    measured decode tick spans exactly 0.5 virtual seconds."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.5
        return self.t


# ---------------------------------------------------------------------------
# routing unit
# ---------------------------------------------------------------------------
def test_least_loaded_picks_min_load_then_wid():
    a = SimpleNamespace(wid=0, load=2)
    b = SimpleNamespace(wid=1, load=1)
    c = SimpleNamespace(wid=2, load=1)
    assert least_loaded([a, b, c]) is b  # ties break toward lower wid
    assert least_loaded([a]) is a
    assert least_loaded([]) is None


def test_submit_too_long_raises_loudly():
    cfg = _arch()
    dis = DisaggScheduler(cfg, _params(cfg), max_len=48,
                          prefill_bucket=BUCKET)
    with pytest.raises(ValueError, match="max_len"):
        dis.submit(np.arange(32, dtype=np.int32),
                   SamplingParams(max_new_tokens=32))
    with pytest.raises(ValueError, match="empty prompt"):
        dis.submit(np.zeros((0,), np.int32))


# ---------------------------------------------------------------------------
# bitwise parity: healthy AND kill-mid-stream requeue, full matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,decode_sla", [
    ("gather", False), ("gather", True),
    ("kernel", False), ("kernel", True),
])
def test_disagg_parity_healthy_and_kill_requeue(backend, decode_sla):
    """The acceptance bar: per-request greedy tokens from the
    disaggregated pools are bitwise equal to a single-Scheduler run —
    both undisturbed AND when a decode worker is killed mid-stream and
    its residents replay from their retained handoff bundles."""
    cfg = _arch(decode=decode_sla)
    params = _params(cfg)
    prompts = _prompts(cfg)
    want = _baseline_tokens(cfg, params, prompts, BUDGETS,
                            backend=backend, decode_sla=decode_sla)

    # healthy run: rolled decode drains, least-loaded routing
    dis = DisaggScheduler(cfg, params, prefill_workers=1,
                          decode_workers=2, slots_per_worker=2,
                          max_len=96, backend=backend,
                          decode_sla=decode_sla, prefill_bucket=BUCKET)
    for p, b in zip(prompts, BUDGETS):
        dis.submit(p, SamplingParams(max_new_tokens=b))
    dis.drain()
    assert _disagg_tokens(dis) == want
    assert dis.stats.completed == dis.stats.submitted == len(prompts)
    assert dis.stats.handoffs == len(prompts)
    assert dis.stats.requeues == 0

    # faulted run: kill decode:0 while its residents are mid-stream
    # (token-step mode so the kill lands inside a request, not between)
    plan = FaultPlan([FaultEvent(tick=3, kind="kill", pool="decode",
                                 worker=0)])
    dis = DisaggScheduler(cfg, params, prefill_workers=1,
                          decode_workers=2, slots_per_worker=2,
                          max_len=96, backend=backend,
                          decode_sla=decode_sla, prefill_bucket=BUCKET,
                          decode_step_mode="token", fault_plan=plan,
                          sleep=lambda s: None)
    for p, b in zip(prompts, BUDGETS):
        dis.submit(p, SamplingParams(max_new_tokens=b))
    dis.drain()
    assert _disagg_tokens(dis) == want  # replay is bitwise faithful
    assert dis.stats.kills == 1
    assert dis.stats.requeues >= 1
    assert dis.stats.completed == len(prompts)
    dead = dis.pool_stats()["decode"][0]
    assert not dead["alive"]


def test_kill_prefill_worker_reprefills_from_scratch():
    """A killed prefill worker has no bundle to replay: its in-flight
    request requeues from scratch, re-prefills on a surviving worker
    (mid-chunk state abandoned), and still matches the baseline."""
    cfg = _arch(chunk=True, kh=0.25)
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32,))
    want = _baseline_tokens(cfg, params, prompts, (6,),
                            backend="gather", decode_sla=False)

    # tick 1 assigns and runs chunk 1 of 2 on prefill:0; the tick-2
    # kill fires before chunk 2, abandoning the carry mid-prompt
    plan = FaultPlan([FaultEvent(tick=2, kind="kill", pool="prefill",
                                 worker=0)])
    dis = DisaggScheduler(cfg, params, prefill_workers=2,
                          decode_workers=1, slots_per_worker=2,
                          max_len=96, backend="gather",
                          decode_sla=False, prefill_bucket=BUCKET,
                          prefill_chunk_blocks=1,  # 16-token chunks
                          fault_plan=plan, sleep=lambda s: None)
    dis.submit(prompts[0], SamplingParams(max_new_tokens=6))
    dis.drain()
    assert _disagg_tokens(dis) == want
    assert dis.stats.kills == 1 and dis.stats.requeues == 1
    assert not dis.pool_stats()["prefill"][0]["alive"]
    assert dis.stats.completed == 1


# ---------------------------------------------------------------------------
# straggler drain: zero lost requests, no new admissions
# ---------------------------------------------------------------------------
def test_straggler_drain_loses_nothing():
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg)
    want = _baseline_tokens(cfg, params, prompts, BUDGETS,
                            backend="gather", decode_sla=False)

    # decode:0 straggles 10x from tick 2; the shared watchdog (EMA
    # seeded by two healthy 0.5s warmup ticks) must flag and DRAIN it
    plan = FaultPlan([FaultEvent(tick=2, kind="straggle", pool="decode",
                                 worker=0, factor=10.0)])
    dis = DisaggScheduler(
        cfg, params, prefill_workers=1, decode_workers=2,
        slots_per_worker=2, max_len=96, backend="gather",
        decode_sla=False, prefill_bucket=BUCKET,
        decode_step_mode="token", fault_plan=plan,
        watchdog=StragglerWatchdog(threshold=2.0, warmup=2),
        clock=TickClock(), sleep=lambda s: None)
    for p, b in zip(prompts, BUDGETS):
        dis.submit(p, SamplingParams(max_new_tokens=b))

    admitted_at_drain = None
    while dis.has_work:
        dis.tick()
        w0 = dis._decode_pool[0]
        if w0.draining and admitted_at_drain is None:
            admitted_at_drain = w0.admitted
    assert admitted_at_drain is not None, "straggler never drained"
    assert dis.stats.straggler_drains == 1
    # the drained worker finished its residents but took nothing new
    assert dis._decode_pool[0].admitted == admitted_at_drain
    assert dis._decode_pool[0].alive  # drained, not killed
    assert dis.stats.completed == len(prompts)  # zero lost
    assert _disagg_tokens(dis) == want


# ---------------------------------------------------------------------------
# double fault: loud failure, no half-admitted limbo
# ---------------------------------------------------------------------------
def test_double_fault_during_requeue_raises_and_leaves_no_limbo():
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32,))

    plan = FaultPlan([
        FaultEvent(tick=3, kind="kill", pool="decode", worker=0),
        FaultEvent(tick=6, kind="kill", pool="decode", worker=1),
    ])
    dis = DisaggScheduler(cfg, params, prefill_workers=1,
                          decode_workers=2, slots_per_worker=2,
                          max_len=96, backend="gather",
                          decode_sla=False, prefill_bucket=BUCKET,
                          decode_step_mode="token", fault_plan=plan,
                          max_requeues=1, sleep=lambda s: None)
    rid = dis.submit(prompts[0], SamplingParams(max_new_tokens=16))
    with pytest.raises(RuntimeError, match="max_requeues"):
        dis.drain()

    (r,) = dis._requests
    assert r.rid == rid
    # the PR 5 invariant: back in the QUEUE, not half-admitted
    assert r.state == RequestState.QUEUED
    assert r.slot is None
    assert r.tokens_out == []
    assert r.metrics.decode_tokens == 0
    assert list(dis._queue) == [r]
    assert rid not in dis._owner and rid not in dis._bundles
    assert dis.stats.kills == 2 and dis.stats.requeues == 1
    # and with every decode worker dead, further progress is loud too
    with pytest.raises(RuntimeError, match="prefill worker|decode"):
        dis.drain()


def test_all_prefill_dead_with_queue_raises():
    cfg = _arch()
    plan = FaultPlan([FaultEvent(tick=1, kind="kill", pool="prefill",
                                 worker=0)])
    dis = DisaggScheduler(cfg, _params(cfg), prefill_workers=1,
                          decode_workers=1, max_len=96,
                          prefill_bucket=BUCKET, fault_plan=plan)
    dis.submit(_prompts(cfg, lens=(20,))[0],
               SamplingParams(max_new_tokens=4))
    with pytest.raises(RuntimeError, match="prefill worker"):
        dis.drain()


def test_fault_plan_naming_missing_worker_raises():
    cfg = _arch()
    plan = FaultPlan([FaultEvent(tick=1, kind="kill", pool="decode",
                                 worker=9)])
    dis = DisaggScheduler(cfg, _params(cfg), decode_workers=2,
                          max_len=96, prefill_bucket=BUCKET,
                          fault_plan=plan)
    dis.submit(_prompts(cfg, lens=(16,))[0],
               SamplingParams(max_new_tokens=2))
    with pytest.raises(ValueError, match="has 2 workers"):
        dis.drain()


# ---------------------------------------------------------------------------
# flake absorption: retry contract with recorded backoff
# ---------------------------------------------------------------------------
def test_flake_retries_with_recorded_backoff():
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg)
    want = _baseline_tokens(cfg, params, prompts, BUDGETS,
                            backend="gather", decode_sla=False)

    sleeps = []
    plan = FaultPlan([FaultEvent(tick=2, kind="flake", pool="decode",
                                 worker=0, failures=2)])
    dis = DisaggScheduler(cfg, params, prefill_workers=1,
                          decode_workers=2, slots_per_worker=2,
                          max_len=96, backend="gather",
                          decode_sla=False, prefill_bucket=BUCKET,
                          decode_step_mode="token", fault_plan=plan,
                          max_retries=3, sleep=sleeps.append)
    for p, b in zip(prompts, BUDGETS):
        dis.submit(p, SamplingParams(max_new_tokens=b))
    dis.drain()
    assert sleeps == [1.0, 2.0]  # min(2**attempt, 10) for attempts 0, 1
    assert dis.stats.retries == 2
    assert dis.stats.kills == 0 and dis.stats.requeues == 0
    assert dis.stats.completed == len(prompts)
    assert _disagg_tokens(dis) == want


def test_flake_beyond_retry_budget_raises():
    cfg = _arch()
    plan = FaultPlan([FaultEvent(tick=1, kind="flake", pool="prefill",
                                 worker=0, failures=5)])
    dis = DisaggScheduler(cfg, _params(cfg), max_len=96,
                          prefill_bucket=BUCKET, fault_plan=plan,
                          max_retries=2, sleep=lambda s: None)
    dis.submit(_prompts(cfg, lens=(16,))[0],
               SamplingParams(max_new_tokens=2))
    with pytest.raises(RuntimeError, match="injected transient fault"):
        dis.drain()


# ---------------------------------------------------------------------------
# streaming surface
# ---------------------------------------------------------------------------
def test_stream_events_well_formed_across_requeue():
    """Event stream stays well-formed under a kill: exactly one start
    per rid (a requeued request does NOT re-emit start), exactly one
    finish, token indices dense from 0 after the replay."""
    cfg = _arch()
    params = _params(cfg)
    prompts = _prompts(cfg, lens=(32, 20))
    plan = FaultPlan([FaultEvent(tick=3, kind="kill", pool="decode",
                                 worker=0)])
    dis = DisaggScheduler(cfg, params, prefill_workers=1,
                          decode_workers=2, slots_per_worker=2,
                          max_len=96, backend="gather",
                          prefill_bucket=BUCKET,
                          decode_step_mode="token", fault_plan=plan,
                          sleep=lambda s: None)
    for p in prompts:
        dis.submit(p, SamplingParams(max_new_tokens=6))
    events = list(dis.stream())
    assert dis.stats.kills == 1 and dis.stats.requeues >= 1
    for rid in (0, 1):
        evs = [e for e in events if e.rid == rid]
        kinds = [e.kind for e in evs]
        assert kinds.count("start") == 1
        assert kinds.count("finish") == 1
        assert kinds[0] == "start" and kinds[-1] == "finish"


# ---------------------------------------------------------------------------
# slow tier: the combined trace-replay scenario
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_trace_replay_mixed_faults_paged_chunked_decode_sla():
    """Everything at once: paged decode workers, chunked prefill,
    decode-SLA, staggered arrivals, and a fault trace mixing flake,
    straggle, and kill — the drained tokens are STILL bitwise equal to
    an undisturbed single-Scheduler run, with zero requests lost."""
    cfg = _arch(decode=True, kh=0.25, chunk=True)
    params = _params(cfg)
    lens = (32, 20, 24, 16, 28, 32, 18, 24)
    budgets = (6, 9, 4, 7, 5, 8, 6, 4)
    prompts = _prompts(cfg, lens=lens, seed=3)
    want = _baseline_tokens(cfg, params, prompts, budgets,
                            backend="gather", decode_sla=True,
                            max_len=128, paged=True)

    plan = FaultPlan([
        FaultEvent(tick=2, kind="flake", pool="decode", worker=1,
                   failures=1),
        FaultEvent(tick=4, kind="straggle", pool="decode", worker=2,
                   factor=10.0),
        FaultEvent(tick=6, kind="kill", pool="decode", worker=0),
    ])
    dis = DisaggScheduler(
        cfg, params, prefill_workers=2, decode_workers=3,
        slots_per_worker=2, max_len=128, backend="gather",
        decode_sla=True, prefill_bucket=BUCKET, paged=True,
        prefill_chunk_blocks=1, decode_step_mode="token",
        fault_plan=plan,
        watchdog=StragglerWatchdog(threshold=2.0, warmup=3),
        clock=TickClock(), sleep=lambda s: None, max_requeues=2)
    # staggered arrivals: half up front, the rest mid-flight
    for p, b in zip(prompts[:4], budgets[:4]):
        dis.submit(p, SamplingParams(max_new_tokens=b))
    for _ in range(3):
        dis.tick()
    for p, b in zip(prompts[4:], budgets[4:]):
        dis.submit(p, SamplingParams(max_new_tokens=b))
    dis.drain()

    assert _disagg_tokens(dis) == want
    assert dis.stats.completed == dis.stats.submitted == len(prompts)
    assert dis.stats.kills == 1
    assert dis.stats.requeues >= 1
    assert dis.stats.retries >= 1
    assert dis.stats.straggler_drains == 1
    assert 0.0 < dis.decode_occupancy() <= 1.0
    assert 0.0 < dis.stats.prefill_occupancy() <= 1.0
