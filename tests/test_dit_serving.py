"""Streaming DiT service tests (ISSUE 10).

Tiers:
  * plan-cache unit coverage: hit/miss/invalidation counters, the LRU
    eviction bound, serialization round-trip + compat-key discrimination;
  * per-sample refresh: `refresh_plan_per_sample` row-for-row bitwise
    equal to batch-1 `refresh_plan` (the lemma the scheduler's parity
    rests on), and scalar-vs-vector t bitwise in dit.forward;
  * the acceptance claim: a multi-user mixed-timestep
    DiffusionScheduler trace produces per-request final latents
    bitwise-equal to sequential per-request `dit.sample` runs (gather
    fast tier; reference + fixed-mode variants in the slow tier);
  * plan-cache drift parity: cached-plan outputs equal fresh-plan
    outputs within the conformance-matrix f32 tolerances;
  * registry smoke: wan2_1_1_3b + lightningdit_1b build, run one
    dit.sample step under SLA, and round-trip through the scheduler.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import plan as plan_lib
from repro.models import dit
from repro.serving.api import RequestState, stats_json_payload
from repro.serving.diffusion import (DenoiseParams, DenoiseRequest,
                                     DiffusionScheduler)
from repro.serving.plan_cache import PlanCache

TOL_F32 = dict(atol=5e-5, rtol=5e-5)  # tests/test_conformance.py TOL
SEQ = 32


@pytest.fixture(scope="module")
def lightning():
    cfg = get_arch("lightningdit_1b").smoke()
    return cfg, dit.init(jax.random.PRNGKey(0), cfg)


def _latent(cfg, i):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(i + 1), (SEQ, cfg.patch_dim), jnp.float32))


def _qk(cfg, seed, b=3):
    r = jax.random.split(jax.random.PRNGKey(seed), 2)
    h, dh = cfg.num_heads, cfg.head_dim
    q = jax.random.normal(r[0], (b, h, SEQ, dh), jnp.float32)
    k = jax.random.normal(r[1], (b, h, SEQ, dh), jnp.float32)
    return q, k


def _sla(cfg):
    return dataclasses.replace(cfg.sla, causal=False)


def _plan_stack(cfg, seed, layers=None):
    """Per-layer stacked batch-1 plans (leaves (L, 1, ...)) the way the
    scheduler stores them."""
    layers = cfg.num_layers if layers is None else layers
    sla = _sla(cfg)
    rows = []
    for l in range(layers):
        q, k = _qk(cfg, seed + 17 * l, b=1)
        rows.append(plan_lib.plan_attention(q, k, sla))
    return jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *rows)


# ---------------------------------------------------------------------------
# plan serialization + compat key
# ---------------------------------------------------------------------------
def test_plan_serialization_roundtrip(lightning):
    cfg, _ = lightning
    q, k = _qk(cfg, 0, b=2)
    plan = plan_lib.plan_attention(q, k, _sla(cfg))
    back = plan_lib.deserialize_plan(plan_lib.serialize_plan(plan))
    for name in ("mc", "lut", "counts", "col_lut", "col_counts",
                 "marginal"):
        a, b = getattr(plan, name), getattr(back, name)
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_plan_deserialize_rejects_wrong_version(lightning):
    cfg, _ = lightning
    q, k = _qk(cfg, 0, b=1)
    data = plan_lib.serialize_plan(plan_lib.plan_attention(q, k, _sla(cfg)))
    data["__version__"] = 99
    with pytest.raises(ValueError, match="wire version"):
        plan_lib.deserialize_plan(data)


def test_plan_compat_key_discriminates(lightning):
    cfg, _ = lightning
    sla = _sla(cfg)
    base = plan_lib.plan_compat_key(sla, 2, 4, 4)
    assert base == plan_lib.plan_compat_key(sla, 2, 4, 4)
    assert base != plan_lib.plan_compat_key(sla, 2, 8, 8)  # shape
    other = dataclasses.replace(sla, kh_frac=sla.kh_frac * 2)
    assert base != plan_lib.plan_compat_key(other, 2, 4, 4)  # config
    # execution-only knobs must NOT invalidate cached structure
    phi = dataclasses.replace(sla, phi="relu")
    assert base == plan_lib.plan_compat_key(phi, 2, 4, 4)


# ---------------------------------------------------------------------------
# PlanCache: counters, LRU bound
# ---------------------------------------------------------------------------
def test_plan_cache_hit_miss_invalidation_counters(lightning):
    cfg, _ = lightning
    cache = PlanCache(_sla(cfg), cfg.num_layers, t_buckets=4,
                      max_entries=64)
    assert cache.get(3) is None and cache.misses == 1
    stack = _plan_stack(cfg, 1)
    cache.put(3, stack)
    got = cache.get(3)
    assert got is not None and cache.hits == 1
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(stack)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # drift invalidation: layer 0 re-planned, layer 1 held
    stack2 = _plan_stack(cfg, 2)
    flags = np.zeros((cfg.num_layers,), bool)
    flags[0] = True
    assert cache.update(3, stack2, flags) == 1
    assert cache.invalidations == 1
    got2 = cache.get(3)
    assert np.array_equal(np.asarray(got2.mc[0]), np.asarray(stack2.mc[0]))
    assert np.array_equal(np.asarray(got2.mc[1]), np.asarray(stack.mc[1]))


def test_plan_cache_lru_eviction_bound(lightning):
    cfg, _ = lightning
    nl = cfg.num_layers
    cache = PlanCache(_sla(cfg), nl, t_buckets=8, max_entries=2 * nl)
    stack = _plan_stack(cfg, 1)
    for bucket in range(4):
        cache.put(bucket, stack)
        assert len(cache) <= 2 * nl  # the bound holds at every step
    assert cache.evictions == 2 * nl  # 4 buckets in, 2 evicted whole
    assert cache.get(0) is None  # oldest bucket gone
    assert cache.get(3) is not None  # newest retained
    # a hit refreshes recency: bucket 2 survives the next insertion
    assert cache.get(2) is not None
    cache.put(5, stack)
    assert cache.get(2) is not None
    assert cache.get(3) is None  # bucket 3 was the LRU, evicted


def test_plan_cache_bucket_of_t():
    cfg = get_arch("lightningdit_1b").smoke()
    cache = PlanCache(_sla(cfg), cfg.num_layers, t_buckets=8)
    assert cache.bucket(1.0) == 7  # t=1.0 clamps into the top bucket
    assert cache.bucket(0.999) == 7
    assert cache.bucket(0.5) == 4
    assert cache.bucket(1e-6) == 0
    assert cache.bucket(0.0) == 0


def test_plan_cache_rejects_incompatible_plan(lightning):
    cfg, _ = lightning
    cache = PlanCache(_sla(cfg), cfg.num_layers, t_buckets=4)
    cache.put(0, _plan_stack(cfg, 1))
    sla = _sla(cfg)
    q, k = _qk(cfg, 3, b=1)
    q2 = jnp.concatenate([q, q], axis=2)  # 2x seq -> 2x blocks
    k2 = jnp.concatenate([k, k], axis=2)
    rows = [plan_lib.plan_attention(q2, k2, sla)
            for _ in range(cfg.num_layers)]
    stack = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *rows)
    with pytest.raises(ValueError, match="incompatible"):
        cache.put(1, stack)


# ---------------------------------------------------------------------------
# per-sample refresh + vector-t lemmas
# ---------------------------------------------------------------------------
def test_refresh_plan_per_sample_matches_batch1(lightning):
    """Row r of the per-sample refresh over a batch == refresh_plan on
    row r alone — bitwise on every leaf, decision included."""
    cfg, _ = lightning
    sla = _sla(cfg)
    q0, k0 = _qk(cfg, 10, b=3)
    q1, k1 = _qk(cfg, 11, b=3)
    plan = plan_lib.plan_attention(q0, k0, sla)
    thr = np.array([0.0, 0.05, 1.0], np.float32)  # force / measure / pin
    new, ret, rep = plan_lib.refresh_plan_per_sample(
        plan, q1, k1, sla, thr)
    for r in range(3):
        row = lambda a: jax.tree_util.tree_map(
            lambda leaf: leaf[r:r + 1], a)
        ref_plan, ref_ret, ref_rep = plan_lib.refresh_plan(
            row(plan), q1[r:r + 1], k1[r:r + 1], sla, float(thr[r]))
        assert bool(rep[r]) == bool(ref_rep)
        assert np.float32(ret[r]) == np.float32(ref_ret)
        for a, b in zip(jax.tree_util.tree_leaves(row(new)),
                        jax.tree_util.tree_leaves(ref_plan)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert bool(rep[0]) and not bool(rep[2])  # 0.0 forces, 1.0 pins


def test_forward_scalar_vs_vector_t_bitwise(lightning):
    """Scalar t == uniform (B,) t, bitwise (the ISSUE contract)."""
    cfg, params = lightning
    lat = jnp.asarray(np.stack([_latent(cfg, i) for i in range(2)]))
    a = dit.forward(params, cfg, lat, 0.625, None, jnp.float32, "gather")
    b = dit.forward(params, cfg, lat, jnp.full((2,), 0.625, jnp.float32),
                    None, jnp.float32, "gather")
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the acceptance claim: batched-vs-sequential bitwise parity
# ---------------------------------------------------------------------------
MIXED_TRACE = ((4, 1.0), (3, 1.0), (5, 0.75), (2, 0.5))


def _parity_run(cfg, params, backend, mode, **kw):
    sched = DiffusionScheduler(
        cfg, params, num_slots=2, seq_len=SEQ, backend=backend,
        compute_dtype=jnp.float32, refresh_mode=mode,
        drift_threshold=0.2, **kw)
    for i, (steps, t0) in enumerate(MIXED_TRACE):
        sched.submit(_latent(cfg, i),
                     DenoiseParams(num_steps=steps, t_start=t0))
    mixed_ticks = 0
    while sched.has_work:
        sched.step()
        live = [t for t in sched.active_timesteps() if t is not None]
        if len(set(live)) >= 2:
            mixed_ticks += 1
    # the trace genuinely exercised mixed timesteps inside one batch
    assert mixed_ticks >= 1
    for i, (steps, t0) in enumerate(MIXED_TRACE):
        ref = dit.sample(params, cfg, jnp.asarray(_latent(cfg, i)[None]),
                         num_steps=steps, compute_dtype=jnp.float32,
                         backend=backend, refresh_mode=mode,
                         refresh_interval=2, drift_threshold=0.2,
                         t_start=t0)
        r = sched._requests[i]
        assert r.state == RequestState.FINISHED
        assert np.array_equal(np.asarray(ref[0]), r.result), \
            f"rid {i}: batched != sequential ({backend}, {mode})"
    return sched


def test_batched_vs_sequential_bitwise_gather_adaptive(lightning):
    cfg, params = lightning
    sched = _parity_run(cfg, params, "gather", "adaptive")
    assert sched.stats.admissions == len(MIXED_TRACE)
    assert sched.stats.denoise_steps == sum(s for s, _ in MIXED_TRACE)


@pytest.mark.slow
def test_batched_vs_sequential_bitwise_gather_fixed(lightning):
    cfg, params = lightning
    _parity_run(cfg, params, "gather", "fixed", refresh_interval=2)


@pytest.mark.slow
def test_batched_vs_sequential_bitwise_reference_adaptive(lightning):
    cfg, params = lightning
    _parity_run(cfg, params, "reference", "adaptive")


def test_parity_run_uses_fixed_interval(lightning):
    """fixed-mode scheduler forwards refresh_interval into the per-slot
    0/1 threshold schedule (replans exactly on multiples)."""
    cfg, params = lightning
    sched = DiffusionScheduler(
        cfg, params, num_slots=1, seq_len=SEQ, backend="gather",
        compute_dtype=jnp.float32, refresh_mode="fixed",
        refresh_interval=2)
    sched.submit(_latent(cfg, 0), DenoiseParams(num_steps=5))
    sched.drain()
    # steps 1..4; replans at steps 2 and 4 -> 2 * num_layers
    assert sched.stats.plan_replans == 2 * cfg.num_layers


# ---------------------------------------------------------------------------
# plan-cache drift parity (cached vs fresh within conformance tol)
# ---------------------------------------------------------------------------
def test_plan_cache_drift_parity_and_counters(lightning):
    cfg, params = lightning

    def run(cache):
        sched = DiffusionScheduler(
            cfg, params, num_slots=2, seq_len=SEQ, backend="gather",
            compute_dtype=jnp.float32, refresh_mode="adaptive",
            drift_threshold=0.3, plan_cache=cache)
        for i in range(5):
            sched.submit(_latent(cfg, i), DenoiseParams(num_steps=3))
        sched.drain()
        return sched

    off, on = run(False), run(True)
    # cached-plan outputs equal fresh-plan outputs within the
    # conformance-matrix f32 tolerances (drift below threshold means
    # the cached classification still captures the critical mass)
    for a, b in zip(off._requests, on._requests):
        np.testing.assert_allclose(a.result, b.result, **TOL_F32)
    st = on.stats
    # request 0 misses; the shared-config admissions behind it hit
    assert st.plan_cache_misses >= 1
    assert st.plan_cache_hits >= 1
    assert st.plan_cache_hits + st.plan_cache_misses == 5
    # reuse cut planning: only the miss paid full per-request builds
    assert st.plan_builds < off.stats.plan_builds
    assert off.stats.plan_cache_hits == 0  # cache-off runs no cache


# ---------------------------------------------------------------------------
# registry smoke: both paper DiT configs through sample + scheduler
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["wan2_1_1_3b", "lightningdit_1b"])
def test_registry_dit_smoke_roundtrip(arch):
    cfg = get_arch(arch).smoke()
    assert cfg.family == "dit"
    params = dit.init(jax.random.PRNGKey(0), cfg)
    lat = _latent(cfg, 0)
    cond = (np.asarray(jax.random.normal(
        jax.random.PRNGKey(9), (cfg.cond_len, cfg.d_model), jnp.float32))
        if cfg.cross_attn else None)
    # one dit.sample step under SLA
    one = dit.sample(params, cfg, jnp.asarray(lat[None]), num_steps=1,
                     cond=(jnp.asarray(cond[None])
                           if cond is not None else None),
                     compute_dtype=jnp.float32, backend="gather")
    assert one.shape == (1, SEQ, cfg.patch_dim)
    assert bool(jnp.isfinite(one).all())
    # round-trip through the scheduler: same single step, same result
    sched = DiffusionScheduler(cfg, params, num_slots=1, seq_len=SEQ,
                               backend="gather",
                               compute_dtype=jnp.float32)
    sched.submit(lat, DenoiseParams(num_steps=1), cond=cond)
    done = sched.drain()
    assert len(done) == 1 and done[0].state == RequestState.FINISHED
    assert np.array_equal(np.asarray(one[0]), done[0].result)


# ---------------------------------------------------------------------------
# request surface: metrics, events, validation, stats json
# ---------------------------------------------------------------------------
def test_metrics_none_safe_and_event_order(lightning):
    cfg, params = lightning
    sched = DiffusionScheduler(cfg, params, num_slots=1, seq_len=SEQ,
                               backend="gather",
                               compute_dtype=jnp.float32)
    sched.submit(_latent(cfg, 0), DenoiseParams(num_steps=3))
    sched.submit(_latent(cfg, 1), DenoiseParams(num_steps=2))
    r0, r1 = sched._requests
    # queued: every derived metric is None, never 0.0
    assert r1.metrics.queue_s is None
    assert r1.metrics.ttft_s is None
    assert r1.metrics.latency_s is None
    events = []
    while sched.has_work:
        events.extend(sched.step())
        if r0.state == RequestState.FINISHED and r1.slot is not None:
            # r1 admitted after r0 retired: in-flight metrics None-safe
            assert r1.metrics.latency_s is None
            assert r1.metrics.ttft_s is not None
    for r in (r0, r1):
        m = r.metrics
        assert m.queue_s is not None and m.queue_s >= 0
        assert m.ttft_s is not None and m.latency_s is not None
        assert m.decode_tokens == r.params.num_steps
        kinds = [e.kind for e in events if e.rid == r.rid]
        assert kinds[0] == "start" and kinds[-1] == "finish"
        assert kinds[1:-1] == ["step"] * r.params.num_steps
    # single slot: the second request queued behind the first
    assert r1.metrics.queue_s > 0


def test_submit_validation(lightning):
    cfg, params = lightning
    sched = DiffusionScheduler(cfg, params, num_slots=1, seq_len=SEQ,
                               backend="gather")
    with pytest.raises(ValueError, match="latent shape"):
        sched.submit(np.zeros((SEQ + 1, cfg.patch_dim), np.float32))
    with pytest.raises(ValueError, match="num_steps"):
        DenoiseParams(num_steps=0).validate()
    with pytest.raises(ValueError, match="t_start"):
        DenoiseParams(t_start=1.5).validate()
    with pytest.raises(ValueError, match="cross-attention"):
        sched.submit(np.zeros((SEQ, cfg.patch_dim), np.float32),
                     cond=np.zeros((4, cfg.d_model), np.float32))
    with pytest.raises(ValueError, match="dit family"):
        DiffusionScheduler(get_arch("qwen3-1.7b").smoke(), params)
    with pytest.raises(ValueError, match="multiple"):
        DiffusionScheduler(cfg, params, seq_len=SEQ + 1,
                           backend="gather")


def test_stats_json_payload_none_safe(lightning):
    """The --stats-json schema: in-flight requests dump null derived
    metrics (PR 7 convention), finished ones real numbers."""
    cfg, params = lightning
    sched = DiffusionScheduler(cfg, params, num_slots=1, seq_len=SEQ,
                               backend="gather",
                               compute_dtype=jnp.float32)
    sched.submit(_latent(cfg, 0), DenoiseParams(num_steps=2))
    sched.submit(_latent(cfg, 1), DenoiseParams(num_steps=2))
    import json
    payload = stats_json_payload("dit", sched.stats, sched._requests)
    json.dumps(payload)  # JSON-serializable as-is
    assert payload["mode"] == "dit"
    assert payload["requests"][1]["latency_s"] is None
    assert payload["requests"][1]["state"] == "queued"
    sched.drain()
    payload = stats_json_payload("dit", sched.stats, sched._requests)
    assert payload["stats"]["denoise_steps"] == 4
    for row in payload["requests"]:
        assert row["state"] == "finished"
        assert row["latency_s"] > 0
