"""Compile-count regression guards (ISSUE 6 satellite).

The rolled loops (dit.sample fixed mode, the static engine's segmented
decode, the scheduler's drain) must keep their compiled graphs
horizon-independent: the expensive inner functions trace a CONSTANT
number of times no matter how many steps actually run. Each test
monkeypatches the inner function with a trace-counting wrapper (the
counter bumps at python call time, i.e. only while jax is tracing) and
runs the same loop at two different horizons — the idiom
test_drift.py established for adaptive DiT sampling.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ArchConfig
from repro.core import SLAConfig
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.serving.api import SamplingParams, Scheduler
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# dit.sample fixed-interval mode
# ---------------------------------------------------------------------------
def _dit_cfg():
    return ArchConfig(
        name="dit-test", family="dit", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=0,
        patch_dim=8, cross_attn=False, attention_kind="sla",
        sla=SLAConfig(block_q=16, block_kv=16, kh_frac=0.25,
                      kl_frac=0.25))


def test_dit_fixed_mode_plans_trace_constant(monkeypatch):
    """Rolled fixed-interval sampling traces the planning pipeline
    exactly twice per sample() — the step-0 call plus the lax.cond
    refresh branch — independent of num_steps. The old python loop
    re-traced forward() at every step."""
    from repro.models import dit

    cfg = _dit_cfg()
    params = dit.init(jax.random.PRNGKey(0), cfg)
    calls = []
    orig = plan_lib.plan_attention

    def counted(q, k, c, scale=None, routing=None):
        calls.append(q.shape)
        return orig(q, k, c, scale)

    monkeypatch.setattr(plan_lib, "plan_attention", counted)
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 8))

    for steps in (4, 12):
        calls.clear()
        out = dit.sample(params, cfg, noise, num_steps=steps,
                         refresh_mode="fixed", refresh_interval=2)
        jax.block_until_ready(out)
        assert len(calls) == 2, (steps, len(calls))


def test_dit_plan_free_mode_never_plans(monkeypatch):
    from repro.models import dit

    cfg = dataclasses.replace(_dit_cfg(), attention_kind="full")
    params = dit.init(jax.random.PRNGKey(0), cfg)
    calls = []
    orig = plan_lib.plan_attention

    def counted(q, k, c, scale=None, routing=None):
        calls.append(q.shape)
        return orig(q, k, c, scale)

    monkeypatch.setattr(plan_lib, "plan_attention", counted)
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 8))
    out = dit.sample(params, cfg, noise, num_steps=6)
    jax.block_until_ready(out)
    assert calls == []


# ---------------------------------------------------------------------------
# serving decode loops
# ---------------------------------------------------------------------------
def _llm_cfg():
    cfg = get_arch("qwen3-1.7b").smoke()
    return dataclasses.replace(
        cfg, sla=cfg.sla.replace(kh_frac=1.0, kl_frac=0.0))


def _llm_params(cfg):
    return tfm.init(jax.random.PRNGKey(0), cfg)


def _counted_decode_step(calls):
    orig = tfm.decode_step

    def counted(*args, **kwargs):
        calls.append(True)
        return orig(*args, **kwargs)

    return counted


def test_engine_decode_traces_once_across_budgets(monkeypatch):
    """The static engine's segmented `_decode_loop` (fori_loop over a
    TRACED step count) compiles decode_step exactly once, then serves
    every budget from the same executable."""
    cfg = _llm_cfg()
    params = _llm_params(cfg)
    calls = []
    monkeypatch.setattr(tfm, "decode_step", _counted_decode_step(calls))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=96)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    for budget in (4, 12):
        eng.run([Request(rid=budget, prompt=prompt,
                         max_new_tokens=budget),
                 Request(rid=budget + 1, prompt=prompt,
                         max_new_tokens=budget)])
    assert len(calls) == 1, len(calls)


def test_scheduler_drain_traces_once_across_budgets(monkeypatch):
    """Scheduler.drain()'s rolled `_decode_multi` compiles decode_step
    exactly once across heterogeneous greedy budgets and separate
    drains (per-token `step()` fallback never fires for pure greedy
    token-budget requests)."""
    cfg = _llm_cfg()
    params = _llm_params(cfg)
    calls = []
    monkeypatch.setattr(tfm, "decode_step", _counted_decode_step(calls))
    sched = Scheduler(cfg, params, num_slots=2, max_len=96)
    rng = np.random.default_rng(1)
    for budget in (4, 9):
        sched.submit(rng.integers(0, cfg.vocab_size, size=32)
                     .astype(np.int32),
                     SamplingParams(max_new_tokens=budget))
    reqs = sched.drain()
    assert all(len(r.tokens_out) == r.sampling.max_new_tokens
               for r in reqs)
    first = len(calls)
    assert first == 1, first
    sched.submit(rng.integers(0, cfg.vocab_size, size=32)
                 .astype(np.int32), SamplingParams(max_new_tokens=13))
    sched.drain()
    assert len(calls) == first  # same executable, third horizon


def test_scheduler_mixed_drain_traces_constant(monkeypatch):
    """Mixed drains (sampling + greedy slots) partition into the masked
    single-step path for controlled slots plus the masked rolled loop
    for greedy slots — at most 3 traces of decode_step total
    (_decode_mask, _decode_multi_mask, _decode_multi), and NO retraces
    on a second mixed drain with different budgets. The old _drain_tick
    dropped EVERY slot to per-token step() whenever any active slot
    sampled."""
    cfg = _llm_cfg()
    params = _llm_params(cfg)
    calls = []
    monkeypatch.setattr(tfm, "decode_step", _counted_decode_step(calls))
    sched = Scheduler(cfg, params, num_slots=2, max_len=96)
    rng = np.random.default_rng(2)

    def load(sample_budget, greedy_budget):
        sched.submit(rng.integers(0, cfg.vocab_size, size=32)
                     .astype(np.int32),
                     SamplingParams(max_new_tokens=sample_budget,
                                    temperature=0.8, seed=7))
        sched.submit(rng.integers(0, cfg.vocab_size, size=32)
                     .astype(np.int32),
                     SamplingParams(max_new_tokens=greedy_budget))

    load(3, 8)
    reqs = sched.drain()
    assert all(len(r.tokens_out) == r.sampling.max_new_tokens
               for r in reqs)
    first = len(calls)
    assert first <= 3, first
    load(5, 16)
    sched.drain()
    assert len(calls) == first  # same executables at new horizons
