"""End-to-end behaviour tests: the paper's workflow at toy scale.

The core claim (paper §5 + Table 2): a full-attention-pretrained model
fine-tuned briefly with SLA recovers its loss, and SLA beats the
sparse-only / linear-only ablations at the same budget."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.core.config import SLAConfig
from repro.data.pipeline import DataConfig, latent_batch
from repro.models import dit
from repro.optim import adamw


def _cfg(mode):
    from repro.configs.base import ArchConfig
    return ArchConfig(
        name="dit-test", family="dit", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=0,
        patch_dim=8, cross_attn=False,
        attention_kind="full" if mode == "full" else "sla",
        sla=SLAConfig(block_q=16, block_kv=16, kh_frac=0.125,
                      kl_frac=0.25, mode="sla"))


def _train(cfg, params, steps, seed, sla_mode=None, lr=1e-3):
    shape = ShapeConfig("d", 128, 4, "train")
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=2,
                                schedule="constant")
    opt = adamw.init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(
            lambda p: dit.loss_fn(p, cfg, b, sla_mode=sla_mode))(p)
        p, o, _ = adamw.update(p, g, o, opt_cfg)
        return p, o, loss

    dc = DataConfig(seed=seed)
    hist = []
    for s in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in latent_batch(cfg, shape, dc, s).items()}
        params, opt, loss = step(params, opt, batch)
        hist.append(float(loss))
    return params, hist


def _eval_loss(cfg, params, sla_mode=None, batches=4, seed=10_000):
    """Held-out evaluation on FIXED batches (trailing train loss is too
    noisy for flow matching: every step draws new t ~ U)."""
    shape = ShapeConfig("d", 128, 4, "train")
    dc = DataConfig(seed=seed)
    total = 0.0
    for s in range(batches):
        batch = {k: jnp.asarray(v)
                 for k, v in latent_batch(cfg, shape, dc, s).items()}
        total += float(dit.loss_fn(params, cfg, batch, sla_mode=sla_mode))
    return total / batches


@pytest.fixture(scope="module")
def pretrained():
    cfg = _cfg("full")
    params = dit.init(jax.random.PRNGKey(0), cfg)
    init_eval = _eval_loss(cfg, params)
    params, hist = _train(cfg, params, 60, seed=0, lr=3e-3)
    return cfg, params, init_eval


def test_pretraining_learns(pretrained):
    cfg, params, init_eval = pretrained
    final_eval = _eval_loss(cfg, params)
    # rank-8 latents bound the learnable fraction at this tiny scale;
    # 60 steps @ 3e-3 lands ~13% below the untrained eval loss
    assert final_eval < init_eval * 0.92, (init_eval, final_eval)


def test_sla_finetune_recovers_loss(pretrained):
    """The paper's headline mechanism: swapping in SLA + a few fine-tune
    steps stays close to the full-attention loss."""
    cfg_full, params, _ = pretrained
    full_eval = _eval_loss(cfg_full, params)
    cfg = _cfg("sla")
    zero_shot = _eval_loss(cfg, params, sla_mode="sla")
    ft, _ = _train(cfg, jax.tree.map(jnp.copy, params), 40,
                   seed=1, sla_mode="sla", lr=5e-4)
    sla_eval = _eval_loss(cfg, ft, sla_mode="sla")
    assert sla_eval < full_eval * 1.5, (full_eval, sla_eval)
    # fine-tuning improved over the zero-shot swap
    assert sla_eval <= zero_shot + 1e-5, (zero_shot, sla_eval)


def test_sla_beats_linear_only_at_same_budget(pretrained):
    cfg_full, params, _ = pretrained
    cfg = _cfg("sla")
    sla_ft, _ = _train(cfg, jax.tree.map(jnp.copy, params), 30,
                       seed=2, sla_mode="sla", lr=5e-4)
    lin_ft, _ = _train(cfg, jax.tree.map(jnp.copy, params), 30,
                       seed=2, sla_mode="linear_only", lr=5e-4)
    sla_eval = _eval_loss(cfg, sla_ft, sla_mode="sla")
    lin_eval = _eval_loss(cfg, lin_ft, sla_mode="linear_only")
    assert sla_eval <= lin_eval * 1.05, (sla_eval, lin_eval)


def test_train_driver_end_to_end(tmp_path):
    """The launch/train.py driver: run, checkpoint, resume."""
    from repro.launch.train import main
    losses = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "6",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
                   "--log-every", "100"])
    assert len(losses) == 6
    losses2 = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "8",
                    "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    assert len(losses2) == 2  # resumed from step 6


def test_serving_engine_end_to_end():
    import numpy as np
    from repro.configs import get_arch
    from repro.models import registry
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch("internvl2-1b").smoke()
    cfg = dataclasses.replace(cfg, family="dense", frontend="none",
                              num_patches=0)
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rs.integers(
        0, cfg.vocab_size, size=32).astype(np.int32),
        max_new_tokens=4 + i % 3) for i in range(4)]
    engine = ServingEngine(cfg, params, batch_size=2, max_len=64)
    done = engine.run(reqs)
    assert all(len(r.tokens_out) == r.max_new_tokens for r in done)
    assert engine.stats.decode_tokens > 0
