"""Finite-difference gradient checks for the kernels/ops custom_vjp
(ISSUE 2 satellite).

Two layers of evidence, for both fresh and stale (reused) plans:
  1. the hand-written custom_vjp matches core/reference.py autodiff on
     the same plan, and
  2. both match central finite differences of the loss itself.

The stale-plan case is the load-bearing one for plan reuse: gradients
must flow through *execution* on the fixed block structure, never
through planning (the plan is a constant, as in the paper — TopK is not
differentiated).

Shapes are deliberately tiny (B=H=1, N=64, D=8) but the FD sweeps are
O(#inputs x #directions) forward passes, so the module is marked slow
(scripts/ci.sh runs it in the second tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SLAConfig, plan_attention, sla_attention, sla_init
from repro.core.phi import phi
from repro.kernels.ops import sla_attention_core
from repro.kernels.ref import sla_attention_core_reference

pytestmark = pytest.mark.slow

EPS = 3e-2  # central-difference step (f32 sweet spot, calibrated)
NAMES = ("q", "k", "v", "qp", "kp")


def _setup(seed, stale):
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    b, h, n, d = 1, 1, 64, 8
    rs = jax.random.split(jax.random.PRNGKey(seed), 8)
    q, k, v = (jax.random.normal(r, (b, h, n, d)) for r in rs[:3])
    plan = plan_attention(q, k, cfg)
    if stale:
        # the plan stays; the inputs move on (cross-timestep reuse)
        q = q + 0.3 * jax.random.normal(rs[5], q.shape)
        k = k + 0.3 * jax.random.normal(rs[6], k.shape)
    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
    ws = jax.random.normal(rs[3], (b, h, n, d))
    wl = jax.random.normal(rs[4], (b, h, n, d))

    def loss_kernel(q, k, v, qp, kp):
        o_s, o_l = sla_attention_core(q, k, v, qp, kp, plan, cfg)
        return jnp.sum(o_s * ws) + jnp.sum(o_l * wl)

    def loss_reference(q, k, v, qp, kp):
        o_s, o_l = sla_attention_core_reference(q, k, v, qp, kp, plan.mc,
                                                cfg)
        return jnp.sum(o_s * ws) + jnp.sum(o_l * wl)

    return (q, k, v, qp, kp), plan, cfg, loss_kernel, loss_reference


@pytest.mark.parametrize("stale", [False, True],
                         ids=["fresh-plan", "stale-plan"])
def test_custom_vjp_matches_reference_autodiff(stale):
    inputs, _, _, loss_k, loss_r = _setup(0, stale)
    gk = jax.grad(loss_k, argnums=tuple(range(5)))(*inputs)
    gr = jax.grad(loss_r, argnums=tuple(range(5)))(*inputs)
    for name, a, b in zip(NAMES, gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4, err_msg=name)


@pytest.mark.parametrize("stale", [False, True],
                         ids=["fresh-plan", "stale-plan"])
def test_custom_vjp_matches_finite_differences(stale):
    """Directional central differences vs the analytic custom_vjp, every
    differentiable input, 3 random directions each."""
    inputs, _, _, loss_k, _ = _setup(1, stale)
    grads = jax.grad(loss_k, argnums=tuple(range(5)))(*inputs)
    loss_jit = jax.jit(loss_k)
    for i, (x, g, name) in enumerate(zip(inputs, grads, NAMES)):
        for s in range(3):
            dvec = jax.random.normal(jax.random.PRNGKey(100 + 10 * i + s),
                                     x.shape)
            dvec = dvec / jnp.linalg.norm(dvec)
            plus = list(inputs)
            plus[i] = x + EPS * dvec
            minus = list(inputs)
            minus[i] = x - EPS * dvec
            fd = (loss_jit(*plus) - loss_jit(*minus)) / (2 * EPS)
            an = jnp.vdot(g, dvec)
            err = abs(float(fd) - float(an))
            tol = 2e-2 * abs(float(an)) + 3e-4
            assert err <= tol, (
                f"{name} dir {s}: fd={float(fd):.6g} "
                f"analytic={float(an):.6g} err={err:.3g} > tol={tol:.3g}")


def test_gradients_flow_through_execution_not_planning():
    """d loss / d q must be identical whether the plan is (a) precomputed
    and passed in or (b) planned inline from (q, k): planning is
    gradient-stopped, so the only gradient path is execution."""
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                    proj_init="identity")
    rs = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(r, (1, 2, 64, 8)) for r in rs)
    params = sla_init(jax.random.PRNGKey(0), 2, 8, cfg)
    plan = plan_attention(q, k, cfg)

    def loss_fixed(q, k, v):
        return jnp.sum(sla_attention(params, q, k, v, cfg,
                                     backend="kernel", plan=plan) ** 2)

    def loss_inline(q, k, v):
        return jnp.sum(sla_attention(params, q, k, v, cfg,
                                     backend="kernel") ** 2)

    gf = jax.grad(loss_fixed, argnums=(0, 1, 2))(q, k, v)
    gi = jax.grad(loss_inline, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gf, gi):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, err_msg=f"d{name}")
        assert bool(jnp.isfinite(a).all())
