"""Pallas kernel validation (interpret mode): backward-kernel and
property sweeps against the pure-jnp oracle. Forward backend parity
(dtype x causal x fresh/reused plan, incl. phi variants) lives in the
table-driven matrix in test_conformance.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import SLAConfig, compute_mask
from repro.core.phi import phi
from repro.kernels.ops import sla_attention_core
from repro.kernels.ref import sla_attention_core_reference


def _inputs(seed, b, h, n, d, dtype, causal, block=16, kh=0.25, kl=0.25,
            phi_kind="softmax"):
    rs = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (jax.random.normal(r, (b, h, n, d), dtype) * 1.3 for r in rs)
    cfg = SLAConfig(block_q=block, block_kv=block, kh_frac=kh, kl_frac=kl,
                    causal=causal, phi=phi_kind)
    qp = phi(q, cfg.phi).astype(dtype)
    kp = phi(k, cfg.phi).astype(dtype)
    mc = compute_mask(q, k, cfg)
    return q, k, v, qp, kp, mc, cfg


SWEEP = [
    # (b, h, n, d, dtype, causal, block)
    (1, 1, 64, 16, jnp.float32, False, 16),
    (2, 2, 128, 32, jnp.float32, True, 16),
    (1, 2, 128, 64, jnp.float32, False, 32),
    (2, 1, 256, 16, jnp.bfloat16, False, 32),
    (1, 2, 128, 32, jnp.bfloat16, True, 16),
    (1, 4, 128, 8, jnp.float32, True, 32),  # tiny head dim
]


@pytest.mark.parametrize("b,h,n,d,dtype,causal,block", SWEEP[:4])
def test_bwd_matches_oracle(b, h, n, d, dtype, causal, block):
    q, k, v, qp, kp, mc, cfg = _inputs(1, b, h, n, d, dtype, causal, block)

    def loss_k(q, k, v, qp, kp):
        a, b_ = sla_attention_core(q, k, v, qp, kp, mc, cfg)
        return jnp.sum(jnp.sin(a.astype(jnp.float32))) + \
            jnp.sum(jnp.cos(b_.astype(jnp.float32)))

    def loss_r(q, k, v, qp, kp):
        a, b_ = sla_attention_core_reference(q, k, v, qp, kp, mc, cfg)
        return jnp.sum(jnp.sin(a.astype(jnp.float32))) + \
            jnp.sum(jnp.cos(b_.astype(jnp.float32)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(q, k, v, qp, kp)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(q, k, v, qp, kp)
    tol = 5e-4 if dtype == jnp.float32 else 0.12
    for name, a, b_ in zip("dq dk dv dqp dkp".split(), gk, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32),
            atol=tol, rtol=tol, err_msg=name)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), kh=st.sampled_from([0.1, 0.25, 0.5]),
       kl=st.sampled_from([0.0, 0.25]), causal=st.booleans())
def test_property_kernel_equals_oracle(seed, kh, kl, causal):
    q, k, v, qp, kp, mc, cfg = _inputs(seed, 1, 2, 64, 16, jnp.float32,
                                       causal, 16, kh, kl)
    os_k, ol_k = sla_attention_core(q, k, v, qp, kp, mc, cfg)
    os_r, ol_r = sla_attention_core_reference(q, k, v, qp, kp, mc, cfg)
    np.testing.assert_allclose(np.asarray(os_k), np.asarray(os_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ol_k), np.asarray(ol_r),
                               atol=1e-4, rtol=1e-4)


def test_kernel_under_jit_and_vmapless_batching():
    q, k, v, qp, kp, mc, cfg = _inputs(3, 2, 3, 128, 32, jnp.float32,
                                       False, 32)
    f = jax.jit(lambda *a: sla_attention_core(*a, mc, cfg))
    o1 = f(q, k, v, qp, kp)
    o2 = sla_attention_core(q, k, v, qp, kp, mc, cfg)
    np.testing.assert_allclose(np.asarray(o1[0]), np.asarray(o2[0]),
                               atol=1e-6)
