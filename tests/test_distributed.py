"""Distributed tests via subprocesses (fake host devices — must NOT
pollute the main test process's device count)."""
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": f"{ROOT}/src:{ROOT}",
           "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/root",
           # fake-device children must never try to init a real
           # accelerator (stripped env + installed libtpu hangs on TPU
           # metadata discovery; host-device fakes need the cpu platform)
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """SLA train step on a (2,4) mesh == single-device result."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, get_shape
        from repro.models import registry
        from repro.distributed.sharding import (param_shardings,
                                                batch_shardings)
        from repro.launch.mesh import make_host_mesh

        cfg = get_arch("qwen3-1.7b").smoke()
        shape = get_shape("train_4k", smoke=True)
        mdl = registry.get_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = mdl.init(rng, cfg)
        batch = registry.make_concrete_batch(rng, cfg, shape)

        loss_1dev = mdl.loss_fn(params, cfg, batch)

        mesh = make_host_mesh(2, 4)
        p_sh = param_shardings(mesh, jax.eval_shape(lambda: params))
        b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch),
                               shape.global_batch)
        params_d = jax.device_put(params, p_sh)
        batch_d = jax.device_put(batch, b_sh)
        with mesh:
            loss_8dev = jax.jit(
                lambda p, b: mdl.loss_fn(p, cfg, b))(params_d, batch_d)
        np.testing.assert_allclose(float(loss_1dev), float(loss_8dev),
                                   rtol=2e-2)
        print("OK", float(loss_1dev), float(loss_8dev))
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (4,2) mesh, restore onto (2,2) with 4 devices — the
    elastic-scaling contract."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint.manager import CheckpointManager
        from repro.distributed.sharding import param_shardings
        from repro.launch.mesh import make_host_mesh

        rng = jax.random.PRNGKey(0)
        params = {"layers": {"wq": jax.random.normal(rng, (2, 16, 32)),
                             "mlp_wi": jax.random.normal(rng, (2, 16, 64))}}
        mesh_a = make_host_mesh(4, 2)
        sh_a = param_shardings(mesh_a, jax.eval_shape(lambda: params))
        params_a = jax.device_put(params, sh_a)

        tmp = tempfile.mkdtemp()
        mgr = CheckpointManager(tmp)
        mgr.save(1, params_a, blocking=True)

        mesh_b = make_host_mesh(2, 2)
        sh_b = param_shardings(mesh_b, jax.eval_shape(lambda: params))
        restored = mgr.restore(1, params, shardings=sh_b)
        np.testing.assert_allclose(np.asarray(restored["layers"]["wq"]),
                                   np.asarray(params["layers"]["wq"]))
        specs = restored["layers"]["wq"].sharding.spec
        print("OK", specs)
    """)
    assert "OK" in out


def test_dryrun_cell_compiles_on_8_devices():
    """A miniature dry-run: lower + compile a train cell on a (2,4) mesh
    with abstract inputs, and extract roofline terms."""
    out = _run("""
        import jax, json
        from repro.configs import get_arch, get_shape
        from repro.launch.dryrun import build_cell
        from repro.launch.mesh import make_host_mesh
        from repro.roofline.analysis import collective_bytes
        from repro.roofline.hlo_cost import xla_cost_analysis

        cfg = get_arch("qwen3-1.7b").smoke()
        shape = get_shape("train_4k", smoke=True)
        mesh = make_host_mesh(2, 4)
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
        with mesh:
            c = jax.jit(fn, in_shardings=in_sh,
                        out_shardings=out_sh).lower(*args).compile()
            cost = xla_cost_analysis(c)
            coll = collective_bytes(c.as_text())
        assert cost.get("flops", 0) > 0
        assert coll["count"] >= 0
        print("OK flops", cost["flops"], "coll", coll["total"])
    """)
    assert "OK" in out


def test_decode_cell_with_cache_sharding():
    out = _run("""
        import jax
        from repro.configs import get_arch, get_shape
        from repro.launch.dryrun import build_cell
        from repro.launch.mesh import make_host_mesh

        cfg = get_arch("qwen3-1.7b").smoke()
        shape = get_shape("decode_32k", smoke=True)
        mesh = make_host_mesh(2, 4)
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
        with mesh:
            c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=(2,)).lower(*args).compile()
        print("OK", c.memory_analysis().temp_size_in_bytes)
    """)
    assert "OK" in out


def test_gradient_agreement_dp_vs_single():
    """Data-parallel gradients == single-device gradients (allreduce
    correctness through GSPMD)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, get_shape
        from repro.models import registry
        from repro.distributed.sharding import (param_shardings,
                                                batch_shardings)
        from repro.launch.mesh import make_host_mesh

        cfg = get_arch("internvl2-1b").smoke()
        shape = get_shape("train_4k", smoke=True)
        mdl = registry.get_model(cfg)
        rng = jax.random.PRNGKey(1)
        params = mdl.init(rng, cfg)
        batch = registry.make_concrete_batch(rng, cfg, shape)
        g1 = jax.grad(lambda p: mdl.loss_fn(p, cfg, batch))(params)

        mesh = make_host_mesh(4, 1)
        p_sh = param_shardings(mesh, jax.eval_shape(lambda: params))
        b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch),
                               shape.global_batch)
        with mesh:
            g8 = jax.jit(jax.grad(
                lambda p, b: mdl.loss_fn(p, cfg, b)))(
                jax.device_put(params, p_sh),
                jax.device_put(batch, b_sh))
        l1 = jax.tree_util.tree_leaves(g1)
        l8 = jax.tree_util.tree_leaves(g8)
        worst = max(float(jnp.abs(a - b).max()) for a, b in zip(l1, l8))
        assert worst < 5e-2, worst
        print("OK", worst)
    """, devices=4)
    assert "OK" in out
