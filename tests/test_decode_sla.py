"""Decode-time SLA (ISSUE 3): incremental block plans + O(1) linear-state
decode.

Four pillars:
  * property tests — `plan_extend` appended row-by-row reproduces
    `plan_from_mask` of the full mask, row-local classification matches
    the full classifier, and the running H/Z state equals a recompute
    from the KV cache after N decoded tokens;
  * the decode parity matrix — SLA decode vs dense decode vs one-shot
    `forward` on the same tokens, across backend x dtype x
    fresh/extended plan (exact greedy-token equality at f32 on
    saturating toy configs, conformance-style tolerances otherwise);
  * engine integration — ServingEngine with decode-SLA on: identical
    greedy tokens vs dense decode plus decode-plan build/extend/replan
    accounting in ServeStats;
  * the FLOPs model — per-token decode attention cost is
    critical-blocks + O(1), independent of context length.

Long parity sweeps carry @pytest.mark.slow (scripts/ci.sh --decode runs
them in a second pass).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.core import (SLAConfig, classify_blocks, classify_row,
                        compute_mask, empty_plan, plan_extend,
                        plan_from_mask, pool_blocks, predict_pc,
                        predict_pc_row, resolve_decode)
from repro.core.flops import dense_decode_flops, sla_decode_flops
from repro.core.phi import phi
from repro.models import transformer as tfm

TOL_F32 = dict(atol=5e-5, rtol=5e-5)
TOL_BF16 = dict(atol=5e-2, rtol=5e-2)


# ---------------------------------------------------------------------------
# property tests: plan_extend == plan_from_mask on the full mask
# ---------------------------------------------------------------------------
def _decode_cfg(**kw):
    base = dict(block_q=16, block_kv=16, causal=True, kl_frac=0.0,
                col_capacity_factor=None, fixed_budget=2)
    base.update(kw)
    return SLAConfig(**base)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), causal=st.booleans())
def test_plan_extend_reproduces_plan_from_mask(seed, causal):
    """Appending rows block-by-block == planning the full mask at once:
    exact on mc/lut/counts/col_counts/marginal, and on live col_lut
    slots (dead padding differs by contract; no backend reads it)."""
    cfg = _decode_cfg(causal=causal)
    rq, rk = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(rq, (1, 2, 128, 16))
    k = jax.random.normal(rk, (1, 2, 128, 16))
    mc = compute_mask(q, k, cfg)
    tm, tn = mc.shape[-2:]
    full = plan_from_mask(mc, cfg)
    plan = empty_plan(cfg, 1, 2, tm, tn)
    for r in range(tm):
        plan = plan_extend(plan, mc[..., r, :], r)
    np.testing.assert_array_equal(np.asarray(plan.mc), np.asarray(mc))
    np.testing.assert_array_equal(np.asarray(plan.lut),
                                  np.asarray(full.lut))
    np.testing.assert_array_equal(np.asarray(plan.counts),
                                  np.asarray(full.counts))
    np.testing.assert_array_equal(np.asarray(plan.col_counts),
                                  np.asarray(full.col_counts))
    np.testing.assert_array_equal(np.asarray(plan.marginal),
                                  np.asarray(full.marginal))
    live = np.arange(full.w_col) < np.asarray(full.col_counts)[..., None]
    np.testing.assert_array_equal(
        np.where(live, np.asarray(plan.col_lut), 0),
        np.where(live, np.asarray(full.col_lut), 0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_plan_extend_partial_prefix(seed):
    """A partially-extended plan equals plan_from_mask of the mask with
    unwritten rows forced all-negligible (the mid-decode state)."""
    cfg = _decode_cfg()
    rq, rk = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(rq, (1, 1, 96, 16))
    k = jax.random.normal(rk, (1, 1, 96, 16))
    mc = np.asarray(compute_mask(q, k, cfg))
    tm, tn = mc.shape[-2:]
    cut = tm // 2
    masked = mc.copy()
    masked[..., cut:, :] = -1
    full = plan_from_mask(jnp.asarray(masked), cfg)
    plan = empty_plan(cfg, 1, 1, tm, tn)
    for r in range(cut):
        plan = plan_extend(plan, jnp.asarray(mc[..., r, :]), r)
    np.testing.assert_array_equal(np.asarray(plan.mc), masked)
    np.testing.assert_array_equal(np.asarray(plan.counts),
                                  np.asarray(full.counts))
    np.testing.assert_array_equal(np.asarray(plan.col_counts),
                                  np.asarray(full.col_counts))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_row_local_classification_matches_full(seed):
    """classify_row / predict_pc_row equal the `row` slice of the full
    classifier — the invariance that makes incremental planning exact."""
    cfg = _decode_cfg()
    rq, rk = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(rq, (1, 2, 128, 16))
    k = jax.random.normal(rk, (1, 2, 128, 16))
    pc = predict_pc(q, k, cfg)
    mc = classify_blocks(pc, cfg)
    qp, kp = pool_blocks(q, cfg.block_q), pool_blocks(k, cfg.block_kv)
    for r in range(pc.shape[-2]):
        np.testing.assert_allclose(
            np.asarray(predict_pc_row(qp[..., r, :], kp, r, cfg)),
            np.asarray(pc[..., r, :]), atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(classify_row(pc[..., r, :], r, cfg)),
            np.asarray(mc[..., r, :]))


def test_classification_invariant_to_grid_width():
    """With a fixed budget and kl_frac=0 the row classification does not
    depend on how many (causally invalid) trailing blocks the static
    grid carries — the static-grid embedding is exact."""
    cfg = _decode_cfg(fixed_budget=3)
    rq, rk = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(rq, (1, 2, 96, 16))
    k = jax.random.normal(rk, (1, 2, 96, 16))
    mc_small = np.asarray(compute_mask(q, k, cfg))
    pad = jnp.zeros((1, 2, 96, 16))
    mc_big = np.asarray(compute_mask(
        jnp.concatenate([q, pad], axis=2),
        jnp.concatenate([k, pad], axis=2), cfg))
    np.testing.assert_array_equal(mc_big[..., :6, :6], mc_small)


# ---------------------------------------------------------------------------
# decode harness
# ---------------------------------------------------------------------------
def _arch(kh=1.0, kl=0.0, decode_budget=None, drift=0.1, num_layers=2):
    cfg = get_arch("qwen3-1.7b").smoke()
    return dataclasses.replace(
        cfg, num_layers=num_layers,
        sla=cfg.sla.replace(kh_frac=kh, kl_frac=kl, decode_mode="sla",
                            decode_budget=decode_budget,
                            plan_drift_threshold=drift))


def _params(cfg, proj_scale=0.3):
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    # a non-zero Proj makes the linear branch (and its empty-marginal
    # gating) observable in logits
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) \
        * proj_scale
    return params


def _greedy(cfg, params, toks, steps, max_len, dtype, sla, backend="gather"):
    """Greedy decode; returns (tokens (T, B), logits (T, B, V), cache)."""
    if sla:
        last, cache = tfm.prefill(params, cfg, toks, compute_dtype=dtype,
                                  decode_max_len=max_len)
    else:
        last, cache = tfm.prefill(params, cfg, toks, compute_dtype=dtype)
        pad = max_len - toks.shape[1]
        cache = {"pos": cache["pos"],
                 "k": jnp.pad(cache["k"],
                              [(0, 0)] * 3 + [(0, pad), (0, 0)]),
                 "v": jnp.pad(cache["v"],
                              [(0, 0)] * 3 + [(0, pad), (0, 0)])}
    step = jax.jit(functools.partial(tfm.decode_step, compute_dtype=dtype,
                                     backend=backend),
                   static_argnums=(1,))
    table = params.get("unembed", params["embed"])
    tok = jnp.argmax(jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                                table.astype(jnp.float32)), -1) \
        .astype(jnp.int32)
    out_t, out_l = [], []
    for _ in range(steps):
        out_t.append(np.asarray(tok))
        logits, cache = step(params, cfg, tok, cache)
        out_l.append(np.asarray(logits, np.float32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(out_t), np.stack(out_l), cache


def _forward_greedy_chain(cfg, params, full_toks, plen, dtype):
    """Teacher-forced one-shot forward over prompt+decoded tokens;
    returns the greedy chain tokens from position plen-1 on."""
    x, _ = tfm.forward(params, cfg, jnp.asarray(full_toks),
                       compute_dtype=dtype)
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    return np.asarray(jnp.argmax(logits[:, plen - 1:-1], -1)).T


# fresh: decode stays inside the first post-prompt block (no plan_extend
# call fires); extended: decode crosses block boundaries and the plan
# grows row-by-row mid-flight.
PARITY = [
    pytest.param(backend, dtype, plan_state,
                 id=f"{backend}-{dtype}-{plan_state}")
    for backend in ("reference", "gather", "kernel")
    for dtype in ("f32", "bf16")
    for plan_state in ("fresh", "extended")
]


@pytest.mark.parametrize("backend,dtype,plan_state", PARITY)
def test_decode_parity_matrix(backend, dtype, plan_state):
    """SLA decode vs dense decode vs one-shot forward on a saturating
    toy config (every valid block critical, so all three compute exact
    causal attention): greedy tokens identical at f32, conformance
    tolerances on logits; bf16 matches within bf16 tolerances."""
    cfg = _arch(kh=1.0, kl=0.0)
    params = _params(cfg)
    plen, max_len = 32, 96
    steps = 16 if plan_state == "fresh" else 32
    dt = jnp.float32 if dtype == "f32" else jnp.bfloat16
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, plen), 0,
                              cfg.vocab_size)
    sla_t, sla_l, cache = _greedy(cfg, params, toks, steps, max_len, dt,
                                  sla=True, backend=backend)
    dense_t, dense_l, _ = _greedy(cfg, params, toks, steps, max_len, dt,
                                  sla=False)
    if dtype == "f32":
        np.testing.assert_array_equal(sla_t, dense_t)
        np.testing.assert_allclose(sla_l, dense_l, atol=2e-4, rtol=2e-4)
        # one-shot forward over the same tokens reproduces the chain
        full = np.concatenate([np.asarray(toks), sla_t.T], axis=1)
        fwd_t = _forward_greedy_chain(cfg, params, full, plen, dt)
        np.testing.assert_array_equal(sla_t, fwd_t)
    else:
        np.testing.assert_allclose(sla_l, dense_l, **TOL_BF16)
    st_ = cache["sla"]
    expect_ext = 0 if plan_state == "fresh" else cfg.num_layers
    assert int(np.sum(np.asarray(st_["extends"]))) == expect_ext
    assert int(st_["rows"]) == plen // cfg.sla.block_q + (
        0 if plan_state == "fresh" else 1)


def test_decode_backends_agree_non_saturating():
    """reference vs gather decode execution on a genuinely sparse
    config: same plan/state evolution, same outputs (f32)."""
    cfg = _arch(kh=0.25, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 48), 0,
                              cfg.vocab_size)
    ref_t, ref_l, _ = _greedy(cfg, params, toks, 24, 96, jnp.float32,
                              sla=True, backend="reference")
    gat_t, gat_l, _ = _greedy(cfg, params, toks, 24, 96, jnp.float32,
                              sla=True, backend="gather")
    np.testing.assert_allclose(gat_l, ref_l, **TOL_F32)
    np.testing.assert_array_equal(gat_t, ref_t)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_running_hz_state_matches_recompute(seed):
    """After N decoded tokens the running per-block h_j/z_j partials and
    their totals equal a recompute sum phi(k) v^T over the KV cache."""
    cfg = _arch(kh=0.25, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, 32), 0,
                              cfg.vocab_size)
    _, _, cache = _greedy(cfg, params, toks, 21, 96, jnp.float32,
                          sla=True)
    pos = int(cache["pos"])
    st_ = cache["sla"]
    bkv = cfg.sla.block_kv
    kc, vc = cache["k"], cache["v"]  # (L, B, Hkv, S, D)
    written = (jnp.arange(kc.shape[-2]) < pos)[:, None]
    kp = phi(kc, cfg.sla.phi) * written
    vb = vc.astype(jnp.float32) * written
    tn = kc.shape[-2] // bkv
    kpb = kp.reshape(*kp.shape[:-2], tn, bkv, kp.shape[-1])
    vbb = vb.reshape(*vb.shape[:-2], tn, bkv, vb.shape[-1])
    hblk = jnp.einsum("...nkd,...nke->...nde", kpb, vbb)
    zblk = jnp.sum(kpb, axis=-2)
    np.testing.assert_allclose(np.asarray(st_["hblk"]), np.asarray(hblk),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_["zblk"]), np.asarray(zblk),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_["htot"]),
                               np.asarray(jnp.sum(hblk, axis=3)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_["ztot"]),
                               np.asarray(jnp.sum(zblk, axis=3)),
                               atol=1e-4, rtol=1e-4)


def test_decode_per_layer_drift_thresholds():
    """Per-layer thresholds gate the live-row refresh layer-by-layer:
    threshold 0.0 re-plans at every block boundary, 1.0 never does."""
    cfg = _arch(kh=0.25, kl=0.0, drift=(0.0, 1.0))
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 32), 0,
                              cfg.vocab_size)
    _, _, cache = _greedy(cfg, params, toks, 40, 96, jnp.float32,
                          sla=True)
    st_ = cache["sla"]
    reps = np.asarray(st_["replans"])
    reuses = np.asarray(st_["reuses"])
    boundaries = reps + reuses
    assert boundaries[0] == boundaries[1] > 0
    assert reps[0] == boundaries[0] and reuses[0] == 0
    assert reps[1] == 0 and reuses[1] == boundaries[1]


@pytest.mark.slow
def test_decode_parity_long_sweep():
    """Long parity sweep: GQA + 80 decoded tokens crossing five block
    boundaries, exact greedy-token parity at f32."""
    cfg = _arch(kh=1.0, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 48), 0,
                              cfg.vocab_size)
    sla_t, sla_l, cache = _greedy(cfg, params, toks, 80, 192,
                                  jnp.float32, sla=True)
    dense_t, dense_l, _ = _greedy(cfg, params, toks, 80, 192,
                                  jnp.float32, sla=False)
    np.testing.assert_array_equal(sla_t, dense_t)
    np.testing.assert_allclose(sla_l, dense_l, atol=5e-4, rtol=5e-4)
    assert int(np.sum(np.asarray(cache["sla"]["extends"]))) == \
        4 * cfg.num_layers


# ---------------------------------------------------------------------------
# engine integration (ISSUE 3 satellite)
# ---------------------------------------------------------------------------
def test_engine_decode_sla_matches_dense_and_counts():
    from repro.serving.engine import Request, ServingEngine

    cfg = _arch(kh=1.0, kl=0.0)
    params = _params(cfg)
    rs = np.random.default_rng(0)

    def mk():
        return [Request(rid=i,
                        prompt=rs.integers(0, cfg.vocab_size, size=32)
                        .astype(np.int32),
                        max_new_tokens=20) for i in range(4)]

    rs = np.random.default_rng(0)
    dense_cfg = dataclasses.replace(
        cfg, sla=cfg.sla.replace(decode_mode="dense"))
    dense = ServingEngine(dense_cfg, params, batch_size=2,
                          max_len=96).run(mk())
    rs = np.random.default_rng(0)
    engine = ServingEngine(cfg, params, batch_size=2, max_len=96,
                           decode_sla=True)
    done = engine.run(mk())
    for ra, rb in zip(dense, done):
        assert ra.tokens_out == rb.tokens_out
    st_ = engine.stats
    nl, groups = cfg.num_layers, 2
    # prompt rows planned once per group prefill; one boundary appends
    # (pos 48) and two boundaries (pos 32, 48) init the live row
    assert st_.decode_plan_builds == groups * nl
    assert st_.decode_plan_extends == groups * nl
    assert st_.decode_plan_replans + st_.decode_plan_reuses == \
        2 * groups * nl
    assert 0.0 <= st_.decode_last_retention <= 1.0


def test_engine_decode_sla_requires_capable_family():
    from repro.serving.engine import ServingEngine

    cfg = get_arch("rwkv6-7b").smoke()
    with pytest.raises(ValueError, match="decode_sla"):
        ServingEngine(cfg, params=None, decode_sla=True)


def test_engine_rounds_max_len_to_block_grid():
    from repro.serving.engine import ServingEngine

    cfg = _arch()
    engine = ServingEngine(cfg, _params(cfg), batch_size=2, max_len=70,
                           decode_sla=True)
    assert engine.max_len % cfg.sla.block_q == 0
    assert engine.max_len >= 70


# ---------------------------------------------------------------------------
# FLOPs: critical-blocks + O(1) linear term instead of O(S)
# ---------------------------------------------------------------------------
def test_decode_flops_independent_of_context_length():
    cfg = SLAConfig(block_q=64, block_kv=64, causal=True, kl_frac=0.0,
                    decode_budget=8, fixed_budget=8)
    f1 = sla_decode_flops(8192, 64, 8, cfg)
    f2 = sla_decode_flops(65536, 64, 8, cfg)
    for key in ("sparse", "state", "linear", "proj"):
        assert f1[key] == f2[key], key
    assert f2["dense"] == 8 * f1["dense"]
    assert f2["reduction_x"] > 4 * f1["reduction_x"]
    # the only context-dependent term is the amortized boundary planning
    assert f2["total"] - f1["total"] == pytest.approx(
        f2["plan"] - f1["plan"])
    # and dense decode is O(S)
    assert dense_decode_flops(65536, 64, 8) == 8 * dense_decode_flops(
        8192, 64, 8)


# ---------------------------------------------------------------------------
# loud failures
# ---------------------------------------------------------------------------
def test_resolve_decode_fails_loudly():
    assert resolve_decode("gather") == "gather"
    assert resolve_decode("kernel") == "kernel"  # real fused Pallas path
    assert resolve_decode("pallas") == "kernel"
    assert resolve_decode("xla") == "gather"
    assert resolve_decode("dense") == "reference"
    with pytest.raises(ValueError, match="unknown SLA decode backend"):
        resolve_decode("cuda")


def test_prefill_rejects_unaligned_decode_grid():
    cfg = _arch()
    params = _params(cfg)
    toks = jnp.zeros((1, 30), jnp.int32)  # not a multiple of block_q=16
    with pytest.raises(ValueError, match="block-aligned"):
        tfm.prefill(params, cfg, toks, decode_max_len=96)
    with pytest.raises(ValueError, match="block-aligned"):
        tfm.prefill(params, cfg, jnp.zeros((1, 32), jnp.int32),
                    decode_max_len=90)


def test_prefill_rejects_window_constrained_decode():
    """The subtractive linear state cannot exclude out-of-window blocks;
    window-constrained SLA must fail loudly instead of silently
    diverging from prefill numerics."""
    cfg = _arch()
    cfg = dataclasses.replace(cfg, sla=cfg.sla.replace(window=32))
    params = _params(cfg)
    with pytest.raises(ValueError, match="window"):
        tfm.prefill(params, cfg, jnp.zeros((1, 32), jnp.int32),
                    decode_max_len=96)
    with pytest.raises(ValueError, match="window"):
        tfm.make_cache(dataclasses.replace(
            _arch(), sliding_window=64), 1, 96, decode_sla=True)


# ---------------------------------------------------------------------------
# fused decode kernel + chunked decode (ISSUE 6)
# ---------------------------------------------------------------------------
def _layer0_state(cache):
    """Per-layer decode state for backends.decode_execute (layer 0)."""
    st_ = cache["sla"]
    return {"k": cache["k"][0], "v": cache["v"][0],
            "hblk": st_["hblk"][0], "zblk": st_["zblk"][0],
            "htot": st_["htot"][0], "ztot": st_["ztot"][0],
            "lut": st_["live_lut"][0], "cnt": st_["live_cnt"][0],
            "marg": st_["live_marg"][0]}


def test_kernel_decode_matches_gather_non_saturating():
    """Fused Pallas decode vs the gather/einsum chain on a genuinely
    sparse config: identical greedy chains, conformance-tight logits."""
    cfg = _arch(kh=0.25, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 48), 0,
                              cfg.vocab_size)
    gat_t, gat_l, _ = _greedy(cfg, params, toks, 24, 96, jnp.float32,
                              sla=True, backend="gather")
    ker_t, ker_l, _ = _greedy(cfg, params, toks, 24, 96, jnp.float32,
                              sla=True, backend="kernel")
    np.testing.assert_allclose(ker_l, gat_l, **TOL_F32)
    np.testing.assert_array_equal(ker_t, gat_t)


def test_kernel_decode_gradients_match_gather():
    """Learned-routing gradients flow through the fused kernel's
    custom_vjp: d loss / d {q, k, v, hblk, zblk, htot, ztot} matches the
    gather backend's plain autodiff, and none of them are zero."""
    from repro.core import backends as backend_lib

    cfg = _arch(kh=0.25, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 32), 0,
                              cfg.vocab_size)
    _, _, cache = _greedy(cfg, params, toks, 21, 96, jnp.float32, sla=True)
    state = _layer0_state(cache)
    # a non-empty marginal set, else the linear-branch grads are
    # legitimately zero and the flow assertion below is vacuous
    assert int(np.sum(np.asarray(state["marg"]))) > 0
    pos = cache["pos"]
    dcfg = cfg.sla.decode_plan_cfg(state["k"].shape[-2]
                                   // cfg.sla.block_kv)
    q = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.num_heads, 1, cfg.head_dim), jnp.float32)
    proj = {"proj": params["layers"]["sla_proj"][0]}
    w = jnp.cos(jnp.arange(q.shape[0] * cfg.num_heads * cfg.head_dim,
                           dtype=jnp.float32))

    def loss(q, k, v, hblk, zblk, htot, ztot, backend):
        st = dict(state, k=k, v=v, hblk=hblk, zblk=zblk, htot=htot,
                  ztot=ztot)
        o = backend_lib.decode_execute(st, proj, q, pos, dcfg,
                                       backend=backend)
        return jnp.sum(o.astype(jnp.float32).reshape(-1) * w)

    args = (q, state["k"].astype(jnp.float32),
            state["v"].astype(jnp.float32), state["hblk"], state["zblk"],
            state["htot"], state["ztot"])
    g_gat = jax.grad(loss, argnums=tuple(range(7)))(*args, "gather")
    g_ker = jax.grad(loss, argnums=tuple(range(7)))(*args, "kernel")
    for a, b_ in zip(g_ker, g_gat):
        assert float(jnp.max(jnp.abs(a))) > 0.0
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5, rtol=1e-5)


def _chunk_setup(cfg, params, toks, max_len, sla, warm=0, backend="gather"):
    """Prefill (+ `warm` decode steps); returns (cache, next_token)."""
    if sla:
        last, cache = tfm.prefill(params, cfg, toks,
                                  compute_dtype=jnp.float32,
                                  decode_max_len=max_len)
    else:
        last, cache = tfm.prefill(params, cfg, toks,
                                  compute_dtype=jnp.float32)
        pad = max_len - toks.shape[1]
        cache = {"pos": cache["pos"],
                 "k": jnp.pad(cache["k"],
                              [(0, 0)] * 3 + [(0, pad), (0, 0)]),
                 "v": jnp.pad(cache["v"],
                              [(0, 0)] * 3 + [(0, pad), (0, 0)])}
    table = params.get("unembed", params["embed"])
    tok = jnp.argmax(jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                                table.astype(jnp.float32)), -1) \
        .astype(jnp.int32)
    for _ in range(warm):
        logits, cache = tfm.decode_step(params, cfg, tok, cache,
                                        compute_dtype=jnp.float32,
                                        backend=backend)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return cache, tok


CHUNK_CASES = [
    pytest.param(True, "gather", id="sla-gather"),
    pytest.param(True, "kernel", id="sla-kernel"),
    pytest.param(False, "gather", id="dense"),
]


@pytest.mark.parametrize("warm", [0, 5], ids=["fresh", "mid"])
@pytest.mark.parametrize("sla,backend", CHUNK_CASES)
def test_decode_chunk_matches_steps(sla, backend, warm):
    """decode_chunk over C tokens is BITWISE-identical (f32) to C
    decode_step calls — fresh after prefill and mid-sequence, with
    decode-SLA on (gather + fused kernel) and off (dense cache). The
    diagonal-substitution protocol (DESIGN.md "Fused decode kernel")
    makes every H_marg term the per-token value, so logits and the
    full post-chunk cache match exactly, not just within tolerance."""
    cfg = _arch(kh=0.5, kl=0.0)
    params = _params(cfg)
    cdim = 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0,
                              cfg.vocab_size)
    cache, tok = _chunk_setup(cfg, params, toks, 128, sla, warm, backend)
    step = jax.jit(functools.partial(tfm.decode_step,
                                     compute_dtype=jnp.float32,
                                     backend=backend),
                   static_argnums=(1,))
    fed, step_l, c_step, t = [], [], cache, tok
    for _ in range(cdim):
        fed.append(np.asarray(t))
        logits, c_step = step(params, cfg, t, c_step)
        step_l.append(np.asarray(logits, np.float32))
        t = jnp.argmax(logits, -1).astype(jnp.int32)
    step_l = np.stack(step_l, axis=1)                 # (B, C, V)
    fed = jnp.asarray(np.stack(fed, axis=1))          # (B, C)
    chunk_l, c_chunk = tfm.decode_chunk(params, cfg, fed, cache,
                                        compute_dtype=jnp.float32,
                                        backend=backend)
    np.testing.assert_array_equal(np.asarray(chunk_l, np.float32), step_l)
    ls, ts = jax.tree_util.tree_flatten(c_step)
    lc, tc = jax.tree_util.tree_flatten(c_chunk)
    assert ts == tc
    for a, b_ in zip(ls, lc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_decode_chunk_split_matches_whole():
    """`chunk=` sub-chunking changes launch shapes, not tokens: the
    greedy chain is identical and logits agree to f32 tolerance."""
    cfg = _arch(kh=0.5, kl=0.0)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 32), 0,
                              cfg.vocab_size)
    cache, _ = _chunk_setup(cfg, params, toks, 128, sla=True)
    feed = jax.random.randint(jax.random.PRNGKey(6), (1, 21), 0,
                              cfg.vocab_size)
    l_whole, _ = tfm.decode_chunk(params, cfg, feed, cache,
                                  compute_dtype=jnp.float32)
    l_split, _ = tfm.decode_chunk(params, cfg, feed, cache,
                                  compute_dtype=jnp.float32, chunk=7)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l_whole, -1)),
                                  np.asarray(jnp.argmax(l_split, -1)))
    np.testing.assert_allclose(np.asarray(l_split), np.asarray(l_whole),
                               **TOL_F32)


def test_decode_chunk_rejects_vector_pos():
    cfg = _arch()
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    cache, _ = _chunk_setup(cfg, params, toks, 96, sla=True)
    cache = dict(cache, pos=jnp.broadcast_to(cache["pos"], (2,)))
    with pytest.raises(ValueError, match="scalar"):
        tfm.decode_chunk(params, cfg, toks[:, :4], cache)
