"""Drift-adaptive plan refresh (ISSUE 2 tentpole): the plan_drift /
plan_retention metric, the lax.cond refresh machinery, the adaptive DiT
sampler, and serving-prefill plan reuse.

Property tests use tests/_hypothesis_compat (real hypothesis when
installed, a deterministic fixed-sample sweep otherwise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig
from repro.core import (SLAConfig, plan_attention, plan_drift,
                        plan_retention, refresh_plan)
from repro.core import plan as plan_lib


def _cfg(**kw):
    base = dict(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    base.update(kw)
    return SLAConfig(**base)


def _qk(seed, b=1, h=2, n=128, d=16):
    rq, rk = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(rq, (b, h, n, d)),
            jax.random.normal(rk, (b, h, n, d)))


# ---------------------------------------------------------------------------
# plan_retention / plan_drift properties
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_retention_is_one_when_inputs_unchanged(seed):
    cfg = _cfg()
    q, k = _qk(seed)
    plan = plan_attention(q, k, cfg)
    r = plan_retention(plan, q, k, cfg)
    assert r.shape == q.shape[:2]
    np.testing.assert_allclose(np.asarray(r), 1.0, atol=1e-6)
    assert float(jnp.max(plan_drift(plan, q, k, cfg))) <= 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(0.01, 10.0),
      causal=st.booleans())
def test_retention_always_in_unit_interval(seed, scale, causal):
    """Even against completely unrelated (q, k), retention is a valid
    fraction — the adaptive controller can always trust its range."""
    cfg = _cfg(causal=causal)
    q0, k0 = _qk(seed)
    plan = plan_attention(q0, k0, cfg)
    q, k = _qk(seed + 1)
    r = plan_retention(plan, q * scale, k * scale, cfg)
    assert float(jnp.min(r)) >= 0.0
    assert float(jnp.max(r)) <= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_retention_non_increasing_under_growing_perturbation(seed):
    """Retention decays (within estimator noise) as (q, k) move further
    from the plan's snapshot along a fixed direction, and ends below the
    identity value."""
    cfg = _cfg()
    q, k = _qk(seed)
    plan = plan_attention(q, k, cfg)
    dq, dk = _qk(seed + 7)
    alphas = [0.0, 0.25, 0.5, 1.0, 2.0]
    rets = [float(jnp.mean(plan_retention(
        plan, q + a * dq, k + a * dk, cfg))) for a in alphas]
    assert rets[0] == pytest.approx(1.0, abs=1e-6)
    for lo, hi in zip(rets[1:], rets[:-1]):
        # the metric is a mass ratio, not a strict Lyapunov function —
        # allow small local wiggle but require the trend
        assert lo <= hi + 0.05, rets
    assert rets[-1] < rets[0], rets


def test_refresh_plan_threshold_semantics():
    """drift >= threshold triggers the rebuild: 0.0 re-plans always
    (even at zero drift), 1.0 never re-plans."""
    cfg = _cfg()
    q, k = _qk(0)
    plan = plan_attention(q, k, cfg)
    _, ret, rep = refresh_plan(plan, q, k, cfg, 0.0)
    assert bool(rep) and float(ret) == pytest.approx(1.0)
    _, ret, rep = refresh_plan(plan, q, k, cfg, 1.0)
    assert not bool(rep)
    # a drifted plan under a mid threshold rebuilds to the fresh structure
    q2, k2 = _qk(99)
    new_plan, ret, rep = refresh_plan(plan, q2, k2, cfg, 0.3)
    if bool(rep):
        fresh = plan_attention(q2, k2, cfg)
        np.testing.assert_array_equal(np.asarray(new_plan.mc),
                                      np.asarray(fresh.mc))
    else:
        np.testing.assert_array_equal(np.asarray(new_plan.mc),
                                      np.asarray(plan.mc))


# ---------------------------------------------------------------------------
# adaptive DiT sampling
# ---------------------------------------------------------------------------
def _dit_cfg(**sla_kw):
    sla = dict(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25)
    sla.update(sla_kw)
    return ArchConfig(
        name="dit-test", family="dit", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=0,
        patch_dim=8, cross_attn=False, attention_kind="sla",
        sla=SLAConfig(**sla))


def _dit_params(cfg):
    from repro.models import dit
    params = dit.init(jax.random.PRNGKey(0), cfg)
    # zero-init output head -> zero velocity -> frozen trajectory; give
    # the sampler real movement so plans can actually drift
    params["patch_out"] = jax.random.normal(
        jax.random.PRNGKey(7), params["patch_out"].shape) * 0.5
    return params


def test_adaptive_sampling_threshold_extremes():
    """threshold=0 re-plans every layer every step; threshold=1 plans
    exactly once (the mandatory step-0 planning) — counted with the
    runtime replan flags, the scanned analogue of the layer-plans-once
    counter in test_plan.py."""
    from repro.models import dit
    cfg = _dit_cfg()
    params = _dit_params(cfg)
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 8))
    steps, nl = 4, cfg.num_layers

    _, tr = dit.sample(params, cfg, noise, num_steps=steps,
                       refresh_mode="adaptive", drift_threshold=0.0,
                       return_trace=True)
    assert bool(tr["replanned"].all())
    assert int(tr["replan_count"].sum()) == (steps - 1) * nl

    _, tr = dit.sample(params, cfg, noise, num_steps=steps,
                       refresh_mode="adaptive", drift_threshold=1.0,
                       return_trace=True)
    assert int(tr["replan_count"].sum()) == 0
    assert not bool(tr["replanned"].any())


def test_adaptive_sampling_is_jit_compatible_and_data_dependent(
        monkeypatch):
    """Acceptance: jit sample() once; re-plan counts then vary with the
    input noise (and with a *traced* threshold) without any retrace —
    no python-level re-plan branching exists in the scanned body."""
    from repro.models import dit
    cfg = _dit_cfg()
    params = _dit_params(cfg)
    steps, nl = 4, cfg.num_layers

    calls = []
    orig = plan_lib.plan_attention

    def counted(q, k, c, scale=None, routing=None):
        calls.append(q.shape)
        return orig(q, k, c, scale)

    monkeypatch.setattr(plan_lib, "plan_attention", counted)

    jitted = jax.jit(lambda noise, thr: dit.sample(
        params, cfg, noise, num_steps=steps, refresh_mode="adaptive",
        drift_threshold=thr, return_trace=True))

    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 8))
    _, tr = jitted(noise, jnp.float32(0.0))
    traced_calls = len(calls)
    # the full planning pipeline is traced exactly once (the step-0
    # scan body) no matter how often re-planning *runs*: the lax.cond
    # refresh branch rebuilds from the drift metric's classification
    # (plan_from_mask) instead of re-entering plan_attention
    assert traced_calls == 1
    assert int(tr["replan_count"].sum()) == (steps - 1) * nl

    _, tr = jitted(noise, jnp.float32(1.0))
    assert len(calls) == traced_calls  # same trace: threshold is traced
    assert int(tr["replan_count"].sum()) == 0

    # drift-dependence: same jitted fn, same mid threshold, different
    # noise -> different measured drift -> different re-plan counts
    thr = jnp.float32(0.05)
    slow_noise = noise * 5.0   # sharp P_c, stable structure
    fast_noise = noise * 0.05  # diffuse P_c, structure churns
    c_slow = int(jitted(slow_noise, thr)[1]["replan_count"].sum())
    c_fast = int(jitted(fast_noise, thr)[1]["replan_count"].sum())
    assert len(calls) == traced_calls
    assert c_fast > c_slow, (c_fast, c_slow)


def test_adaptive_matches_every_step_replanning_at_threshold_zero():
    """threshold=0 adaptive sampling is numerically the exact paper
    behavior (re-plan every step == fixed refresh_interval=1)."""
    from repro.models import dit
    cfg = _dit_cfg()
    params = _dit_params(cfg)
    noise = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 8))
    out_fixed = dit.sample(params, cfg, noise, num_steps=3,
                           refresh_mode="fixed", refresh_interval=1)
    out_adapt, _ = dit.sample(params, cfg, noise, num_steps=3,
                              refresh_mode="adaptive",
                              drift_threshold=0.0, return_trace=True)
    np.testing.assert_allclose(np.asarray(out_fixed),
                               np.asarray(out_adapt), atol=2e-5,
                               rtol=2e-5)


def test_drift_thresholds_helper_normalizes_per_layer():
    """SLAConfig.plan_drift_threshold accepts a per-layer tuple (ISSUE 3
    satellite: per-layer, not min-reduced)."""
    assert _cfg().drift_thresholds(3) == (0.1, 0.1, 0.1)
    cfg = _cfg(plan_drift_threshold=(0.0, 0.5))
    assert cfg.drift_thresholds(2) == (0.0, 0.5)
    with pytest.raises(ValueError, match="2 entries"):
        cfg.drift_thresholds(3)


def test_per_layer_drift_thresholds_gate_layers_independently():
    """threshold (0.0, 1.0): layer 0 re-plans every adaptive step, layer
    1 never — each layer's decision uses its own threshold instead of
    one min-reduced scalar for the whole stack."""
    from repro.models import dit
    cfg = _dit_cfg()
    params = _dit_params(cfg)
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 8))
    steps = 4
    _, tr = dit.sample(params, cfg, noise, num_steps=steps,
                       refresh_mode="adaptive",
                       drift_threshold=jnp.asarray([0.0, 1.0]),
                       return_trace=True)
    reps = np.asarray(tr["replanned"])  # (steps-1, L)
    assert reps[:, 0].all() and not reps[:, 1].any()
    assert list(np.asarray(tr["replan_count"])) == [steps - 1, 0]
    # the same per-layer thresholds flow from the config default
    import dataclasses as dc
    cfg2 = dc.replace(cfg, sla=cfg.sla.replace(
        plan_drift_threshold=(0.0, 1.0), plan_refresh_mode="adaptive"))
    _, tr2 = dit.sample(params, cfg2, noise, num_steps=steps,
                        refresh_mode="adaptive", return_trace=True)
    assert list(np.asarray(tr2["replan_count"])) == [steps - 1, 0]


def test_per_layer_thresholds_in_lm_prefill_refresh():
    """transformer.forward threads per-layer thresholds through the
    layer scan: with (0.0, 1.0) only layer 0 refreshes on reuse."""
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    cfg = get_arch("qwen3-1.7b").smoke()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                              cfg.vocab_size)
    toks2 = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0,
                               cfg.vocab_size)
    *_, plans = tfm.prefill(params, cfg, toks, return_plans=True)
    *_, info = tfm.prefill(params, cfg, toks2, plans=plans,
                           drift_threshold=jnp.asarray([0.0, 1.0]),
                           return_plans=True)
    rep = np.asarray(info["replanned"])
    assert rep[0] and not rep[1]


def test_sample_rejects_unknown_refresh_mode():
    from repro.models import dit
    cfg = _dit_cfg()
    params = _dit_params(cfg)
    noise = jnp.zeros((1, 64, 8))
    with pytest.raises(ValueError, match="plan_refresh_mode"):
        dit.sample(params, cfg, noise, num_steps=2,
                   refresh_mode="sometimes")


# ---------------------------------------------------------------------------
# serving-prefill plan reuse
# ---------------------------------------------------------------------------
def test_serving_prefill_plan_reuse_across_chunks():
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.models import registry
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch("qwen3-1.7b").smoke()
    cfg = dc.replace(cfg, sla=cfg.sla.replace(plan_drift_threshold=0.5))
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rs.integers(0, cfg.vocab_size, size=24 + i)
                    .astype(np.int32),
                    max_new_tokens=3) for i in range(6)]
    engine = ServingEngine(cfg, params, batch_size=2, max_len=96,
                           plan_reuse="adaptive")
    done = engine.run(reqs)
    assert all(len(r.tokens_out) == r.max_new_tokens for r in done)
    st_ = engine.stats
    nl = cfg.num_layers
    # chunk 1 builds every layer's plan; chunks 2-3 reuse or re-plan
    assert st_.plan_builds == nl
    assert st_.plan_reuses + st_.plan_replans == 2 * nl
    assert 0.0 <= st_.last_retention <= 1.0
    # the shared bucket is one whole number of SLA blocks
    assert engine._bucket % cfg.sla.block_q == 0
    assert engine._bucket >= max(len(r.prompt) for r in reqs)


def test_serving_prefill_reuse_matches_fresh_outputs():
    """Plan reuse must not change served tokens when structure is
    retained: same requests, plan_reuse off vs adaptive, same outputs
    (prompts are padded to the same bucket for a like-for-like run)."""
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.models import registry
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch("qwen3-1.7b").smoke()
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.default_rng(1)

    def mk():
        return [Request(rid=i,
                        prompt=rs.integers(0, cfg.vocab_size, size=32)
                        .astype(np.int32),
                        max_new_tokens=3) for i in range(4)]

    rs = np.random.default_rng(1)
    a = ServingEngine(cfg, params, batch_size=2, max_len=64,
                      plan_reuse="off").run(mk())
    rs = np.random.default_rng(1)
    # threshold 0 -> re-plan every chunk -> numerically identical to off
    b = ServingEngine(cfg, params, batch_size=2, max_len=64,
                      plan_reuse="adaptive",
                      drift_threshold=0.0).run(mk())
    for ra, rb in zip(a, b):
        assert ra.tokens_out == rb.tokens_out
