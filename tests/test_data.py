"""Data pipeline: determinism, restartability, host-sharding."""
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import (DataConfig, latent_batch, make_iterator,
                                 token_batch)

SHAPE = ShapeConfig("t", 64, 4, "train")


def test_deterministic_same_step():
    cfg = get_arch("qwen3-1.7b").smoke()
    a = token_batch(cfg, SHAPE, DataConfig(seed=1), step=5)
    b = token_batch(cfg, SHAPE, DataConfig(seed=1), step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_different_steps_differ():
    cfg = get_arch("qwen3-1.7b").smoke()
    a = token_batch(cfg, SHAPE, DataConfig(seed=1), step=5)
    b = token_batch(cfg, SHAPE, DataConfig(seed=1), step=6)
    assert (a["tokens"] != b["tokens"]).any()


def test_hosts_produce_disjoint_streams():
    cfg = get_arch("qwen3-1.7b").smoke()
    a = token_batch(cfg, SHAPE, DataConfig(seed=1, num_hosts=2, host_id=0),
                    step=3)
    b = token_batch(cfg, SHAPE, DataConfig(seed=1, num_hosts=2, host_id=1),
                    step=3)
    assert a["tokens"].shape[0] == SHAPE.global_batch // 2
    assert (a["tokens"] != b["tokens"]).any()


def test_restart_mid_stream_is_bit_identical():
    """Resume-from-step-k yields the same batches as never stopping —
    the property that makes checkpoint-restart deterministic."""
    cfg = get_arch("qwen3-1.7b").smoke()
    it = make_iterator(cfg, SHAPE, DataConfig(seed=2))
    batches = [next(it) for _ in range(6)]
    it2 = make_iterator(cfg, SHAPE, DataConfig(seed=2), start_step=4)
    resumed = next(it2)
    np.testing.assert_array_equal(batches[4]["tokens"], resumed["tokens"])


def test_targets_are_next_tokens():
    cfg = get_arch("qwen3-1.7b").smoke()
    b = token_batch(cfg, SHAPE, DataConfig(seed=3), step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_latent_batch_structure():
    cfg = get_arch("wan2_1_1_3b").smoke()
    b = latent_batch(cfg, ShapeConfig("d", 64, 4, "train"),
                     DataConfig(seed=0), 0)
    assert b["latents"].shape == (4, 64, cfg.patch_dim)
    assert b["noise"].shape == b["latents"].shape
    assert ((b["t"] > 0) & (b["t"] < 1)).all()
    assert "cond" in b  # wan has cross-attn
