"""Cross-backend conformance matrix (ISSUE 2 satellite).

One table-driven suite asserting that every execution backend
(reference / gather / kernel-interpret) computes the same forward
attention, across dtypes (f32 / bf16), causal / non-causal masks, and
fresh vs reused (stale) plans — replacing the ad-hoc parity asserts
that used to live in test_plan.py / test_kernels.py. Run standalone via
`scripts/ci.sh --conformance`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLAConfig, get_backend, plan_attention, resolve,
                        sla_attention, sla_init)
from repro.core.phi import phi

BACKENDS = ("reference", "gather", "kernel")
DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}
# Per-dtype tolerances: f32 disagreement is numerical noise; bf16 adds
# ~3 decimal digits of input rounding on top.
TOL = {"f32": dict(atol=5e-5, rtol=5e-5),
       "bf16": dict(atol=5e-2, rtol=5e-2)}


def _cfg(causal, phi_kind="softmax"):
    return SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.25,
                     causal=causal, phi=phi_kind, proj_init="identity")


def _case(seed, dtype, causal, plan_state, phi_kind="softmax",
          b=1, h=2, n=128, d=16):
    """Returns (plan, q, k, v, qp, kp, cfg) for one matrix cell.

    plan_state "fresh": plan built from the very (q, k) being executed.
    plan_state "reused": plan built from an earlier (q0, k0), then the
    inputs move on — the cross-timestep / cross-chunk serving situation;
    backends must still agree on the *stale* structure.
    """
    cfg = _cfg(causal, phi_kind)
    rs = jax.random.split(jax.random.PRNGKey(seed), 6)
    q0, k0 = (jax.random.normal(r, (b, h, n, d), dtype) for r in rs[:2])
    plan = plan_attention(q0, k0, cfg)
    if plan_state == "reused":
        q = q0 + 0.3 * jax.random.normal(rs[2], q0.shape, dtype)
        k = k0 + 0.3 * jax.random.normal(rs[3], k0.shape, dtype)
    else:
        q, k = q0, k0
    v = jax.random.normal(rs[4], (b, h, n, d), dtype)
    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
    return plan, q, k, v, qp, kp, cfg


MATRIX = [
    pytest.param(backend, dtype, causal, plan_state,
                 id=f"{backend}-{dtype}-"
                    f"{'causal' if causal else 'bidir'}-{plan_state}")
    for backend in BACKENDS if backend != "reference"
    for dtype in DTYPES
    for causal in (False, True)
    for plan_state in ("fresh", "reused")
]


@pytest.mark.parametrize("backend,dtype,causal,plan_state", MATRIX)
def test_backend_forward_conformance(backend, dtype, causal, plan_state):
    """(O^s, O^l) of every backend match the dense reference oracle."""
    plan, q, k, v, qp, kp, cfg = _case(0, DTYPES[dtype], causal,
                                       plan_state)
    os_r, ol_r = get_backend("reference")(plan, q, k, v, qp, kp, cfg, None)
    os_b, ol_b = get_backend(backend)(plan, q, k, v, qp, kp, cfg, None)
    np.testing.assert_allclose(np.asarray(os_b, np.float32),
                               np.asarray(os_r, np.float32),
                               **TOL[dtype], err_msg=f"{backend} O^s")
    np.testing.assert_allclose(np.asarray(ol_b, np.float32),
                               np.asarray(ol_r, np.float32),
                               **TOL[dtype], err_msg=f"{backend} O^l")


@pytest.mark.parametrize("backend,dtype,causal,plan_state", MATRIX)
def test_public_api_conformance(backend, dtype, causal, plan_state):
    """Same matrix through the public sla_attention (Proj merge, Eq. 6)."""
    plan, q, k, v, _, _, cfg = _case(1, DTYPES[dtype], causal, plan_state)
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1], cfg)
    out_r = sla_attention(params, q, k, v, cfg, backend="reference",
                          plan=plan)
    out_b = sla_attention(params, q, k, v, cfg, backend=backend, plan=plan)
    np.testing.assert_allclose(np.asarray(out_b, np.float32),
                               np.asarray(out_r, np.float32),
                               **TOL[dtype], err_msg=backend)


@pytest.mark.parametrize("phi_kind", ["elu1", "relu"])
@pytest.mark.parametrize("backend", [b for b in BACKENDS
                                     if b != "reference"])
def test_phi_variant_conformance(backend, phi_kind):
    """Linear-branch feature-map variants agree across backends (f32)."""
    plan, q, k, v, qp, kp, cfg = _case(2, jnp.float32, False, "fresh",
                                       phi_kind=phi_kind)
    _, ol_r = get_backend("reference")(plan, q, k, v, qp, kp, cfg, None)
    _, ol_b = get_backend(backend)(plan, q, k, v, qp, kp, cfg, None)
    np.testing.assert_allclose(np.asarray(ol_b), np.asarray(ol_r),
                               **TOL["f32"], err_msg=phi_kind)


@pytest.mark.parametrize("backend", BACKENDS)
def test_reused_plan_matches_fresh_when_inputs_unchanged(backend):
    """If (q, k) have not moved, executing on a reused plan is exactly
    executing on a fresh plan — the plan-reuse numerics contract."""
    plan, q, k, v, _, _, cfg = _case(3, jnp.float32, False, "fresh")
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1], cfg)
    v2 = v + 0.25  # fresh values; the structure depends only on (q, k)
    out_reused = sla_attention(params, q, k, v2, cfg, backend=backend,
                               plan=plan)
    out_fresh = sla_attention(params, q, k, v2, cfg, backend=backend)
    np.testing.assert_allclose(np.asarray(out_reused),
                               np.asarray(out_fresh), atol=1e-6)


# Forward shape/block sweep (the coverage the old ad-hoc
# test_kernels.test_fwd_matches_oracle carried): batch/head counts,
# sequence lengths, head dims incl. tiny d=8, and both block sizes.
SHAPE_SWEEP = [
    # (b, h, n, d, dtype, causal, block)
    (1, 1, 64, 16, jnp.float32, False, 16),
    (2, 2, 128, 32, jnp.float32, True, 16),
    (1, 2, 128, 64, jnp.float32, False, 32),
    (2, 1, 256, 16, jnp.bfloat16, False, 32),
    (1, 2, 128, 32, jnp.bfloat16, True, 16),
    (1, 4, 128, 8, jnp.float32, True, 32),  # tiny head dim
]


@pytest.mark.parametrize("backend", [b for b in BACKENDS
                                     if b != "reference"])
@pytest.mark.parametrize("b,h,n,d,dtype,causal,block", SHAPE_SWEEP)
def test_backend_shape_sweep(backend, b, h, n, d, dtype, causal, block):
    cfg = SLAConfig(block_q=block, block_kv=block, kh_frac=0.25,
                    kl_frac=0.25, causal=causal)
    rs = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(r, (b, h, n, d), dtype) * 1.3
               for r in rs)
    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
    plan = plan_attention(q, k, cfg)
    os_r, ol_r = get_backend("reference")(plan, q, k, v, qp, kp, cfg, None)
    os_b, ol_b = get_backend(backend)(plan, q, k, v, qp, kp, cfg, None)
    tol = TOL["f32" if dtype == jnp.float32 else "bf16"]
    np.testing.assert_allclose(np.asarray(os_b, np.float32),
                               np.asarray(os_r, np.float32), **tol,
                               err_msg=f"{backend} O^s")
    np.testing.assert_allclose(np.asarray(ol_b, np.float32),
                               np.asarray(ol_r, np.float32), **tol,
                               err_msg=f"{backend} O^l")


def test_gqa_conformance():
    """KV-head broadcast (GQA) agrees across backends via the public API."""
    cfg = _cfg(False)
    rs = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(rs[0], (1, 4, 128, 16))
    k = jax.random.normal(rs[1], (1, 2, 128, 16))
    v = jax.random.normal(rs[2], (1, 2, 128, 16))
    params = sla_init(jax.random.PRNGKey(0), 4, 16, cfg)
    plan = plan_attention(q, k, cfg)
    out_r = sla_attention(params, q, k, v, cfg, backend="reference",
                          plan=plan)
    for backend in ("gather", "kernel"):
        out_b = sla_attention(params, q, k, v, cfg, backend=backend,
                              plan=plan)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r),
                                   **TOL["f32"], err_msg=backend)


# ---------------------------------------------------------------------------
# loud failure on unknown backend names — one resolve() error path
# ---------------------------------------------------------------------------
def test_resolve_canonicalizes_and_fails_loudly():
    assert resolve("gather") == "gather"
    assert resolve("pallas") == "kernel"  # legacy alias
    assert resolve("dense") == "reference"
    with pytest.raises(ValueError, match="unknown SLA backend"):
        resolve("cuda")


def test_drivers_fail_loudly_on_unknown_backend():
    """fig6 / quickstart / serving resolve the backend at entry — no
    silent fallback, no deep-in-jit failure."""
    import benchmarks.fig6_kernel_speed as fig6
    import examples.quickstart as quickstart
    with pytest.raises(ValueError, match="unknown SLA backend"):
        fig6.run(backend="does-not-exist")
    with pytest.raises(ValueError, match="unknown SLA backend"):
        quickstart.main(backend="does-not-exist")
    from repro.launch import serve
    with pytest.raises(ValueError, match="unknown SLA backend"):
        serve.main(["--arch", "qwen3-1.7b", "--smoke",
                    "--backend", "does-not-exist"])
    from repro.serving.engine import ServingEngine
    from repro.configs import get_arch
    with pytest.raises(ValueError, match="unknown SLA backend"):
        ServingEngine(get_arch("qwen3-1.7b").smoke(), params=None,
                      backend="does-not-exist")
    with pytest.raises(ValueError, match="unknown plan_reuse"):
        ServingEngine(get_arch("qwen3-1.7b").smoke(), params=None,
                      plan_reuse="sometimes")


# ---------------------------------------------------------------------------
# decode conformance (ISSUE 6): one-token decode backends
# ---------------------------------------------------------------------------
def _decode_state(seed, dt, posv):
    """Self-consistent per-layer decode state: random KV cache, per-slot
    positions `posv`, a LUT whose rows (incl. the forced diagonal) stay
    inside each slot's valid prefix, and H/Z partials recomputed from
    the written tokens — the invariants transformer.decode_step
    maintains, built directly so the matrix stays core-only."""
    from repro.core.backends import _group_heads  # noqa: F401 (layout doc)

    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.5, kl_frac=0.0,
                    causal=True, decode_mode="sla")
    b, hkv, g, smax, d = len(posv), 2, 2, 128, 16
    h, bkv = hkv * g, cfg.block_kv
    tn, k_sel = smax // bkv, 4
    rs = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(rs[0], (b, hkv, smax, d), dt)
    v = jax.random.normal(rs[1], (b, hkv, smax, d), dt)
    q = jax.random.normal(rs[2], (b, h, 1, d), dt)
    rng = np.random.default_rng(seed)
    lut = np.zeros((b, h, k_sel), np.int32)
    cnt = np.zeros((b, h), np.int32)
    for bi in range(b):
        tnv = posv[bi] // bkv + 1
        for hi in range(h):
            sel = {posv[bi] // bkv}           # forced diagonal block
            want = int(rng.integers(2, min(k_sel, tnv) + 1))
            while len(sel) < want:
                sel.add(int(rng.integers(0, tnv)))
            row = sorted(sel)
            lut[bi, hi, :len(row)] = row
            cnt[bi, hi] = len(row)
    marg = np.array([[posv[bi] // bkv + 1 for _ in range(h)]
                     for bi in range(b)], np.int32) - cnt
    written = (jnp.arange(smax)[None, :]
               <= jnp.asarray(posv)[:, None])[:, None, :, None]
    kp = phi(k, cfg.phi) * written
    vf = v.astype(jnp.float32) * written
    kpb = kp.reshape(b, hkv, tn, bkv, d)
    vbb = vf.reshape(b, hkv, tn, bkv, d)
    hblk = jnp.einsum("bntkd,bntke->bntde", kpb, vbb)
    zblk = jnp.sum(kpb, axis=3)
    state = {"k": k, "v": v, "hblk": hblk, "zblk": zblk,
             "htot": jnp.sum(hblk, 2), "ztot": jnp.sum(zblk, 2),
             "lut": jnp.asarray(lut), "cnt": jnp.asarray(cnt),
             "marg": jnp.asarray(marg)}
    return state, q, cfg


DECODE_MATRIX = [
    pytest.param(backend, dtype, pos_kind,
                 id=f"{backend}-{dtype}-{pos_kind}")
    for backend in ("gather", "kernel")
    for dtype in DTYPES
    for pos_kind in ("scalar", "vector")
]


@pytest.mark.parametrize("backend,dtype,pos_kind", DECODE_MATRIX)
def test_decode_backend_conformance(backend, dtype, pos_kind):
    """decode_execute: the gather chain and the fused Pallas kernel both
    match the dense reference oracle — f32 and bf16, shared scalar
    position (static batch) and per-slot vector positions (continuous
    batching)."""
    from repro.core.backends import decode_execute

    posv = [77, 77] if pos_kind == "scalar" else [77, 54]
    state, q, cfg = _decode_state(3, DTYPES[dtype], posv)
    pos = jnp.int32(posv[0]) if pos_kind == "scalar" \
        else jnp.asarray(posv, jnp.int32)
    d = q.shape[-1]
    proj = {"proj": 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                            (q.shape[1], d, d))}
    out_r = decode_execute(state, proj, q, pos, cfg, backend="reference")
    out_b = decode_execute(state, proj, q, pos, cfg, backend=backend)
    np.testing.assert_allclose(np.asarray(out_b, np.float32),
                               np.asarray(out_r, np.float32),
                               **TOL[dtype], err_msg=backend)
