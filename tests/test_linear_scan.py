"""Chunked decayed-linear-attention vs the naive recurrence oracle
(the compute core of RWKV6 and Mamba2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.linear_scan import (decayed_la_chunked, decayed_la_scan,
                                      decayed_la_step)


def _inputs(seed, b=2, h=2, n=64, dk=8, dv=12):
    rs = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(rs[0], (b, h, n, dk))
    k = jax.random.normal(rs[1], (b, h, n, dk))
    v = jax.random.normal(rs[2], (b, h, n, dv))
    logw = -jnp.exp(jax.random.normal(rs[3], (b, h, n, dk)))
    loga = -jax.nn.softplus(jax.random.normal(rs[4], (b, h, n)))
    u = jax.random.normal(rs[5], (h, dk)) * 0.2
    return q, k, v, logw, loga, u


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_rwkv_mode_chunked_equals_scan(chunk):
    q, k, v, logw, _, u = _inputs(0)
    o1, s1 = decayed_la_scan(q, k, v, logw, u=u)
    o2, s2 = decayed_la_chunked(q, k, v, logw, u=u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 32])
def test_mamba_mode_chunked_equals_scan(chunk):
    q, k, v, _, loga, _ = _inputs(1)
    la_vec = jnp.broadcast_to(loga[..., None], q.shape)
    o1, s1 = decayed_la_scan(q, k, v, la_vec, inclusive=True)
    o2, s2 = decayed_la_chunked(q, k, v, loga, inclusive=True,
                                chunk=chunk, scalar_decay=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_initial_state_carries():
    q, k, v, logw, _, u = _inputs(2)
    # split the sequence: scan(all) == chunked(first half) -> chunked(rest)
    o_full, s_full = decayed_la_scan(q, k, v, logw, u=u)
    h1, s_mid = decayed_la_chunked(q[:, :, :32], k[:, :, :32],
                                   v[:, :, :32], logw[:, :, :32], u=u,
                                   chunk=16)
    h2, s_end = decayed_la_chunked(q[:, :, 32:], k[:, :, 32:],
                                   v[:, :, 32:], logw[:, :, 32:], u=u,
                                   chunk=16, s0=s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 2)),
                               np.asarray(o_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=2e-4)


def test_decode_step_matches_scan():
    q, k, v, logw, _, u = _inputs(3, n=8)
    o_ref, _ = decayed_la_scan(q, k, v, logw, u=u)
    s = jnp.zeros((2, 2, 8, 12))
    outs = []
    for t in range(8):
        o, s = decayed_la_step(q[:, :, t], k[:, :, t], v[:, :, t],
                               logw[:, :, t], s, u=u)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 2)),
                               np.asarray(o_ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), decay_scale=st.floats(0.1, 6.0),
       inclusive=st.booleans())
def test_property_chunked_stable_under_fast_decay(seed, decay_scale,
                                                  inclusive):
    """Overflow-free guarantee: even extreme decays keep exponents <= 0."""
    q, k, v, logw, loga, u = _inputs(seed, n=32)
    logw = logw * decay_scale
    o, s = decayed_la_chunked(q, k, v, logw, u=None if inclusive else u,
                              inclusive=inclusive, chunk=8)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(s).all())
    o_ref, _ = decayed_la_scan(q, k, v, logw,
                               u=None if inclusive else u,
                               inclusive=inclusive)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=5e-4)
