"""Per-arch smoke tests: every assigned architecture instantiates a
REDUCED same-family config and runs one forward/train step on CPU,
asserting output shapes + no NaNs; decoder archs also run prefill +
decode_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_arch,
                           get_shape)
from repro.models import registry

SHAPE = get_shape("train_4k", smoke=True)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).smoke()
    mdl = registry.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = mdl.init(rng, cfg)
    batch = registry.make_concrete_batch(rng, cfg, SHAPE)
    loss, grads = jax.value_and_grad(
        lambda p: mdl.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"


DECODER_ARCHS = [a for a in ASSIGNED_ARCHS
                 if get_arch(a).family in ("dense", "moe", "vlm", "ssm",
                                           "hybrid")]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_arch(arch).smoke()
    mdl = registry.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = mdl.init(rng, cfg)
    toks = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    last, cache = mdl.prefill(params, cfg, toks)
    assert bool(jnp.isfinite(last).all())
    # grow kv caches so decode has room
    def grow(path, leaf):
        if hasattr(leaf, "ndim") and leaf.ndim == 5 and \
                leaf.shape[-2] >= 8 and leaf.dtype != jnp.float32:
            pad = jnp.zeros(leaf.shape[:3] + (8,) + leaf.shape[4:],
                            leaf.dtype)
            return jnp.concatenate([leaf, pad], axis=3)
        return leaf
    cache = jax.tree_util.tree_map_with_path(grow, cache)
    token = jnp.array([1, 2], jnp.int32)
    logits, cache2 = mdl.decode_step(params, cfg, token, cache)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_whisper_prefill_decode():
    cfg = get_arch("whisper-small").smoke()
    from repro.models import encdec
    rng = jax.random.PRNGKey(0)
    params = encdec.init(rng, cfg)
    batch = {"audio_embeds": jax.random.normal(rng, (2, 64, cfg.d_model))}
    enc, cache = encdec.prefill(params, cfg, batch)
    assert bool(jnp.isfinite(enc).all())
    logits, cache2 = encdec.decode_step(params, cfg,
                                        jnp.array([1, 2], jnp.int32),
                                        cache)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_transformer_decode_consistent_with_forward():
    """Greedy decode over a cache must reproduce teacher-forced logits.

    attention_kind='full' so both paths are exact attention — the test
    verifies the cache/position/rope plumbing (the SLA prefill path vs
    exact decode differs by construction)."""
    import dataclasses
    from repro.models import transformer as tfm
    cfg = dataclasses.replace(get_arch("qwen3-1.7b").smoke(),
                              attention_kind="full")
    rng = jax.random.PRNGKey(0)
    params = tfm.init(rng, cfg)
    toks = jax.random.randint(rng, (1, 48), 0, cfg.vocab_size)
    # full forward logits at position 32 given the prefix
    x, _ = tfm.forward(params, cfg, toks, compute_dtype=jnp.float32)
    logits_fwd = jnp.einsum("d,vd->v", x[0, 31].astype(jnp.float32),
                            params["embed"].astype(jnp.float32))
    # prefill 32 then decode token 32
    last, cache = tfm.prefill(params, cfg, toks[:, :32],
                              compute_dtype=jnp.float32)
    cache = jax.tree_util.tree_map_with_path(
        lambda p, l: (jnp.concatenate(
            [l, jnp.zeros(l.shape[:3] + (8,) + l.shape[4:], l.dtype)], 3)
            if hasattr(l, "ndim") and l.ndim == 5 else l), cache)
    logits_dec, _ = tfm.decode_step(params, cfg, toks[:, 32],
                                    cache, compute_dtype=jnp.float32)
    # the decode path recomputes position 32's logits
    np.testing.assert_allclose(np.asarray(logits_dec[0]),
                               np.asarray(jnp.einsum(
                                   "d,vd->v",
                                   x[0, 32].astype(jnp.float32),
                                   params["embed"].astype(jnp.float32))),
                               atol=2e-2, rtol=2e-2)
    del logits_fwd


def test_rwkv_decode_consistent_with_forward():
    from repro.models import rwkv6
    cfg = get_arch("rwkv6-7b").smoke()
    rng = jax.random.PRNGKey(0)
    params = rwkv6.init(rng, cfg)
    toks = jax.random.randint(rng, (1, 17), 0, cfg.vocab_size)
    x, _ = rwkv6.forward(params, cfg, toks, compute_dtype=jnp.float32)
    ref_logits = jnp.einsum("d,vd->v", x[0, -1].astype(jnp.float32),
                            params["embed"].astype(jnp.float32))
    last, cache = rwkv6.prefill(params, cfg, toks[:, :-1],
                                compute_dtype=jnp.float32)
    logits, _ = rwkv6.decode_step(params, cfg, toks[:, -1], cache,
                                  compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(ref_logits), atol=2e-2,
                               rtol=2e-2)


def test_dit_forward_and_loss():
    from repro.models import dit
    cfg = get_arch("wan2_1_1_3b").smoke()
    rng = jax.random.PRNGKey(0)
    params = dit.init(rng, cfg)
    b, n = 2, 64
    batch = {
        "latents": jax.random.normal(rng, (b, n, cfg.patch_dim)),
        "noise": jax.random.normal(jax.random.PRNGKey(1),
                                   (b, n, cfg.patch_dim)),
        "t": jnp.array([0.3, 0.7]),
        "cond": jax.random.normal(rng, (b, cfg.cond_len, cfg.d_model)),
    }
    for mode in (None, "sparse_only", "linear_only", "l_plus_s"):
        loss = dit.loss_fn(params, cfg, batch, sla_mode=mode)
        assert bool(jnp.isfinite(loss)), f"dit mode={mode}"
