"""Paper Fig. 3: stable-rank decomposition of attention weights.

Claim: P splits into a small high-rank sparse part (top ~8%) and a large
extremely low-rank remainder (bottom ~92%) — the structural fact that
makes sparse+linear the right hybrid.  stable_rank(A) = ||A||_F^2 /
||A||_2^2 (Rudelson & Vershynin, 2006).
"""
import time

import numpy as np

from benchmarks._toy import attention_weights, trained_qkv


def stable_rank(a: np.ndarray) -> float:
    fro2 = float((a * a).sum())
    top = float(np.linalg.norm(a, 2) ** 2)
    return fro2 / max(top, 1e-12)


def run():
    t0 = time.time()
    q, k, _ = trained_qkv()
    p = np.asarray(attention_weights(q, k))
    # average over a few heads
    heads = [(0, 0), (0, 1), (0, 2), (0, 3)]
    srs_full, srs_top, srs_rest = [], [], []
    for b, h in heads:
        a = p[b, h]
        kth = np.quantile(a, 0.92, axis=-1, keepdims=True)
        top = np.where(a >= kth, a, 0.0)
        rest = a - top
        srs_full.append(stable_rank(a))
        srs_top.append(stable_rank(top))
        srs_rest.append(stable_rank(rest))
    us = (time.time() - t0) * 1e6
    return [
        ("fig3.stable_rank.full", us, float(np.mean(srs_full))),
        ("fig3.stable_rank.top8pct", us, float(np.mean(srs_top))),
        ("fig3.stable_rank.bottom92pct", us, float(np.mean(srs_rest))),
        ("fig3.lowrank_ratio.bottom_vs_full", us,
         float(np.mean(srs_rest) / np.mean(srs_full))),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
