"""Learned vs threshold routing at matched critical-block budgets
(DESIGN.md "Learned routing").

Three measurements, all at the SAME kh_frac/kl_frac (so both routers
select the same number of critical blocks — the comparison is routing
quality/cost, never FLOP budget):
  (a) MEASURED plan-build latency: the learned router adds two per-head
      d x d projections of the pooled block features to the planning
      pipeline — this prices that overhead on compiled XLA;
  (b) DERIVED attention-FLOPs overhead of the routing head from
      `core/flops.sla_flops` (share of total SLA attention cost);
  (c) MEASURED end-to-end distillation fine-tune on a toy DiT
      (exact-attention teacher): per-step wall time and first->final
      loss with the router frozen at the threshold rule vs trainable
      learned routing (+ sla_proj in both arms). Both arms start from
      the identical loss (identity init == threshold, bitwise); at toy
      scale and a handful of steps the arms land close — the row exists
      to price the step-time overhead and track the gap as configs
      scale.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import SLAConfig, plan_attention, resolve, routing_init
from repro.core.flops import sla_flops


def _time(fn, *args, reps=10):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def plan_latency(n=2048, d=64, h=4):
    """(us threshold, us learned, critical_frac) for one plan build."""
    cfg_t = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10)
    cfg_l = cfg_t.replace(routing_mode="learned")
    q, k = (jax.random.normal(r, (1, h, n, d))
            for r in jax.random.split(jax.random.PRNGKey(0), 2))
    routing = routing_init(h, d)
    plan_t = jax.jit(lambda q, k: plan_attention(q, k, cfg_t))
    plan_l = jax.jit(lambda q, k: plan_attention(q, k, cfg_l,
                                                 routing=routing))
    crit = float(jnp.mean(plan_t(q, k).mc == 1))
    assert crit == float(jnp.mean(plan_l(q, k).mc == 1))  # matched budget
    return _time(plan_t, q, k), _time(plan_l, q, k), crit


def distill_race(steps=10):
    """Fine-tune (routing + sla_proj) under the distillation loss with
    each router; returns {mode: (us_per_step, first_loss, final_loss)}."""
    from benchmarks._toy import toy_dit_distill_setup
    from repro.models import dit
    from repro.optim import adamw

    out = {}
    for mode in ("threshold", "learned"):
        cfg, params, batch = toy_dit_distill_setup(mode)
        mask = adamw.trainable_mask(params, ("routing", "sla_proj"))
        opt_cfg = adamw.AdamWConfig(lr=3e-2, total_steps=steps,
                                    warmup_steps=1, weight_decay=0.0)
        opt = adamw.init(params)

        @jax.jit
        def step(p, o):
            loss, g = jax.value_and_grad(
                lambda p: dit.distill_loss_fn(
                    p, cfg, batch, compute_dtype=jnp.float32))(p)
            p, o, _ = adamw.update(p, g, o, opt_cfg, trainable=mask)
            return p, o, loss

        params, opt, first = step(params, opt)  # compile + step 0
        jax.block_until_ready(first)
        t0 = time.time()
        last = first
        for _ in range(steps - 1):
            params, opt, last = step(params, opt)
        jax.block_until_ready(last)
        us = (time.time() - t0) / max(steps - 1, 1) * 1e6
        out[mode] = (us, float(first), float(last))
    return out


def run(backend: str = "gather"):
    resolve(backend)
    rows = []
    t_thr, t_lrn, crit = plan_latency()
    rows.append(("fig_routing.plan_us.threshold", t_thr,
                 f"crit_frac={crit:.3f}"))
    rows.append(("fig_routing.plan_us.learned", t_lrn,
                 f"x{t_lrn / t_thr:.2f} vs threshold (matched budget)"))
    f = sla_flops(32768, 128, 12,
                  SLAConfig(routing_mode="learned"))
    rows.append(("fig_routing.flops.head_share", 0.0,
                 f"routing={f['routing']:.3g} "
                 f"({100.0 * f['routing'] / f['total']:.2f}% of total)"))
    race = distill_race()
    for mode, (us, first, last) in race.items():
        rows.append((f"fig_routing.distill.{mode}", us,
                     f"loss {first:.5f}->{last:.5f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
