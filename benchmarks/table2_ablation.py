"""Paper Table 2: SLA ablations — phi activation and k_h sweep.

Mechanism-level on real (toy-trained) attention inputs: fidelity of each
variant vs full attention + its FLOPs at the Wan2.1 point.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks._toy import trained_qkv
from benchmarks.table1_quality_efficiency import wan_tflops
from repro.core import SLAConfig, sla_attention, sla_init


def run():
    t0 = time.time()
    q, k, v = trained_qkv()
    base = SLAConfig(block_q=32, block_kv=32, kh_frac=0.05, kl_frac=0.10,
                     proj_init="identity")
    full = sla_attention(None, q, k, v, base.replace(mode="full"))

    def fidelity(cfg):
        params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1],
                          cfg)
        out = sla_attention(params, q, k, v, cfg)
        return float(jnp.linalg.norm(out - full) / jnp.linalg.norm(full))

    rows = []
    for phi in ("softmax", "elu1", "relu"):
        cfg = base.replace(phi=phi)
        us = (time.time() - t0) * 1e6
        rows.append((f"table2.phi_{phi}.rel_err", us,
                     round(fidelity(cfg), 4)))
    for kh in (0.05, 0.10, 0.20):
        cfg = base.replace(kh_frac=kh)
        us = (time.time() - t0) * 1e6
        rows.append((f"table2.top{int(kh*100)}pct.rel_err", us,
                     round(fidelity(cfg), 4)))
        rows.append((f"table2.top{int(kh*100)}pct.wan_TFLOPs", us,
                     round(wan_tflops("sla", cfg), 2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
