"""Serving throughput + tail latency: static lockstep batches vs the
v2 continuous-batching scheduler (DESIGN.md "Serving API v2").

One synthetic Poisson-ish arrival trace (exponential inter-arrival
gaps, heterogeneous decode budgets) is served twice:

  * static — a v1-style driver: when the engine is idle it takes up to
    `batch` arrived requests and runs the group to completion in
    lockstep (late arrivals wait; short requests burn their slot until
    the group's longest budget drains);
  * continuous — the `Scheduler` slot pool: requests are submitted the
    moment they arrive and admitted into whichever slot frees first.

Reported per path: decode throughput, TTFT p50/p95 (measured from the
request's ARRIVAL, so static pays its queueing honestly), latency p50,
and decode-slot occupancy (bookkeeping-deterministic — the acceptance
metric: continuous > static on this workload).

A third stage measures the paged KV cache (DESIGN.md "Paged KV &
prefix caching"): the same request mix is served twice through the
paged Scheduler — once with every prompt sharing a common system-style
prefix, once with fully unique prompts — and the page-pool counters
are compared. The acceptance metric `shared_prefix_saves_pages` pins
the tentpole claim: N requests sharing a prefix allocate
O(prefix + sum of unique suffixes) pages, strictly fewer than N unique
prompts of identical lengths.

A fourth stage measures chunked admission prefill (DESIGN.md "Chunked
admission prefill"): a short request is mid-decode when a long prompt
arrives, served once with blocking admission (the whole prefill
dispatch stalls every decoding slot) and once with
`prefill_chunk_blocks` set (the prompt advances one chunk per tick
between decode steps). The acceptance metric
`chunked_reduces_decode_stall` compares the two traces' max
inter-token gap (`ServeStats.max_decode_gap_s`).

A fifth stage measures disaggregated serving (DESIGN.md
"Disaggregated serving"): one tick-indexed synthetic arrival + length
trace is served twice through the `DisaggScheduler` pools — once
undisturbed, once with a `FaultPlan` that kills a decode worker
mid-stream so its residents requeue from their retained handoff
bundles. Per run: per-pool occupancy, TTFT p50/p95, goodput (decode
tokens of COMPLETED requests over wall time), fault counters, and a
checksum of every request's greedy tokens. The acceptance booleans pin
the tentpole claims: the healthy run completes everything, the faulted
run loses nothing (with the kill actually firing), and the replayed
trajectories are bitwise identical to the undisturbed run's.

Everything lands in BENCH_serving.json with the acceptance booleans
recomputed from the stored cells (the fig_decode honesty rule: a
boolean reads exactly the cells its name points at, enforced by
recompute_acceptance + tests).
"""
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serving.json"

N_REQ = 10
SLOTS = 2
PROMPT_LEN = 32
# bursty trace: arrivals outpace decode, so both paths stay saturated
# and the occupancy gap measures lockstep waste, not arrival gaps
MEAN_GAP_S = 0.005
MAX_LEN = 96
# paged stage: equal-length prompts (left-padding is part of the prefix
# interning key, so only same-length prompts share pages) = a shared
# 2-block prefix plus a unique 1-block suffix
PREFIX_LEN = 32
SUFFIX_LEN = 16
# stall stage: a short request is mid-decode when a 16-block prompt
# arrives; blocking admission stalls decode for the whole prefill
# dispatch, chunked admission for at most one chunk per tick. The long
# prompt is deliberately much longer than the serving trace's so the
# blocking dispatch costs visibly more than one chunk even at smoke
# scale (a 1-block chunk attends to at most the 256 tokens before it;
# the blocking prefill runs all 16 blocks at once)
STALL_SHORT = 16
STALL_LONG = 256
STALL_MAX_LEN = 288
STALL_CHUNK_BLOCKS = 1
STALL_SHORT_BUDGET = 24
STALL_LONG_BUDGET = 4
# disagg stage: 1 prefill worker feeding 2 decode workers; the faulted
# run kills decode:0 a few ticks in, while residents are mid-stream
DISAGG_PREFILL_WORKERS = 1
DISAGG_DECODE_WORKERS = 2
DISAGG_KILL_AFTER_TICKS = 4


def _setup():
    from repro.configs import get_arch
    from repro.models import registry

    cfg = get_arch("qwen3-1.7b").smoke()
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg, seed=0):
    rs = np.random.default_rng(seed)
    prompts = [rs.integers(0, cfg.vocab_size, size=PROMPT_LEN)
               .astype(np.int32) for _ in range(N_REQ)]
    budgets = [int(b) for b in rs.integers(4, 20, size=N_REQ)]
    arrivals = np.cumsum(rs.exponential(MEAN_GAP_S, size=N_REQ))
    return prompts, budgets, arrivals


def _pct(xs, p):
    from repro.serving.api import percentile
    return percentile(xs, p)


def _run_continuous(cfg, params, prompts, budgets, arrivals):
    from repro.serving.api import SamplingParams, Scheduler

    sched = Scheduler(cfg, params, num_slots=SLOTS, max_len=MAX_LEN,
                      prefill_bucket=PROMPT_LEN)
    # warm the compile caches off the clock
    sched.submit(prompts[0], SamplingParams(max_new_tokens=2))
    sched.drain()
    sched.stats.__init__()

    t0 = time.time()
    submitted, rids = 0, set()
    while submitted < N_REQ or sched.has_work:
        now = time.time() - t0
        while submitted < N_REQ and arrivals[submitted] <= now:
            rids.add(sched.submit(
                prompts[submitted],
                SamplingParams(max_new_tokens=budgets[submitted])))
            submitted += 1
        if not sched.has_work:
            time.sleep(min(0.002, max(0.0, arrivals[submitted] - now)))
            continue
        sched.step()
    wall = time.time() - t0
    done = [r for r in sched.drain() if r.rid in rids]
    ttfts = [r.metrics.ttft_s for r in done]
    lats = [r.metrics.latency_s for r in done]
    return sched.stats, wall, ttfts, lats


def _run_static(cfg, params, prompts, budgets, arrivals):
    from repro.serving.api import RequestMetrics
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(cfg, params, batch_size=SLOTS, max_len=MAX_LEN)
    # warm the compile caches off the clock (full AND partial groups)
    eng.run([Request(rid=-1, prompt=prompts[0], max_new_tokens=2)
             for _ in range(SLOTS)])
    eng.run([Request(rid=-1, prompt=prompts[0], max_new_tokens=2)])
    eng.stats.__init__()

    t0 = time.time()
    done, i = [], 0
    while i < N_REQ:
        now = time.time() - t0
        ready = []
        while i < N_REQ and arrivals[i] <= now and len(ready) < SLOTS:
            r = Request(rid=i, prompt=prompts[i],
                        max_new_tokens=budgets[i])
            r.metrics = RequestMetrics(submit_t=t0 + arrivals[i])
            ready.append(r)
            i += 1
        if not ready:
            time.sleep(min(0.002, max(0.0, arrivals[i] - now)))
            continue
        done.extend(eng.run(ready))
    wall = time.time() - t0
    ttfts = [r.metrics.ttft_s for r in done]
    lats = [r.metrics.latency_s for r in done]
    return eng.stats, wall, ttfts, lats


def _paged_prompts(cfg, shared: bool, seed=1):
    """Equal-length prompts: a common PREFIX_LEN prefix + unique
    suffix (shared=True), or fully unique tokens of the same length."""
    rs = np.random.default_rng(seed)
    total = PREFIX_LEN + SUFFIX_LEN
    prefix = rs.integers(0, cfg.vocab_size, size=PREFIX_LEN) \
        .astype(np.int32)
    out = []
    for _ in range(N_REQ):
        if shared:
            suf = rs.integers(0, cfg.vocab_size, size=SUFFIX_LEN)
            p = np.concatenate([prefix, suf.astype(np.int32)])
        else:
            p = rs.integers(0, cfg.vocab_size, size=total) \
                .astype(np.int32)
        out.append(p)
    return out


def _run_paged(cfg, params, prompts, budgets):
    """Drain the request mix through a FRESH paged Scheduler and report
    its page-pool counters. A fresh pool per run keeps the counters
    honest — warmup would leave interned pages behind and understate
    the unique-prompt cost."""
    from repro.serving.api import SamplingParams, Scheduler

    sched = Scheduler(cfg, params, num_slots=SLOTS, max_len=MAX_LEN,
                      prefill_bucket=PREFIX_LEN + SUFFIX_LEN,
                      paged=True)
    for p, b in zip(prompts, budgets):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    sched.drain()
    st = sched.stats
    return {"page_allocs": st.page_allocs, "pages_peak": st.pages_peak,
            "pages_in_use": st.pages_in_use,
            "prefix_hits": st.prefix_hits,
            "prefix_misses": st.prefix_misses,
            "prefix_full_hits": st.prefix_full_hits,
            "cow_copies": st.cow_copies,
            "occupancy": st.occupancy(),
            "decode_tokens": st.decode_tokens}


def _stall_cfg(cfg):
    """Chunk-eligible variant of the smoke config: `prefill_chunk`
    requires causal attention and per-row critical sets
    (`col_capacity_factor=None`) — see
    `transformer.check_chunked_prefill`. Both stall cells (blocking AND
    chunked) use this config so the ONLY variable is the admission
    policy."""
    return dataclasses.replace(
        cfg, sla=cfg.sla.replace(causal=True, col_capacity_factor=None))


def _run_stall(cfg, params, chunk_blocks):
    """Serve the stall trace: a short request decodes while a long
    prompt is admitted. chunk_blocks=None is blocking admission (the
    decode loop stalls for the entire prefill dispatch);
    chunk_blocks=K advances the prompt K blocks per tick between
    decode steps. Reports the max inter-token gap the decoding
    request observed."""
    from repro.serving.api import SamplingParams, Scheduler

    sched = Scheduler(cfg, params, num_slots=SLOTS,
                      max_len=STALL_MAX_LEN, prefill_bucket=STALL_LONG,
                      paged=True, prefill_chunk_blocks=chunk_blocks)

    def trace(s, l):
        """short decodes; long arrives mid-stream; drain both."""
        sched.submit(s, SamplingParams(max_new_tokens=STALL_SHORT_BUDGET))
        toks, guard = 0, 0
        while toks < 2 and guard < 200:  # short request is mid-decode
            toks += sum(1 for e in sched.step() if e.kind == "token")
            guard += 1
        sched.submit(l, SamplingParams(max_new_tokens=STALL_LONG_BUDGET))
        sched.drain()

    rs = np.random.default_rng(7)
    short = rs.integers(0, cfg.vocab_size, size=STALL_SHORT) \
        .astype(np.int32)
    long_p = rs.integers(0, cfg.vocab_size, size=STALL_LONG) \
        .astype(np.int32)
    # warm every compiled path off the clock by running the SAME trace
    # shape once with DIFFERENT tokens (same tokens would intern the
    # measured prompts' pages and store full-prompt snapshots, so the
    # measured admissions would take the snapshot fast path and skip
    # prefill entirely — measuring nothing). Mirroring the trace warms
    # the 2-slot decode dispatch too, not just per-request paths.
    warm_s = rs.integers(0, cfg.vocab_size, size=STALL_SHORT) \
        .astype(np.int32)
    warm_l = rs.integers(0, cfg.vocab_size, size=STALL_LONG) \
        .astype(np.int32)
    trace(warm_s, warm_l)
    sched.stats.__init__()
    sched._last_token_t = None  # ignore the warmup->run idle gap

    trace(short, long_p)
    st = sched.stats
    return {"max_decode_gap_ms": st.max_decode_gap_s * 1e3,
            "chunked_admissions": st.chunked_admissions,
            "prefill_chunks": st.prefill_chunks,
            "decode_tokens": st.decode_tokens}


def _disagg_trace(cfg, seed=5):
    """Tick-indexed arrivals (deterministic — the disagg control plane
    is tick-driven, so the trace replays exactly) with mixed prompt
    lengths and decode budgets."""
    rs = np.random.default_rng(seed)
    lens = [int(n) for n in rs.integers(12, PROMPT_LEN + 1, size=N_REQ)]
    prompts = [rs.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    budgets = [int(b) for b in rs.integers(4, 16, size=N_REQ)]
    arrive_ticks = [int(t) for t in
                    np.cumsum(rs.integers(0, 3, size=N_REQ))]
    return prompts, budgets, arrive_ticks


def _run_disagg(cfg, params, prompts, budgets, arrive_ticks,
                kill: bool):
    """Serve the trace through the disaggregated pools; per-token
    decode steps so a kill lands mid-request, not between requests."""
    from repro.distributed.fault_tolerance import FaultEvent, FaultPlan
    from repro.serving import DisaggScheduler
    from repro.serving.api import SamplingParams

    dis = DisaggScheduler(
        cfg, params, prefill_workers=DISAGG_PREFILL_WORKERS,
        decode_workers=DISAGG_DECODE_WORKERS, slots_per_worker=SLOTS,
        max_len=MAX_LEN, prefill_bucket=PROMPT_LEN,
        decode_step_mode="token", sleep=lambda s: None)
    # warm the compile caches off the clock, then arm the fault plan
    # relative to the measured run's first tick (FaultEvent ticks index
    # the scheduler's own tick counter, which the warmup advanced)
    dis.submit(prompts[0][:12], SamplingParams(max_new_tokens=2))
    dis.drain()
    warm_rids = {r.rid for r in dis._requests}
    dis.stats = type(dis.stats)()
    if kill:
        dis._faults = FaultPlan([FaultEvent(
            tick=dis._tick_no + DISAGG_KILL_AFTER_TICKS, kind="kill",
            pool="decode", worker=0)])

    t0 = time.time()
    i, tick = 0, 0
    while i < N_REQ or dis.has_work:
        while i < N_REQ and arrive_ticks[i] <= tick:
            dis.submit(prompts[i],
                       SamplingParams(max_new_tokens=budgets[i]))
            i += 1
        tick += 1
        if dis.has_work:
            dis.tick()
    wall = time.time() - t0
    done = [r for r in dis._requests if r.rid not in warm_rids]
    ttfts = [r.metrics.ttft_s for r in done]
    goodput = sum(len(r.tokens_out) for r in done) / max(wall, 1e-9)
    checksum = ";".join(
        f"{r.rid}:" + ",".join(str(t) for t in r.tokens_out)
        for r in sorted(done, key=lambda r: r.rid))
    st = dis.stats
    return {"submitted": st.submitted, "completed": st.completed,
            "goodput_tok_s": goodput,
            "ttft_p50_ms": _pct(ttfts, 0.5) * 1e3,
            "ttft_p95_ms": _pct(ttfts, 0.95) * 1e3,
            "prefill_occupancy": st.prefill_occupancy(),
            "decode_occupancy": dis.decode_occupancy(),
            "handoffs": st.handoffs, "requeues": st.requeues,
            "kills": st.kills, "retries": st.retries,
            "straggler_drains": st.straggler_drains,
            "tokens_checksum": checksum}


def recompute_acceptance(payload: dict) -> dict:
    """Derive the acceptance booleans from EXACTLY the cells their
    names point at (same honesty contract as fig_decode's — see
    tests/test_benchmarks.py for why the recompute must be the single
    source of truth)."""
    paths, paged = payload["paths"], payload["paged"]
    return {
        # decode-slot utilization: the continuous scheduler backfills
        # freed slots instead of draining lockstep groups
        "continuous_beats_static_occupancy": (
            paths["continuous"]["occupancy"]
            > paths["static"]["occupancy"]),
        # the tentpole claim: a shared prompt prefix is paid for ONCE
        # across requests (O(prefix + sum unique-suffix) pages), so the
        # shared-prefix trace allocates strictly fewer physical pages
        # than the same request mix with unique prompts
        "shared_prefix_saves_pages": (
            paged["shared_prefix"]["page_allocs"]
            < paged["unique_prompts"]["page_allocs"]),
        # chunked admission claim: interleaving one prefill chunk per
        # tick bounds the decode stall by a chunk dispatch instead of
        # the whole prompt's, so the decoding request's worst
        # inter-token gap strictly shrinks
        "chunked_reduces_decode_stall": (
            payload["stall"]["chunked"]["max_decode_gap_ms"]
            < payload["stall"]["blocking"]["max_decode_gap_ms"]),
        # disagg claims: the healthy pools drain the whole trace...
        "disagg_completes_all_healthy": (
            payload["disagg"]["healthy"]["completed"]
            == payload["disagg"]["healthy"]["submitted"]
            and payload["disagg"]["healthy"]["submitted"] > 0),
        # ...a mid-stream decode-worker kill loses NOTHING (and the
        # kill + requeue actually fired — a faulted run where the
        # worker was idle at kill time proves nothing)
        "disagg_requeue_zero_lost": (
            payload["disagg"]["faulted"]["completed"]
            == payload["disagg"]["faulted"]["submitted"]
            and payload["disagg"]["faulted"]["kills"] >= 1
            and payload["disagg"]["faulted"]["requeues"] >= 1),
        # ...and the requeued trajectories replay bitwise: every
        # request's greedy tokens identical across the two runs
        "disagg_fault_tokens_bitwise_equal": (
            payload["disagg"]["faulted"]["tokens_checksum"]
            == payload["disagg"]["healthy"]["tokens_checksum"]),
    }


def run(backend: str = "gather"):
    cfg, params = _setup()
    prompts, budgets, arrivals = _trace(cfg)
    rows, paths = [], {}
    for name, fn in (("static", _run_static),
                     ("continuous", _run_continuous)):
        st, wall, ttfts, lats = fn(cfg, params, prompts, budgets,
                                   arrivals)
        tput = st.decode_tokens / max(wall, 1e-9)
        paths[name] = {"throughput_tok_s": tput,
                       "ttft_p50_ms": _pct(ttfts, 0.5) * 1e3,
                       "ttft_p95_ms": _pct(ttfts, 0.95) * 1e3,
                       "latency_p50_ms": _pct(lats, 0.5) * 1e3,
                       "occupancy": st.occupancy(),
                       "admissions": st.admissions}
        rows.append((f"fig_serving.{name}.throughput_tok_s", tput,
                     f"{st.decode_tokens} decode tok / {wall:.2f}s"))
        rows.append((f"fig_serving.{name}.ttft_ms",
                     _pct(ttfts, 0.5) * 1e3,
                     f"p95={_pct(ttfts, 0.95)*1e3:.0f}ms "
                     f"lat_p50={_pct(lats, 0.5)*1e3:.0f}ms"))
        rows.append((f"fig_serving.{name}.occupancy", st.occupancy(),
                     f"{st.slot_steps_active}/{st.slot_steps_total} "
                     f"slot-steps, {st.admissions} admissions"))
    gain = rows[5][1] / max(rows[2][1], 1e-9)
    rows.append(("fig_serving.occupancy_gain", gain,
                 "continuous/static decode-slot utilization"))

    # paged KV: shared-prefix trace vs unique-prompt trace
    rs = np.random.default_rng(2)
    pbudgets = [int(b) for b in rs.integers(4, 16, size=N_REQ)]
    paged = {}
    for key, shared in (("shared_prefix", True),
                        ("unique_prompts", False)):
        cell = _run_paged(cfg, params,
                          _paged_prompts(cfg, shared), pbudgets)
        paged[key] = cell
        rows.append((f"fig_serving.paged.{key}.page_allocs",
                     float(cell["page_allocs"]),
                     f"peak={cell['pages_peak']} "
                     f"hits={cell['prefix_hits']} "
                     f"full={cell['prefix_full_hits']} "
                     f"cow={cell['cow_copies']}"))
    saved = (paged["unique_prompts"]["page_allocs"]
             - paged["shared_prefix"]["page_allocs"])
    rows.append(("fig_serving.paged.pages_saved", float(saved),
                 f"{N_REQ} reqs sharing a {PREFIX_LEN}-token prefix"))

    # chunked admission: blocking vs chunked decode-stall trace
    scfg = _stall_cfg(cfg)
    stall = {}
    for key, chunk in (("blocking", None),
                       ("chunked", STALL_CHUNK_BLOCKS)):
        cell = _run_stall(scfg, params, chunk)
        stall[key] = cell
        rows.append((f"fig_serving.stall.{key}.max_decode_gap_ms",
                     cell["max_decode_gap_ms"],
                     f"{cell['chunked_admissions']} chunked adm, "
                     f"{cell['prefill_chunks']} chunks, "
                     f"{cell['decode_tokens']} decode tok"))

    # disaggregated pools: healthy vs kill-mid-stream trace replay
    dprompts, dbudgets, dticks = _disagg_trace(cfg)
    disagg = {}
    for key, kill in (("healthy", False), ("faulted", True)):
        cell = _run_disagg(cfg, params, dprompts, dbudgets, dticks,
                           kill=kill)
        disagg[key] = cell
        rows.append((f"fig_serving.disagg.{key}.goodput_tok_s",
                     cell["goodput_tok_s"],
                     f"{cell['completed']}/{cell['submitted']} done, "
                     f"kills={cell['kills']} "
                     f"requeues={cell['requeues']} "
                     f"ttft_p95={cell['ttft_p95_ms']:.0f}ms"))
        rows.append((f"fig_serving.disagg.{key}.occupancy",
                     cell["decode_occupancy"],
                     f"decode pool {DISAGG_DECODE_WORKERS}w; prefill "
                     f"pool {DISAGG_PREFILL_WORKERS}w occ "
                     f"{cell['prefill_occupancy']:.2f}"))

    payload = {
        "config": {"n_req": N_REQ, "slots": SLOTS,
                   "prompt_len": PROMPT_LEN, "max_len": MAX_LEN,
                   "prefix_len": PREFIX_LEN, "suffix_len": SUFFIX_LEN,
                   "block_kv": cfg.sla.block_kv,
                   "mean_gap_s": MEAN_GAP_S,
                   "stall_short": STALL_SHORT, "stall_long": STALL_LONG,
                   "stall_max_len": STALL_MAX_LEN,
                   "stall_chunk_blocks": STALL_CHUNK_BLOCKS,
                   "disagg_prefill_workers": DISAGG_PREFILL_WORKERS,
                   "disagg_decode_workers": DISAGG_DECODE_WORKERS,
                   "disagg_kill_after_ticks": DISAGG_KILL_AFTER_TICKS},
        "paths": paths,
        "paged": paged,
        "stall": stall,
        "disagg": disagg,
    }
    payload["acceptance"] = recompute_acceptance(payload)
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    for key, ok in payload["acceptance"].items():
        rows.append((f"fig_serving.accept.{key}", 0.0,
                     "PASS" if ok else "FAIL"))
    rows.append(("fig_serving.json", 0.0, BENCH_PATH.name))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
