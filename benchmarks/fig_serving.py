"""Serving throughput + tail latency: static lockstep batches vs the
v2 continuous-batching scheduler (DESIGN.md "Serving API v2").

One synthetic Poisson-ish arrival trace (exponential inter-arrival
gaps, heterogeneous decode budgets) is served twice:

  * static — a v1-style driver: when the engine is idle it takes up to
    `batch` arrived requests and runs the group to completion in
    lockstep (late arrivals wait; short requests burn their slot until
    the group's longest budget drains);
  * continuous — the `Scheduler` slot pool: requests are submitted the
    moment they arrive and admitted into whichever slot frees first.

Reported per path: decode throughput, TTFT p50/p95 (measured from the
request's ARRIVAL, so static pays its queueing honestly), latency p50,
and decode-slot occupancy (bookkeeping-deterministic — the acceptance
metric: continuous > static on this workload).
"""
import time

import jax
import numpy as np

N_REQ = 10
SLOTS = 2
PROMPT_LEN = 32
# bursty trace: arrivals outpace decode, so both paths stay saturated
# and the occupancy gap measures lockstep waste, not arrival gaps
MEAN_GAP_S = 0.005
MAX_LEN = 96


def _setup():
    from repro.configs import get_arch
    from repro.models import registry

    cfg = get_arch("qwen3-1.7b").smoke()
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg, seed=0):
    rs = np.random.default_rng(seed)
    prompts = [rs.integers(0, cfg.vocab_size, size=PROMPT_LEN)
               .astype(np.int32) for _ in range(N_REQ)]
    budgets = [int(b) for b in rs.integers(4, 20, size=N_REQ)]
    arrivals = np.cumsum(rs.exponential(MEAN_GAP_S, size=N_REQ))
    return prompts, budgets, arrivals


def _pct(xs, p):
    from repro.serving.api import percentile
    return percentile(xs, p)


def _run_continuous(cfg, params, prompts, budgets, arrivals):
    from repro.serving.api import SamplingParams, Scheduler

    sched = Scheduler(cfg, params, num_slots=SLOTS, max_len=MAX_LEN,
                      prefill_bucket=PROMPT_LEN)
    # warm the compile caches off the clock
    sched.submit(prompts[0], SamplingParams(max_new_tokens=2))
    sched.drain()
    sched.stats.__init__()

    t0 = time.time()
    submitted, rids = 0, set()
    while submitted < N_REQ or sched.has_work:
        now = time.time() - t0
        while submitted < N_REQ and arrivals[submitted] <= now:
            rids.add(sched.submit(
                prompts[submitted],
                SamplingParams(max_new_tokens=budgets[submitted])))
            submitted += 1
        if not sched.has_work:
            time.sleep(min(0.002, max(0.0, arrivals[submitted] - now)))
            continue
        sched.step()
    wall = time.time() - t0
    done = [r for r in sched.drain() if r.rid in rids]
    ttfts = [r.metrics.ttft_s for r in done]
    lats = [r.metrics.latency_s for r in done]
    return sched.stats, wall, ttfts, lats


def _run_static(cfg, params, prompts, budgets, arrivals):
    from repro.serving.api import RequestMetrics
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(cfg, params, batch_size=SLOTS, max_len=MAX_LEN)
    # warm the compile caches off the clock (full AND partial groups)
    eng.run([Request(rid=-1, prompt=prompts[0], max_new_tokens=2)
             for _ in range(SLOTS)])
    eng.run([Request(rid=-1, prompt=prompts[0], max_new_tokens=2)])
    eng.stats.__init__()

    t0 = time.time()
    done, i = [], 0
    while i < N_REQ:
        now = time.time() - t0
        ready = []
        while i < N_REQ and arrivals[i] <= now and len(ready) < SLOTS:
            r = Request(rid=i, prompt=prompts[i],
                        max_new_tokens=budgets[i])
            r.metrics = RequestMetrics(submit_t=t0 + arrivals[i])
            ready.append(r)
            i += 1
        if not ready:
            time.sleep(min(0.002, max(0.0, arrivals[i] - now)))
            continue
        done.extend(eng.run(ready))
    wall = time.time() - t0
    ttfts = [r.metrics.ttft_s for r in done]
    lats = [r.metrics.latency_s for r in done]
    return eng.stats, wall, ttfts, lats


def run(backend: str = "gather"):
    cfg, params = _setup()
    prompts, budgets, arrivals = _trace(cfg)
    rows = []
    for name, fn in (("static", _run_static),
                     ("continuous", _run_continuous)):
        st, wall, ttfts, lats = fn(cfg, params, prompts, budgets,
                                   arrivals)
        tput = st.decode_tokens / max(wall, 1e-9)
        rows.append((f"fig_serving.{name}.throughput_tok_s", tput,
                     f"{st.decode_tokens} decode tok / {wall:.2f}s"))
        rows.append((f"fig_serving.{name}.ttft_ms",
                     _pct(ttfts, 0.5) * 1e3,
                     f"p95={_pct(ttfts, 0.95)*1e3:.0f}ms "
                     f"lat_p50={_pct(lats, 0.5)*1e3:.0f}ms"))
        rows.append((f"fig_serving.{name}.occupancy", st.occupancy(),
                     f"{st.slot_steps_active}/{st.slot_steps_total} "
                     f"slot-steps, {st.admissions} admissions"))
    gain = rows[5][1] / max(rows[2][1], 1e-9)
    rows.append(("fig_serving.occupancy_gain", gain,
                 "continuous/static decode-slot utilization"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
