"""Decode-time SLA: per-token attention FLOPs + measured decode latency.

Three measurements (DESIGN.md "Decode-time SLA" / "Fused decode
kernel"):
  (a) DERIVED per-token decode attention FLOPs across context lengths:
      dense masked decode is O(S); decode-SLA pays critical-blocks +
      an O(1) linear term (+ an amortized O(Tn / b_q) planning term),
      so the reduction factor grows linearly with context.
  (b) MEASURED one-token decode attention across the context sweep
      {8k, 32k, 131k}: dense masked attention over the cache vs
      decode-SLA through the gather/einsum chain vs the fused Pallas
      kernel (interpret mode off-TPU), compile time reported separately
      from steady-state per-token wall-clock.
  (c) MEASURED chunked decode (`decode_execute_chunk`, C tokens per
      launch): the fused kernel's launch overhead amortized C-fold —
      the verify-style speculative-decode path.
  (d) MEASURED model-level decode through the full transformer at
      {8k, 32k}: per-token `decode_step` (the gather backend, one jit
      launch + plan bookkeeping per token) vs `decode_chunk` (the fused
      single-launch path: one attention launch per layer scores a whole
      block of tokens, H/Z + plan_extend boundary work folded into one
      scanned update). This is where the fused path's win lives — the
      per-token O(Tn) plan bookkeeping amortizes C-fold.

Emits BENCH_decode.json at the repo root (consumed by benchmarks/run.py,
which prints the headline speedups).
"""
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SLAConfig
from repro.core.flops import dense_decode_flops, sla_decode_flops

FLOPS_CTXS = (4096, 16384, 65536, 262144)
CTXS = (8192, 32768, 131072)
MODEL_CTXS = (8192, 32768)  # full-transformer cells (131k's decode-grid
                            # plan buffer alone is >0.5 GB on a CPU host)
BUDGET = 16        # critical KV blocks per decode row
CHUNK = 8          # tokens per chunked launch (attention-level cells)
MCHUNK = 16        # model-level chunk = one KV block: both paths cross
                   # exactly one plan_extend boundary per measured run
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_decode.json"


def flops_rows(d=128, h=12):
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.0,
                    causal=True, decode_budget=26)  # 5% of 32k/64 blocks
    rows = []
    for n in FLOPS_CTXS:
        f = sla_decode_flops(n, d, h, cfg)
        rows.append((f"fig_decode.flops.n{n}", 0.0,
                     f"dense={f['dense']:.3g} sla={f['total']:.3g} "
                     f"x{f['reduction_x']:.1f}"))
    return rows


def _decode_state(smax, b=1, hkv=2, g=2, d=32, seed=0):
    """Synthetic mid-sequence decode state at context `smax`: random
    cache + H/Z tensors of the right SHAPE (contents don't move the
    clock), and a self-consistent LUT — BUDGET evenly spaced critical
    blocks per head, diagonal included, everything inside the valid
    prefix. Building state this way sidesteps a 131k-token prefill."""
    cfg = SLAConfig(block_q=16, block_kv=16, kh_frac=0.25, kl_frac=0.0,
                    causal=True, decode_mode="sla", fixed_budget=BUDGET)
    h, bkv = hkv * g, cfg.block_kv
    tn = smax // bkv
    pos = smax - bkv // 2              # mid-block, near-full cache
    tnv = pos // bkv + 1
    rs = jax.random.split(jax.random.PRNGKey(seed), 6)
    k = jax.random.normal(rs[0], (b, hkv, smax, d))
    v = jax.random.normal(rs[1], (b, hkv, smax, d))
    hblk = 0.1 * jax.random.normal(rs[2], (b, hkv, tn, d, d))
    zblk = jnp.abs(jax.random.normal(rs[3], (b, hkv, tn, d))) + 0.1
    lut_row = np.unique(np.concatenate(
        [np.linspace(0, tnv - 2, BUDGET - 1, dtype=np.int64),
         [pos // bkv]])).astype(np.int32)[:BUDGET]
    k_sel = len(lut_row)
    lut = np.broadcast_to(lut_row, (b, h, k_sel)).copy()
    cnt = np.full((b, h), k_sel, np.int32)
    marg = np.full((b, h), tnv - k_sel, np.int32)
    state = {"k": k, "v": v, "hblk": hblk, "zblk": zblk,
             "htot": jnp.sum(hblk, 2), "ztot": jnp.sum(zblk, 2),
             "lut": jnp.asarray(lut), "cnt": jnp.asarray(cnt),
             "marg": jnp.asarray(marg)}
    q = jax.random.normal(rs[4], (b, h, CHUNK, d))
    proj = {"proj": 0.1 * jax.random.normal(rs[5], (h, d, d))}
    return state, q, proj, pos, cfg


def _chunk_state(state, pos, cdim, bkv):
    """Per-token chunk layout for decode_execute_chunk's gather path
    (the fused kernel's XLA twin): broadcast the live plan row and
    running totals to every chunk token and slice the at-time diagonal
    partials (transformer.decode_chunk builds the real thing)."""
    b, h, k_sel = state["lut"].shape
    hkv = state["k"].shape[1]
    d = state["k"].shape[-1]
    rows = (pos + np.arange(cdim)) // bkv
    return dict(
        state,
        lut=jnp.broadcast_to(state["lut"][:, :, None],
                             (b, h, cdim, k_sel)),
        cnt=jnp.broadcast_to(state["cnt"][..., None], (b, h, cdim)),
        marg=jnp.broadcast_to(state["marg"][..., None], (b, h, cdim)),
        htot=jnp.broadcast_to(state["htot"][:, :, None],
                              (b, hkv, cdim, d, d)),
        ztot=jnp.broadcast_to(state["ztot"][:, :, None], (b, hkv, cdim, d)),
        hdiag=state["hblk"][:, :, rows],
        zdiag=state["zblk"][:, :, rows])


def _dense_one_token(q1, k, v, pos):
    b, hkv, smax, d = k.shape
    qg = q1.reshape(b, hkv, -1, d)
    s = jnp.einsum("bngd,bnsd->bngs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    s = jnp.where(jnp.arange(smax) <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bngs,bnsd->bngd", p, v.astype(jnp.float32))


def _bench(fn, reps, trials=3):
    t0 = time.time()
    jax.block_until_ready(fn())
    compile_s = time.time() - t0
    best = float("inf")
    for _ in range(trials):       # best-of-trials: shields the numbers
        t0 = time.time()          # from scheduler noise on shared hosts
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.time() - t0) / reps)
    return compile_s, best


def measure_context_sweep(reps=24):
    """Per-context cells: compile_s (first call: trace + compile + run)
    and steady-state per_token_us for dense / sla-gather / sla-kernel
    one-token decode plus the chunked fused kernel (per-token =
    launch / CHUNK).

    On TPU the kernel cells run the real fused Pallas kernel. Off-TPU,
    Pallas only runs in interpret mode — a correctness tool, ~1000x off
    compiled speed — so the kernel cells time the kernel's compiled XLA
    twin instead (`sla_decode._decode_math`, the custom_vjp backward's
    reference: bit-for-bit the same math, chunk layout included)."""
    from repro.core import backends as backend_lib

    on_tpu = jax.default_backend() == "tpu"
    cells = {}
    for smax in CTXS:
        state, q, proj, pos, cfg = _decode_state(smax)
        q1 = q[:, :, :1, :]
        posj = jnp.int32(pos)

        def cell(fn, reps_=reps, scale=1.0):
            compile_s, t = _bench(fn, reps_)
            return {"compile_s": round(compile_s, 4),
                    "per_token_us": round(t / scale * 1e6, 2)}

        dense = jax.jit(lambda: _dense_one_token(q1, state["k"],
                                                 state["v"], posj))
        gather = jax.jit(functools.partial(
            backend_lib.decode_execute, state, proj, q1, posj, cfg,
            backend="gather"))
        if on_tpu:
            kernel = jax.jit(functools.partial(
                backend_lib.decode_execute, state, proj, q1, posj, cfg,
                backend="kernel"))
            kchunk = jax.jit(functools.partial(
                backend_lib.decode_execute_chunk, state, proj, q, posj,
                cfg, backend="kernel"))
        else:
            bkv = cfg.block_kv
            kernel = jax.jit(functools.partial(
                backend_lib.decode_execute_chunk,
                _chunk_state(state, pos, 1, bkv), proj, q1, posj, cfg,
                backend="gather"))
            kchunk = jax.jit(functools.partial(
                backend_lib.decode_execute_chunk,
                _chunk_state(state, pos, CHUNK, bkv), proj, q, posj, cfg,
                backend="gather"))
        cells[str(smax)] = {
            "dense": cell(dense),
            "sla_gather": cell(gather),
            "sla_kernel": cell(kernel),
            "sla_kernel_chunk": cell(kchunk, scale=CHUNK),
        }
    return cells


def _model_cache(cfg, ctx):
    """Mid-sequence decode cache at context `ctx` without a ctx-token
    prefill: make_cache's empty decode-SLA state, position advanced and
    the live plan row backfilled the same way `_decode_state` does at
    the attention level (tensor CONTENTS don't move the clock; shapes
    and the plan bookkeeping do)."""
    from repro.models import transformer as tfm

    bkv = cfg.sla.block_kv
    cache = tfm.make_cache(cfg, 1, ctx, decode_sla=True)
    pos = ctx - 16 * bkv                 # block-aligned, room to decode
    tnv = pos // bkv
    st = cache["sla"]
    nl, b, h, k_sel = st["live_lut"].shape
    lut_row = np.unique(np.concatenate(
        [np.linspace(0, tnv - 2, k_sel - 1, dtype=np.int64),
         [tnv - 1]])).astype(np.int32)[:k_sel]
    st["live_lut"] = jnp.broadcast_to(jnp.asarray(lut_row),
                                      (nl, b, h, k_sel))
    st["live_cnt"] = jnp.full((nl, b, h), len(lut_row), jnp.int32)
    st["live_marg"] = jnp.full((nl, b, h), tnv - len(lut_row), jnp.int32)
    st["rows"] = jnp.int32(pos // cfg.sla.block_q)
    cache["pos"] = jnp.int32(pos)
    return cache


def measure_model_decode(reps=2):
    """Full-transformer per-token decode: MCHUNK teacher-forced tokens
    through per-token `decode_step` (gather backend) vs one
    `decode_chunk` launch (the fused kernel's single-launch entry
    point; its compiled XLA twin off-TPU). Same cache, same tokens,
    same block-boundary crossings — only the launch granularity
    differs."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models import transformer as tfm

    cfg = get_arch("qwen3-1.7b").smoke()
    cfg = dataclasses.replace(
        cfg, sla=cfg.sla.replace(kh_frac=0.25, kl_frac=0.0,
                                 decode_mode="sla",
                                 decode_budget=BUDGET))
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (1, MCHUNK), 0,
                           cfg.vocab_size), np.int32)
    jstep = jax.jit(functools.partial(tfm.decode_step, params, cfg))
    jchunk = jax.jit(functools.partial(tfm.decode_chunk, params, cfg))

    cells = {}
    for ctx in MODEL_CTXS:
        cache0 = _model_cache(cfg, ctx)

        def run_steps():
            cache = cache0
            out = None
            for c in range(MCHUNK):
                out, cache = jstep(jnp.asarray(toks[:, c]), cache)
            return out

        def run_chunk():
            out, _ = jchunk(jnp.asarray(toks), cache0)
            return out

        def cell(fn):
            compile_s, t = _bench(fn, reps, trials=2)
            return {"compile_s": round(compile_s, 4),
                    "per_token_us": round(t / MCHUNK * 1e6, 2)}

        cells[str(ctx)] = {"step_gather": cell(run_steps),
                           "chunk_kernel": cell(run_chunk)}
    return cells


def recompute_acceptance(payload: dict) -> dict:
    """Acceptance booleans, each computed from EXACTLY the cells its
    name points at (tests/test_benchmarks.py holds this to account —
    an earlier revision computed `kernel_beats_gather_32k` from the
    model-level cells while naming the attention-level ones, reporting
    true over cells that said 67.33us kernel vs 55.88us gather)."""
    cells = payload["cells"]
    model_cells = payload["model_cells"]
    ctxs = payload["config"]["contexts"]
    mk = model_cells[str(max(int(c)
                             for c in payload["config"]
                             ["model_contexts"]))]
    return {
        # attention-level: SLA decode vs dense masked decode at >= 32k
        "sla_beats_dense_32k": all(
            cells[str(n)]["dense"]["per_token_us"]
            > cells[str(n)]["sla_gather"]["per_token_us"]
            for n in ctxs if int(n) >= 32768),
        # attention-level: the fused kernel's one-token cell vs the
        # gather backend at 32k. Honest reading: off-TPU the kernel's
        # XLA twin LOSES to gather at one-token granularity (its win
        # is chunked launches — see model_chunk_beats_step_32k)
        "kernel_beats_gather_32k": (
            cells["32768"]["sla_kernel"]["per_token_us"]
            < cells["32768"]["sla_gather"]["per_token_us"]),
        # model-level: one single-launch decode_chunk vs MCHUNK
        # per-token decode_step launches through the full transformer
        # at the largest model context — where launch + plan-keeping
        # granularity is the real difference between the two paths
        "model_chunk_beats_step_32k": (
            mk["chunk_kernel"]["per_token_us"]
            < mk["step_gather"]["per_token_us"]),
    }


def run(backend: str = "gather"):
    rows = flops_rows()
    cells = measure_context_sweep()
    model_cells = measure_model_decode()
    payload = {
        "config": {"contexts": list(CTXS), "budget_blocks": BUDGET,
                   "chunk": CHUNK, "block_kv": 16, "heads": 4,
                   "kv_heads": 2, "head_dim": 32,
                   "kernel_is_pallas": jax.default_backend() == "tpu",
                   "backend_note": "off-TPU the sla_kernel cells time "
                                   "the kernel's compiled XLA twin "
                                   "(identical math); interpret-mode "
                                   "Pallas is correctness-only",
                   "model_contexts": list(MODEL_CTXS),
                   "model_chunk": MCHUNK},
        "cells": cells,
        "model_cells": model_cells,
    }
    payload["acceptance"] = recompute_acceptance(payload)
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    for smax, c in cells.items():
        dense, gat = c["dense"], c["sla_gather"]
        kbest = min(c["sla_kernel"]["per_token_us"],
                    c["sla_kernel_chunk"]["per_token_us"])
        rows.append((f"fig_decode.step_us.dense.n{smax}",
                     dense["per_token_us"],
                     f"compile_s={dense['compile_s']}"))
        rows.append((f"fig_decode.step_us.sla_gather.n{smax}",
                     gat["per_token_us"],
                     f"x{dense['per_token_us'] / gat['per_token_us']:.2f}"
                     f" vs dense"))
        rows.append((f"fig_decode.step_us.sla_kernel.n{smax}", kbest,
                     f"x{gat['per_token_us'] / kbest:.2f} vs gather "
                     f"(best of 1-token/chunked)"))
    for ctx, c in model_cells.items():
        st, ch = c["step_gather"], c["chunk_kernel"]
        rows.append((f"fig_decode.decode_us.step_gather.n{ctx}",
                     st["per_token_us"],
                     f"per-token decode_step, compile_s={st['compile_s']}"))
        rows.append((f"fig_decode.decode_us.chunk_kernel.n{ctx}",
                     ch["per_token_us"],
                     f"x{st['per_token_us'] / ch['per_token_us']:.2f} "
                     f"vs per-token step (single-launch decode_chunk)"))
    rows.append(("fig_decode.json", 0.0, BENCH_PATH.name))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
