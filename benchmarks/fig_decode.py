"""Decode-time SLA: per-token attention FLOPs + measured decode latency.

Two measurements (DESIGN.md "Decode-time SLA"):
  (a) DERIVED per-token decode attention FLOPs across context lengths:
      dense masked decode is O(S); decode-SLA pays critical-blocks +
      an O(1) linear term (+ an amortized O(Tn / b_q) planning term),
      so the reduction factor grows linearly with context.
  (b) MEASURED wall time of one compiled decode_step on a toy
      transformer, dense cache vs decode-SLA cache, on this host (the
      CPU analogue of the paper's kernel race, decode edition).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import SLAConfig
from repro.core.flops import dense_decode_flops, sla_decode_flops

CTXS = (4096, 16384, 65536, 262144)


def flops_rows(d=128, h=12):
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.0,
                    causal=True, decode_budget=26)  # 5% of 32k/64 blocks
    rows = []
    for n in CTXS:
        f = sla_decode_flops(n, d, h, cfg)
        rows.append((f"fig_decode.flops.n{n}", 0.0,
                     f"dense={f['dense']:.3g} sla={f['total']:.3g} "
                     f"x{f['reduction_x']:.1f}"))
    return rows


def measured_decode(prompt_len=64, max_len=256, reps=16):
    """Compiled decode_step wall time: dense vs decode-SLA cache."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models import transformer as tfm

    cfg = get_arch("qwen3-1.7b").smoke()
    cfg = dataclasses.replace(cfg, sla=cfg.sla.replace(kh_frac=0.25,
                                                       kl_frac=0.0))
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len), 0,
                              cfg.vocab_size)
    token = jnp.array([1, 2], jnp.int32)
    step = jax.jit(lambda p, t, c: tfm.decode_step(p, cfg, t, c))

    def bench(cache):
        logits, _ = step(params, token, cache)
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(reps):
            logits, _ = step(params, token, cache)
        jax.block_until_ready(logits)
        return (time.time() - t0) / reps * 1e6  # us

    _, dense_cache = tfm.prefill(params, cfg, toks)
    pad = max_len - prompt_len
    dense_cache = {
        "k": jnp.pad(dense_cache["k"], [(0, 0)] * 3 + [(0, pad), (0, 0)]),
        "v": jnp.pad(dense_cache["v"], [(0, 0)] * 3 + [(0, pad), (0, 0)]),
        "pos": dense_cache["pos"]}
    _, sla_cache = tfm.prefill(params, cfg, toks, decode_max_len=max_len)
    return bench(dense_cache), bench(sla_cache)


def run(backend: str = "gather"):
    rows = flops_rows()
    t_dense, t_sla = measured_decode()
    rows.append(("fig_decode.step_us.dense", t_dense, "S=256"))
    rows.append(("fig_decode.step_us.sla", t_sla,
                 f"x{t_dense / t_sla:.2f} vs dense"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
