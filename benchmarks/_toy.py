"""Shared benchmark substrate: quickly-trained toy models whose attention
maps have realistic structure (the paper's Figs. 1/3 sample from Wan2.1;
we sample from these). Cached to artifacts/ so benchmarks are fast.

A 2-layer causal LM on Markov-chain tokens develops sharply peaked
attention within ~100 CPU steps (induction/previous-token heads) — far
faster than a toy DiT develops spatial attention — so the attention-
structure claims (Fig 1/3) are validated on it; the DiT path remains for
the end-to-end fine-tuning claims (examples/finetune_dit.py).
"""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig

CACHE = pathlib.Path(__file__).resolve().parent.parent / "artifacts"
SEQ = 256


def trained_qkv(train_steps: int = 120, seq: int = SEQ):
    """(q, k, v) from layer 1 of a briefly-trained toy causal LM,
    shapes (B, H, N, D)."""
    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, token_batch
    from repro.models import transformer as tfm
    from repro.optim import adamw

    CACHE.mkdir(exist_ok=True)
    cache_file = CACHE / f"toy_qkv_lm_{seq}_{train_steps}.npz"
    if cache_file.exists():
        z = np.load(cache_file)
        return (jnp.asarray(z["q"]), jnp.asarray(z["k"]),
                jnp.asarray(z["v"]))

    cfg = dataclasses.replace(get_arch("qwen3-1.7b").smoke(),
                              attention_kind="full", num_layers=2)
    shape = ShapeConfig("lm", seq, 8, "train")
    rng = jax.random.PRNGKey(0)
    params = tfm.init(rng, cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, total_steps=train_steps,
                                warmup_steps=10, schedule="cosine")
    opt = adamw.init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(
            lambda p: tfm.loss_fn(p, cfg, b))(p)
        p, o, _ = adamw.update(p, g, o, opt_cfg)
        return p, o, loss

    dc = DataConfig(seed=0)
    for s in range(train_steps):
        batch = {k: jnp.asarray(v)
                 for k, v in token_batch(cfg, shape, dc, s).items()}
        params, opt, loss = step(params, opt, batch)

    batch = {k: jnp.asarray(v)
             for k, v in token_batch(cfg, shape, dc, 10_000).items()}
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    p1 = jax.tree.map(lambda t: t[1], params["layers"])
    # run layer 0 to get layer 1's input
    from repro.models.common import rms_norm
    p0 = jax.tree.map(lambda t: t[0], params["layers"])
    pos = jnp.arange(seq, dtype=jnp.int32)[None].repeat(x.shape[0], 0)
    a0 = tfm._attn(p0, rms_norm(x, p0["ln1"]), jnp.int32(1), cfg,
                   pos, "reference")[0]
    x = x + a0
    f0, _ = tfm._ffn(p0, rms_norm(x, p0["ln2"]), cfg)
    x = x + f0
    q, k, v = tfm._qkv(p1, rms_norm(x, p1["ln1"]), cfg, pos)
    h = q.shape[1]
    kk = jnp.repeat(k, h // k.shape[1], 1)
    vv = jnp.repeat(v, h // v.shape[1], 1)
    np.savez(cache_file, q=np.asarray(q, np.float32),
             k=np.asarray(kk, np.float32), v=np.asarray(vv, np.float32))
    return q, kk, vv


def toy_dit_distill_setup(routing_mode, routing_temp=0.05, seed=0,
                          n=128, b=2):
    """Shared toy-DiT distillation harness (benchmarks/fig_routing.py and
    tests/test_routing.py): a 2-layer DiT whose output head and SLA
    merge are randomized — fresh DiTs zero-init `patch_out`/`sla_proj`,
    which would make the distillation target trivially zero and kill
    the linear branch (and with it the routing head's straight-through
    gradients). Returns (cfg, params, batch)."""
    from repro.configs.base import ArchConfig
    from repro.core.config import SLAConfig
    from repro.models import dit

    cfg = ArchConfig(
        name=f"dit-routing-{routing_mode}", family="dit", num_layers=2,
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
        vocab_size=0, patch_dim=8, cross_attn=False,
        attention_kind="sla",
        sla=SLAConfig(block_q=16, block_kv=16, kh_frac=0.25,
                      kl_frac=0.25, routing_mode=routing_mode,
                      routing_temp=routing_temp))
    params = dit.init(jax.random.PRNGKey(seed), cfg)
    params["patch_out"] = jax.random.normal(
        jax.random.PRNGKey(3), params["patch_out"].shape) * 0.2
    params["layers"]["sla_proj"] = jax.random.normal(
        jax.random.PRNGKey(7), params["layers"]["sla_proj"].shape) * 0.3
    rb = jax.random.split(jax.random.PRNGKey(2), 3)
    batch = {"latents": jax.random.normal(rb[0], (b, n, cfg.patch_dim)),
             "noise": jax.random.normal(rb[1], (b, n, cfg.patch_dim)),
             "t": jax.random.uniform(rb[2], (b,))}
    return cfg, params, batch


def attention_weights(q, k):
    """Full softmax attention weights P (B, H, N, N) f32 (causal)."""
    d = q.shape[-1]
    s = jnp.einsum("bhnd,bhmd->bhnm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d**-0.5)
    n = s.shape[-1]
    s = jnp.where(jnp.tril(jnp.ones((n, n), bool)), s, -1e30)
    return jax.nn.softmax(s, axis=-1)
