"""Paper Fig. 1: attention-weight distribution + sparse-attention accuracy.

Claims validated on a trained toy DiT's real attention maps:
  (1) only a small fraction of weights exceed the uniform value 1/N;
  (2) a large fraction fall below 1/(100N);
  (3) skipping the bottom-X% weights costs little; keeping only the
      top-Y% costs a lot (the dilemma SLA resolves).
"""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks._toy import attention_weights, trained_qkv


def run():
    t0 = time.time()
    q, k, v = trained_qkv()
    p = attention_weights(q, k)
    n = p.shape[-1]
    pf = np.asarray(p).reshape(-1, n)

    frac_above_uniform = float((pf > 1.0 / n).mean())
    frac_tiny = float((pf < 1.0 / (100.0 * n)).mean())

    # sparse accuracy: keep top-q% of weights per row, rel-L1 of output
    v32 = np.asarray(v, np.float32).reshape(-1, n, v.shape[-1])[:8]
    pr = np.asarray(p).reshape(-1, n, n)[:8]
    full_out = pr @ v32
    rows = []
    for keep_frac in (0.05, 0.081, 0.20, 0.55, 0.90):
        kth = np.quantile(pr, 1.0 - keep_frac, axis=-1, keepdims=True)
        mask = pr >= kth
        ps = np.where(mask, pr, 0.0)
        ps = ps / np.maximum(ps.sum(-1, keepdims=True), 1e-9)
        err = float(np.abs(ps @ v32 - full_out).sum()
                    / np.abs(full_out).sum())
        rows.append((f"fig1.sparse_err@keep{keep_frac:.0%}", err))
    us = (time.time() - t0) * 1e6
    out = [("fig1.frac_above_1/N", us, frac_above_uniform),
           ("fig1.frac_below_1/100N", us, frac_tiny)]
    out += [(name, us, val) for name, val in rows]
    return out


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
