"""Paper Fig. 6: attention kernel speed + end-to-end latency.

No GPU/TPU in this container, so four complementary measurements:
  (a) MEASURED wall time of compiled XLA full attention vs compiled XLA
      gather-SLA on CPU (same-backend, same-compiler comparison — the
      honest CPU analogue of the paper's kernel race);
  (b) DERIVED TPU-v5e roofline projection of both kernels at the Wan2.1
      point (compute + memory terms, 197 TFLOP/s & 819 GB/s);
  (c) the end-to-end attention-share model: with attention 44% of
      step time (97s / 220s, Fig. 6b), speedup_e2e = 1 / (0.56 + 0.44/s);
  (d) MEASURED plan-amortized speedup: planning (pool -> P_c -> top-k ->
      LUTs) vs execution on a fixed plan, and the per-step time when one
      plan is reused for K denoising steps
      (SLAConfig.plan_refresh_interval; DESIGN.md "Plan/execute split").
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (SLAConfig, compute_mask, plan_attention,
                        sla_attention, sla_init)
from repro.core.flops import full_attention_flops, sla_flops
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

WAN = dict(n=32760, d=128, h=12)


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def measured_cpu(n=2048, d=64, h=4):
    rng = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(r, (1, h, n, d), jnp.bfloat16)
               for r in jax.random.split(rng, 3))
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10)
    params = sla_init(rng, h, d, cfg)

    full_fn = jax.jit(lambda q, k, v: sla_attention(
        None, q, k, v, cfg.replace(mode="full")))
    sla_fn = jax.jit(lambda q, k, v: sla_attention(
        params, q, k, v, cfg, backend="gather"))
    t_full = _time(full_fn, q, k, v)
    t_sla = _time(sla_fn, q, k, v)
    return t_full, t_sla


def measured_plan_amortization(n=2048, d=64, h=4, refresh=(1, 4, 8)):
    """Plan/execute split timings: planning cost vs execution cost, and
    the amortized per-step attention time when one plan serves K steps."""
    rng = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(r, (1, h, n, d), jnp.bfloat16)
               for r in jax.random.split(rng, 3))
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10)
    params = sla_init(rng, h, d, cfg)

    plan_fn = jax.jit(lambda q, k: plan_attention(q, k, cfg))
    plan = jax.block_until_ready(plan_fn(q, k))
    exec_fn = jax.jit(lambda q, k, v, plan: sla_attention(
        params, q, k, v, cfg, backend="gather", plan=plan))
    t_plan = _time(lambda q, k: plan_fn(q, k).mc, q, k)
    t_exec = _time(exec_fn, q, k, v, plan)
    per_step = {kk: t_plan / kk + t_exec for kk in refresh}
    return t_plan, t_exec, per_step


def tpu_projection():
    n, d, h = WAN["n"], WAN["d"], WAN["h"]
    bsz = 2  # bf16
    fl_full = full_attention_flops(n, d, h)
    io_full = 4 * n * d * h * bsz  # q,k,v,o streamed once (flash)
    t_full = max(fl_full / PEAK_FLOPS, io_full / HBM_BW)
    acct = sla_flops(n, d, h, SLAConfig())
    # SLA streams q,k,v,o + the h_j/z_j block state once
    io_sla = io_full + (n // 64) * (d * d + d) * h * 4
    t_sla = max(acct["total"] / PEAK_FLOPS, io_sla / HBM_BW)
    return t_full * 1e6, t_sla * 1e6


def run():
    rows = []
    t_full_cpu, t_sla_cpu = measured_cpu()
    rows.append(("fig6.cpu_measured.full_us", t_full_cpu,
                 round(t_full_cpu, 1)))
    rows.append(("fig6.cpu_measured.sla_us", t_sla_cpu,
                 round(t_sla_cpu, 1)))
    rows.append(("fig6.cpu_measured.speedup_x", t_sla_cpu,
                 round(t_full_cpu / t_sla_cpu, 2)))
    t_full_tpu, t_sla_tpu = tpu_projection()
    kernel_speedup = t_full_tpu / t_sla_tpu
    rows.append(("fig6.tpu_projected.full_us", 0, round(t_full_tpu, 1)))
    rows.append(("fig6.tpu_projected.sla_us", 0, round(t_sla_tpu, 1)))
    rows.append(("fig6.tpu_projected.kernel_speedup_x", 0,
                 round(kernel_speedup, 2)))
    rows.append(("fig6.paper_kernel_speedup_x", 0, 13.7))
    # end-to-end: attention is 97s of 220s on Wan2.1 (Fig. 6b)
    att_share = 97.0 / 220.0
    e2e = 1.0 / ((1 - att_share) + att_share / kernel_speedup)
    rows.append(("fig6.e2e_projected_speedup_x", 0, round(e2e, 2)))
    rows.append(("fig6.paper_e2e_speedup_x", 0, 2.2))
    # (d) plan-amortized speedup across denoising steps
    t_plan, t_exec, per_step = measured_plan_amortization()
    rows.append(("fig6.plan_us", t_plan, round(t_plan, 1)))
    rows.append(("fig6.execute_us", t_exec, round(t_exec, 1)))
    base = per_step[1]
    for kk, t in sorted(per_step.items()):
        rows.append((f"fig6.plan_amortized.refresh_{kk}.step_us", t,
                     round(t, 1)))
        rows.append((f"fig6.plan_amortized.refresh_{kk}.speedup_x", t,
                     round(base / t, 3)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
