"""Paper Fig. 6: attention kernel speed + end-to-end latency.

No GPU/TPU in this container, so four complementary measurements:
  (a) MEASURED wall time of compiled XLA full attention vs compiled XLA
      gather-SLA on CPU (same-backend, same-compiler comparison — the
      honest CPU analogue of the paper's kernel race);
  (b) DERIVED TPU-v5e roofline projection of both kernels at the Wan2.1
      point (compute + memory terms, 197 TFLOP/s & 819 GB/s);
  (c) the end-to-end attention-share model: with attention 44% of
      step time (97s / 220s, Fig. 6b), speedup_e2e = 1 / (0.56 + 0.44/s);
  (d) MEASURED plan-amortized speedup: planning (pool -> P_c -> top-k ->
      LUTs) vs execution on a fixed plan, and the per-step time when one
      plan is reused for K denoising steps
      (SLAConfig.plan_refresh_interval; DESIGN.md "Plan/execute split");
  (e) MEASURED fixed-K vs drift-adaptive refresh on a small DiT sampling
      run: re-plan counts, retained-mass traces, and per-step wall time
      for each policy (DESIGN.md "Plan lifetime & drift").
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (SLAConfig, compute_mask, plan_attention,
                        resolve, sla_attention, sla_init)
from repro.core.flops import full_attention_flops, sla_flops
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

WAN = dict(n=32760, d=128, h=12)


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def measured_cpu(n=2048, d=64, h=4, backend="gather"):
    rng = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(r, (1, h, n, d), jnp.bfloat16)
               for r in jax.random.split(rng, 3))
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10)
    params = sla_init(rng, h, d, cfg)

    full_fn = jax.jit(lambda q, k, v: sla_attention(
        None, q, k, v, cfg.replace(mode="full")))
    sla_fn = jax.jit(lambda q, k, v: sla_attention(
        params, q, k, v, cfg, backend=backend))
    t_full = _time(full_fn, q, k, v)
    t_sla = _time(sla_fn, q, k, v)
    return t_full, t_sla


def measured_plan_amortization(n=2048, d=64, h=4, refresh=(1, 4, 8),
                               backend="gather"):
    """Plan/execute split timings: planning cost vs execution cost, and
    the amortized per-step attention time when one plan serves K steps."""
    rng = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(r, (1, h, n, d), jnp.bfloat16)
               for r in jax.random.split(rng, 3))
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10)
    params = sla_init(rng, h, d, cfg)

    plan_fn = jax.jit(lambda q, k: plan_attention(q, k, cfg))
    plan = jax.block_until_ready(plan_fn(q, k))
    exec_fn = jax.jit(lambda q, k, v, plan: sla_attention(
        params, q, k, v, cfg, backend=backend, plan=plan))
    t_plan = _time(lambda q, k: plan_fn(q, k).mc, q, k)
    t_exec = _time(exec_fn, q, k, v, plan)
    per_step = {kk: t_plan / kk + t_exec for kk in refresh}
    return t_plan, t_exec, per_step


def measured_refresh_policies(num_steps=8, backend="gather",
                              thresholds=(0.02, 0.1), fixed_k=(1, 4)):
    """Fixed-K vs drift-adaptive refresh on a small DiT sampling run:
    per-policy re-plan counts, retained-mass traces, per-step wall time
    (DESIGN.md "Plan lifetime & drift")."""
    from repro.configs.base import ArchConfig
    from repro.models import dit

    cfg = ArchConfig(
        name="dit-fig6", family="dit", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=0,
        patch_dim=8, cross_attn=False, attention_kind="sla",
        sla=SLAConfig(block_q=32, block_kv=32, kh_frac=0.25, kl_frac=0.25))
    params = dit.init(jax.random.PRNGKey(0), cfg)
    # zero-init output head -> zero velocity -> zero drift; give the
    # sampler a real trajectory so the policies have something to track
    params["patch_out"] = jax.random.normal(
        jax.random.PRNGKey(7), params["patch_out"].shape) * 0.5
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 8))

    out = {}
    for kk in fixed_k:
        fn = jax.jit(lambda x, kk=kk: dit.sample(
            params, cfg, x, num_steps=num_steps, backend=backend,
            refresh_mode="fixed", refresh_interval=kk, return_trace=True))
        _, trace = jax.block_until_ready(fn(noise))
        t_us = _time(lambda x: fn(x)[0], noise) / num_steps
        out[f"fixed_k{kk}"] = dict(
            replans=int(trace["replan_count"].sum()), retention=1.0,
            step_us=t_us)
    for thr in thresholds:
        fn = jax.jit(lambda x, t: dit.sample(
            params, cfg, x, num_steps=num_steps, backend=backend,
            refresh_mode="adaptive", drift_threshold=t, return_trace=True))
        tj = jnp.float32(thr)
        _, trace = jax.block_until_ready(fn(noise, tj))
        t_us = _time(lambda x: fn(x, tj)[0], noise) / num_steps
        out[f"adaptive_thr{thr}"] = dict(
            replans=int(trace["replan_count"].sum()),
            retention=float(trace["retention"].mean()), step_us=t_us)
    return out


def tpu_projection():
    n, d, h = WAN["n"], WAN["d"], WAN["h"]
    bsz = 2  # bf16
    fl_full = full_attention_flops(n, d, h)
    io_full = 4 * n * d * h * bsz  # q,k,v,o streamed once (flash)
    t_full = max(fl_full / PEAK_FLOPS, io_full / HBM_BW)
    acct = sla_flops(n, d, h, SLAConfig())
    # SLA streams q,k,v,o + the h_j/z_j block state once
    io_sla = io_full + (n // 64) * (d * d + d) * h * 4
    t_sla = max(acct["total"] / PEAK_FLOPS, io_sla / HBM_BW)
    return t_full * 1e6, t_sla * 1e6


def run(backend="gather"):
    backend = resolve(backend)  # unknown backend= fails loudly, up front
    rows = []
    t_full_cpu, t_sla_cpu = measured_cpu(backend=backend)
    rows.append(("fig6.cpu_measured.full_us", t_full_cpu,
                 round(t_full_cpu, 1)))
    rows.append(("fig6.cpu_measured.sla_us", t_sla_cpu,
                 round(t_sla_cpu, 1)))
    rows.append(("fig6.cpu_measured.speedup_x", t_sla_cpu,
                 round(t_full_cpu / t_sla_cpu, 2)))
    t_full_tpu, t_sla_tpu = tpu_projection()
    kernel_speedup = t_full_tpu / t_sla_tpu
    rows.append(("fig6.tpu_projected.full_us", 0, round(t_full_tpu, 1)))
    rows.append(("fig6.tpu_projected.sla_us", 0, round(t_sla_tpu, 1)))
    rows.append(("fig6.tpu_projected.kernel_speedup_x", 0,
                 round(kernel_speedup, 2)))
    rows.append(("fig6.paper_kernel_speedup_x", 0, 13.7))
    # end-to-end: attention is 97s of 220s on Wan2.1 (Fig. 6b)
    att_share = 97.0 / 220.0
    e2e = 1.0 / ((1 - att_share) + att_share / kernel_speedup)
    rows.append(("fig6.e2e_projected_speedup_x", 0, round(e2e, 2)))
    rows.append(("fig6.paper_e2e_speedup_x", 0, 2.2))
    # (d) plan-amortized speedup across denoising steps
    t_plan, t_exec, per_step = measured_plan_amortization(backend=backend)
    rows.append(("fig6.plan_us", t_plan, round(t_plan, 1)))
    rows.append(("fig6.execute_us", t_exec, round(t_exec, 1)))
    base = per_step[1]
    for kk, t in sorted(per_step.items()):
        rows.append((f"fig6.plan_amortized.refresh_{kk}.step_us", t,
                     round(t, 1)))
        rows.append((f"fig6.plan_amortized.refresh_{kk}.speedup_x", t,
                     round(base / t, 3)))
    # (e) fixed-K vs drift-adaptive refresh policies on a DiT sampler
    for name, m in measured_refresh_policies(backend=backend).items():
        rows.append((f"fig6.refresh.{name}.replans", m["replans"],
                     m["replans"]))
        rows.append((f"fig6.refresh.{name}.retained_mass", m["retention"],
                     round(m["retention"], 4)))
        rows.append((f"fig6.refresh.{name}.step_us", m["step_us"],
                     round(m["step_us"], 1)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="gather",
                    help="SLA execution backend (core.backends registry)")
    args = ap.parse_args()
    for r in run(backend=args.backend):
        print(",".join(str(x) for x in r))
