"""Streaming DiT service: batched-vs-sequential parity + plan-cache
economics (DESIGN.md "Streaming DiT service").

Two stages, one JSON artifact (BENCH_dit_serving.json):

  * parity — a multi-user mixed-timestep trace (heterogeneous
    num_steps AND t_start, so slots genuinely sit at different t inside
    one batched forward) is served through the `DiffusionScheduler`
    (plan cache off), then each request is re-run sequentially through
    `dit.sample(..., t_start=...)` at batch 1. Per backend (reference
    and gather, f32) the cells store a sha256 over every request's
    final latent bytes; the acceptance boolean
    `dit_batched_bitwise_equal_sequential` is checksum equality on BOTH
    backends — bitwise, not allclose.
  * plan_cache — a shared-config trace (same seq_len/t_start/steps
    across users) served twice, cache off vs on. Off: every admission
    plans all L layers from scratch (`plan_builds` counts them). On:
    the first admission misses and populates the per-(layer,
    timestep-bucket) cache; later admissions hit and *validate* the
    cached stack through the drift machinery instead of planning. The
    acceptance boolean `plan_cache_cuts_plan_builds` pins the
    amortization claim: plan builds with the cache strictly below
    per-request planning, with at least one real cache hit.

Acceptance booleans are recomputed from EXACTLY the cells their names
point at (`recompute_acceptance`; the fig_decode honesty rule —
tests/test_benchmarks.py pins the recompute and flips synthetic cells).
"""
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_dit_serving.json"

ARCH = "lightningdit_1b"
SEQ_LEN = 32
SLOTS = 2
BACKENDS = ("reference", "gather")
# (num_steps, t_start) per request — mixed on purpose: different step
# counts AND start times put every slot at its own t each tick
PARITY_TRACE = ((4, 1.0), (3, 1.0), (5, 0.75), (2, 0.5), (4, 1.0))
PARITY_THRESHOLD = 0.2
# shared-config trace for the cache stage: same bucket at admission
CACHE_REQS = 6
CACHE_STEPS = 4
CACHE_THRESHOLD = 0.3
T_BUCKETS = 8


def _setup():
    from repro.configs import get_arch
    from repro.models import dit

    cfg = get_arch(ARCH).smoke()
    params = dit.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _latent(cfg, i):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(i + 1), (SEQ_LEN, cfg.patch_dim),
        jnp.float32))


def _checksum(latents) -> str:
    """sha256 over the raw f32 bytes of every request's final latent,
    in rid order — bitwise equality, nothing weaker."""
    h = hashlib.sha256()
    for lat in latents:
        h.update(np.ascontiguousarray(np.asarray(lat, np.float32))
                 .tobytes())
    return h.hexdigest()


def _run_parity(cfg, params, backend):
    from repro.models import dit
    from repro.serving.diffusion import DenoiseParams, DiffusionScheduler

    sched = DiffusionScheduler(
        cfg, params, num_slots=SLOTS, seq_len=SEQ_LEN, backend=backend,
        compute_dtype=jnp.float32, refresh_mode="adaptive",
        drift_threshold=PARITY_THRESHOLD)
    for i, (steps, t0) in enumerate(PARITY_TRACE):
        sched.submit(_latent(cfg, i),
                     DenoiseParams(num_steps=steps, t_start=t0))
    done = sched.drain()
    batched = [r.result for r in sorted(done, key=lambda r: r.rid)]
    sequential = []
    for i, (steps, t0) in enumerate(PARITY_TRACE):
        out = dit.sample(params, cfg, jnp.asarray(_latent(cfg, i)[None]),
                         num_steps=steps, compute_dtype=jnp.float32,
                         backend=backend, refresh_mode="adaptive",
                         drift_threshold=PARITY_THRESHOLD, t_start=t0)
        sequential.append(np.asarray(out[0]))
    return {"batched_checksum": _checksum(batched),
            "sequential_checksum": _checksum(sequential),
            "requests": len(done),
            "denoise_steps": sched.stats.denoise_steps,
            "occupancy": sched.stats.occupancy()}


def _run_cache(cfg, params, cache: bool):
    from repro.serving.diffusion import DenoiseParams, DiffusionScheduler

    sched = DiffusionScheduler(
        cfg, params, num_slots=SLOTS, seq_len=SEQ_LEN, backend="gather",
        compute_dtype=jnp.float32, refresh_mode="adaptive",
        drift_threshold=CACHE_THRESHOLD, plan_cache=cache,
        t_buckets=T_BUCKETS)
    for i in range(CACHE_REQS):
        sched.submit(_latent(cfg, i),
                     DenoiseParams(num_steps=CACHE_STEPS))
    done = sched.drain()
    st = sched.stats
    cell = {"requests": len(done), "plan_builds": st.plan_builds,
            "plan_replans": st.plan_replans,
            "plan_reuses": st.plan_reuses}
    if cache:
        cell.update(hits=st.plan_cache_hits, misses=st.plan_cache_misses,
                    invalidations=st.plan_cache_invalidations,
                    evictions=st.plan_cache_evictions,
                    entries=len(sched.cache))
    return cell


def recompute_acceptance(payload: dict) -> dict:
    """Derive the acceptance booleans from EXACTLY the cells their
    names point at (fig_decode honesty contract)."""
    parity, cache = payload["parity"], payload["plan_cache"]
    return {
        # the tentpole claim: every request's final latent out of the
        # mixed-timestep batched scheduler is bitwise what its own
        # sequential dit.sample run produces — on BOTH backends
        "dit_batched_bitwise_equal_sequential": all(
            parity[b]["batched_checksum"]
            == parity[b]["sequential_checksum"]
            for b in payload["config"]["backends"]),
        # the amortization claim: cross-request plan reuse cuts plan
        # builds vs per-request planning on a shared-config trace, and
        # the cut came from REAL cache hits, not a shorter trace
        "plan_cache_cuts_plan_builds": (
            cache["cache"]["plan_builds"]
            < cache["no_cache"]["plan_builds"]
            and cache["cache"]["hits"] >= 1),
    }


def run(backend: str = "gather"):
    cfg, params = _setup()
    rows = []
    parity = {}
    for b in BACKENDS:
        parity[b] = _run_parity(cfg, params, b)
        ok = (parity[b]["batched_checksum"]
              == parity[b]["sequential_checksum"])
        rows.append((f"fig_dit_serving.parity.{b}", 0.0,
                     "bitwise" if ok else "MISMATCH"))
    cache = {"no_cache": _run_cache(cfg, params, False),
             "cache": _run_cache(cfg, params, True)}
    rows.append(("fig_dit_serving.plan_builds.no_cache",
                 float(cache["no_cache"]["plan_builds"]),
                 f"{CACHE_REQS} reqs, per-request planning"))
    rows.append(("fig_dit_serving.plan_builds.cache",
                 float(cache["cache"]["plan_builds"]),
                 f"{cache['cache']['hits']} hits / "
                 f"{cache['cache']['misses']} misses, "
                 f"{cache['cache']['invalidations']} invalidations"))
    payload = {
        "config": {"arch": ARCH, "seq_len": SEQ_LEN, "slots": SLOTS,
                   "backends": list(BACKENDS),
                   "parity_trace": [list(x) for x in PARITY_TRACE],
                   "parity_threshold": PARITY_THRESHOLD,
                   "cache_reqs": CACHE_REQS,
                   "cache_steps": CACHE_STEPS,
                   "cache_threshold": CACHE_THRESHOLD,
                   "t_buckets": T_BUCKETS},
        "parity": parity,
        "plan_cache": cache,
    }
    payload["acceptance"] = recompute_acceptance(payload)
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    for key, ok in payload["acceptance"].items():
        rows.append((f"fig_dit_serving.accept.{key}", 0.0,
                     "PASS" if ok else "FAIL"))
    rows.append(("fig_dit_serving.json", 0.0, BENCH_PATH.name))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
