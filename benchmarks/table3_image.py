"""Paper Table 3 (Appendix A.2): image generation — LightningDiT point.

N=1024 (512x512 images), b_q=b_kv=64 -> 16 KV blocks/row; the paper's
87.5% sparsity = 2 critical blocks (kh=12.5%), kl=25%. FLOPs accounting
vs the paper's 12.88G -> 1.73G claim + fidelity proxy at that setting.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks._toy import trained_qkv
from repro.core import SLAConfig, sla_attention, sla_init
from repro.core.flops import full_attention_flops, sla_flops

LDIT = dict(n=1024, d=108, h=16, layers=28)


def run():
    t0 = time.time()
    n, d, h, l = LDIT["n"], LDIT["d"], LDIT["h"], LDIT["layers"]
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.125, kl_frac=0.25,
                    proj_init="identity")
    fl = sla_flops(n, d, h, cfg)
    rows = [
        ("table3.full.GFLOPs_per_layer", 0, round(
            full_attention_flops(n, d, h) / 1e9, 3)),
        ("table3.sla.GFLOPs_per_layer", 0, round(fl["total"] / 1e9, 3)),
        ("table3.sla.sparsity", 0, round(fl["sparsity"], 4)),
        ("table3.sla.reduction_x", 0, round(fl["reduction_x"], 2)),
    ]
    # fidelity proxy at 87.5% sparsity on trained toy attention (N=512,
    # same blocks-per-row regime: 16 blocks of 32)
    q, k, v = trained_qkv()
    cfg_t = SLAConfig(block_q=32, block_kv=32, kh_frac=0.125, kl_frac=0.25,
                      proj_init="identity")
    full = sla_attention(None, q, k, v, cfg_t.replace(mode="full"))
    params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1],
                      cfg_t)
    out = sla_attention(params, q, k, v, cfg_t)
    err = float(jnp.linalg.norm(out - full) / jnp.linalg.norm(full))
    us = (time.time() - t0) * 1e6
    rows.append(("table3.sla.rel_err", us, round(err, 4)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
