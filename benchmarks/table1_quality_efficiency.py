"""Paper Table 1: quality + efficiency of SLA vs ablation baselines.

Efficiency: analytic FLOPs at the Wan2.1 operating point (N=32760, 12
heads, d=128, 30 layers) — validates the paper's 52.75T -> 2.74T (~19x)
accounting. Quality proxy (no video model on CPU): attention-output
rel-L2 error vs full attention on a trained toy DiT's real Q/K/V.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks._toy import trained_qkv
from repro.core import SLAConfig, sla_attention, sla_init
from repro.core.flops import (full_attention_flops, linear_attention_flops,
                              sla_flops)

WAN = dict(n=32760, d=128, h=12, layers=30)


def wan_tflops(mode: str, cfg: SLAConfig) -> float:
    n, d, h, l = WAN["n"], WAN["d"], WAN["h"], WAN["layers"]
    if mode == "full":
        per = full_attention_flops(n, d, h)
    elif mode == "linear_only":
        per = linear_attention_flops(n, d, h)
    elif mode == "sparse_only":
        per = sla_flops(n, d, h, cfg)["sparse"] + \
            sla_flops(n, d, h, cfg)["mask"]
    elif mode == "l_plus_s":
        per = (sla_flops(n, d, h, cfg)["sparse"]
               + sla_flops(n, d, h, cfg)["mask"]
               + linear_attention_flops(n, d, h))
    else:
        per = sla_flops(n, d, h, cfg)["total"]
    return per * l / 1e12


def run():
    t0 = time.time()
    q, k, v = trained_qkv()
    base = SLAConfig(block_q=32, block_kv=32, kh_frac=0.05, kl_frac=0.10,
                     proj_init="identity")
    full = sla_attention(None, q, k, v, base.replace(mode="full"))
    rows = []
    # paper Table 1 rows: Full / Sparse-only@15% / SLA@5% + the L/S ablations
    cases = [
        ("full", base.replace(mode="full"), 0.0),
        ("sparse_only_15pct", base.replace(mode="sparse_only",
                                           kh_frac=0.15), 0.85),
        ("linear_only", base.replace(mode="linear_only"), 1.0),
        ("l_plus_s", base.replace(mode="l_plus_s"), 0.90),
        ("sla_5pct", base.replace(mode="sla", kh_frac=0.05), 0.95),
    ]
    for name, cfg, sparsity in cases:
        params = sla_init(jax.random.PRNGKey(0), q.shape[1], q.shape[-1],
                          cfg)
        out = sla_attention(params, q, k, v, cfg)
        err = float(jnp.linalg.norm(out - full)
                    / jnp.linalg.norm(full)) if name != "full" else 0.0
        tf = wan_tflops(cfg.mode, cfg)
        us = (time.time() - t0) * 1e6
        rows.append((f"table1.{name}.wan_TFLOPs", us, round(tf, 2)))
        rows.append((f"table1.{name}.rel_err", us, round(err, 4)))
    # headline reduction — two conventions:
    # (a) paper's (Table 1 counts ONLY the sparse component: 52.75T ->
    #     2.74T = 19.3x; the linear branch is "<0.5% of full" and mask/
    #     proj overheads are excluded);
    # (b) ours (full systems accounting incl. mask prediction, marginal
    #     aggregation, and Proj).
    tf_full = wan_tflops("full", base)
    cfg5 = base.replace(kh_frac=0.05)
    from repro.core.flops import sla_flops
    n, d, h, l = WAN["n"], WAN["d"], WAN["h"], WAN["layers"]
    sparse_only_paper = sla_flops(n, d, h, cfg5)["sparse"] * l / 1e12
    rows.append(("table1.sla_reduction_x_paper_convention",
                 (time.time() - t0) * 1e6,
                 round(tf_full / sparse_only_paper, 2)))
    tf_sla = wan_tflops("sla", cfg5)
    rows.append(("table1.sla_reduction_x_full_accounting",
                 (time.time() - t0) * 1e6, round(tf_full / tf_sla, 2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
