"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table1]
"""
import argparse
import json
import sys
import traceback

MODULES = [
    "fig1_weight_distribution",
    "fig3_stable_rank",
    "table1_quality_efficiency",
    "table2_ablation",
    "table3_image",
    "fig6_kernel_speed",
    "fig_decode",
    "fig_routing",
    "fig_serving",
    "fig_dit_serving",
]


def decode_headlines() -> list:
    """Headline rows from BENCH_decode.json (written by fig_decode):
    the decode speedups at the largest measured context plus the
    acceptance booleans, so `-m benchmarks.run` surfaces the decode
    story without re-reading the raw cells."""
    from benchmarks.fig_decode import BENCH_PATH

    if not BENCH_PATH.exists():
        return []
    bench = json.loads(BENCH_PATH.read_text())
    rows = []
    n = str(max(int(k) for k in bench["cells"]))
    c = bench["cells"][n]
    rows.append((f"decode.headline.sla_vs_dense.n{n}", 0.0,
                 f"x{c['dense']['per_token_us'] / c['sla_gather']['per_token_us']:.1f}"))
    mn = str(max(int(k) for k in bench["model_cells"]))
    m = bench["model_cells"][mn]
    rows.append((f"decode.headline.chunk_vs_step.n{mn}", 0.0,
                 f"x{m['step_gather']['per_token_us'] / m['chunk_kernel']['per_token_us']:.1f}"))
    for key, ok in bench.get("acceptance", {}).items():
        rows.append((f"decode.accept.{key}", 0.0,
                     "PASS" if ok else "FAIL"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    args = ap.parse_args()
    selected = MODULES
    if args.only:
        keys = args.only.split(",")
        selected = [m for m in MODULES if any(k in m for k in keys)]

    print("name,us_per_call,derived")
    failed = 0
    for name in selected:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failed += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    try:
        for row in decode_headlines():
            print(",".join(str(x) for x in row), flush=True)
    except Exception:
        traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark module(s) failed")


if __name__ == "__main__":
    main()
