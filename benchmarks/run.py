"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table1]
"""
import argparse
import sys
import traceback

MODULES = [
    "fig1_weight_distribution",
    "fig3_stable_rank",
    "table1_quality_efficiency",
    "table2_ablation",
    "table3_image",
    "fig6_kernel_speed",
    "fig_decode",
    "fig_routing",
    "fig_serving",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    args = ap.parse_args()
    selected = MODULES
    if args.only:
        keys = args.only.split(",")
        selected = [m for m in MODULES if any(k in m for k in keys)]

    print("name,us_per_call,derived")
    failed = 0
    for name in selected:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failed += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark module(s) failed")


if __name__ == "__main__":
    main()
