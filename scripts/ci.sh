#!/usr/bin/env bash
# Tier-1 CI: fast tests first (fail fast on core numerics), then the
# slow subprocess/distributed suites. Mirrors ROADMAP.md "Tier-1 verify".
#
#   scripts/ci.sh                 # full split run
#   scripts/ci.sh --fast          # fast tier only
#   scripts/ci.sh --conformance   # cross-backend conformance matrix only
#   scripts/ci.sh --decode        # decode-time SLA parity + drift suites
#   scripts/ci.sh --decode-kernel # fused decode kernel + chunked decode
#   scripts/ci.sh --routing       # learned-routing parity + gradient suite
#   scripts/ci.sh --serve         # serving API v2: scheduler parity suite
#   scripts/ci.sh --paged         # paged KV + CoW prefix sharing suite
#   scripts/ci.sh --chunked-prefill # chunked admission prefill suite
#   scripts/ci.sh --disagg        # disaggregated pools + fault injection
#   scripts/ci.sh --dit-serve     # streaming DiT service + plan cache
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Never let a CI host with a half-configured accelerator hang test
# collection; the suite is CPU-correct (Pallas runs in interpret mode).
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

PYTEST=(python -m pytest -q -p no:cacheprovider)

if [[ "${1:-}" == "--decode" ]]; then
    # Decode-time SLA: incremental-plan properties, decode parity
    # matrix, engine integration, and the drift-refresh suite (the
    # long parity sweeps carry @pytest.mark.slow and run second).
    echo "=== decode-SLA (fast: properties + parity) ==="
    "${PYTEST[@]}" -x -m "not slow" tests/test_decode_sla.py tests/test_drift.py
    echo "=== decode-SLA (slow: long parity sweeps) ==="
    "${PYTEST[@]}" -m slow tests/test_decode_sla.py
    exit 0
fi

if [[ "${1:-}" == "--decode-kernel" ]]; then
    # Fused Pallas decode kernel (DESIGN.md "Fused decode kernel"):
    # decode-backend conformance cells (gather/kernel x f32/bf16 x
    # scalar/vector pos), the kernel parity + custom_vjp gradient +
    # chunked-decode bitwise-parity tests, and the compile-count
    # regression guards for every rolled decode loop.
    echo "=== decode kernel (conformance cells) ==="
    "${PYTEST[@]}" -x tests/test_conformance.py -k decode_backend
    echo "=== decode kernel (parity + grads + chunked decode) ==="
    "${PYTEST[@]}" -x tests/test_decode_sla.py -k "kernel or chunk"
    echo "=== decode kernel (compile-count guards) ==="
    "${PYTEST[@]}" -x tests/test_compile_count.py
    exit 0
fi

if [[ "${1:-}" == "--routing" ]]; then
    # Learned routing (DESIGN.md "Learned routing"): init-parity matrix
    # (bitwise plan/execution equality vs the threshold rule), decode
    # parity, straight-through gradient flow, and the distillation
    # fine-tune smoke; then the slow serving/engine integration cells.
    echo "=== routing (fast: init parity + gradient flow) ==="
    "${PYTEST[@]}" -x -m "not slow" tests/test_routing.py
    echo "=== routing (slow: serve CLI + engine parity) ==="
    "${PYTEST[@]}" -m slow tests/test_routing.py
    exit 0
fi

if [[ "${1:-}" == "--serve" ]]; then
    # Serving API v2 (DESIGN.md "Serving API v2"): continuous-vs-static
    # token parity on staggered arrivals, slot turnover/admission
    # counters, decode-SLA state scatter, streaming event ordering,
    # and the SLAConfig.validate loud-failure matrix; then the slow
    # engine-wrapper parity cell.
    echo "=== serving (fast: scheduler parity + events + validate) ==="
    "${PYTEST[@]}" -x -m "not slow" tests/test_serving.py
    echo "=== serving (slow: continuous engine wrapper) ==="
    "${PYTEST[@]}" -m slow tests/test_serving.py
    exit 0
fi

if [[ "${1:-}" == "--paged" ]]; then
    # Paged KV cache + copy-on-write prefix sharing (DESIGN.md "Paged
    # KV & prefix caching"): PagePool refcount/eviction/exhaustion
    # units, device-level paged-vs-monolithic bitwise parity across
    # every decode backend, the scheduler parity matrix with full
    # cache-leaf equality, CoW divergence after a shared prefix, the
    # page-saving acceptance bound, and a paged serve-CLI smoke.
    echo "=== paged KV (pool units + bitwise parity + CoW) ==="
    "${PYTEST[@]}" -x tests/test_paged.py
    echo "=== paged KV (benchmark-artifact honesty guards) ==="
    "${PYTEST[@]}" -x tests/test_benchmarks.py
    echo "=== paged KV (serve CLI smoke) ==="
    python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --scheduler continuous --paged --requests 4 --max-new 8
    exit 0
fi

if [[ "${1:-}" == "--chunked-prefill" ]]; then
    # Chunked admission prefill (DESIGN.md "Chunked admission
    # prefill"): the bitwise chunked-vs-blocking parity matrix
    # (tokens + cache leaves, gather/kernel x decode-SLA on/off),
    # decode/chunk event interleaving, carry-resume at chunk-aligned
    # shared prefixes, the traced-offset compile-count guard, the
    # snapshot-hit counter invariants, and the nearest-rank percentile
    # fix; then the stall-trace benchmark regenerates
    # BENCH_serving.json and the honesty guards re-check it.
    echo "=== chunked prefill (parity + interleaving + counters) ==="
    "${PYTEST[@]}" -x -k "chunked or percentile or snapshot" \
        tests/test_serving.py tests/test_paged.py
    echo "=== chunked prefill (stall-trace benchmark) ==="
    PYTHONPATH="src:." python benchmarks/fig_serving.py
    echo "=== chunked prefill (benchmark honesty guards) ==="
    "${PYTEST[@]}" -x tests/test_benchmarks.py
    echo "=== chunked prefill (serve CLI smoke) ==="
    python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --scheduler continuous --paged --prefill-chunk 1 \
        --requests 3 --prompt-len 32 --max-new 4
    exit 0
fi

if [[ "${1:-}" == "--disagg" ]]; then
    # Disaggregated prefill/decode pools (DESIGN.md "Disaggregated
    # serving"): fast first — fault-tolerance primitive units
    # (watchdog/retry/FaultPlan; the module is slow-TIERED but cheap,
    # so run it here explicitly) and the fault-injection parity matrix
    # (healthy + kill-requeue bitwise parity, straggler drain,
    # double-fault limbo check, flake backoff); then the slow combined
    # trace-replay scenario, the benchmark's disagg cells + honesty
    # guards, and a disagg serve-CLI smoke.
    echo "=== disagg (fault primitives: watchdog/retry/FaultPlan) ==="
    "${PYTEST[@]}" -x tests/test_fault_tolerance.py
    echo "=== disagg (fast: fault-injection parity matrix) ==="
    "${PYTEST[@]}" -x -m "not slow" tests/test_disagg.py
    echo "=== disagg (slow: mixed-fault trace replay) ==="
    "${PYTEST[@]}" -m slow tests/test_disagg.py
    echo "=== disagg (trace-driven benchmark stage) ==="
    PYTHONPATH="src:." python benchmarks/fig_serving.py
    echo "=== disagg (benchmark honesty guards) ==="
    "${PYTEST[@]}" -x tests/test_benchmarks.py
    echo "=== disagg (serve CLI smoke) ==="
    python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --disagg --prefill-workers 1 --decode-workers 2 \
        --requests 4 --max-new 6 --batch 2 --prompt-len 32
    exit 0
fi

if [[ "${1:-}" == "--dit-serve" ]]; then
    # Streaming DiT denoise service (DESIGN.md "Streaming DiT
    # service"): fast first — plan-cache units (counters, LRU bound,
    # serialization round-trip, compat key), the per-sample refresh
    # lemma, the gather-backend bitwise batched-vs-sequential parity,
    # drift-cache parity, and both paper DiT registry smokes; then the
    # slow reference-backend + fixed-mode parity traces, the
    # parity/plan-cache benchmark regenerating BENCH_dit_serving.json,
    # its honesty guards, and a dit serve-CLI smoke with --stats-json.
    echo "=== dit serve (fast: cache units + gather parity + smokes) ==="
    "${PYTEST[@]}" -x -m "not slow" tests/test_dit_serving.py
    echo "=== dit serve (slow: reference/fixed parity traces) ==="
    "${PYTEST[@]}" -m slow tests/test_dit_serving.py
    echo "=== dit serve (parity + plan-cache benchmark) ==="
    PYTHONPATH="src:." python benchmarks/fig_dit_serving.py
    echo "=== dit serve (benchmark honesty guards) ==="
    "${PYTEST[@]}" -x tests/test_benchmarks.py
    echo "=== dit serve (serve CLI smoke, stats json) ==="
    python -m repro.launch.serve --arch lightningdit_1b --smoke \
        --workload dit --requests 3 --num-steps 3 --seq-len 32 \
        --batch 2 --plan-cache --stats-json /tmp/dit_stats.json
    exit 0
fi

if [[ "${1:-}" == "--conformance" ]]; then
    # The backend-parity matrix (backends x dtypes x causal x
    # fresh/reused plan) from tests/test_conformance.py, standalone:
    # the cheap gate for kernel/backend changes.
    echo "=== conformance matrix (backends x dtypes x plans) ==="
    "${PYTEST[@]}" -x tests/test_conformance.py
    exit 0
fi

echo "=== tier 1 / fast (core numerics, plans, kernels) ==="
"${PYTEST[@]}" -x -m "not slow"

if [[ "${1:-}" == "--fast" ]]; then
    exit 0
fi

echo "=== tier 1 / slow (subprocess, distributed, end-to-end) ==="
"${PYTEST[@]}" -m slow
