"""Re-derive roofline terms from saved (gzipped) HLO texts — no
recompilation. Used to iterate on the cost model and after hillclimb
changes that only affect analysis.

    PYTHONPATH=src python -m repro.roofline.reanalyze --dir artifacts/dryrun
"""
from __future__ import annotations

import argparse
import gzip
import json
import pathlib

from repro.roofline.hlo_cost import analyze
from repro.roofline.analysis import roofline_terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    d = pathlib.Path(args.dir)
    n = 0
    for jf in sorted(d.glob("*.json")):
        rec = json.loads(jf.read_text())
        if rec.get("status") != "ok":
            continue
        hf = d / (jf.stem + ".hlo.txt.gz")
        if not hf.exists():
            continue
        parsed = analyze(gzip.decompress(hf.read_bytes()).decode())
        rec["cost"]["flops_per_device"] = parsed["flops"]
        rec["cost"]["bytes_per_device"] = parsed["bytes"]
        coll = {k.replace("coll_", ""): v for k, v in parsed.items()
                if k.startswith("coll_")}
        coll["total"] = parsed["collective_bytes"]
        coll["count"] = rec["collectives"].get("count", 0)
        rec["collectives"] = coll
        rec["roofline"] = roofline_terms(parsed["flops"], parsed["bytes"],
                                         parsed["collective_bytes"], 1)
        mf = rec.get("model_flops_total", 0.0)
        rec["useful_flops_ratio"] = (
            mf / (parsed["flops"] * rec["chips"]) if parsed["flops"] else 0)
        jf.write_text(json.dumps(rec, indent=2))
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
