"""Roofline analysis from compiled dry-run artifacts (TPU v5e model).

Three terms per (arch x shape x mesh):
  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s per ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective
bytes are parsed out of the HLO text (operand sizes of all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute), since
cost_analysis does not report them.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind from HLO text.

    Two passes: build a {name: result_shape} table, then for each
    collective op sum the byte sizes of its operand names.
    """
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # operand names inside the first (...) after the op name
        call = line[m.end():]
        paren = call.find("(")
        if paren < 0:
            continue
        depth, j = 0, paren
        for j in range(paren, len(call)):
            depth += call[j] == "("
            depth -= call[j] == ")"
            if depth == 0:
                break
        args = call[paren + 1: j]
        nbytes = 0
        for name in re.findall(r"%?([\w.\-]+)", args):
            if name in shapes:
                nbytes += _shape_bytes(shapes[name])
        if nbytes == 0:  # fallback: result shape
            nbytes = _shape_bytes(m.group(2))
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops_total: float, bytes_total: float,
                   coll_bytes: float, chips: int) -> Dict[str, float]:
    """All three terms in seconds PER CHIP (inputs are whole-program)."""
    compute = flops_total / (chips * PEAK_FLOPS)
    memory = bytes_total / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute, memory, collective)
    terms["bound_s"] = total
    terms["roofline_fraction"] = compute / total if total > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) per step.

    N counts *active* parameters touched per token; D is tokens
    processed. For decode shapes D = global_batch (one token each);
    training uses 6ND (fwd+bwd), inference 2ND."""
    params_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * params_active * tokens
    return 2.0 * params_active * shape.global_batch


def active_params(cfg) -> float:
    """Rough active-parameter count from the config (per token)."""
    d, l = cfg.d_model, cfg.num_layers
    attn = d * cfg.head_dim * (cfg.num_heads * 2
                               + cfg.num_kv_heads * 2) * l
    if cfg.num_experts:
        k = cfg.experts_per_token + (1 if cfg.moe_shared_expert else 0)
        ffn = 3.0 * d * cfg.moe_d_ff * k * l
    else:
        ffn = 3.0 * d * cfg.d_ff * l
    if cfg.family == "ssm":
        attn = 6.0 * d * d * l  # r,k,v,g,o + lora
        ffn = 2.5 * d * cfg.d_ff * l
    if cfg.family == "hybrid":
        h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        attn = (d * (2 * h * pd + 2 * n + h) + h * pd * d) * l
        nseg = max(1, cfg.num_layers // max(cfg.attn_every, 1))
        ffn = (d * cfg.head_dim * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
               + 3 * d * cfg.d_ff) * nseg
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "dit":
        emb = cfg.patch_dim * d * 2
    return attn + ffn + emb
