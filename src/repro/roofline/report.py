"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON artifacts.

    PYTHONPATH=src python -m repro.roofline.report --dir artifacts/dryrun
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import List


def load(dir_: str) -> List[dict]:
    recs = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.2f}TiB"


def roofline_table(recs: List[dict], mesh: str = "single") -> str:
    rows = ["| cell | compute s | memory s | collective s | dominant | "
            "HBM GiB | MODEL/HLO flops | one-line diagnosis |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok" or not r["cell"].endswith(mesh):
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio", 0.0)
        dom = t["dominant"].replace("_s", "")
        diag = {
            "compute": "FLOPs-bound: good — push MFU via layout/fusion",
            "memory": "HBM-bound: raise arithmetic intensity "
                      "(batch locality, bf16 state, fusion)",
            "collective": "ICI-bound: reshard or overlap collectives",
        }[dom]
        rows.append(
            f"| {r['cell'].rsplit('__', 1)[0]} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | {dom} | "
            f"{r['memory']['peak_estimate_gib']} | {ratio:.3f} | {diag} |")
    return "\n".join(rows)


def dryrun_table(recs: List[dict]) -> str:
    rows = ["| cell | status | bytes/dev (arg+tmp) | flops/dev | "
            "collective bytes/dev | collectives |",
            "|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['cell']} | SKIP ({r['reason'][:60]}…) "
                        "| — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['cell']} | ERROR | — | — | — | — |")
            continue
        m = r["memory"]
        c = r["collectives"]
        kinds = ", ".join(f"{k}:{fmt_bytes(v)}" for k, v in c.items()
                          if k not in ("count", "total") and v > 0)
        rows.append(
            f"| {r['cell']} | ok | "
            f"{fmt_bytes(m['argument_bytes_per_device'])}+"
            f"{fmt_bytes(m['temp_bytes_per_device'])} | "
            f"{r['cost']['flops_per_device']:.3e} | "
            f"{fmt_bytes(c['total'])} | {kinds or '—'} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--section", default="both",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline (single-pod, per-device terms)\n")
        print(roofline_table(recs, "single"))
        print("\n### Roofline (multi-pod)\n")
        print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
