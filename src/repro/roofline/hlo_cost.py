"""Loop-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts while-loop (lax.scan) bodies
ONCE — for an 88-layer scanned transformer that under-reports FLOPs,
bytes, and collective traffic by ~88x. This module walks the optimized
HLO text, recovers each while loop's trip count from its condition
computation (scan emits `compare(counter, constant(N)), direction=LT`),
and accumulates:

  flops      — dot ops: 2 * prod(result dims) * prod(contracting dims);
               elementwise at fusion granularity: prod(result dims).
  bytes      — HBM traffic model: operand + result bytes at fusion /
               top-level-op boundaries (XLA materializes exactly these).
  collective — operand bytes per collective kind, x trip counts.

Validated against closed-form counts in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s+\(.*\)\s*->.*\{\s*$")

_DATA_MOVERS = {
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "slice", "pad", "concatenate", "reshape", "transpose", "broadcast",
    "reverse", "select-and-scatter",
}

_KNOWN_OPS = {
    "dot", "fusion", "while", "conditional", "call", "custom-call",
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "convolution", "iota", "async-start", "async-done",
} | _DATA_MOVERS | set(_COLLECTIVES) | \
    {c + "-start" for c in _COLLECTIVES} | \
    {c + "-done" for c in _COLLECTIVES}

_CALL_TOKEN_RE = re.compile(r"([a-z][\w\-]*)\(")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]  # symbol table: op name -> result shape
    by_name: Dict[str, Op] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in _COLLECTIVES:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _parse_op(ls: str) -> Optional[Op]:
    """Parse '%name = <shape> opcode(...), attrs' robustly: the opcode is
    the first known-op token followed by '('; unknown ops are 'generic'
    (elementwise/data-movement — costed from the result shape alone)."""
    if " = " not in ls:
        return None
    lhs, rest = ls.split(" = ", 1)
    name = lhs.strip()
    if name.startswith("ROOT "):
        name = name[5:].strip()
    name = name.lstrip("%")
    opcode, shape = None, None
    for m in _CALL_TOKEN_RE.finditer(rest):
        tok = m.group(1)
        if tok in _KNOWN_OPS:
            opcode, shape = tok, rest[: m.start()].strip()
            break
    if opcode is None:
        opcode, shape = "generic", rest
    return Op(name, shape, opcode, ls)


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in hlo.splitlines():
        ls = raw.strip()
        m = _COMP_RE.match(ls)
        if m:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            if ls.startswith("ENTRY"):
                entry = cur.name
            continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        op = _parse_op(ls)
        if op is not None:
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape
            cur.by_name[op.name] = op
    return comps, entry


def _bf16_legalized(operand: str, comp: Computation,
                    comps: Dict[str, Computation]) -> bool:
    """True if `operand` (an f32 tensor) is really a bf16 value that the
    CPU backend upcast (no native bf16): its producer is convert(bf16) or
    a fusion whose ROOT is convert(bf16). Collectives on such values run
    in bf16 on TPU — count half the bytes."""
    op = comp.by_name.get(operand)
    if op is None:
        return False
    if op.opcode == "generic" and " convert(" in op.line:
        src = _operands(op.line)
        return bool(src) and "bf16[" in comp.shapes.get(src[0], "")
    if op.opcode == "fusion":
        callee = _called(op.line, "calls")
        sub = comps.get(callee)
        if sub is None:
            return False
        for o in sub.ops:
            if "ROOT" in o.line and " convert(" in o.line:
                src = _operands(o.line)
                return bool(src) and "bf16[" in sub.shapes.get(src[0], "")
    return False


def _called(line: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=%?([\w.\-$]+)", line)
    return m.group(1) if m else None


def _operands(line: str) -> List[str]:
    paren = line.find("(", line.find("=") + 1)
    if paren < 0:
        return []
    depth, j = 0, paren
    for j in range(paren, len(line)):
        depth += line[j] == "("
        depth -= line[j] == ")"
        if depth == 0:
            break
    return re.findall(r"%([\w.\-$]+)", line[paren + 1: j])


def trip_count(cond: Computation) -> int:
    """Scan-style condition: compare(counter, constant(N)) LT -> N."""
    consts = [int(m.group(1))
              for op in cond.ops
              for m in [re.search(r"constant\((\d+)\)", op.line)]
              if m]
    return max(consts) if consts else 1


def _dot_flops(op: Op, comp: Computation) -> float:
    result_elems = _numel(op.shape)
    opnds = _operands(op.line)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m or not opnds:
        return 2.0 * result_elems  # fallback
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs_shape = comp.shapes.get(opnds[0], "")
    dims = _shape_dims(lhs_shape)
    if not dims:
        return 2.0 * result_elems
    lhs_dims = dims[0][1]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * result_elems * k


def _eff_bytes(operand: str, comp: Computation,
               comps: Dict[str, Computation]) -> float:
    """Operand bytes with the CPU bf16->f32 legalization halving."""
    shape = comp.shapes.get(operand, "")
    b = _shape_bytes(shape)
    if "f32[" in shape and _bf16_legalized(operand, comp, comps):
        return b * 0.5
    return b


_PARAM_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_param_charges(callee: str, comps: Dict[str, Computation]
                          ) -> Dict[int, float]:
    """Per-parameter-index byte charge for a fused computation.

    A parameter consumed ONLY via dynamic-slice / gather touches just the
    sliced region — charging the full operand would bill a scan body for
    its entire stacked (L, ...) weights EVERY iteration (measured 100x
    inflation on the rwkv6 cell)."""
    sub = comps.get(callee)
    if sub is None:
        return {}
    pidx: Dict[str, int] = {}
    for o in sub.ops:
        if o.opcode == "parameter":
            m = _PARAM_RE.search(o.line)
            if m:
                pidx[o.name] = int(m.group(1))
    charge: Dict[int, object] = {}
    for o in sub.ops:
        if o.opcode == "parameter":
            continue
        srcs = _operands(o.line)
        for pos, src in enumerate(srcs):
            if src not in pidx:
                continue
            i = pidx[src]
            sliced = (o.opcode in ("dynamic-slice", "gather")
                      and pos == 0)
            if sliced and charge.get(i) != "full":
                charge[i] = charge.get(i, 0.0) + 2.0 * _shape_bytes(o.shape)
            else:
                charge[i] = "full"
    return {i: v for i, v in charge.items() if v != "full"}


def comp_cost(name: str, comps: Dict[str, Computation],
              memo: Dict[str, Cost], fused: bool = False) -> Cost:
    """Cost of one computation. `fused=True` -> inside a fusion: count
    dot flops but no boundary bytes (counted at the fusion op)."""
    key = (name, fused)
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    total = Cost()
    if comp is None:
        memo[key] = total
        return total
    for op in comp.ops:
        oc = op.opcode
        if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "copy", "iota") or oc.endswith("-done"):
            continue
        if oc == "while":
            body = _called(op.line, "body")
            cond = _called(op.line, "condition")
            n = trip_count(comps[cond]) if cond in comps else 1
            total += comp_cost(body, comps, memo).scaled(n)
            continue
        if oc == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                  op.line)
            names = (re.findall(r"%?([\w.\-$]+)", branches[0])
                     if branches else [])
            tc = _called(op.line, "true_computation")
            fc = _called(op.line, "false_computation")
            names += [x for x in (tc, fc) if x]
            if names:
                costs = [comp_cost(b, comps, memo) for b in names]
                total += max(costs, key=lambda c: c.flops + c.bytes)
            continue
        if oc == "fusion":
            callee = _called(op.line, "calls")
            inner = comp_cost(callee, comps, memo, fused=True)
            total.flops += inner.flops
            if not fused:
                nbytes = _shape_bytes(op.shape)
                if "f32[" in op.shape and _bf16_legalized(op.name, comp,
                                                          comps):
                    nbytes *= 0.5
                charges = _fusion_param_charges(callee, comps)
                for pos, o in enumerate(_operands(op.line)):
                    if pos in charges:
                        nbytes += charges[pos]
                    else:
                        nbytes += _eff_bytes(o, comp, comps)
                total.bytes += nbytes
            continue
        if oc in ("call", "async-start", "async-done", "custom-call"):
            callee = _called(op.line, "calls") or \
                _called(op.line, "called_computations=\\{")
            if callee:
                total += comp_cost(callee, comps, memo, fused=fused)
            if not fused and oc != "call":
                total.bytes += _shape_bytes(op.shape)
            continue
        if oc in _DATA_MOVERS:
            # data movement: traffic = touched bytes (read + write of the
            # RESULT region), NOT operand bytes — a dynamic-slice of the
            # stacked (L, d, e) scan params touches one layer's slice.
            # For dynamic-update-slice the touched region is the update
            # operand (2nd), read+written in place under aliasing.
            if fused:
                continue
            if oc == "dynamic-update-slice":
                opnds = _operands(op.line)
                upd = (comp.shapes.get(opnds[1], "")
                       if len(opnds) > 1 else op.shape)
                total.bytes += 2.0 * _shape_bytes(upd)
            else:
                total.bytes += 2.0 * _shape_bytes(op.shape)
            continue
        kind = next((c for c in _COLLECTIVES if oc.startswith(c)), None)
        if kind is not None:
            nbytes = 0.0
            for o in _operands(op.line):
                b = _shape_bytes(comp.shapes.get(o, ""))
                if "f32[" in comp.shapes.get(o, "") and \
                        _bf16_legalized(o, comp, comps):
                    b *= 0.5  # CPU-backend bf16->f32 legalization artifact
                nbytes += b
            if nbytes == 0:
                nbytes = _shape_bytes(op.shape)
            # ring all-reduce moves ~2x the payload of RS/AG per chip
            total.coll[kind] += nbytes * (2.0 if kind == "all-reduce"
                                          else 1.0)
            total.bytes += _shape_bytes(op.shape)
            continue
        if oc == "dot":
            total.flops += _dot_flops(op, comp)
            if not fused:
                nbytes = _shape_bytes(op.shape)
                for o in _operands(op.line):
                    nbytes += _eff_bytes(o, comp, comps)
                total.bytes += nbytes
            continue
        if oc == "convolution":
            total.flops += 2.0 * _numel(op.shape) * 128  # rough
            if not fused:
                total.bytes += _shape_bytes(op.shape)
            continue
        # generic elementwise / data movement
        total.flops += _numel(op.shape)
        if not fused:
            nbytes = _shape_bytes(op.shape)
            for o in _operands(op.line):
                nbytes += _eff_bytes(o, comp, comps)
            total.bytes += nbytes
    memo[key] = total
    return total


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """XLA's own `compiled.cost_analysis()` with its cross-version shape
    normalized: older jax returns one dict, newer returns a list with one
    dict per partitioned executable. Always returns a flat {metric: value}
    dict ({} when XLA reports nothing), so callers can index ["flops"]
    regardless of the installed jax."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        out: Dict[str, float] = {}
        for entry in ca:
            for key, val in (entry or {}).items():
                out[key] = out.get(key, 0.0) + float(val)
        return out
    return dict(ca)


def analyze(hlo_text: str) -> Dict[str, float]:
    comps, entry = parse_computations(hlo_text)
    cost = comp_cost(entry, comps, {})
    out = {"flops": cost.flops, "bytes": cost.bytes,
           "collective_bytes": cost.coll_total}
    out.update({f"coll_{k}": v for k, v in cost.coll.items()})
    return out
