"""Fused SLA decode Pallas TPU kernel (ISSUE 6 tentpole).

One launch covers a *chunk* of C decode tokens (C = 1 is the plain
`decode_step` shape): grid (B*H, C, K_sel). The trailing axis streams
the critical KV pages named by the per-token scalar-prefetched LUT
(`state["lut"]` / `state["cnt"]`), carrying online-softmax state in
VMEM scratch exactly like the prefill kernel (`sla_fwd`). Fused into
the same launch, the selected blocks' linear summaries (hblk / zblk)
accumulate into scratch so the finalize step can apply the subtractive
marginal aggregation of paper App. A.3 —

    H_marg = htot - sum_{j in lut} hblk[j]

against the running H/Z totals, replacing the 6-gather/einsum chain of
`backends._decode_gather_backend` with a single kernel. Exact because
decode plans classify with kl_frac = 0 (every valid non-critical block
is marginal; `SLAConfig.decode_plan_cfg`).

The public entry is wrapped in a `custom_vjp` whose backward runs
plain-JAX autodiff over `_decode_math` — a chunk-aware twin of the
gather backend's math — so learned-routing gradients flow through the
plan's marginal aggregation with the gather backend's contract. Integer
plan inputs (lut / cnt / marg / positions) get float0 tangents.

On hosts without a TPU the kernel runs in Pallas interpret mode (see
`backends._decode_kernel_backend` for the one-line warning); numerics
are identical either way: f32 accumulation, bf16 inputs cast on load.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import reference as ref
from repro.core.config import SLAConfig

NEG_INF = -1e30
EPS = 1e-6
LANES = 128


def _dot(a, b, trans_b=False):
    dims = (((1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


def _decode_kernel(lut_ref, cnt_ref, marg_ref, pos_ref,  # scalar prefetch
                   q_ref, qp_ref, k_ref, v_ref, hb_ref, zb_ref,
                   hd_ref, zd_ref, ht_ref, zt_ref,       # inputs
                   os_ref, ol_ref,                       # outputs
                   acc_ref, m_ref, l_ref, hsel_ref, zsel_ref,  # VMEM scratch
                   *, scale: float, k_sel: int, block_kv: int):
    bh, c, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        hsel_ref[...] = jnp.zeros_like(hsel_ref)
        zsel_ref[...] = jnp.zeros_like(zsel_ref)

    @pl.when(s < cnt_ref[bh, c])
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (1, d)
        kk = k_ref[0, 0].astype(jnp.float32)              # (bkv, d)
        sij = _dot(q, kk, trans_b=True) * scale           # (1, bkv)
        j = lut_ref[bh, c, s]
        cols = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        sij = jnp.where(cols <= pos_ref[bh] + c, sij, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(sij, axis=-1))
        p = jnp.exp(sij - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + _dot(p, v_ref[0, 0].astype(jnp.float32)))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
        # the diagonal block is still accumulating mid-chunk: its
        # streamed hblk/zblk are end-of-chunk values, so substitute the
        # per-token at-time partials (chunk boundary protocol; for
        # single-token decode hd/zd == the streamed block, a no-op)
        is_diag = j == (pos_ref[bh] + c) // block_kv
        hsel_ref[...] += jnp.where(is_diag, hd_ref[0, 0], hb_ref[0, 0])
        zsel_ref[...] += jnp.where(is_diag, zd_ref[0], zb_ref[0])

    @pl.when(s == k_sel - 1)
    def _finalize():
        l = l_ref[:, 0]
        alive = l > 0.0
        os_ref[0] = (acc_ref[...]
                     / jnp.where(alive, l, 1.0)[:, None]).astype(os_ref.dtype)
        # subtractive marginal linear branch against the H/Z totals
        qp = qp_ref[0].astype(jnp.float32)                # (1, d)
        h_m = ht_ref[0, 0] - hsel_ref[...]                # (d, d)
        z_m = zt_ref[0] - zsel_ref[...]                   # (1, d)
        num = _dot(qp, h_m)                               # (1, d)
        den = jnp.sum(qp * z_m, axis=-1, keepdims=True)   # (1, 1)
        live = jnp.logical_and(den > EPS, marg_ref[bh, c] > 0)
        ol = jnp.where(live, num / jnp.where(live, den, 1.0), 0.0)
        ol_ref[0] = ol.astype(ol_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_kv", "group", "interpret"))
def _fused_decode(lut, cnt, marg, posv, q, qp, k, v, hblk, zblk,
                  hdiag, zdiag, htot, ztot,
                  *, scale, block_kv, group, interpret):
    """Flat-layout fused decode: one launch for C tokens x K_sel blocks.

    lut: (BH, C, K) int32; cnt/marg: (BH, C) int32; posv: (BH,) int32
    base positions (token c sits at posv + c). q/qp: (BH, C, D).
    k/v: (BH_kv, Tn, bkv, D); hblk: (BH_kv, Tn, D, D); zblk: (BH_kv,
    Tn, D); hdiag/htot: per-token snapshots (BH_kv, C, D, D);
    zdiag/ztot: (BH_kv, C, D). Returns (o_s, o_l) both (BH, C, D) f32.
    """
    bh, c, k_sel = lut.shape
    d = q.shape[-1]
    grid = (bh, c, k_sel)

    kern = functools.partial(
        _decode_kernel, scale=scale, k_sel=k_sel, block_kv=block_kv)

    def kv_map(bh_i, c_i, s, lut_ref, *_):
        return (bh_i // group, lut_ref[bh_i, c_i, s], 0, 0)

    def z_map(bh_i, c_i, s, lut_ref, *_):
        return (bh_i // group, lut_ref[bh_i, c_i, s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i, c_i, 0)),                        # q
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i, c_i, 0)),                        # qp
            pl.BlockSpec((1, 1, block_kv, d), kv_map),           # k
            pl.BlockSpec((1, 1, block_kv, d), kv_map),           # v
            pl.BlockSpec((1, 1, d, d), kv_map),                  # hblk
            pl.BlockSpec((1, 1, d), z_map),                      # zblk
            pl.BlockSpec((1, 1, d, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0, 0)),            # hdiag
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0)),               # zdiag
            pl.BlockSpec((1, 1, d, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0, 0)),            # htot
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0)),               # ztot
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_: (bh_i, c_i, 0)),
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_: (bh_i, c_i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),       # acc
            pltpu.VMEM((1, LANES), jnp.float32),   # m
            pltpu.VMEM((1, LANES), jnp.float32),   # l
            pltpu.VMEM((d, d), jnp.float32),       # hsel
            pltpu.VMEM((1, d), jnp.float32),       # zsel
        ],
    )

    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bh, c, d), jnp.float32)] * 2,
        interpret=interpret,
    )(lut, cnt, marg, posv, q, qp, k, v, hblk, zblk, hdiag, zdiag,
      htot, ztot)


def _decode_kernel_paged(lut_ref, plut_ref, cnt_ref, marg_ref, pos_ref,
                         *args, **kw):
    """Paged kernel body: identical math to `_decode_kernel` — the extra
    `plut_ref` (physical page ids) is consumed only by the BlockSpec
    index maps that stream KV/h/z pages out of the global pools; all
    masking arithmetic (column ids, diagonal detection) stays on the
    LOGICAL block ids in `lut_ref`."""
    return _decode_kernel(lut_ref, cnt_ref, marg_ref, pos_ref, *args, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_kv", "group", "hkv", "interpret"))
def _fused_decode_paged(lut, plut, cnt, marg, posv, q, qp, k, v, hblk, zblk,
                        hdiag, zdiag, htot, ztot,
                        *, scale, block_kv, group, hkv, interpret):
    """Paged fused decode (DESIGN.md "Paged KV & prefix caching"): the
    scalar-prefetched LUT points at the PAGE TABLE instead of contiguous
    cache rows.

    lut: (BH, C, K) logical block ids (masking math); plut: (BH, C, K)
    physical page ids (plut = pt[b, lut] — the block-streaming index).
    k/v: (Hkv, P, bkv, D) and hblk: (Hkv, P, D, D) / zblk: (Hkv, P, D)
    are the global page pools, head-major so the index map addresses
    them as ((bh // group) % hkv, page); per-token operands (q/qp,
    hdiag/zdiag, htot/ztot) keep the flat (B*H / B*Hkv, C, ...) layout
    of `_fused_decode`. Returns (o_s, o_l) both (BH, C, D) f32."""
    bh, c, k_sel = lut.shape
    d = q.shape[-1]
    grid = (bh, c, k_sel)

    kern = functools.partial(
        _decode_kernel_paged, scale=scale, k_sel=k_sel, block_kv=block_kv)

    def kv_map(bh_i, c_i, s, lut_ref, plut_ref, *_):
        return ((bh_i // group) % hkv, plut_ref[bh_i, c_i, s], 0, 0)

    def z_map(bh_i, c_i, s, lut_ref, plut_ref, *_):
        return ((bh_i // group) % hkv, plut_ref[bh_i, c_i, s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i, c_i, 0)),                        # q
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i, c_i, 0)),                        # qp
            pl.BlockSpec((1, 1, block_kv, d), kv_map),           # k pool
            pl.BlockSpec((1, 1, block_kv, d), kv_map),           # v pool
            pl.BlockSpec((1, 1, d, d), kv_map),                  # hblk pool
            pl.BlockSpec((1, 1, d), z_map),                      # zblk pool
            pl.BlockSpec((1, 1, d, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0, 0)),            # hdiag
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0)),               # zdiag
            pl.BlockSpec((1, 1, d, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0, 0)),            # htot
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_:
                         (bh_i // group, c_i, 0)),               # ztot
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_: (bh_i, c_i, 0)),
            pl.BlockSpec((1, 1, d), lambda bh_i, c_i, s, *_: (bh_i, c_i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),       # acc
            pltpu.VMEM((1, LANES), jnp.float32),   # m
            pltpu.VMEM((1, LANES), jnp.float32),   # l
            pltpu.VMEM((d, d), jnp.float32),       # hsel
            pltpu.VMEM((1, d), jnp.float32),       # zsel
        ],
    )

    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bh, c, d), jnp.float32)] * 2,
        interpret=interpret,
    )(lut, plut, cnt, marg, posv, q, qp, k, v, hblk, zblk, hdiag, zdiag,
      htot, ztot)


def _decode_attention_paged(state, qg, qpg, pos, cfg: SLAConfig, scale,
                            interpret: bool):
    """Paged entry: route the live-row LUT through the page table and
    launch `_fused_decode_paged` against the global pools. Single-token
    steps only (the chunked path snapshots per-token state the paged
    scheduler never builds); inference-only — no custom VJP (serving
    decode never differentiates)."""
    b, hkv, g, cdim, d = qg.shape
    if cdim != 1:
        raise ValueError(
            "paged fused decode supports single-token steps only "
            f"(got chunk of {cdim})")
    h = hkv * g
    bh = b * h
    pt = state["pt"]
    tn = pt.shape[1]
    bkv = cfg.block_kv
    lut, cnt, marg = state["lut"], state["cnt"], state["marg"]
    plut = jax.vmap(lambda row, l: row[l])(pt, lut)       # (B, H, K)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    # the diagonal (still-accumulating) block's partials, read from the
    # pool at the slot's current page (clamp keeps runaway inactive
    # slots on a valid — scratch — page)
    dpid = pt[jnp.arange(b), jnp.minimum(posv // bkv, tn - 1)]
    hdiag = state["hblk"][dpid]                           # (B, Hkv, D, D)
    zdiag = state["zblk"][dpid]                           # (B, Hkv, D)
    k_sel = lut.shape[-1]
    scale = float(d**-0.5) if scale is None else float(scale)
    o_s, o_l = _fused_decode_paged(
        lut.reshape(bh, 1, k_sel).astype(jnp.int32),
        plut.reshape(bh, 1, k_sel).astype(jnp.int32),
        cnt.reshape(bh, 1).astype(jnp.int32),
        marg.reshape(bh, 1).astype(jnp.int32),
        jnp.repeat(posv, h),
        qg.astype(jnp.float32).reshape(bh, 1, d),
        qpg.astype(jnp.float32).reshape(bh, 1, d),
        jnp.moveaxis(state["k"], 0, 1), jnp.moveaxis(state["v"], 0, 1),
        jnp.moveaxis(state["hblk"], 0, 1),
        jnp.moveaxis(state["zblk"], 0, 1),
        hdiag.reshape(b * hkv, 1, d, d), zdiag.reshape(b * hkv, 1, d),
        state["htot"].reshape(b * hkv, 1, d, d),
        state["ztot"].reshape(b * hkv, 1, d),
        scale=scale, block_kv=bkv, group=g, hkv=hkv,
        interpret=bool(interpret))
    shape = (b, hkv, g, 1, d)
    return o_s.reshape(shape), o_l.reshape(shape)


# ---------------------------------------------------------------------------
# plain-JAX twin: the gather backend's math with a chunk axis
# ---------------------------------------------------------------------------
def _decode_math(q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot,
                 lut, cnt, marg, posv, cfg: SLAConfig, scale: float):
    """Chunk-aware gather-backend math (autodiff reference + VJP body).

    q/qp: (B, Hkv, G, C, D) f32; kc/vc: (B, Hkv, Smax, D);
    hblk: (B, Hkv, Tn, D, D); zblk: (B, Hkv, Tn, D); hdiag/htot:
    per-token snapshots (B, Hkv, C, D, D); zdiag/ztot: (B, Hkv, C, D);
    lut: (B, Hkv, G, C, K) int32; cnt/marg: (B, Hkv, G, C) int32;
    posv: (B,) int32 base positions. Returns (o_s, o_l), both
    (B, Hkv, G, C, D) f32 — for C = 1 this reduces term-for-term to
    `backends._decode_gather_backend`.
    """
    b, hkv, g, cdim, d = q.shape
    bkv = cfg.block_kv
    tn = kc.shape[2] // bkv
    k_sel = lut.shape[-1]
    idx = lut.reshape(b, hkv, -1)

    def gat(x):
        pad = (1,) * (x.ndim - 3)
        out = jnp.take_along_axis(x, idx.reshape(b, hkv, -1, *pad), axis=2)
        return out.reshape(b, hkv, g, cdim, k_sel, *x.shape[3:])

    kg = gat(kc.astype(jnp.float32).reshape(b, hkv, tn, bkv, d))
    vg = gat(vc.astype(jnp.float32).reshape(b, hkv, tn, bkv, d))
    s = jnp.einsum("bngcd,bngckvd->bngckv", q, kg) * scale
    pos_tok = posv[:, None] + jnp.arange(cdim)           # (B, C)
    cols = lut[..., None] * bkv + jnp.arange(bkv)        # (B,Hkv,G,C,K,bkv)
    live = jnp.arange(k_sel) < cnt[..., None]            # (B,Hkv,G,C,K)
    ok = jnp.logical_and(
        cols <= pos_tok[:, None, None, :, None, None], live[..., None])
    sf = jnp.where(ok, s, NEG_INF).reshape(b, hkv, g, cdim, k_sel * bkv)
    m = jnp.max(sf, axis=-1, keepdims=True)
    p = jnp.exp(sf - m)
    o_s = jnp.einsum("bngck,bngckd->bngcd",
                     p / jnp.sum(p, -1, keepdims=True),
                     vg.reshape(b, hkv, g, cdim, k_sel * bkv, d))
    # subtractive marginal aggregation against the per-token totals;
    # the mid-chunk diagonal block reads its at-time partial (chunk
    # boundary protocol, same substitution as the kernel)
    is_diag = lut == (pos_tok // bkv)[:, None, None, :, None]
    hg = jnp.where(is_diag[..., None, None],
                   hdiag[:, :, None, :, None], gat(hblk))
    zg = jnp.where(is_diag[..., None], zdiag[:, :, None, :, None], gat(zblk))
    hg = jnp.where(live[..., None, None], hg, 0.0)
    zg = jnp.where(live[..., None], zg, 0.0)
    h_m = htot[:, :, None] - jnp.sum(hg, axis=4)         # (B,Hkv,G,C,D,D)
    z_m = ztot[:, :, None] - jnp.sum(zg, axis=4)
    num = jnp.einsum("bngcd,bngcde->bngce", qp, h_m)
    den = jnp.einsum("bngcd,bngcd->bngc", qp, z_m)[..., None]
    o_l = ref._safe_div(num, den)
    o_l = jnp.where(marg[..., None] > 0, o_l, 0.0)
    return o_s, o_l


# ---------------------------------------------------------------------------
# custom_vjp: Pallas forward, gather-math backward
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(14, 15, 16))
def _decode_core(q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot,
                 lut, cnt, marg, posv, cfg, scale, interpret):
    out, _ = _decode_core_fwd(q, qp, kc, vc, hblk, zblk, hdiag, zdiag,
                              htot, ztot, lut, cnt, marg, posv,
                              cfg, scale, interpret)
    return out


def _decode_core_fwd(q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot,
                     lut, cnt, marg, posv, cfg, scale, interpret):
    b, hkv, g, cdim, d = q.shape
    h = hkv * g
    bh = b * h
    bkv = cfg.block_kv
    tn = kc.shape[2] // bkv
    k_sel = lut.shape[-1]
    # (b, hkv, g, ...) flattens so flat bh // g == b * hkv + n exactly
    # as the prefill kernel's head layout (bh = b*H + n*g + gi).
    o_s, o_l = _fused_decode(
        lut.reshape(bh, cdim, k_sel),
        cnt.reshape(bh, cdim).astype(jnp.int32),
        marg.reshape(bh, cdim).astype(jnp.int32),
        jnp.repeat(posv.astype(jnp.int32), h),
        q.reshape(bh, cdim, d), qp.reshape(bh, cdim, d),
        kc.reshape(b * hkv, tn, bkv, d), vc.reshape(b * hkv, tn, bkv, d),
        hblk.reshape(b * hkv, tn, d, d), zblk.reshape(b * hkv, tn, d),
        hdiag.reshape(b * hkv, cdim, d, d), zdiag.reshape(b * hkv, cdim, d),
        htot.reshape(b * hkv, cdim, d, d), ztot.reshape(b * hkv, cdim, d),
        scale=scale, block_kv=bkv, group=g, interpret=interpret)
    shape = (b, hkv, g, cdim, d)
    out = (o_s.reshape(shape), o_l.reshape(shape))
    res = (q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot,
           lut, cnt, marg, posv)
    return out, res


def _decode_core_bwd(cfg, scale, interpret, res, cts):
    (q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot,
     lut, cnt, marg, posv) = res

    def f(q_, qp_, k_, v_, hb_, zb_, hd_, zd_, ht_, zt_):
        return _decode_math(q_, qp_, k_, v_, hb_, zb_, hd_, zd_, ht_, zt_,
                            lut, cnt, marg, posv, cfg, scale)

    _, vjp = jax.vjp(f, q, qp, kc, vc, hblk, zblk, hdiag, zdiag, htot, ztot)
    grads = vjp(cts)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)  # noqa: E731
    return grads + (f0(lut), f0(cnt), f0(marg), f0(posv))


_decode_core.defvjp(_decode_core_fwd, _decode_core_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def decode_attention(state, qg, qpg, pos, cfg: SLAConfig, scale=None,
                     interpret: bool = True):
    """Fused decode attention for a chunk of C tokens.

    qg / qpg: (B, Hkv, G, C, D) grouped queries (C = 1 for single-token
    decode). `state` is the decode-cache slice of `backends`: k/v
    (B, Hkv, Smax, D); hblk (B, Hkv, Tn, D, D); zblk (B, Hkv, Tn, D);
    htot/ztot either running totals (B, Hkv, D, D) — broadcast to every
    token — or per-token chunk snapshots with a C axis at dim 2;
    lut/cnt/marg either live-row (B, H, K)/(B, H) or per-token with a C
    axis before K. `pos` is the base position: scalar or (B,) per-slot
    (token c sits at pos + c). Returns (o_s, o_l), both
    (B, Hkv, G, C, D) f32; gradients flow through q/qp/k/v/hblk/zblk/
    htot/ztot via the gather-math VJP.
    """
    if "pt" in state:
        return _decode_attention_paged(state, qg, qpg, pos, cfg, scale,
                                       interpret)
    b, hkv, g, cdim, d = qg.shape
    lut, cnt, marg = state["lut"], state["cnt"], state["marg"]
    if lut.ndim == 3:                       # (B, H, K) live-row layout:
        # every chunk token shares the one live plan row
        lut = jnp.broadcast_to(lut[:, :, None],
                               (*lut.shape[:2], cdim, lut.shape[-1]))
        cnt = jnp.broadcast_to(cnt[..., None], (*cnt.shape, cdim))
        marg = jnp.broadcast_to(marg[..., None], (*marg.shape, cdim))
    htot, ztot = state["htot"], state["ztot"]
    if htot.ndim == 4:                      # (B, Hkv, D, D) running total
        htot = jnp.broadcast_to(htot[:, :, None], (b, hkv, cdim, d, d))
        ztot = jnp.broadcast_to(ztot[:, :, None], (b, hkv, cdim, d))
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    hdiag, zdiag = state.get("hdiag"), state.get("zdiag")
    if hdiag is None:
        # live-row decode: the at-time diagonal partial IS the stored
        # block — slice it so the kernel's substitution is a no-op
        rows = (posv[:, None] + jnp.arange(cdim)) // cfg.block_kv  # (B, C)
        hdiag = jnp.take_along_axis(
            state["hblk"], rows[:, None, :, None, None], axis=2)
        zdiag = jnp.take_along_axis(
            state["zblk"], rows[:, None, :, None], axis=2)
    k_sel = lut.shape[-1]
    lutg = lut.reshape(b, hkv, g, cdim, k_sel)
    cntg = cnt.reshape(b, hkv, g, cdim)
    margg = marg.reshape(b, hkv, g, cdim)
    scale = float(d**-0.5) if scale is None else float(scale)
    return _decode_core(qg.astype(jnp.float32), qpg.astype(jnp.float32),
                        state["k"], state["v"], state["hblk"], state["zblk"],
                        hdiag, zdiag, htot, ztot, lutg, cntg, margg, posv,
                        cfg, scale, bool(interpret))
