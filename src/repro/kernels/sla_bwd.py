"""SLA backward Pallas TPU kernels (paper Alg. 2, sparse component).

Two kernels (TPU has no atomics, so each gradient is produced by the pass
whose grid axis owns it — the FlashAttention-2 decomposition):

  dQ kernel : grid (BH, T_m, K_sel) over the *row* LUT — accumulates
              dQ_i += dS_ij K_j in VMEM scratch across the critical blocks
              of row i.
  dKV kernel: grid (BH, T_n, W_col) over the *column* LUT — accumulates
              dK_j += dS_ij^T Q_i and dV_j += P_ij^T dO_i. The column LUT
              has static width W_col thanks to the column-capacity
              constraint on the mask (DESIGN.md §3).

P_ij is recomputed from the stored row log-sum-exp L_i (no O(N^2) residual
is ever materialized). The linear-branch gradients are dense matmuls and
live in ops.py (XLA/MXU path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dot(a, b, trans_a=False, trans_b=False):
    dims = (((0 if trans_a else 1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


def _recompute_p(q, kk, lse_row, *, scale, causal, i, j, block_q, block_kv):
    """P_ij = exp(S_ij - L_i), with the token-causal mask zeroing inside the
    diagonal block (exp(NEG_INF - L) underflows to exactly 0)."""
    sij = _dot(q, kk, trans_b=True) * scale
    if causal:
        rows = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        cols = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        sij = jnp.where(rows >= cols, sij, NEG_INF)
    return jnp.exp(sij - lse_row[:, None])


def _dq_kernel(lut_ref, counts_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, ds_ref,
               dq_ref, dq_acc,
               *, scale: float, k_sel: int, causal: bool,
               block_q: int, block_kv: int):
    bh, i, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when(s < counts_ref[bh, i])
    def _step():
        j = lut_ref[bh, i, s]
        q = q_ref[0].astype(jnp.float32)
        kk = k_ref[0].astype(jnp.float32)
        p = _recompute_p(q, kk, lse_ref[0, 0], scale=scale, causal=causal,
                         i=i, j=j, block_q=block_q, block_kv=block_kv)
        do = do_ref[0].astype(jnp.float32)
        dp = _dot(do, v_ref[0].astype(jnp.float32), trans_b=True)
        dsij = p * (dp - ds_ref[0, 0][:, None]) * scale
        dq_acc[...] += _dot(dsij, kk)

    @pl.when(s == k_sel - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(col_lut_ref, col_counts_ref,
                q_ref, k_ref, v_ref, do_ref, lse_ref, ds_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale: float, w_col: int, causal: bool,
                block_q: int, block_kv: int):
    bh, j, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(c < col_counts_ref[bh, j])
    def _step():
        i = col_lut_ref[bh, j, c]
        q = q_ref[0].astype(jnp.float32)
        kk = k_ref[0].astype(jnp.float32)
        p = _recompute_p(q, kk, lse_ref[0, 0], scale=scale, causal=causal,
                         i=i, j=j, block_q=block_q, block_kv=block_kv)
        do = do_ref[0].astype(jnp.float32)
        dv_acc[...] += _dot(p, do, trans_a=True)
        dp = _dot(do, v_ref[0].astype(jnp.float32), trans_b=True)
        dsij = p * (dp - ds_ref[0, 0][:, None]) * scale
        dk_acc[...] += _dot(dsij, q, trans_a=True)

    @pl.when(c == w_col - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "block_q", "block_kv", "interpret"))
def sla_bwd_dq(lut, counts, q, k, v, do_s, lse, d_s, *, scale, causal,
               block_q, block_kv, interpret=True):
    """dQ of the sparse component. Shapes as in sla_fwd; d_s=(BH,N) rowsum
    (dO^s . O^s). Returns dq (BH, N, D) f32."""
    bh_q, n, d = q.shape
    group = bh_q // k.shape[0]
    tm = n // block_q
    k_sel = lut.shape[-1]

    def kv_map(bh, i, s, lut_ref, counts_ref):
        return (bh // group, lut_ref[bh, i, s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh_q, tm, k_sel),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),   # q
            pl.BlockSpec((1, block_kv, d), kv_map),                           # k
            pl.BlockSpec((1, block_kv, d), kv_map),                           # v
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),   # do
            pl.BlockSpec((1, 1, block_q), lambda bh, i, s, *_: (bh, 0, i)),   # lse
            pl.BlockSpec((1, 1, block_q), lambda bh, i, s, *_: (bh, 0, i)),   # d_s
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
    )
    kern = functools.partial(_dq_kernel, scale=scale, k_sel=k_sel,
                             causal=causal, block_q=block_q, block_kv=block_kv)
    (dq,) = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bh_q, n, d), jnp.float32)],
        interpret=interpret,
    )(lut, counts, q, k, v, do_s, lse[:, None, :], d_s[:, None, :])
    return dq


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "block_q", "block_kv", "interpret"))
def sla_bwd_dkv(col_lut, col_counts, q, k, v, do_s, lse, d_s, *, scale,
                causal, block_q, block_kv, interpret=True):
    """dK, dV of the sparse component via the column LUT.

    k, v may be GQA-shared: (BH_kv, N, D). The kernel runs per *query* head
    (grid BH) and the wrapper reduces over the head group afterwards.
    Returns (dk, dv): (BH, N, D) f32 (per query head — caller group-sums).
    """
    bh_q, n, d = q.shape
    group = bh_q // k.shape[0]
    tn = n // block_kv
    w_col = col_lut.shape[-1]

    def row_map(bh, j, c, col_lut_ref, col_counts_ref):
        return (bh, col_lut_ref[bh, j, c], 0)

    def row_map_lse(bh, j, c, col_lut_ref, col_counts_ref):
        return (bh, 0, col_lut_ref[bh, j, c])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh_q, tn, w_col),
        in_specs=[
            pl.BlockSpec((1, block_q, d), row_map),                            # q
            pl.BlockSpec((1, block_kv, d),
                         lambda bh, j, c, *_: (bh // group, j, 0)),            # k
            pl.BlockSpec((1, block_kv, d),
                         lambda bh, j, c, *_: (bh // group, j, 0)),            # v
            pl.BlockSpec((1, block_q, d), row_map),                            # do
            pl.BlockSpec((1, 1, block_q), row_map_lse),                        # lse
            pl.BlockSpec((1, 1, block_q), row_map_lse),                        # d_s
        ],
        out_specs=[
            pl.BlockSpec((1, block_kv, d), lambda bh, j, c, *_: (bh, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, j, c, *_: (bh, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, d), jnp.float32),
            pltpu.VMEM((block_kv, d), jnp.float32),
        ],
    )
    kern = functools.partial(_dkv_kernel, scale=scale, w_col=w_col,
                             causal=causal, block_q=block_q, block_kv=block_kv)
    dk, dv = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bh_q, n, d), jnp.float32),
                   jax.ShapeDtypeStruct((bh_q, n, d), jnp.float32)],
        interpret=interpret,
    )(col_lut, col_counts, q, k, v, do_s, lse[:, None, :], d_s[:, None, :])
    return dk, dv
