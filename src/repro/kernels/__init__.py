"""Pallas TPU kernels for SLA (the paper's fused-kernel contribution)."""
from repro.kernels.ops import sla_attention_core
from repro.kernels.ref import sla_attention_core_reference
