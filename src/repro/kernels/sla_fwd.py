"""Fused SLA forward Pallas TPU kernel (paper Alg. 1, TPU adaptation).

Grid: (B*H, T_m, K_sel) — the trailing axis iterates the *critical* KV
blocks of one query row, streamed HBM->VMEM through a scalar-prefetched
lookup table (`lut`) so only selected blocks are ever copied. Online
softmax state (m, l, acc) lives in VMEM scratch, carried across the
sequential trailing grid axis. At the last step the kernel finalizes the
sparse output O^s, the log-sum-exp L (for the backward pass), and merges
the linear branch O^l = phi(Q_i) H_i / (phi(Q_i) Z_i) from the
pre-aggregated per-row (H_i, Z_i) — the single-pass fusion of sparse +
linear that is the paper's kernel contribution.

All matmuls accumulate in f32 (MXU-native); inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
EPS = 1e-6
LANES = 128


def _dot(a, b, trans_b=False):
    dims = (((1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


def _fwd_kernel(lut_ref, counts_ref, base_ref,  # scalar prefetch
                q_ref, k_ref, v_ref, qp_ref, hi_ref, zi_ref,  # inputs
                os_ref, ol_ref, lse_ref,  # outputs
                acc_ref, m_ref, l_ref,  # VMEM scratch
                *, scale: float, k_sel: int, causal: bool,
                block_q: int, block_kv: int):
    bh, i, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(s < counts_ref[bh, i])
    def _step():
        q = q_ref[0].astype(jnp.float32)
        kk = k_ref[0].astype(jnp.float32)
        sij = _dot(q, kk, trans_b=True) * scale  # (bq, bkv) f32
        if causal:
            j = lut_ref[bh, i, s]
            rows = (base_ref[0] + i) * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            cols = j * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            sij = jnp.where(rows >= cols, sij, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(sij, axis=-1))
        p = jnp.exp(sij - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + _dot(p, v_ref[0].astype(jnp.float32)))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(s == k_sel - 1)
    def _finalize():
        m, l = m_ref[:, 0], l_ref[:, 0]
        os_ref[0] = (acc_ref[...] / l[:, None]).astype(os_ref.dtype)
        lse_ref[0] = (m + jnp.log(l))[None, :].astype(lse_ref.dtype)
        # Linear branch (Eq. 5): one (bq,d)x(d,d) matmul + normalizer.
        qp = qp_ref[0].astype(jnp.float32)
        num = _dot(qp, hi_ref[0, 0])
        den = _dot(qp, zi_ref[0, 0][:, None])  # (bq, 1)
        live = den > EPS
        ol = jnp.where(live, num / jnp.where(live, den, 1.0), 0.0)
        ol_ref[0] = ol.astype(ol_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "block_q", "block_kv", "interpret"))
def sla_fwd(lut, counts, q, k, v, qp, hi, zi, *, scale, causal,
            block_q, block_kv, interpret=True, base=None):
    """Run the fused forward kernel.

    Args:
      lut:    (BH, Tm, K_sel) int32 critical block indices (padded).
      counts: (BH, Tm) int32 live entries per row.
      q, qp:  (BH, N, D); k, v: (BH_kv, N, D) with BH % BH_kv == 0.
      hi:     (BH, Tm, D, D) f32 aggregated marginal H per row.
      zi:     (BH, Tm, D) f32 aggregated marginal Z per row.
      base:   (1,) int32 absolute block id of query row 0 (default 0) —
        shifts the causal mask so a chunked-prefill span attends its
        true positions; TRACED (scalar-prefetched), so every chunk
        index shares one compiled kernel.

    Returns: (o_s (BH,N,D) f32, o_l (BH,N,D) f32, lse (BH,N) f32)
    """
    if base is None:
        base = jnp.zeros((1,), jnp.int32)
    bh_q, n, d = q.shape
    bh_kv = k.shape[0]
    group = bh_q // bh_kv
    tm = n // block_q
    k_sel = lut.shape[-1]
    grid = (bh_q, tm, k_sel)

    kern = functools.partial(
        _fwd_kernel, scale=scale, k_sel=k_sel, causal=causal,
        block_q=block_q, block_kv=block_kv)

    def kv_map(bh, i, s, lut_ref, counts_ref, base_ref):
        return (bh // group, lut_ref[bh, i, s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),  # q
            pl.BlockSpec((1, block_kv, d), kv_map),                          # k
            pl.BlockSpec((1, block_kv, d), kv_map),                          # v
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),  # qp
            pl.BlockSpec((1, 1, d, d), lambda bh, i, s, *_: (bh, i, 0, 0)),  # hi
            pl.BlockSpec((1, 1, d), lambda bh, i, s, *_: (bh, i, 0)),        # zi
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),  # o_s
            pl.BlockSpec((1, block_q, d), lambda bh, i, s, *_: (bh, i, 0)),  # o_l
            pl.BlockSpec((1, 1, block_q), lambda bh, i, s, *_: (bh, 0, i)),  # lse
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # m (lane-broadcast)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # l (lane-broadcast)
        ],
    )
    o_s, o_l, lse = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh_q, n, d), jnp.float32),
            jax.ShapeDtypeStruct((bh_q, n, d), jnp.float32),
            jax.ShapeDtypeStruct((bh_q, 1, n), jnp.float32),
        ],
        interpret=interpret,
    )(lut, counts, base, q, k, v, qp, hi, zi)
    return o_s, o_l, lse[:, 0, :]
