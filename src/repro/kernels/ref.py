"""Pure-jnp oracle with the exact signature of ops.sla_attention_core.

Used by every kernel test: the Pallas outputs (interpret mode on CPU) and
their custom_vjp gradients must match jax.grad through this reference.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.core.config import SLAConfig
from repro.core import reference as _ref


def sla_attention_core_reference(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array, mc: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Dense reference for (O^s, O^l). Differentiable by jax autodiff."""
    return _ref.sla_forward_reference(q, k, v, qp, kp, mc, cfg, scale)


full_attention = _ref.full_attention
full_linear = _ref.full_linear
sparse_component = _ref.sparse_component
linear_component = _ref.linear_component
