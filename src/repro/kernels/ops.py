"""jit'd SLA attention op: Pallas kernels + custom_vjp (Alg. 1 + Alg. 2).

`sla_attention_core(q, k, v, qp, kp, plan, cfg)` returns (O^s, O^l); the
caller applies Proj and the sum (Eq. 6). Differentiable w.r.t. q, k, v,
qp, kp (the plan is a constant, as in the paper: TopK is not
differentiated).

The block structure arrives as an `SLAPlan` (core/plan.py): row LUT for
the forward/dQ kernels, column LUT for the dK/dV kernel. Both are
threaded through the custom_vjp residuals so the backward pass consumes
the forward's plan verbatim — no LUT is ever rebuilt here.

Division of labor (DESIGN.md §3):
  * sparse fwd + linear merge ........ Pallas kernel (sla_fwd)
  * sparse bwd dQ / dK,dV ............ Pallas kernels (sla_bwd, row/col LUTs)
  * per-block h_j, z_j + marginal agg  XLA einsum (MXU matmul — the paper's
    App. A.3 pre-aggregation in its TPU-native dense form)
  * linear-branch gradients .......... XLA einsums (Alg. 2 lines 4-5, 17)
"""
from __future__ import annotations

import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SLAConfig
from repro.core.plan import SLAPlan, plan_from_mask
from repro.kernels.sla_fwd import sla_fwd
from repro.kernels.sla_bwd import sla_bwd_dq, sla_bwd_dkv

EPS = 1e-6


def _flat(x):
    """(B, H, ...) -> (B*H, ...)."""
    b, h = x.shape[:2]
    return x.reshape(b * h, *x.shape[2:])


def _block(x, blk):
    """(BH, N, D) -> (BH, T, blk, D)."""
    bh, n, d = x.shape
    return x.reshape(bh, n // blk, blk, d)


def _hz_blocks(kp, v, block_kv):
    """Per-KV-block linear-attention state: h_j = phi(K_j)^T V_j, z_j."""
    kpb = _block(kp.astype(jnp.float32), block_kv)
    vb = _block(v.astype(jnp.float32), block_kv)
    h = jnp.einsum("gnkd,gnke->gnde", kpb, vb)
    z = jnp.sum(kpb, axis=-2)
    return h, z


def _aggregate(a, h, z):
    """H_i = sum_{j marginal} h_j, Z_i likewise (dense-matmul form)."""
    hi = jnp.einsum("gmn,gnde->gmde", a, h)
    zi = jnp.einsum("gmn,gnd->gmd", a, z)
    return hi, zi


def _linear_bwd(do_l, qp, hi, zi, a, kp, v, block_q, block_kv):
    """Linear-branch gradients (Alg. 2 lines 2, 4-5, 14, 17)."""
    qpb = _block(qp.astype(jnp.float32), block_q)  # (g, Tm, bq, d)
    num = jnp.einsum("gmqd,gmde->gmqe", qpb, hi)
    den = jnp.einsum("gmqd,gmd->gmq", qpb, zi)[..., None]
    live = den > EPS
    sden = jnp.where(live, den, 1.0)
    o_l = jnp.where(live, num / sden, 0.0)
    dob = _block(do_l.astype(jnp.float32), block_q)
    dob = jnp.where(live, dob, 0.0)
    d_l = jnp.sum(dob * o_l, axis=-1, keepdims=True)  # D^l (g,Tm,bq,1)
    qp_over = jnp.where(live, qpb / sden, 0.0)
    dhi = jnp.einsum("gmqd,gmqe->gmde", qp_over, dob)
    dzi = -jnp.einsum("gmqd,gmq->gmd", qp_over, d_l[..., 0])
    dqp = (jnp.einsum("gmqe,gmde->gmqd", dob, hi) - d_l * zi[..., None, :])
    dqp = jnp.where(live, dqp / sden, 0.0)
    # Aggregate row gradients back to per-column dh_j, dz_j (A^T matmul).
    dh = jnp.einsum("gmn,gmde->gnde", a, dhi)
    dz = jnp.einsum("gmn,gmd->gnd", a, dzi)
    vb = _block(v.astype(jnp.float32), block_kv)
    kpb = _block(kp.astype(jnp.float32), block_kv)
    dkp = jnp.einsum("gnke,gnde->gnkd", vb, dh) + dz[..., None, :]
    dv_l = jnp.einsum("gnkd,gnde->gnke", kpb, dh)
    bh, tm, bq, d = dqp.shape
    return (dqp.reshape(bh, tm * bq, d),
            dkp.reshape(bh, -1, d),
            dv_l.reshape(bh, -1, d))


@functools.partial(jax.custom_vjp, nondiff_argnums=(10, 11, 12))
def _sla_core(q, k, v, qp, kp, marginal, lut, counts, col_lut, col_counts,
              cfg: SLAConfig, scale: float, interpret: bool):
    o_s, o_l = _fwd_impl(q, k, v, qp, kp, marginal, lut, counts, cfg,
                         scale, interpret)[:2]
    return o_s.reshape(q.shape), o_l.reshape(q.shape)


def _fwd_impl(q, k, v, qp, kp, marginal, lut, counts, cfg, scale,
              interpret):
    fq, fk, fv, fqp, fkp = map(_flat, (q, k, v, qp, kp))
    a, flut, fcounts = map(_flat, (marginal, lut, counts))
    hb, zb = _hz_blocks(fkp, fv, cfg.block_kv)
    hi, zi = _aggregate(a, hb, zb)
    o_s, o_l, lse = sla_fwd(flut, fcounts, fq, fk, fv, fqp, hi, zi,
                            scale=scale, causal=cfg.causal,
                            block_q=cfg.block_q, block_kv=cfg.block_kv,
                            interpret=interpret)
    return o_s, o_l, lse, a, hi, zi, flut, fcounts


def _sla_core_fwd(q, k, v, qp, kp, marginal, lut, counts, col_lut,
                  col_counts, cfg, scale, interpret):
    o_s, o_l, lse, a, hi, zi, flut, fcounts = _fwd_impl(
        q, k, v, qp, kp, marginal, lut, counts, cfg, scale, interpret)
    shape = q.shape
    res = (q, k, v, qp, kp, o_s, lse, a, hi, zi,
           flut, fcounts, _flat(col_lut), _flat(col_counts))
    out = (o_s.reshape(shape), o_l.reshape(shape))
    return out, res


def _sla_core_bwd(cfg, scale, interpret, res, cts):
    (q, k, v, qp, kp, o_s, lse, a, hi, zi,
     flut, fcounts, fcol_lut, fcol_counts) = res
    do_s, do_l = cts
    shape = q.shape
    fq, fk, fv, fqp, fkp = map(_flat, (q, k, v, qp, kp))
    fdo_s, fdo_l = map(_flat, (do_s, do_l))
    fdo_s = fdo_s.astype(jnp.float32)

    # --- sparse component (Pallas kernels, LUTs reused from the fwd plan) ---
    d_s = jnp.sum(fdo_s * o_s, axis=-1)  # (BH, N)
    dq = sla_bwd_dq(flut, fcounts, fq, fk, fv, fdo_s, lse, d_s,
                    scale=scale, causal=cfg.causal,
                    block_q=cfg.block_q, block_kv=cfg.block_kv,
                    interpret=interpret)
    dk, dv_s = sla_bwd_dkv(fcol_lut, fcol_counts, fq, fk, fv, fdo_s, lse,
                           d_s, scale=scale, causal=cfg.causal,
                           block_q=cfg.block_q, block_kv=cfg.block_kv,
                           interpret=interpret)

    # --- linear component (XLA einsums) ---
    dqp, dkp, dv_l = _linear_bwd(fdo_l, fqp, hi, zi, a, fkp, fv,
                                 cfg.block_q, cfg.block_kv)
    dv = dv_s + dv_l

    b, h = shape[0], shape[1]
    unflat = lambda x: x.reshape(b, h, shape[2], shape[3])
    tm, tn = a.shape[-2:]
    k_sel, w_col = flut.shape[-1], fcol_lut.shape[-1]
    f0 = lambda *s: np.zeros((b, h) + s, dtype=jax.dtypes.float0)
    d_marginal = jnp.zeros((b, h, tm, tn), jnp.float32)  # plan: constant
    return (unflat(dq).astype(q.dtype), unflat(dk).astype(k.dtype),
            unflat(dv).astype(v.dtype), unflat(dqp).astype(qp.dtype),
            unflat(dkp).astype(kp.dtype),
            d_marginal, f0(tm, k_sel), f0(tm), f0(tn, w_col), f0(tn))


_sla_core.defvjp(_sla_core_fwd, _sla_core_bwd)


def sla_attention_rows(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array,
    marginal: jax.Array, lut: jax.Array, counts: jax.Array,
    cfg: SLAConfig, scale: float | None = None, interpret: bool = True,
    row_offset=0,
) -> Tuple[jax.Array, jax.Array]:
    """Forward-only fused kernel over a SPAN of query-row blocks.

    Chunked-prefill entry point (DESIGN.md "Chunked admission prefill"):
    q/qp cover `C = Cm * block_q` query tokens whose first row block
    sits at absolute block id `row_offset` (python int or traced int32
    — traced keeps every chunk index on one compiled kernel), while
    k/v/kp cover the FULL (B, H, N, D) KV bucket. `marginal`
    (B, H, Cm, Tn), `lut` (B, H, Cm, K) and `counts` (B, H, Cm) are the
    chunk's rows of the full plan. Mirrors `_fwd_impl` op-for-op — the
    per-block h/z einsums run at full bucket width and the row
    reductions are batch-independent, so chunk outputs are bitwise
    equal to the same rows of the blocking forward. No custom_vjp:
    prefill chunks are inference-only.
    """
    scale = float(q.shape[-1] ** -0.5) if scale is None else float(scale)
    fq, fk, fv, fqp, fkp = map(_flat, (q, k, v, qp, kp))
    a, flut, fcounts = map(_flat, (marginal, lut, counts))
    hb, zb = _hz_blocks(fkp, fv, cfg.block_kv)
    hi, zi = _aggregate(a, hb, zb)
    base = jnp.asarray(row_offset, jnp.int32).reshape(1)
    o_s, o_l, _ = sla_fwd(flut, fcounts, fq, fk, fv, fqp, hi, zi,
                          scale=scale, causal=cfg.causal,
                          block_q=cfg.block_q, block_kv=cfg.block_kv,
                          interpret=interpret, base=base)
    return o_s.reshape(q.shape), o_l.reshape(q.shape)


def sla_attention_core(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array,
    plan: Union[SLAPlan, jax.Array], cfg: SLAConfig,
    scale: float | None = None, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused-kernel SLA core. All of q,k,v,qp,kp are (B, H, N, D); `plan`
    is an SLAPlan (or, for convenience, a raw (B, H, Tm, Tn) int8 M_c,
    from which a plan is derived). Returns (O^s, O^l) f32, (B, H, N, D).
    """
    if not isinstance(plan, SLAPlan):
        plan = plan_from_mask(plan, cfg)
    scale = float(q.shape[-1] ** -0.5) if scale is None else float(scale)
    return _sla_core(q, k, v, qp, kp, plan.marginal, plan.lut,
                     plan.counts, plan.col_lut, plan.col_counts, cfg,
                     scale, bool(interpret))
