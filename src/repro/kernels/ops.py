"""jit'd SLA attention op: Pallas kernels + custom_vjp (Alg. 1 + Alg. 2).

`sla_attention_core(q, k, v, qp, kp, mc, cfg)` returns (O^s, O^l); the
caller applies Proj and the sum (Eq. 6). Differentiable w.r.t. q, k, v,
qp, kp (the mask mc is a constant, as in the paper).

Division of labor (DESIGN.md §3):
  * sparse fwd + linear merge ........ Pallas kernel (sla_fwd)
  * sparse bwd dQ / dK,dV ............ Pallas kernels (sla_bwd, row/col LUTs)
  * per-block h_j, z_j + marginal agg  XLA einsum (MXU matmul — the paper's
    App. A.3 pre-aggregation in its TPU-native dense form)
  * linear-branch gradients .......... XLA einsums (Alg. 2 lines 4-5, 17)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SLAConfig
from repro.core.masks import build_col_lut, build_lut
from repro.kernels.sla_fwd import sla_fwd
from repro.kernels.sla_bwd import sla_bwd_dq, sla_bwd_dkv

EPS = 1e-6


def _flat(x):
    """(B, H, N, D) -> (B*H, N, D)."""
    b, h, n, d = x.shape
    return x.reshape(b * h, n, d)


def _block(x, blk):
    """(BH, N, D) -> (BH, T, blk, D)."""
    bh, n, d = x.shape
    return x.reshape(bh, n // blk, blk, d)


def _hz_blocks(kp, v, block_kv):
    """Per-KV-block linear-attention state: h_j = phi(K_j)^T V_j, z_j."""
    kpb = _block(kp.astype(jnp.float32), block_kv)
    vb = _block(v.astype(jnp.float32), block_kv)
    h = jnp.einsum("gnkd,gnke->gnde", kpb, vb)
    z = jnp.sum(kpb, axis=-2)
    return h, z


def _aggregate(a, h, z):
    """H_i = sum_{j marginal} h_j, Z_i likewise (dense-matmul form)."""
    hi = jnp.einsum("gmn,gnde->gmde", a, h)
    zi = jnp.einsum("gmn,gnd->gmd", a, z)
    return hi, zi


def _linear_bwd(do_l, qp, hi, zi, a, kp, v, block_q, block_kv):
    """Linear-branch gradients (Alg. 2 lines 2, 4-5, 14, 17)."""
    qpb = _block(qp.astype(jnp.float32), block_q)  # (g, Tm, bq, d)
    num = jnp.einsum("gmqd,gmde->gmqe", qpb, hi)
    den = jnp.einsum("gmqd,gmd->gmq", qpb, zi)[..., None]
    live = den > EPS
    sden = jnp.where(live, den, 1.0)
    o_l = jnp.where(live, num / sden, 0.0)
    dob = _block(do_l.astype(jnp.float32), block_q)
    dob = jnp.where(live, dob, 0.0)
    d_l = jnp.sum(dob * o_l, axis=-1, keepdims=True)  # D^l (g,Tm,bq,1)
    qp_over = jnp.where(live, qpb / sden, 0.0)
    dhi = jnp.einsum("gmqd,gmqe->gmde", qp_over, dob)
    dzi = -jnp.einsum("gmqd,gmq->gmd", qp_over, d_l[..., 0])
    dqp = (jnp.einsum("gmqe,gmde->gmqd", dob, hi) - d_l * zi[..., None, :])
    dqp = jnp.where(live, dqp / sden, 0.0)
    # Aggregate row gradients back to per-column dh_j, dz_j (A^T matmul).
    dh = jnp.einsum("gmn,gmde->gnde", a, dhi)
    dz = jnp.einsum("gmn,gmd->gnd", a, dzi)
    vb = _block(v.astype(jnp.float32), block_kv)
    kpb = _block(kp.astype(jnp.float32), block_kv)
    dkp = jnp.einsum("gnke,gnde->gnkd", vb, dh) + dz[..., None, :]
    dv_l = jnp.einsum("gnkd,gnde->gnke", kpb, dh)
    bh, tm, bq, d = dqp.shape
    return (dqp.reshape(bh, tm * bq, d),
            dkp.reshape(bh, -1, d),
            dv_l.reshape(bh, -1, d))


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _sla_core(q, k, v, qp, kp, mc, cfg: SLAConfig, scale: float,
              interpret: bool):
    o_s, o_l = _fwd_impl(q, k, v, qp, kp, mc, cfg, scale, interpret)[:2]
    return o_s.reshape(q.shape), o_l.reshape(q.shape)


def _fwd_impl(q, k, v, qp, kp, mc, cfg, scale, interpret):
    fq, fk, fv, fqp, fkp = map(_flat, (q, k, v, qp, kp))
    b, h, tm, tn = mc.shape
    fmc = mc.reshape(b * h, tm, tn)
    k_sel = cfg.num_critical(tn)
    lut, counts = build_lut(fmc, k_sel)
    a = (fmc == 0).astype(jnp.float32)
    hb, zb = _hz_blocks(fkp, fv, cfg.block_kv)
    hi, zi = _aggregate(a, hb, zb)
    o_s, o_l, lse = sla_fwd(lut, counts, fq, fk, fv, fqp, hi, zi,
                            scale=scale, causal=cfg.causal,
                            block_q=cfg.block_q, block_kv=cfg.block_kv,
                            interpret=interpret)
    return o_s, o_l, lse, lut, counts, a, hi, zi, fmc


def _sla_core_fwd(q, k, v, qp, kp, mc, cfg, scale, interpret):
    o_s, o_l, lse, lut, counts, a, hi, zi, fmc = _fwd_impl(
        q, k, v, qp, kp, mc, cfg, scale, interpret)
    shape = q.shape
    res = (q, k, v, qp, kp, fmc, o_s, lse, a, hi, zi)
    out = (o_s.reshape(shape), o_l.reshape(shape))
    return out, res


def _sla_core_bwd(cfg, scale, interpret, res, cts):
    q, k, v, qp, kp, fmc, o_s, lse, a, hi, zi = res
    do_s, do_l = cts
    shape = q.shape
    fq, fk, fv, fqp, fkp = map(_flat, (q, k, v, qp, kp))
    fdo_s, fdo_l = map(_flat, (do_s, do_l))
    fdo_s = fdo_s.astype(jnp.float32)

    # --- sparse component (Pallas kernels) ---
    d_s = jnp.sum(fdo_s * o_s, axis=-1)  # (BH, N)
    dq = sla_bwd_dq(*build_lut(fmc, cfg.num_critical(fmc.shape[-1])),
                    fq, fk, fv, fdo_s, lse, d_s,
                    scale=scale, causal=cfg.causal,
                    block_q=cfg.block_q, block_kv=cfg.block_kv,
                    interpret=interpret)
    w_col = cfg.col_capacity(fmc.shape[-2], fmc.shape[-1])
    col_lut, col_counts = build_col_lut(fmc, w_col)
    dk, dv_s = sla_bwd_dkv(col_lut, col_counts, fq, fk, fv, fdo_s, lse, d_s,
                           scale=scale, causal=cfg.causal,
                           block_q=cfg.block_q, block_kv=cfg.block_kv,
                           interpret=interpret)

    # --- linear component (XLA einsums) ---
    dqp, dkp, dv_l = _linear_bwd(fdo_l, fqp, hi, zi, a, fkp, fv,
                                 cfg.block_q, cfg.block_kv)
    dv = dv_s + dv_l

    b, h = shape[0], shape[1]
    unflat = lambda x: x.reshape(b, h, shape[2], shape[3])
    return (unflat(dq).astype(q.dtype), unflat(dk).astype(k.dtype),
            unflat(dv).astype(v.dtype), unflat(dqp).astype(qp.dtype),
            unflat(dkp).astype(kp.dtype),
            np.zeros((b, h) + fmc.shape[-2:], dtype=jax.dtypes.float0))


_sla_core.defvjp(_sla_core_fwd, _sla_core_bwd)


def sla_attention_core(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array, mc: jax.Array, cfg: SLAConfig,
    scale: float | None = None, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused-kernel SLA core. All of q,k,v,qp,kp are (B, H, N, D); mc is
    (B, H, Tm, Tn) int8. Returns (O^s, O^l) f32, each (B, H, N, D)."""
    scale = float(q.shape[-1] ** -0.5) if scale is None else float(scale)
    return _sla_core(q, k, v, qp, kp, mc, cfg, scale, bool(interpret))
