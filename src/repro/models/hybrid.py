"""Zamba2-style hybrid: Mamba2 backbone + one *shared* SLA-attention
transformer block applied every `attn_every` layers (arXiv:2411.15242).

The shared block has a single parameter set reused at every application
point (zamba's signature trick), so the mamba stack scans in segments of
`attn_every` layers with the shared block between segments.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx
from repro.models import mamba2
from repro.models.common import (attention, chunked_softmax_xent, dense_init,
                                 embed_init, rms_norm, rope)


def _shared_attn_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    r = list(jax.random.split(rng, 6))
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "wq": dense_init(r[0], d, h * dh, dtype),
        "wk": dense_init(r[1], d, cfg.num_kv_heads * dh, dtype),
        "wv": dense_init(r[2], d, cfg.num_kv_heads * dh, dtype),
        "wo": dense_init(r[3], h * dh, d, dtype),
        "sla_proj": jnp.zeros((h, dh, dh), dtype),
        "mlp_wi": dense_init(r[4], d, 2 * cfg.d_ff, dtype),
        "mlp_wo": dense_init(r[5], cfg.d_ff, d, dtype),
    }
    if cfg.sla.routing_mode == "learned":
        from repro.core.masks import routing_init
        p["routing"] = routing_init(h, dh, dtype)
    return p


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, cfg.num_layers + 2)
    layers = jax.vmap(lambda k: mamba2.mamba_init(k, cfg, dtype))(
        jnp.stack(r[: cfg.num_layers]))
    return {
        "embed": embed_init(r[-1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "shared_attn": _shared_attn_init(r[-2], cfg, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }


def _segments(cfg: ArchConfig):
    """Static split of the mamba stack into attn_every-sized segments."""
    l, every = cfg.num_layers, cfg.attn_every or cfg.num_layers
    sizes, start = [], 0
    while start < l:
        sizes.append(min(every, l - start))
        start += every
    return sizes


def _shared_block(p, x, cfg: ArchConfig, positions, backend,
                  kv_cache=None, pos=None):
    """SLA-attention transformer block (single shared param set)."""
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,de->bse", xn, p["wq"].astype(x.dtype)) \
        .reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,de->bse", xn, p["wk"].astype(x.dtype)) \
        .reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", xn, p["wv"].astype(x.dtype)) \
        .reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if kv_cache is not None:
        kc, vc = kv_cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 pos, axis=2)
        new_cache = (kc, vc)
        smax = kc.shape[-2]
        kk = jnp.repeat(kc, h // hkv, 1) if hkv != h else kc
        vv = jnp.repeat(vc, h // hkv, 1) if hkv != h else vc
        sc = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (dh**-0.5)
        ok = jnp.arange(smax)[None, None, None, :] <= pos
        sc = jnp.where(ok, sc, -1e30)
        o = jnp.einsum("bhqs,bhsd->bhqd", jax.nn.softmax(sc, -1),
                       vv.astype(jnp.float32)).astype(x.dtype)
    else:
        o = attention({"proj": p["sla_proj"]}, q, k, v, "sla", cfg.sla,
                      causal=True, backend=backend,
                      routing=p.get("routing"))
        new_cache = (k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    x = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    hmid = jnp.einsum("bsd,df->bsf", rms_norm(x, p["ln2"]),
                      p["mlp_wi"].astype(x.dtype))
    g, u = jnp.split(hmid, 2, axis=-1)
    x = x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                       p["mlp_wo"].astype(x.dtype))
    return x, new_cache


def forward(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather", return_cache: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    sizes = _segments(cfg)
    ssm_states, conv_tails, attn_kvs = [], [], []
    start = 0
    for seg in sizes:
        seg_params = jax.tree.map(
            lambda t: jax.lax.slice_in_dim(t, start, start + seg, axis=0),
            params["layers"])

        def body(x, p):
            out, (st, tail) = mamba2.mamba_apply(
                p, rms_norm(x, p["ln"]), cfg)
            return ctx.shard_residual(x + out), (st, tail)

        x, (sts, tails) = jax.lax.scan(ctx.maybe_remat(body), x, seg_params)
        ssm_states.append(sts)
        conv_tails.append(tails)
        x, kv = _shared_block(params["shared_attn"], x, cfg, positions,
                              backend)
        attn_kvs.append(kv)
        start += seg
    x = rms_norm(x, params["ln_f"])
    if return_cache:
        cache = {
            "ssm": jnp.concatenate(ssm_states, 0),
            "conv": jnp.concatenate(conv_tails, 0),
            "attn_k": jnp.stack([kv[0] for kv in attn_kvs], 0),
            "attn_v": jnp.stack([kv[1] for kv in attn_kvs], 0),
        }
        return x, jnp.float32(0.0), cache
    return x, jnp.float32(0.0)


def loss_fn(params, cfg: ArchConfig, batch, compute_dtype=jnp.bfloat16,
            backend: str = "gather"):
    x, _ = forward(params, cfg, batch["tokens"], compute_dtype, backend)
    return chunked_softmax_xent(x, params["embed"], batch["targets"],
                                batch.get("mask"))


def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    l = cfg.num_layers
    nseg = len(_segments(cfg))
    d_conv = h * pd + 2 * n
    return {
        "ssm": jnp.zeros((l, batch, h, n, pd), jnp.float32),
        "conv": jnp.zeros((l, batch, cfg.conv_kernel - 1, d_conv), dtype),
        "attn_k": jnp.zeros((nseg, batch, cfg.num_kv_heads, max_len,
                             cfg.head_dim), dtype),
        "attn_v": jnp.zeros((nseg, batch, cfg.num_kv_heads, max_len,
                             cfg.head_dim), dtype),
        "pos": jnp.int32(0),
    }


def prefill(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather"):
    x, _, cache = forward(params, cfg, tokens, compute_dtype, backend,
                          return_cache=True)
    cache["pos"] = jnp.int32(tokens.shape[1])
    return x[:, -1], cache


def decode_step(params, cfg: ArchConfig, token, cache,
                compute_dtype=jnp.bfloat16):
    """Decode: O(1) mamba state updates + O(S) shared-attention cache reads
    (zamba2's cost profile for the long_500k cell)."""
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(
        compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]
    sizes = _segments(cfg)
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    start = 0
    for si, seg in enumerate(sizes):
        seg_params = jax.tree.map(
            lambda t: jax.lax.slice_in_dim(t, start, start + seg, axis=0),
            params["layers"])
        seg_ssm = jax.lax.slice_in_dim(cache["ssm"], start, start + seg,
                                       axis=0)
        seg_conv = jax.lax.slice_in_dim(cache["conv"], start, start + seg,
                                        axis=0)

        def body(x, layer):
            p, st, tail = layer
            out, (st2, tail2) = mamba2.mamba_apply(
                p, rms_norm(x, p["ln"]), cfg, conv_tail=tail, state=st)
            return x + out, (st2, tail2)

        x, (sts, tails) = jax.lax.scan(body, x,
                                       (seg_params, seg_ssm, seg_conv))
        new_ssm.append(sts)
        new_conv.append(tails)
        x, (kc, vc) = _shared_block(
            params["shared_attn"], x, cfg,
            jnp.full((b, 1), pos, jnp.int32), "gather",
            kv_cache=(cache["attn_k"][si], cache["attn_v"][si]), pos=pos)
        new_k.append(kc)
        new_v.append(vc)
        start += seg
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "attn_k": jnp.stack(new_k, 0),
        "attn_v": jnp.stack(new_v, 0),
        "pos": pos + 1,
    }
    return logits, new_cache
