"""Shared model building blocks (pure JAX, functional params-as-pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init fns take (rng, cfg).
  * activations bf16 by default, norms/softmax/losses in f32.
  * attention tensors are (B, H, N, Dh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import SLAConfig, sla_attention
from repro.core import reference as sref

NEG_INF = -1e30


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32):
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (vocab, dim), jnp.float32)
            * dim**-0.5).astype(dtype)


# --------------------------------------------------------------------------
# norms / rotary
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics and *bf16 gradient boundaries*.

    The hand-written VJP keeps the incoming/outgoing cotangents in x.dtype:
    without it, XLA hoists the f32 cast of the norm backward above the
    tensor-parallel all-reduce of dX, doubling that collective's bytes
    (measured on mistral-large x train_4k; EXPERIMENTS.md §Perf)."""
    return _rms_fwd(x, w, eps)[0]


def _rms_fwd(x, w, eps):
    # statistics in f32; the O(B*S*D) elementwise math stays in x.dtype so
    # no f32 copy of the activation ever reaches a fusion/collective
    # boundary (bf16 ARs: half the wire bytes of the naive f32 version).
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    wp1 = (1.0 + w.astype(jnp.float32)).astype(x.dtype)
    out = x * r.astype(x.dtype) * wp1
    return out, (x, w, r)


def _rms_bwd(eps, res, g):
    x, w, r = res
    d = x.shape[-1]
    rb = r.astype(x.dtype)
    wp1 = (1.0 + w.astype(jnp.float32)).astype(x.dtype)
    gx = g * wp1 * rb
    # d var path (reduction in f32, correction applied in x.dtype)
    dot = jnp.sum((g * wp1 * x).astype(jnp.float32), axis=-1,
                  keepdims=True)
    corr = (r * r * r * dot / d).astype(x.dtype)
    gx = gx - x * corr
    dw_axes = tuple(range(x.ndim - 1))
    dw = jnp.sum((g * x).astype(jnp.float32) * r, axis=dw_axes)
    return gx, dw.astype(w.dtype)


rms_norm.defvjp(lambda x, w, eps: ((o := _rms_fwd(x, w, eps))[0], o[1]),
                _rms_bwd)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: (B, H, N, D); positions: (B, N) or (N,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, N, half)
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention dispatch: full / sliding-window / SLA
# --------------------------------------------------------------------------
def _swa_attention(q, k, v, window: int, causal: bool, scale=None,
                   block: int = 128):
    """Banded sliding-window attention, O(N * window) compute + memory.

    Implemented as block-sparse attention over a *static* band LUT reusing
    the SLA gather machinery — no N x N score matrix is ever built (this
    matters for gemma3 local layers at 32K+).
    """
    from repro.core.block_sparse_xla import sparse_component_gather
    from repro.core.config import SLAConfig

    b, h, n, d = q.shape
    block = min(block, n)
    while n % block:
        block //= 2
    tm = n // block
    wb = min(tm, max(1, (window + block - 1) // block + 1))
    rows = jnp.arange(tm)[:, None]
    offs = jnp.arange(wb)[None, :]
    if causal:
        idx = jnp.clip(rows - (wb - 1) + offs, 0, tm - 1)
        counts = jnp.minimum(rows[:, 0] + 1, wb)
    else:
        start = jnp.clip(rows - wb // 2, 0, tm - wb)
        idx = start + offs  # shifted-in-bounds window, no duplicates
        counts = jnp.full((tm,), wb)
    # de-duplicate clipped entries by marking early slots dead on short rows
    if causal:
        # live slots are the *last* `counts` ones; rebuild as leading-live
        shift = wb - counts[:, None]
        idx = jnp.take_along_axis(
            idx, (jnp.arange(wb)[None, :] + shift) % wb, axis=-1)
    lut = jnp.broadcast_to(idx[None, None], (b, h, tm, wb)) \
        .astype(jnp.int32)
    cnts = jnp.broadcast_to(counts[None, None], (b, h, tm)) \
        .astype(jnp.int32)
    cfg = SLAConfig(block_q=block, block_kv=block, causal=causal,
                    window=window)
    o, _ = sparse_component_gather(q, k, v, lut, cnts, cfg, scale)
    return o.astype(q.dtype)


def attention(
    sla_params: Optional[dict],
    q: jax.Array, k: jax.Array, v: jax.Array,
    kind: str,
    sla_cfg: SLAConfig,
    window: int = 0,
    causal: bool = True,
    backend: str = "gather",
    plan=None,
    routing: Optional[dict] = None,
) -> jax.Array:
    """Unified attention entry. kind: "sla" | "full" | "swa".

    k, v may have fewer (GQA) heads. `backend` names an SLA execution
    backend from the core.backends registry ("gather" XLA / "reference"
    dense / "kernel" fused Pallas). `plan` is an optional precomputed
    SLAPlan for (q, k) — pass it to reuse block structure across calls
    (e.g. adjacent diffusion timesteps); None plans inline. `routing`
    carries the layer's learned-routing scorer for inline planning
    under sla_cfg.routing_mode == "learned".
    """
    if kind == "full":
        h = q.shape[1]
        kk = jnp.repeat(k, h // k.shape[1], 1) if k.shape[1] != h else k
        vv = jnp.repeat(v, h // v.shape[1], 1) if v.shape[1] != h else v
        return sref.full_attention(q, kk, vv, causal).astype(q.dtype)
    if kind == "swa":
        h = q.shape[1]
        kk = jnp.repeat(k, h // k.shape[1], 1) if k.shape[1] != h else k
        vv = jnp.repeat(v, h // v.shape[1], 1) if v.shape[1] != h else v
        return _swa_attention(q, kk, vv, window, causal)
    if kind == "sla":
        cfg = dataclasses.replace(sla_cfg, causal=causal)
        return sla_attention(sla_params, q, k, v, cfg,
                             backend=backend, plan=plan, routing=routing)
    raise ValueError(f"unknown attention kind {kind!r}")


# --------------------------------------------------------------------------
# output head
# --------------------------------------------------------------------------
def logits_from_hidden(params: dict, hidden: jax.Array) -> jax.Array:
    """Unembed final hidden states: (..., D) -> (..., V) f32 logits.

    The ONE place serving paths (engine first token, scheduler
    admission, decode_step) turn hidden states into logits — tied
    embeddings fall back to `params['embed']` when no `unembed` table
    exists, and the matmul runs in f32 so greedy argmax is deterministic
    across callers."""
    table = params.get("unembed", params["embed"])
    return jnp.einsum("...d,vd->...v", hidden.astype(jnp.float32),
                      table.astype(jnp.float32))


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def chunked_softmax_xent(
    x: jax.Array, embed: jax.Array, targets: jax.Array,
    mask: Optional[jax.Array] = None, chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing full (B, S, V) logits.

    x: final hidden states (B, S, D); embed: (V, D) tied output table;
    targets: (B, S) int32. Scans over sequence chunks — peak logits memory
    is (B, chunk, V). Production trick for V up to 262k (gemma3).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    xc = x.reshape(b, s // chunk, chunk, d)
    tc = targets.reshape(b, s // chunk, chunk)
    mc = (jnp.ones_like(tc, jnp.float32) if mask is None
          else mask.reshape(b, s // chunk, chunk).astype(jnp.float32))

    def body(carry, args):
        xi, ti, mi = args  # (B, chunk, D), (B, chunk), (B, chunk)
        logits = jnp.einsum("bcd,vd->bcv", xi.astype(jnp.float32),
                            embed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mi)
        return carry + loss, None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(tc, 1, 0),
         jnp.moveaxis(mc, 1, 0)))
    denom = jnp.maximum(jnp.sum(mc), 1.0)
    return total / denom


def mse_loss(pred: jax.Array, target: jax.Array) -> jax.Array:
    diff = pred.astype(jnp.float32) - target.astype(jnp.float32)
    return jnp.mean(diff * diff)
