"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are scanned (stacked params, single trace per layer kind) for
compile-time sanity at 88-layer scale. Per-layer attention kind is a
static-shaped int array consumed by lax.switch: 0=SLA, 1=full, 2=sliding
window (gemma3 local layers). SLA layers carry the learnable Proj.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import masks as masks_lib
from repro.core import plan as plan_lib
from repro.distributed import ctx
from repro.models import moe as moe_lib
from repro.models.common import (NEG_INF, attention, chunked_softmax_xent,
                                 dense_init, embed_init, logits_from_hidden,
                                 mse_loss, rms_norm, rope)

KIND_SLA, KIND_FULL, KIND_SWA = 0, 1, 2


def layer_kinds_list(cfg: ArchConfig) -> list:
    """Static per-layer attention kinds."""
    l = cfg.num_layers
    if cfg.local_global_pattern:
        p = cfg.local_global_pattern
        return [KIND_SLA if (i + 1) % p == 0 else KIND_SWA for i in range(l)]
    if cfg.attention_kind == "full":
        return [KIND_FULL] * l
    if cfg.attention_kind == "swa":
        return [KIND_SWA] * l
    return [KIND_SLA] * l


def layer_kinds(cfg: ArchConfig) -> jnp.ndarray:
    return jnp.asarray(layer_kinds_list(cfg), jnp.int32)


def _layer_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = list(jax.random.split(rng, 8))
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "wq": dense_init(r[0], d, h * dh, dtype),
        "wk": dense_init(r[1], d, hkv * dh, dtype),
        "wv": dense_init(r[2], d, hkv * dh, dtype),
        "wo": dense_init(r[3], h * dh, d, dtype),
        "sla_proj": jnp.zeros((h, dh, dh), dtype),
    }
    if cfg.sla.routing_mode == "learned":
        # identity init: the learned router reproduces the threshold
        # rule bitwise until fine-tuning moves it (no RNG consumed, so
        # threshold-mode params are unchanged)
        p["routing"] = masks_lib.routing_init(h, dh, dtype)
    if cfg.qk_norm:
        p["qnorm"] = jnp.zeros((dh,), dtype)
        p["knorm"] = jnp.zeros((dh,), dtype)
    if cfg.num_experts:
        p["moe"] = moe_lib.moe_init(r[4], cfg, dtype)
    else:
        p["mlp_wi"] = dense_init(r[5], d, 2 * cfg.d_ff, dtype)
        p["mlp_wo"] = dense_init(r[6], cfg.d_ff, d, dtype)
    return p


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, cfg.num_layers + 2)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jnp.stack(r[: cfg.num_layers]))
    params = {
        "embed": embed_init(r[-1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(r[-2], cfg.vocab_size, cfg.d_model,
                                       dtype)
    return params


# --------------------------------------------------------------------------
# attention sub-block
# --------------------------------------------------------------------------
def _qkv(p, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wq"].astype(x.dtype), "col"))
    k = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wk"].astype(x.dtype), "col"))
    v = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wv"].astype(x.dtype), "col"))
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn(p, x, kind, cfg: ArchConfig, positions, backend,
          layer_plan=None, drift_threshold=None, want_plan=False,
          decode_plan_cfg=None):
    """Returns (attn_out (B,S,d), k_cache, v_cache, plan, retention,
    replanned, decode_mc).

    Plan reuse for LM prefill (DESIGN.md "Plan lifetime & drift"):
    `want_plan=True` with layer_plan=None plans inline and returns the
    plan; a given `layer_plan` is reused — and, when `drift_threshold`
    is set (a scalar: per-layer callers pass their layer's entry),
    refreshed under `lax.cond` when its retained critical mass decays
    (same drift metric as the DiT sampler). The plan is built outside
    the kind switch so it rides the layer scan with static shapes even
    in mixed-kind stacks (non-SLA layers just carry it).

    `decode_plan_cfg` (DESIGN.md "Decode-time SLA") additionally
    returns this layer's decode-grid block classification of the
    prompt — the rows that seed the incremental decode plan."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    sla_cfg = cfg.sla
    if cfg.sliding_window:
        sla_cfg = dataclasses.replace(sla_cfg, window=cfg.sliding_window)
    sla_params = {"proj": p["sla_proj"]}
    # the layer's learned-routing scorer (DESIGN.md "Learned routing");
    # None under threshold routing — every planning path below threads it
    routing = p.get("routing") if sla_cfg.routing_mode == "learned" \
        else None
    retention = jnp.float32(1.0)
    replanned = jnp.bool_(False)
    decode_mc = None
    if decode_plan_cfg is not None:
        kr = k if k.shape[1] == q.shape[1] else \
            jnp.repeat(k, q.shape[1] // k.shape[1], axis=1)
        decode_mc = masks_lib.compute_mask(q, kr, decode_plan_cfg,
                                           routing=routing)
    if want_plan or layer_plan is not None:
        plan_cfg = dataclasses.replace(sla_cfg, causal=True)
        if layer_plan is None:
            layer_plan = plan_lib.plan_attention(q, k, plan_cfg,
                                                 routing=routing)
        elif drift_threshold is not None:
            layer_plan, retention, replanned = plan_lib.refresh_plan(
                layer_plan, q, k, plan_cfg, drift_threshold,
                routing=routing)

    def do_sla(q, k, v):
        return attention(sla_params, q, k, v, "sla", sla_cfg,
                         causal=True, backend=backend, plan=layer_plan,
                         routing=routing)

    def do_full(q, k, v):
        return attention(None, q, k, v, "full", sla_cfg, causal=True)

    def do_swa(q, k, v):
        return attention(None, q, k, v, "swa", sla_cfg,
                         window=cfg.local_window or cfg.sliding_window,
                         causal=True)

    # Only compile branches that actually occur (a dead full-attention
    # branch would put N^2 temporaries into every lowered cell).
    branches = [do_sla, do_full, do_swa]
    used = sorted(set(layer_kinds_list(cfg)))
    if len(used) == 1:
        out = branches[used[0]](q, k, v)
    else:
        import numpy as np
        remap = np.zeros((3,), np.int32)
        for pos, orig in enumerate(used):
            remap[orig] = pos
        out = jax.lax.switch(jnp.asarray(remap)[kind],
                             [branches[u] for u in used], q, k, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out,
                     ctx.fsdp_gather(p["wo"].astype(x.dtype), "row"))
    return out, k, v, layer_plan, retention, replanned, decode_mc


def _ffn(p, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.num_experts:
        return moe_lib.moe_apply(p["moe"], x, cfg)
    h = jnp.einsum("bsd,df->bsf", x,
                   ctx.fsdp_gather(p["mlp_wi"].astype(x.dtype), "col"))
    g, u = jnp.split(h, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                     ctx.fsdp_gather(p["mlp_wo"].astype(x.dtype), "row"))
    return out, jnp.float32(0.0)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------
def forward(params, cfg: ArchConfig, tokens: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16, backend: str = "gather",
            return_cache: bool = False,
            plans=None, return_plans: bool = False,
            drift_threshold=None, decode_plan_cfg=None):
    """Returns hidden states (B, S, d); optionally the per-layer KV cache.

    VLM (cfg.frontend == "vision_stub"): prefix_embeds (B, P, d) are
    prepended to the token embeddings (patch positions share the rope
    position space, positions 0..P-1).

    LM-prefill plan reuse (DESIGN.md "Plan lifetime & drift"): with
    `return_plans=True` the per-layer SLAPlan stack rides out of the
    layer scan; pass it back as `plans=` on a later same-shape prefill
    to reuse the block structure, optionally with `drift_threshold=` to
    refresh drifted layers under `lax.cond`. `drift_threshold` may be
    a scalar or a per-layer (L,) array/tuple — each layer's refresh
    decision uses its own entry (never min-reduced across the stack).

    Decode-plan seeding (DESIGN.md "Decode-time SLA"): with
    `decode_plan_cfg=` (an `SLAConfig.decode_plan_cfg(...)` result) the
    per-layer decode-grid block classification of the prompt is also
    returned — `prefill(..., decode_max_len=)` embeds it into the
    static decode plan. Return value order:
    (x, aux[, caches][, plans][, decode_mc][, drift info dict]).
    """
    emb = params["embed"]
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds.astype(compute_dtype))
    if tokens is not None:
        parts.append(jnp.take(emb, tokens, axis=0).astype(compute_dtype))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    kinds = layer_kinds(cfg)
    want_plan = return_plans or plans is not None
    adaptive = drift_threshold is not None and plans is not None
    if adaptive:
        thresholds = jnp.broadcast_to(
            jnp.asarray(drift_threshold, jnp.float32), (cfg.num_layers,))

    def body(x, layer):
        layer = list(layer)
        p, kind = layer.pop(0), layer.pop(0)
        layer_plan = layer.pop(0) if plans is not None else None
        thr = layer.pop(0) if adaptive else None
        a, k, v, layer_plan, ret, rep, dmc = _attn(
            p, rms_norm(x, p["ln1"]), kind, cfg, positions, backend,
            layer_plan=layer_plan, drift_threshold=thr,
            want_plan=want_plan, decode_plan_cfg=decode_plan_cfg)
        # constraining the block OUTPUT (pre-residual-add) turns the TP
        # boundary all-reduce into a reduce-scatter (half the wire bytes)
        x = ctx.shard_residual(x + ctx.shard_residual(a))
        f, aux = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        x = ctx.shard_residual(x + ctx.shard_residual(f))
        ys = (aux, (k, v) if return_cache else None,
              layer_plan if want_plan else None,
              dmc if decode_plan_cfg is not None else None,
              (ret, rep) if adaptive else None)
        return x, ys

    xs = (params["layers"], kinds)
    if plans is not None:
        xs = xs + (plans,)
    if adaptive:
        xs = xs + (thresholds,)
    x, (auxs, caches, out_plans, decode_mcs, drift_ys) = jax.lax.scan(
        ctx.maybe_remat(body), x, xs)
    x = rms_norm(x, params["ln_f"])
    aux = jnp.sum(auxs)
    rets = (x, aux)
    if return_cache:
        rets += (caches,)  # caches: (k (L,B,Hkv,S,Dh), v ...)
    if return_plans:
        rets += (out_plans,)
    if decode_plan_cfg is not None:
        rets += (decode_mcs,)  # (L, B, H, Tm, Tn) int8 decode-grid rows
    if adaptive:
        rets += ({"retention": drift_ys[0], "replanned": drift_ys[1]},)
    return rets


def loss_fn(params, cfg: ArchConfig, batch: dict,
            compute_dtype=jnp.bfloat16, backend: str = "gather") -> jax.Array:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, targets[, mask,
    patch_embeds]."""
    x, aux = forward(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("patch_embeds"),
                     compute_dtype=compute_dtype, backend=backend)
    npatch = 0
    if batch.get("patch_embeds") is not None:
        npatch = batch["patch_embeds"].shape[1]
        x = x[:, npatch:]
    table = params.get("unembed", params["embed"])
    loss = chunked_softmax_xent(x, table, batch["targets"],
                                batch.get("mask"))
    return loss + 0.01 * aux


def distill_loss_fn(params, cfg: ArchConfig, batch: dict,
                    compute_dtype=jnp.bfloat16,
                    backend: str = "gather") -> jax.Array:
    """End-to-end distillation (the paper's fine-tuning objective):
    MSE between the SLA student's final hidden states and a
    gradient-stopped exact-attention teacher running the SAME params.

    The student runs under cfg as-is (SLA layers, learned routing if
    cfg.sla.routing_mode == "learned"), so the sla_proj merge and —
    via the straight-through marginal gates — the routing parameters
    receive gradients; a few steps at a fixed critical-block budget
    recover the exact-attention behavior (paper Sec. 5). Requires an
    autodiff backend for routing grads ("gather"/"reference"; the
    fused kernel treats the plan as a constant)."""
    tcfg = dataclasses.replace(
        cfg, sla=cfg.sla.replace(mode="full", routing_mode="threshold"))
    x_t, _ = forward(params, tcfg, batch["tokens"],
                     prefix_embeds=batch.get("patch_embeds"),
                     compute_dtype=compute_dtype, backend=backend)
    x_s, aux = forward(params, cfg, batch["tokens"],
                       prefix_embeds=batch.get("patch_embeds"),
                       compute_dtype=compute_dtype, backend=backend)
    return mse_loss(x_s, jax.lax.stop_gradient(x_t)) + 0.01 * aux


# --------------------------------------------------------------------------
# serving: prefill + single-token decode over a static-size KV cache
# --------------------------------------------------------------------------
def _seed_decode_state(cfg: ArchConfig, kc, vc, decode_mcs, max_len: int):
    """Decode-SLA cache state from the prefill caches (DESIGN.md
    "Decode-time SLA").

    kc, vc: (L, B, Hkv, S, Dh) prompt caches; decode_mcs: (L, B, H,
    Tm_p, Tn_p) decode-grid classification of the prompt rows. Builds
    the static-grid incremental plan plus the linear branch's running
    state: per-block h_j = sum phi(k) v^T / z_j = sum phi(k) partials
    and their running totals (updated O(1) per decoded token)."""
    from repro.core.phi import phi
    sla = cfg.sla
    bq, bkv = sla.block_q, sla.block_kv
    nl, b, hkv, s, dh = kc.shape
    tn = max_len // bkv
    tm_p, tn_p = s // bq, s // bkv
    dcfg = sla.decode_plan_cfg(tn)
    mc = jnp.full((nl, b, cfg.num_heads, tn, tn), -1, jnp.int8)
    mc = mc.at[..., :tm_p, :tn_p].set(decode_mcs)
    # col_width=1: decode never runs the dK/dV backward, so the plan
    # skips the O(Tn^2)-per-head column LUT (it would otherwise ride —
    # and be where()-selected — in every decode step's scan carry)
    plan = plan_lib.plan_from_mask(mc, dcfg, col_width=1)
    kp = phi(kc, sla.phi)  # f32
    kpb = kp.reshape(nl, b, hkv, tn_p, bkv, dh)
    vb = vc.astype(jnp.float32).reshape(nl, b, hkv, tn_p, bkv, dh)
    pad = [(0, 0)] * 3 + [(0, tn - tn_p)]
    hblk = jnp.pad(jnp.einsum("...nkd,...nke->...nde", kpb, vb),
                   pad + [(0, 0), (0, 0)])
    zblk = jnp.pad(jnp.sum(kpb, axis=-2), pad + [(0, 0)])
    kpool = jnp.pad(
        jnp.sum(kc.astype(jnp.float32)
                .reshape(nl, b, hkv, tn_p, bkv, dh), axis=-2),
        pad + [(0, 0)])
    k_sel = dcfg.num_critical(tn)
    return {
        "hblk": hblk, "zblk": zblk,
        "htot": jnp.sum(hblk, axis=3), "ztot": jnp.sum(zblk, axis=3),
        "kpool": kpool,
        "qpool": jnp.zeros((nl, b, cfg.num_heads, dh), jnp.float32),
        "plan": plan,
        "rows": jnp.int32(tm_p),
        "live_lut": jnp.zeros((nl, b, cfg.num_heads, k_sel), jnp.int32),
        "live_cnt": jnp.zeros((nl, b, cfg.num_heads), jnp.int32),
        "live_marg": jnp.zeros((nl, b, cfg.num_heads), jnp.int32),
        "extends": jnp.zeros((nl,), jnp.int32),
        "replans": jnp.zeros((nl,), jnp.int32),
        "reuses": jnp.zeros((nl,), jnp.int32),
        "retention": jnp.ones((nl,), jnp.float32),
    }


def _check_decode_grid(cfg: ArchConfig, seq_len: int, max_len: int):
    sla = cfg.sla
    if sla.block_q != sla.block_kv:
        raise ValueError("decode-time SLA requires block_q == block_kv")
    if sla.window or cfg.sliding_window:
        # the subtractive linear state cannot exclude out-of-window past
        # blocks (decode_plan_cfg classifies with window=0), so decode
        # would silently diverge from the window-constrained prefill —
        # fail loudly instead
        raise ValueError(
            "decode-time SLA does not support window-constrained SLA "
            "layers (SLAConfig.window / cfg.sliding_window); use dense "
            "decode for sliding-window configs")
    if seq_len % sla.block_q or max_len % sla.block_q:
        raise ValueError(
            f"decode-time SLA needs block-aligned lengths: prompt "
            f"{seq_len} and max_len {max_len} must be multiples of "
            f"sla.block_q={sla.block_q}")


def prefill(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather", plans=None, drift_threshold=None,
            return_plans: bool = False,
            decode_max_len: Optional[int] = None):
    """Run the prompt; returns (last_hidden (B, d), cache dict).

    Plan reuse across prefill chunks (serving): `return_plans=True`
    additionally returns the per-layer SLAPlan stack; pass it back as
    `plans=` (with `drift_threshold=` for drift-gated refresh) on the
    next same-shape prefill chunk — the serving engine amortizes block
    planning across the request stream this way. Return value order:
    (last_hidden, cache[, plans][, drift info]).

    Decode-time SLA (DESIGN.md "Decode-time SLA"): `decode_max_len=`
    sizes a static decode block grid, pads the KV caches out to it, and
    seeds the cache with the incremental decode plan (prompt rows
    classified on the decode grid) plus the linear branch's running
    H/Z state — `decode_step` then runs SLA decode instead of dense."""
    dcfg = None
    if decode_max_len is not None:
        _check_decode_grid(cfg, tokens.shape[1], decode_max_len)
        dcfg = cfg.sla.decode_plan_cfg(decode_max_len // cfg.sla.block_kv)
    out = forward(params, cfg, tokens, compute_dtype=compute_dtype,
                  backend=backend, return_cache=True, plans=plans,
                  return_plans=return_plans,
                  drift_threshold=drift_threshold, decode_plan_cfg=dcfg)
    x, (kc, vc) = out[0], out[2]
    extras = out[3:]
    cache = {"k": kc, "v": vc, "pos": jnp.int32(tokens.shape[1])}
    if decode_max_len is not None:
        i = 1 if return_plans else 0
        decode_mcs, extras = extras[i], extras[:i] + extras[i + 1:]
        cache["sla"] = _seed_decode_state(cfg, kc, vc, decode_mcs,
                                          decode_max_len)
        grow = decode_max_len - kc.shape[-2]
        if grow > 0:
            pad = [(0, 0)] * 3 + [(0, grow), (0, 0)]
            cache["k"] = jnp.pad(kc, pad)
            cache["v"] = jnp.pad(vc, pad)
    return (x[:, -1], cache) + extras


# --------------------------------------------------------------------------
# chunked admission prefill (DESIGN.md "Chunked admission prefill"):
# consume the prompt one block-aligned span at a time so the scheduler can
# interleave decode ticks between chunks. The carry maintains exactly the
# state later chunks (and `_seed_decode_state`) need: per-layer KV written
# so far, the mean-pooled q/k block features (so every chunk can re-score
# the FULL block map via `masks.score_map_pooled` — bitwise what blocking
# prefill scores), and the decode-grid classification rows. Finalization
# goes through `_seed_decode_state` on the carried KV + rows, so every
# cache leaf is bitwise identical to blocking `prefill` BY CONSTRUCTION.
# --------------------------------------------------------------------------
def check_chunked_prefill(cfg: ArchConfig, backend: str = "gather"):
    """Loudly reject configs the chunked-prefill machine cannot serve
    bitwise. Chunk plan rows are sliced from a full-map classification,
    which is only row-decomposable without the column-capacity demotion
    pass (it couples rows); the execution path covers SLA layers on the
    gather/kernel backends only."""
    from repro.core import backends as backend_lib

    sla = cfg.sla
    if sla.mode != "sla":
        raise ValueError(
            f"chunked admission prefill requires sla.mode='sla' (got "
            f"{sla.mode!r})")
    if sorted(set(layer_kinds_list(cfg))) != [KIND_SLA]:
        raise ValueError(
            "chunked admission prefill requires an all-SLA layer stack "
            "(mixed full/swa stacks prefill blocking)")
    if sla.col_capacity_factor is not None:
        raise ValueError(
            "chunked admission prefill requires "
            "sla.col_capacity_factor=None: the column-capacity demotion "
            "pass couples query rows, so chunk plan rows could not be "
            "sliced from the full classification bitwise")
    if sla.window or cfg.sliding_window:
        raise ValueError(
            "chunked admission prefill does not support window-"
            "constrained SLA layers")
    if sla.block_q != sla.block_kv:
        raise ValueError(
            f"chunked admission prefill requires block_q == block_kv "
            f"(got {sla.block_q} vs {sla.block_kv})")
    if backend_lib.resolve(backend) not in ("gather", "kernel"):
        raise ValueError(
            f"chunked admission prefill supports backends "
            f"'gather'/'kernel' (got {backend!r})")


def make_prefill_carry(cfg: ArchConfig, bucket: int,
                       compute_dtype=jnp.bfloat16,
                       decode_sla: bool = False) -> dict:
    """Zero-initialized chunked-prefill carry for a (1, bucket) admission.

    Leaves (all stacked (L, ...) so `prefill_chunk` scans them):
      k/v  (L, 1, Hkv, bucket, Dh)  KV written so far (future rows zero)
      qpm  (L, 1, H, Tm, Dh) f32    mean-pooled q per written block row
      kpm  (L, 1, H, Tm, Dh) f32    mean-pooled (GQA-repeated) k per block
      dmc  (L, 1, H, Tm, Tm) int8   decode-grid rows (decode_sla only)
    """
    sla = cfg.sla
    if bucket % sla.block_q:
        raise ValueError(
            f"chunked prefill needs a block-aligned bucket (got {bucket} "
            f"for block_q={sla.block_q})")
    nl, hkv, h, dh = (cfg.num_layers, cfg.num_kv_heads, cfg.num_heads,
                      cfg.head_dim)
    tm = bucket // sla.block_q
    carry = {
        "k": jnp.zeros((nl, 1, hkv, bucket, dh), compute_dtype),
        "v": jnp.zeros((nl, 1, hkv, bucket, dh), compute_dtype),
        "qpm": jnp.zeros((nl, 1, h, tm, dh), jnp.float32),
        "kpm": jnp.zeros((nl, 1, h, tm, dh), jnp.float32),
    }
    if decode_sla:
        carry["dmc"] = jnp.full((nl, 1, h, tm, tm), -1, jnp.int8)
    return carry


def prefill_chunk(params, cfg: ArchConfig, tokens, carry, start,
                  compute_dtype=jnp.bfloat16, backend: str = "gather",
                  decode_max_len: Optional[int] = None):
    """Consume one block-aligned span of prompt tokens against the
    already-prefilled prefix.

    tokens: (1, C) int32, C a multiple of block_q; `start` the span's
    absolute token offset (block-aligned; python int or TRACED int32 —
    traced keeps every chunk index on one compiled graph). Returns
    (new_carry, last_hidden (1, d)) — the final chunk's last hidden
    feeds `logits_from_hidden` for the admission's first token.

    Bitwise contract (tests/test_serving.py chunked-parity suite): after
    the last chunk, carry k/v/dmc equal blocking `prefill`'s caches and
    decode rows bit-for-bit. Per layer the chunk (a) writes its KV and
    pooled q/k rows into the carry, (b) re-scores the FULL block map
    from the pooled carry (`masks.score_map_pooled` — masked-softmax
    rows depend only on columns <= row, all written) and slices its
    rows, (c) replicates `backends.execute`'s glue against the
    full-bucket carried KV (zero-padded future blocks contribute exact
    zeros through the marginal mask), (d) classifies its decode-grid
    rows from the same pooled maps. `decode_max_len` must match the
    value blocking prefill would get (required when carry has "dmc").
    """
    from repro.core import backends as backend_lib
    from repro.core.block_sparse_xla import sla_forward_gather
    from repro.core.phi import phi
    from repro.kernels import ops as kops

    check_chunked_prefill(cfg, backend)
    backend = backend_lib.resolve(backend)
    sla = cfg.sla
    bq = sla.block_q
    b, c = tokens.shape
    if b != 1:
        raise ValueError(f"prefill_chunk takes a batch-1 span (got {b})")
    if c % bq:
        raise ValueError(
            f"chunk length {c} must be a multiple of block_q={bq}")
    bucket = carry["k"].shape[-2]
    tm = bucket // bq
    nb = c // bq
    decode_sla = "dmc" in carry
    if decode_sla and decode_max_len is None:
        raise ValueError(
            "carry tracks decode-grid rows ('dmc') — pass the same "
            "decode_max_len blocking prefill would use")
    plan_cfg = dataclasses.replace(sla, causal=True)
    dcfg = (sla.decode_plan_cfg(decode_max_len // sla.block_kv)
            if decode_sla else None)
    start = jnp.asarray(start, jnp.int32)
    sb = start // bq
    positions = jnp.broadcast_to(
        (start + jnp.arange(c, dtype=jnp.int32))[None, :], (b, c))
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    interpret = jax.default_backend() != "tpu"  # kernel-backend parity

    def body(x, layer):
        layer = list(layer)
        p, kc, vc, qpm, kpm = (layer.pop(0), layer.pop(0), layer.pop(0),
                               layer.pop(0), layer.pop(0))
        dmc = layer.pop(0) if decode_sla else None
        xn = rms_norm(x, p["ln1"])
        q, k, v = _qkv(p, xn, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k.astype(kc.dtype), start, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v.astype(vc.dtype), start, axis=2)
        h, hkv = q.shape[1], k.shape[1]
        g = h // hkv
        kr = jnp.repeat(k, g, axis=1) if g > 1 else k
        # pooled-map rows: mean over each block's own tokens only, so
        # chunk-local pooling equals full-prefill pooling bitwise (and
        # repeat/pool commute for the GQA broadcast)
        qpm = jax.lax.dynamic_update_slice_in_dim(
            qpm, masks_lib.pool_blocks(q, bq), sb, axis=2)
        kpm = jax.lax.dynamic_update_slice_in_dim(
            kpm, masks_lib.pool_blocks(kr, sla.block_kv), sb, axis=2)
        routing = p.get("routing") if sla.routing_mode == "learned" \
            else None
        # full-map re-score + slice: rows <= written region are exact
        # (masked softmax rows never read unwritten columns; argsort is
        # per-row with col_capacity None)
        mc = masks_lib.classify_blocks(
            masks_lib.score_map_pooled(routing, qpm, kpm, plan_cfg),
            plan_cfg)
        mc_rows = jax.lax.dynamic_slice_in_dim(mc, sb, nb, axis=2)
        lut, counts = plan_lib.build_lut(mc_rows,
                                         plan_cfg.num_critical(tm))
        # inference-only: the hard indicator is bitwise the forward
        # value of the learned-routing straight-through gates
        marginal = (mc_rows == 0).astype(jnp.float32)
        if decode_sla:
            mcd = masks_lib.classify_blocks(
                masks_lib.score_map_pooled(routing, qpm, kpm, dcfg),
                dcfg)
            dmc = jax.lax.dynamic_update_slice_in_dim(
                dmc, jax.lax.dynamic_slice_in_dim(mcd, sb, nb, axis=2),
                sb, axis=2)
        # chunk attention: replicate backends.execute's glue with the
        # chunk's rows against the full-bucket carry KV
        krf = jnp.repeat(kc, g, axis=1) if g > 1 else kc
        vrf = jnp.repeat(vc, g, axis=1) if g > 1 else vc
        qp, kp = phi(q, sla.phi), phi(krf, sla.phi)
        if backend == "gather":
            rows_plan = plan_lib.SLAPlan(
                mc=mc_rows, lut=lut, counts=counts,
                col_lut=jnp.zeros((b, h, tm, 1), jnp.int32),
                col_counts=jnp.zeros((b, h, tm), jnp.int32),
                marginal=marginal)
            o_s, o_l = sla_forward_gather(q, krf, vrf, qp, kp, rows_plan,
                                          plan_cfg, row_offset=sb)
        else:
            o_s, o_l = kops.sla_attention_rows(
                q, krf, vrf, qp, kp, marginal, lut, counts, plan_cfg,
                interpret=interpret, row_offset=sb)
        proj = p["sla_proj"].astype(jnp.float32)
        o = (o_s + jnp.einsum("bhnd,hde->bhne", o_l, proj)).astype(x.dtype)
        out = o.transpose(0, 2, 1, 3).reshape(b, c, -1)
        out = jnp.einsum("bse,ed->bsd", out,
                         ctx.fsdp_gather(p["wo"].astype(x.dtype), "row"))
        x = ctx.shard_residual(x + ctx.shard_residual(out))
        f, _ = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        x = ctx.shard_residual(x + ctx.shard_residual(f))
        ys = (kc, vc, qpm, kpm) + ((dmc,) if decode_sla else ())
        return x, ys

    xs = (params["layers"], carry["k"], carry["v"], carry["qpm"],
          carry["kpm"])
    if decode_sla:
        xs = xs + (carry["dmc"],)
    x, ys = jax.lax.scan(body, x, xs)
    new_carry = {"k": ys[0], "v": ys[1], "qpm": ys[2], "kpm": ys[3]}
    if decode_sla:
        new_carry["dmc"] = ys[4]
    x = rms_norm(x, params["ln_f"])
    return new_carry, x[:, -1]


def finalize_chunked_prefill(cfg: ArchConfig, carry,
                             decode_max_len: Optional[int] = None) -> dict:
    """Chunked-prefill carry -> the cache dict blocking `prefill`
    returns. Deliberately mirrors `prefill`'s tail exactly — the decode
    state is rebuilt with `_seed_decode_state` (`plan_from_mask` on the
    full carried rows), NOT `plan_extend`, because the incremental path
    leaves stale values in dead col_lut padding slots and the serving
    bitwise bar covers every cache leaf."""
    kc, vc = carry["k"], carry["v"]
    bucket = kc.shape[-2]
    cache = {"k": kc, "v": vc, "pos": jnp.int32(bucket)}
    if decode_max_len is not None:
        _check_decode_grid(cfg, bucket, decode_max_len)
        cache["sla"] = _seed_decode_state(cfg, kc, vc, carry["dmc"],
                                          decode_max_len)
        grow = decode_max_len - bucket
        if grow > 0:
            pad = [(0, 0)] * 3 + [(0, grow), (0, 0)]
            cache["k"] = jnp.pad(kc, pad)
            cache["v"] = jnp.pad(vc, pad)
    return cache


def _dense_decode_attn(q, kc, vc, pos, kind, cfg: ArchConfig):
    """Masked softmax over the full static cache — O(S) per token.

    q: (B, H, 1, Dh); kc, vc: (B, Hkv, Smax, Dh); pos: scalar (aligned
    static-batch decode) or (B,) per-slot positions (continuous
    batching). GQA decode without materializing repeated KV: fold the
    head group into the query ("bkgd" layout) — scores are
    (B, Hkv, G, S) against the cache directly. Returns (B, 1, H * Dh)
    in q.dtype."""
    b, h = q.shape[0], q.shape[1]
    hkv, smax = kc.shape[1], kc.shape[2]
    g = h // hkv
    qg = q[:, :, 0, :].reshape(b, hkv, g, cfg.head_dim)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * (cfg.head_dim**-0.5)
    idx = jnp.arange(smax)[None, None, None, :]
    posb = pos if jnp.ndim(pos) == 0 else pos[:, None, None, None]
    ok = idx <= posb

    def swa_mask(s):
        w = cfg.local_window or cfg.sliding_window
        return jnp.where(idx > posb - w, s, NEG_INF)

    s = jnp.where(ok, s, NEG_INF)
    s = jax.lax.cond(kind == KIND_SWA, swa_mask, lambda s: s, s)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p_attn, vc.astype(jnp.float32))
    return o.astype(q.dtype).reshape(b, 1, h * cfg.head_dim)


def _cache_write(c, new, pos):
    """Write one new-token KV at `pos`: c (B, Hn, S, D), new (B, Hn, 1, D).

    Scalar `pos` is the aligned static-batch O(1) write; a (B,) vector
    writes each slot at its own position (vmapped per-example update —
    the continuous-batching layout, DESIGN.md "Serving API v2")."""
    new = new.astype(c.dtype)
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(c, new, pos, axis=2)
    return jax.vmap(lambda cb, nb, pb: jax.lax.dynamic_update_slice_in_dim(
        cb, nb, pb, axis=1))(c, new, pos)


def _blk_update(buf, upd, row):
    """Add `upd` into block `row` of a per-block running buffer.

    buf: (B, Hn, Tn, ...); upd: (B, Hn, ...); row: scalar or (B,)."""
    if jnp.ndim(row) == 0:
        j = jax.lax.dynamic_slice_in_dim(buf, row, 1, axis=2)
        return jax.lax.dynamic_update_slice_in_dim(
            buf, j + upd[:, :, None], row, axis=2)

    def one(bb, ub, rb):
        j = jax.lax.dynamic_slice_in_dim(bb, rb, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(
            bb, j + ub[:, None], rb, axis=1)

    return jax.vmap(one)(buf, upd, row)


def _page_gather(pool, pt):
    """Per-layer page pool (P, Hkv, ...) -> per-slot block view
    (B, Hkv, Tn, ...) through the page table pt (B, Tn) int32."""
    return jnp.moveaxis(jnp.take(pool, pt, axis=0), 2, 1)


def _page_gather_kv(pool, pt):
    """KV page pool (P, Hkv, bkv, Dh) -> the contiguous (B, Hkv, S, Dh)
    cache view a monolithic per-slot cache would hold."""
    g = _page_gather(pool, pt)                  # (B, Hkv, Tn, bkv, Dh)
    return g.reshape(g.shape[:2] + (g.shape[2] * g.shape[3], g.shape[4]))


def _page_write_kv(pool, new, pid, off):
    """Write one new-token KV into its page: pool (P, Hkv, bkv, Dh),
    new (B, Hkv, 1, Dh), pid/off (B,). Page-table invariant (enforced
    by the scheduler's copy-on-write pass): every active slot's write
    page is privately owned, so the pids are distinct and the scatter
    is conflict-free."""
    return pool.at[pid, :, off].set(new[:, :, 0, :].astype(pool.dtype))


def decode_step(params, cfg: ArchConfig, token, cache,
                compute_dtype=jnp.bfloat16, backend: str = "gather",
                drift_threshold=None):
    """One decode step. token: (B,) int32; cache k/v: (L, B, Hkv, S, Dh);
    cache['pos'] is a scalar (static-batch serving, aligned sequences)
    or a (B,) vector of per-slot positions (continuous batching —
    every slot advances through its own sequence independently).

    The new KV is written at `pos` via dynamic_update_slice (O(1)
    write; vmapped per slot under vector positions). Attention: caches
    made with `prefill(decode_max_len=)` or `make_cache(decode_sla=True)`
    carry decode-SLA state and run incremental-plan SLA decode
    (`_decode_step_sla`); otherwise dense masked attention over the
    full static cache (O(S) per token — exactly the decode_* cells'
    old cost model).

    Paged caches (`make_paged_cache`, DESIGN.md "Paged KV & prefix
    caching") carry `kp`/`vp` page pools plus a `pt` page table instead
    of monolithic k/v; the same step math runs against page-gathered
    views, so paged and monolithic decode are bitwise identical.
    """
    if "sla" in cache:
        return _decode_step_sla(params, cfg, token, cache, compute_dtype,
                                backend, drift_threshold)
    paged = "kp" in cache
    emb = params["embed"]
    x = jnp.take(emb, token[:, None], axis=0).astype(compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]  # scalar or (B,) int32
    positions = jnp.broadcast_to(pos, (b,))[:, None]
    kinds = layer_kinds(cfg)
    if paged:
        pt = cache["pt"]
        bkv = cache["kp"].shape[3]
        tn = pt.shape[1]
        # runaway inactive slots clamp onto their scratch page
        wpid = pt[jnp.arange(b), jnp.minimum(pos // bkv, tn - 1)]
        woff = pos % bkv

    def body(x, layer):
        p, kind, kc, vc = layer
        xn = rms_norm(x, p["ln1"])
        q, k_new, v_new = _qkv(p, xn, cfg, positions)
        if paged:
            kc = _page_write_kv(kc, k_new, wpid, woff)
            vc = _page_write_kv(vc, v_new, wpid, woff)
            kd, vd = _page_gather_kv(kc, pt), _page_gather_kv(vc, pt)
        else:
            kc = _cache_write(kc, k_new, pos)
            vc = _cache_write(vc, v_new, pos)
            kd, vd = kc, vc
        o = _dense_decode_attn(q, kd, vd, pos, kind, cfg)
        x = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        f, _ = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        return x + f, (kc, vc)

    kv_in = (cache["kp"], cache["vp"]) if paged else (cache["k"], cache["v"])
    x, (kc, vc) = jax.lax.scan(body, x, (params["layers"], kinds) + kv_in)
    x = rms_norm(x, params["ln_f"])
    logits = logits_from_hidden(params, x[:, 0])
    if paged:
        new_cache = {"kp": kc, "vp": vc, "pt": pt, "pos": pos + 1}
    else:
        new_cache = {"k": kc, "v": vc, "pos": pos + 1}
    return logits, new_cache


def _decode_step_sla(params, cfg: ArchConfig, token, cache, compute_dtype,
                     backend: str, drift_threshold=None):
    """Decode-time SLA step (DESIGN.md "Decode-time SLA").

    Per token: O(1) running-state update (phi(k) v^T into the current
    block's h/z partials and totals), then attention over only the live
    row's critical KV blocks plus the O(1) subtractive linear branch —
    per-step attention cost is critical-blocks + O(1) instead of O(S).

    Incremental plan maintenance happens at block boundaries
    (pos % b_q == 0): the just-completed row is classified from its
    full pooled q and appended with `plan_extend` ("extend"), and the
    new live row's structure is drift-gated per layer — inherit the
    previous row's critical set (+ forced diagonal, SLA2-style reuse,
    "reuse") unless its drift against a fresh classification from the
    first token's q reaches that layer's threshold ("replan").
    Boundary quantities are computed unconditionally and selected with
    `where` — they are O(Tn) block-level ops, noise next to the
    attention itself — which keeps the step a single static-shape jit.
    The exception is row *scoring* (and only it): the learned routing
    head projects the whole pooled-K cache (O(Tn d^2) per head), so
    both score_row calls sit under `lax.cond(boundary, ...)` — the
    amortized-per-boundary cost `flops.sla_decode_flops` reports.

    Per-slot positions (DESIGN.md "Serving API v2"): a (B,) `pos`
    vector runs every piece of the above per slot — each slot crosses
    its own block boundaries, appends its own plan rows, and makes its
    own drift decision (min over ITS heads only, where the aligned
    scalar-pos batch keeps the historical min-over-batch decision).
    Boundary scoring then runs whenever ANY slot is at a boundary
    (`lax.cond(jnp.any(boundary))`), so the amortized-cost claim
    holds per slot on average but individual steps may pay it for a
    single slot. Plan/state counters become per-slot (L, B) arrays.

    Paged caches (DESIGN.md "Paged KV & prefix caching") swap the
    monolithic per-slot k/v/hblk/zblk/kpool for global page pools
    indexed by the `pt` page table; every read goes through a
    page-gathered view that is value-identical to the monolithic
    layout, and every write lands in the slot's (privately owned)
    current page — so the step stays bitwise equal to unpaged decode.
    """
    from repro.core import backends as backend_lib
    from repro.core.phi import phi

    backend_lib.resolve_decode(backend)
    paged = "kp" in cache
    emb = params["embed"]
    x = jnp.take(emb, token[:, None], axis=0).astype(compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]
    vec = jnp.ndim(pos) > 0  # per-slot positions (continuous batching)
    if paged and not vec:
        raise ValueError("paged decode requires per-slot (B,) positions")
    st = cache["sla"]
    sla = cfg.sla
    bq = sla.block_q
    if paged:
        pt = cache["pt"]
        tn = pt.shape[1]
        smax = tn * sla.block_kv
        # runaway inactive slots clamp onto their scratch page
        wpid = pt[jnp.arange(b), jnp.minimum(pos // sla.block_kv, tn - 1)]
        woff = pos % sla.block_kv
    else:
        smax = cache["k"].shape[-2]
        tn = smax // sla.block_kv
    dcfg = sla.decode_plan_cfg(tn)
    kinds = layer_kinds(cfg)
    used = sorted(set(layer_kinds_list(cfg)))
    if drift_threshold is None:
        thresholds = jnp.asarray(sla.drift_thresholds(cfg.num_layers),
                                 jnp.float32)
    else:
        thresholds = jnp.broadcast_to(
            jnp.asarray(drift_threshold, jnp.float32), (cfg.num_layers,))

    row = pos // bq                      # current (partial) query row(s)
    boundary = (pos % bq) == 0           # block(s) just completed
    any_boundary = jnp.any(boundary)
    append = jnp.logical_and(boundary, st["rows"] < row)
    rowm = row[:, None] if vec else row  # row arg for masks_lib helpers
    positions = jnp.broadcast_to(pos, (b,))[:, None]
    blk = jnp.arange(tn)
    # tokens per KV block AFTER this step's write (for pooled-k means)
    posx = pos[:, None] if vec else pos
    blk_cnt = jnp.clip(jnp.minimum((posx + 1) - blk * sla.block_kv,
                                   sla.block_kv), 1, sla.block_kv)
    # shaped to divide kp_sum (B, Hkv, Tn, D)
    cnt_div = blk_cnt[:, None, :, None] if vec else blk_cnt[:, None]

    def bsel(m, a, o):
        """where(m, a, o) with m a scalar bool or a per-slot (B,) bool."""
        mm = m if jnp.ndim(m) == 0 else m.reshape((b,) + (1,) * (a.ndim - 1))
        return jnp.where(mm, a, o)

    def body(x, layer):
        (p, kind, thr, kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan,
         llut, lcnt, lmarg, ret_prev) = layer
        xn = rms_norm(x, p["ln1"])
        q, k_new, v_new = _qkv(p, xn, cfg, positions)
        if paged:
            kc = _page_write_kv(kc, k_new, wpid, woff)
            vc = _page_write_kv(vc, v_new, wpid, woff)
        else:
            kc = _cache_write(kc, k_new, pos)
            vc = _cache_write(vc, v_new, pos)
        h, hkv = q.shape[1], k_new.shape[1]
        g = h // hkv
        qf = q[:, :, 0, :].astype(jnp.float32)       # (B, H, D)
        kf = k_new[:, :, 0, :].astype(jnp.float32)   # (B, Hkv, D)
        vf = v_new[:, :, 0, :].astype(jnp.float32)

        # same row scorer as prefill (learned routing included), so
        # decode rows classify exactly as the one-shot classifier would.
        # Scoring runs under lax.cond on the block boundary: it is the
        # one boundary quantity whose cost is NOT O(Tn) block-level
        # noise (the learned head projects the whole pooled-K cache,
        # O(Tn d^2) per head), and flops.sla_decode_flops amortizes it
        # by /b_q — the cond makes that accounting true.
        routing = p.get("routing") if dcfg.routing_mode == "learned" \
            else None
        pc_zeros = jnp.zeros(qf.shape[:2] + (tn,), jnp.float32)

        # ---- 1. finalize the just-completed row (uses the PRE-update
        # kpool: the completed row cannot see the current block) ----
        kp_view = _page_gather(kp_sum, pt) if paged else kp_sum
        kpool_mean = kp_view / sla.block_kv
        kpm = jnp.repeat(kpool_mean, g, axis=1)      # (B, H, Tn, D)
        pc_prev = jax.lax.cond(
            any_boundary,
            lambda _: masks_lib.score_row(routing, qp_sum / bq, kpm,
                                          rowm - 1, dcfg),
            lambda _: pc_zeros, None)
        mc_prev = masks_lib.classify_row(pc_prev, rowm - 1, dcfg)
        if vec:
            ext = jax.vmap(plan_lib.plan_extend)(plan, mc_prev, row - 1)
        else:
            ext = plan_lib.plan_extend(plan, mc_prev, row - 1)
        plan = jax.tree_util.tree_map(
            lambda a, o: bsel(append, a, o), ext, plan)

        # ---- 2. O(1) running-state update for the new token ----
        phik = phi(kf, sla.phi)                      # (B, Hkv, D) f32
        hupd = jnp.einsum("bkd,bke->bkde", phik, vf)
        if paged:
            # distinct private write pages -> conflict-free update; the
            # gather/add/set form (not scatter-add) mirrors the
            # monolithic slice/add/write so XLA fuses the phi-derived
            # update identically and the partials stay BITWISE equal
            hb = hb.at[wpid].set(hb[wpid] + hupd)
            zb = zb.at[wpid].set(zb[wpid] + phik)
            kp_sum = kp_sum.at[wpid].set(kp_sum[wpid] + kf)
        else:
            hb = _blk_update(hb, hupd, row)
            zb = _blk_update(zb, phik, row)
            kp_sum = _blk_update(kp_sum, kf, row)
        ht = ht + hupd
        zt = zt + phik

        # ---- 3. live-row structure (boundary only): drift-gated
        # inherit-vs-fresh, per-layer threshold ----
        kp_view = _page_gather(kp_sum, pt) if paged else kp_sum
        kpm_live = jnp.repeat(kp_view / cnt_div, g, axis=1)
        pc_live = jax.lax.cond(
            any_boundary,
            lambda _: masks_lib.score_row(routing, qf, kpm_live, rowm,
                                          dcfg),
            lambda _: pc_zeros, None)
        mc_fresh = masks_lib.classify_row(pc_live, rowm, dcfg)
        if vec:
            mc_inh = jax.vmap(lambda m, r: jax.lax.dynamic_slice_in_dim(
                m, r, 1, axis=1)[:, 0, :])(plan.mc, row - 1)
            diag = (blk[None, :] == row[:, None])[:, None, :]
        else:
            mc_inh = jax.lax.dynamic_slice_in_dim(
                plan.mc, row - 1, 1, axis=2)[..., 0, :]  # (B, H, Tn)
            diag = blk == row
        mc_inh = jnp.where(diag, jnp.int8(1), mc_inh)
        stale = jnp.sum(pc_live * (mc_inh == 1), axis=-1)
        fresh = jnp.sum(pc_live * (mc_fresh == 1), axis=-1)
        r = jnp.clip(stale / jnp.maximum(fresh, plan_lib.EPS), 0.0, 1.0)
        if vec:
            # per-slot decision: each slot's own heads gate its row
            retention = jnp.min(r, axis=1)                      # (B,)
            replan = jnp.logical_and((1.0 - retention) >= thr,
                                     thr < 1.0)
            rep_m = replan[:, None, None]
        else:
            # aligned static batch: one decision for every row
            retention = jnp.min(r)
            replan = jnp.logical_and((1.0 - retention) >= thr,
                                     thr < 1.0)
            rep_m = replan
        mc_live = jnp.where(rep_m, mc_fresh, mc_inh)
        llut_n, lcnt_n = plan_lib.build_lut(mc_live[..., None, :],
                                            plan.k_sel)
        llut = bsel(boundary, llut_n[..., 0, :], llut)
        lcnt = bsel(boundary, lcnt_n[..., 0], lcnt)
        lmarg = bsel(boundary,
                     jnp.sum((mc_live == 0).astype(jnp.int32), -1),
                     lmarg)

        # ---- 4. attention: critical blocks + O(1) linear state ----
        state = {"k": kc, "v": vc, "hblk": hb, "zblk": zb, "htot": ht,
                 "ztot": zt, "lut": llut, "cnt": lcnt, "marg": lmarg}
        if paged:
            state["pt"] = pt

        def do_sla(_):
            return backend_lib.decode_execute(
                state, {"proj": p["sla_proj"]}, q, pos, dcfg,
                backend=backend).reshape(b, 1, h * cfg.head_dim) \
                .astype(x.dtype)

        def do_dense(_):
            if paged:
                return _dense_decode_attn(q, _page_gather_kv(kc, pt),
                                          _page_gather_kv(vc, pt), pos,
                                          kind, cfg)
            return _dense_decode_attn(q, kc, vc, pos, kind, cfg)

        if used == [KIND_SLA]:
            o = do_sla(None)
        else:
            o = jax.lax.cond(kind == KIND_SLA, do_sla, do_dense, None)
        x2 = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        f, _ = _ffn(p, rms_norm(x2, p["ln2"]), cfg)
        qp_sum = bsel(boundary, qf, qp_sum + qf)
        ys = (kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt,
              lmarg, append.astype(jnp.int32),
              jnp.logical_and(boundary, replan).astype(jnp.int32),
              jnp.logical_and(boundary, ~replan).astype(jnp.int32),
              jnp.where(boundary, retention, ret_prev))
        return x2 + f, ys

    if paged:
        slap = cache["slap"]
        xs = (params["layers"], kinds, thresholds, cache["kp"],
              cache["vp"], slap["hblk"], slap["zblk"], st["htot"],
              st["ztot"], slap["kpool"], st["qpool"], st["plan"],
              st["live_lut"], st["live_cnt"], st["live_marg"],
              st["retention"])
    else:
        xs = (params["layers"], kinds, thresholds, cache["k"], cache["v"],
              st["hblk"], st["zblk"], st["htot"], st["ztot"], st["kpool"],
              st["qpool"], st["plan"], st["live_lut"], st["live_cnt"],
              st["live_marg"], st["retention"])
    x, ys = jax.lax.scan(body, x, xs)
    (kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt, lmarg,
     exts, reps, reuses, rets) = ys
    x = rms_norm(x, params["ln_f"])
    logits = logits_from_hidden(params, x[:, 0])
    new_st = {
        "htot": ht, "ztot": zt,
        "qpool": qp_sum, "plan": plan, "rows": st["rows"] + append,
        "live_lut": llut, "live_cnt": lcnt, "live_marg": lmarg,
        "extends": st["extends"] + exts, "replans": st["replans"] + reps,
        "reuses": st["reuses"] + reuses, "retention": rets,
    }
    if paged:
        return logits, {"kp": kc, "vp": vc, "pt": pt, "pos": pos + 1,
                        "slap": {"hblk": hb, "zblk": zb, "kpool": kp_sum},
                        "sla": new_st}
    new_st.update({"hblk": hb, "zblk": zb, "kpool": kp_sum})
    return logits, {"k": kc, "v": vc, "pos": pos + 1, "sla": new_st}


def _dense_decode_chunk_attn(q, kc, vc, pos_c, kind, cfg: ArchConfig):
    """Chunked `_dense_decode_attn`: q (B, H, C, Dh) against the full
    static cache, token c masked to columns <= pos_c[c]. Returns
    (B, C, H * Dh) in q.dtype."""
    b, h, cdim = q.shape[0], q.shape[1], q.shape[2]
    hkv, smax = kc.shape[1], kc.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, cdim, cfg.head_dim)
    s = jnp.einsum("bkgcd,bksd->bkgcs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * (cfg.head_dim**-0.5)
    idx = jnp.arange(smax)
    ok = idx[None, :] <= pos_c[:, None]                  # (C, S)

    def swa_mask(s):
        w = cfg.local_window or cfg.sliding_window
        return jnp.where(idx[None, :] > pos_c[:, None] - w, s, NEG_INF)

    s = jnp.where(ok, s, NEG_INF)
    s = jax.lax.cond(kind == KIND_SWA, swa_mask, lambda s: s, s)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bksd->bkgcd", p_attn, vc.astype(jnp.float32))
    return (o.astype(q.dtype).transpose(0, 3, 1, 2, 4)
            .reshape(b, cdim, h * cfg.head_dim))


def decode_chunk(params, cfg: ArchConfig, tokens, cache,
                 compute_dtype=jnp.bfloat16, backend: str = "gather",
                 drift_threshold=None, chunk: Optional[int] = None):
    """Score a chunk of C given tokens against the cache in one pass
    (verify-style multi-token decode, for speculative drafts).

    tokens: (B, C) int32. Returns (logits (B, C, V) f32, new_cache):
    logits[:, c] are the next-token logits after consuming
    tokens[:, :c + 1] — the values C successive `decode_step` calls
    produce — and new_cache is the state after all C tokens. One
    attention launch per layer covers the whole chunk (per-token plan
    rows ride the kernel's scalar-prefetch LUT; see
    `backends.decode_execute_chunk`), and the O(1) H/Z running-state
    updates plus `plan_extend` boundary work fold into a single scanned
    update per layer instead of C jit steps — launch and
    boundary-scoring overhead amortize C-fold.

    `chunk=` splits a longer token run into sub-chunks of that size
    (a python loop over at most two compiled shapes). Requires a scalar
    `cache['pos']` (aligned static batch); the continuous-batching
    scheduler decodes per token.
    """
    if jnp.ndim(cache["pos"]) > 0:
        raise ValueError(
            "decode_chunk requires a scalar cache['pos'] (aligned "
            "static-batch decode); per-slot continuous batching decodes "
            "one token at a time via decode_step")
    cdim = tokens.shape[1]
    if chunk is not None and cdim > chunk:
        outs = []
        for lo in range(0, cdim, chunk):
            logits, cache = decode_chunk(
                params, cfg, tokens[:, lo:lo + chunk], cache,
                compute_dtype, backend, drift_threshold)
            outs.append(logits)
        return jnp.concatenate(outs, axis=1), cache
    if "sla" in cache:
        return _decode_chunk_sla(params, cfg, tokens, cache, compute_dtype,
                                 backend, drift_threshold)
    emb = params["embed"]
    x = jnp.take(emb, tokens, axis=0).astype(compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]
    pos_c = pos + jnp.arange(cdim, dtype=jnp.int32)
    positions = jnp.broadcast_to(pos_c, (b, cdim))
    kinds = layer_kinds(cfg)

    def body(x, layer):
        p, kind, kc, vc = layer
        xn = rms_norm(x, p["ln1"])
        q, k_new, v_new = _qkv(p, xn, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k_new.astype(kc.dtype), pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v_new.astype(vc.dtype), pos, axis=2)
        o = _dense_decode_chunk_attn(q, kc, vc, pos_c, kind, cfg)
        x = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        f, _ = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        return x + f, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["layers"], kinds, cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"])
    logits = logits_from_hidden(params, x)
    return logits, {"k": kc, "v": vc, "pos": pos + cdim}


def _decode_chunk_sla(params, cfg: ArchConfig, tokens, cache, compute_dtype,
                      backend: str, drift_threshold=None):
    """Chunked decode-time SLA (ISSUE 6 tentpole, multi-token decode).

    Per layer: one inner `lax.scan` over the C tokens replays
    `_decode_step_sla`'s boundary/state phases 1-3 op-for-op (so the
    final cache state is bitwise the per-token state), emitting each
    token's live plan row (lut/cnt/marg) and its at-time-c H/Z totals;
    then ONE chunked attention call covers all C tokens.

    Snapshot protocol (why one end-of-chunk hblk suffices): token c's
    marginal set contains only completed blocks j < row_c, and no later
    chunk token writes those (tokens only write their own row, which is
    >= row_c) — so end-of-chunk hblk/zblk are already the at-time-c
    values for every marginal block. The one exception is the forced
    critical diagonal block row_c, still accumulating inside the chunk;
    the scan emits its at-time partial per token (state["hdiag"] /
    ["zdiag"]) and the kernel substitutes it for the streamed block at
    the LUT's diagonal entry — every term in H_marg = htot_c -
    sum_lut h_c[j] is then the per-token value in the per-token order,
    so chunked logits match the per-token ones bitwise. The sparse
    branch needs no protocol at all: the chunk's KV is written before
    attention and token c masks columns > pos + c.
    """
    from repro.core import backends as backend_lib
    from repro.core.phi import phi

    backend_lib.resolve_decode(backend)
    emb = params["embed"]
    x = jnp.take(emb, tokens, axis=0).astype(compute_dtype)
    b, cdim = tokens.shape
    pos = cache["pos"]
    st = cache["sla"]
    sla = cfg.sla
    bq = sla.block_q
    smax = cache["k"].shape[-2]
    tn = smax // sla.block_kv
    dcfg = sla.decode_plan_cfg(tn)
    kinds = layer_kinds(cfg)
    used = sorted(set(layer_kinds_list(cfg)))
    if drift_threshold is None:
        thresholds = jnp.asarray(sla.drift_thresholds(cfg.num_layers),
                                 jnp.float32)
    else:
        thresholds = jnp.broadcast_to(
            jnp.asarray(drift_threshold, jnp.float32), (cfg.num_layers,))

    offs = jnp.arange(cdim, dtype=jnp.int32)
    pos_c = pos + offs                       # (C,) per-token positions
    row_c = pos_c // bq
    boundary_c = (pos_c % bq) == 0
    positions = jnp.broadcast_to(pos_c, (b, cdim))
    blk = jnp.arange(tn)
    blk_cnt_c = jnp.clip(jnp.minimum(
        (pos_c[:, None] + 1) - blk * sla.block_kv, sla.block_kv),
        1, sla.block_kv)                     # (C, Tn)

    # rows bookkeeping is layer-independent: replay the append decisions
    def rows_scan(rows, cc):
        app = jnp.logical_and(boundary_c[cc], rows < row_c[cc])
        return rows + app.astype(jnp.int32), app

    rows_after, append_c = jax.lax.scan(rows_scan, st["rows"], offs)

    def body(x, layer):
        (p, kind, thr, kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan,
         llut, lcnt, lmarg, ret_prev) = layer
        xn = rms_norm(x, p["ln1"])
        q, k_new, v_new = _qkv(p, xn, cfg, positions)   # q (B, H, C, D)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k_new.astype(kc.dtype), pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v_new.astype(vc.dtype), pos, axis=2)
        h, hkv = q.shape[1], k_new.shape[1]
        g = h // hkv
        qf = q.astype(jnp.float32)                      # (B, H, C, D)
        kf = k_new.astype(jnp.float32)                  # (B, Hkv, C, D)
        vf = v_new.astype(jnp.float32)
        phik = phi(kf, sla.phi)
        routing = p.get("routing") if dcfg.routing_mode == "learned" \
            else None
        pc_zeros = jnp.zeros((b, h, tn), jnp.float32)

        def tok(carry, cc):
            (hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt, lmarg,
             ret) = carry
            rowc, bnd = row_c[cc], boundary_c[cc]
            app = append_c[cc]
            qf_c, kf_c, vf_c = qf[:, :, cc], kf[:, :, cc], vf[:, :, cc]
            phik_c = phik[:, :, cc]
            # ---- 1. finalize the just-completed row (PRE-update kpool)
            kpm = jnp.repeat(kp_sum / sla.block_kv, g, axis=1)
            pc_prev = jax.lax.cond(
                bnd,
                lambda _: masks_lib.score_row(routing, qp_sum / bq, kpm,
                                              rowc - 1, dcfg),
                lambda _: pc_zeros, None)
            mc_prev = masks_lib.classify_row(pc_prev, rowc - 1, dcfg)
            ext = plan_lib.plan_extend(plan, mc_prev, rowc - 1)
            plan = jax.tree_util.tree_map(
                lambda a, o: jnp.where(app, a, o), ext, plan)
            # ---- 2. O(1) running-state update ----
            hupd = jnp.einsum("bkd,bke->bkde", phik_c, vf_c)
            hb = _blk_update(hb, hupd, rowc)
            zb = _blk_update(zb, phik_c, rowc)
            ht = ht + hupd
            zt = zt + phik_c
            kp_sum = _blk_update(kp_sum, kf_c, rowc)
            hdiag = jax.lax.dynamic_slice_in_dim(hb, rowc, 1,
                                                 axis=2)[:, :, 0]
            zdiag = jax.lax.dynamic_slice_in_dim(zb, rowc, 1,
                                                 axis=2)[:, :, 0]
            # ---- 3. live-row structure (boundary only) ----
            cnt_div = blk_cnt_c[cc][:, None]
            kpm_live = jnp.repeat(kp_sum / cnt_div, g, axis=1)
            pc_live = jax.lax.cond(
                bnd,
                lambda _: masks_lib.score_row(routing, qf_c, kpm_live,
                                              rowc, dcfg),
                lambda _: pc_zeros, None)
            mc_fresh = masks_lib.classify_row(pc_live, rowc, dcfg)
            mc_inh = jax.lax.dynamic_slice_in_dim(
                plan.mc, rowc - 1, 1, axis=2)[..., 0, :]
            mc_inh = jnp.where(blk == rowc, jnp.int8(1), mc_inh)
            stale = jnp.sum(pc_live * (mc_inh == 1), axis=-1)
            fresh = jnp.sum(pc_live * (mc_fresh == 1), axis=-1)
            r = jnp.clip(stale / jnp.maximum(fresh, plan_lib.EPS),
                         0.0, 1.0)
            retention = jnp.min(r)
            replan = jnp.logical_and((1.0 - retention) >= thr, thr < 1.0)
            mc_live = jnp.where(replan, mc_fresh, mc_inh)
            llut_n, lcnt_n = plan_lib.build_lut(mc_live[..., None, :],
                                                plan.k_sel)
            llut = jnp.where(bnd, llut_n[..., 0, :], llut)
            lcnt = jnp.where(bnd, lcnt_n[..., 0], lcnt)
            lmarg = jnp.where(bnd,
                              jnp.sum((mc_live == 0).astype(jnp.int32), -1),
                              lmarg)
            qp_sum = jnp.where(bnd, qf_c, qp_sum + qf_c)
            ret = jnp.where(bnd, retention, ret)
            carry = (hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt,
                     lmarg, ret)
            ys = (llut, lcnt, lmarg, ht, zt, hdiag, zdiag,
                  jnp.logical_and(bnd, replan).astype(jnp.int32),
                  jnp.logical_and(bnd, ~replan).astype(jnp.int32))
            return carry, ys

        carry0 = (hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt, lmarg,
                  ret_prev)
        carryn, tys = jax.lax.scan(tok, carry0, offs)
        (hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt, lmarg,
         ret) = carryn
        (llut_t, lcnt_t, lmarg_t, ht_t, zt_t, hdiag_t, zdiag_t, reps_t,
         reuse_t) = tys
        # per-token plan rows / totals: scan axis (C) -> chunk axis
        lut_ct = jnp.moveaxis(llut_t, 0, 2)             # (B, H, C, K)
        cnt_ct = jnp.moveaxis(lcnt_t, 0, 2)             # (B, H, C)
        marg_ct = jnp.moveaxis(lmarg_t, 0, 2)
        ht_ct = jnp.moveaxis(ht_t, 0, 2)                # (B, Hkv, C, D, D)
        zt_ct = jnp.moveaxis(zt_t, 0, 2)

        # ---- 4. attention: one chunked launch over C tokens ----
        state = {"k": kc, "v": vc, "hblk": hb, "zblk": zb,
                 "hdiag": jnp.moveaxis(hdiag_t, 0, 2),
                 "zdiag": jnp.moveaxis(zdiag_t, 0, 2),
                 "htot": ht_ct, "ztot": zt_ct,
                 "lut": lut_ct, "cnt": cnt_ct, "marg": marg_ct}

        def do_sla(_):
            return backend_lib.decode_execute_chunk(
                state, {"proj": p["sla_proj"]}, q, pos, dcfg,
                backend=backend).transpose(0, 2, 1, 3) \
                .reshape(b, cdim, h * cfg.head_dim).astype(x.dtype)

        def do_dense(_):
            return _dense_decode_chunk_attn(q, kc, vc, pos_c, kind, cfg)

        if used == [KIND_SLA]:
            o = do_sla(None)
        else:
            o = jax.lax.cond(kind == KIND_SLA, do_sla, do_dense, None)
        x2 = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        f, _ = _ffn(p, rms_norm(x2, p["ln2"]), cfg)
        ys = (kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt,
              lmarg, jnp.sum(append_c.astype(jnp.int32)),
              jnp.sum(reps_t), jnp.sum(reuse_t), ret)
        return x2 + f, ys

    xs = (params["layers"], kinds, thresholds, cache["k"], cache["v"],
          st["hblk"], st["zblk"], st["htot"], st["ztot"], st["kpool"],
          st["qpool"], st["plan"], st["live_lut"], st["live_cnt"],
          st["live_marg"], st["retention"])
    x, ys = jax.lax.scan(body, x, xs)
    (kc, vc, hb, zb, ht, zt, kp_sum, qp_sum, plan, llut, lcnt, lmarg,
     exts, reps, reuses, rets) = ys
    x = rms_norm(x, params["ln_f"])
    logits = logits_from_hidden(params, x)
    new_st = {
        "hblk": hb, "zblk": zb, "htot": ht, "ztot": zt, "kpool": kp_sum,
        "qpool": qp_sum, "plan": plan, "rows": rows_after,
        "live_lut": llut, "live_cnt": lcnt, "live_marg": lmarg,
        "extends": st["extends"] + exts, "replans": st["replans"] + reps,
        "reuses": st["reuses"] + reuses, "retention": rets,
    }
    return logits, {"k": kc, "v": vc, "pos": pos + cdim, "sla": new_st}


def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16,
               decode_sla: Optional[bool] = None,
               per_slot: bool = False) -> dict:
    """Empty decode cache. `decode_sla` (default: cfg.sla.decode_mode ==
    "sla") adds the decode-time SLA state (empty incremental plan +
    zeroed running H/Z); production callers seed a *filled* decode
    cache via `prefill(decode_max_len=...)` instead.

    `per_slot=True` lays the cache out for continuous batching
    (DESIGN.md "Serving API v2"): `pos` (and the decode-SLA `rows` /
    counter state) become per-slot vectors, so each batch row advances
    through its own sequence and `insert_slot` can scatter a fresh
    prefill into any slot independently."""
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
             "pos": (jnp.zeros((batch,), jnp.int32) if per_slot
                     else jnp.int32(0))}
    if decode_sla is None:
        decode_sla = cfg.sla.decode_mode == "sla"
    if decode_sla:
        _check_decode_grid(cfg, max_len, max_len)
        mc = jnp.full((cfg.num_layers, batch, cfg.num_heads, 0, 0),
                      -1, jnp.int8)
        st = _seed_decode_state(
            cfg, cache["k"][..., :0, :], cache["v"][..., :0, :],
            mc, max_len)
        if per_slot:
            st["rows"] = jnp.full((batch,), st["rows"], jnp.int32)
            for key in ("extends", "replans", "reuses", "retention"):
                st[key] = jnp.repeat(st[key][:, None], batch, axis=1)
        cache["sla"] = st
    return cache


def insert_slot(cache: dict, single: dict, slot) -> dict:
    """Scatter a batch-1 prefill cache into decode slot `slot` of a
    per-slot cache (`make_cache(..., per_slot=True)`).

    `single` comes from `prefill(params, cfg, prompt[None, :], ...)`
    over the SAME max_len — decode-SLA prefills size their caches via
    `decode_max_len`; dense callers pad k/v before inserting. Every
    piece of request state rides along: KV rows, the incremental
    decode plan's rows, the running H/Z linear state, and the pooled
    q/k features, so the admitted request decodes exactly as it would
    have in a fresh aligned batch (DESIGN.md "Serving API v2"). The
    write is jit-traceable with a traced `slot` — admission compiles
    to one scatter.
    """
    if single["k"].shape[1] != 1:
        raise ValueError(
            f"insert_slot takes a batch-1 prefill cache (got batch "
            f"{single['k'].shape[1]})")
    if ("sla" in cache) != ("sla" in single):
        raise ValueError(
            "decode-SLA 'sla' state mismatch: the slot cache and the "
            "prefill cache must both (or neither) carry it")
    if single["k"].shape[-2] != cache["k"].shape[-2]:
        raise ValueError(
            f"cache length mismatch: the slot cache holds "
            f"{cache['k'].shape[-2]} positions but the prefill cache "
            f"has {single['k'].shape[-2]}; prefill with decode_max_len "
            f"(or pad k/v) to the scheduler's max_len first")

    def upd(live, one):
        return jax.lax.dynamic_update_slice_in_dim(
            live, one.astype(live.dtype), slot, axis=1)

    out = {"k": upd(cache["k"], single["k"]),
           "v": upd(cache["v"], single["v"]),
           "pos": cache["pos"].at[slot].set(single["pos"])}
    if "sla" in cache:
        s, t = cache["sla"], single["sla"]
        ns = {key: upd(s[key], t[key])
              for key in ("hblk", "zblk", "htot", "ztot", "kpool",
                          "qpool", "live_lut", "live_cnt", "live_marg")}
        ns["plan"] = jax.tree_util.tree_map(upd, s["plan"], t["plan"])
        ns["rows"] = s["rows"].at[slot].set(t["rows"])
        for key in ("extends", "replans", "reuses", "retention"):
            # (L,) single-request counters -> column `slot` of (L, B)
            ns[key] = s[key].at[:, slot].set(t[key])
        out["sla"] = ns
    return out


# --------------------------------------------------------------------------
# paged serving: page pools + page table (DESIGN.md "Paged KV & prefix
# caching"). Host-side refcounting/CoW lives in serving/pages.py; these
# are the device-side constructors and scatters.
# --------------------------------------------------------------------------
PAGED_POOL_KEYS = ("hblk", "zblk", "kpool")  # per-block leaves that move
#                                              from per-slot state into the
#                                              global page pools under paging
PAGED_SLOT_KEYS = ("htot", "ztot", "qpool", "live_lut", "live_cnt",
                   "live_marg")


def make_paged_cache(cfg: ArchConfig, batch: int, max_len: int,
                     num_pages: int, dtype=jnp.bfloat16,
                     decode_sla: Optional[bool] = None) -> dict:
    """Paged decode cache: global pools of block_kv-sized pages plus a
    per-slot page table, replacing make_cache(per_slot=True)'s
    monolithic (L, B, Hkv, max_len, Dh) slabs.

      kp/vp   (L, P, Hkv, bkv, Dh)  KV page pools
      pt      (B, Tn) int32         logical block -> physical page,
                                    shared by every layer (page ids are
                                    allocated per logical block, and all
                                    layers of one block live at one id)
      slap.*  (L, P, Hkv, ...)      decode-SLA per-block h/z/kpool
                                    partials, pooled at the same ids

    Physical page 0 is the permanent all-zero page; the scheduler pins
    one private scratch page per slot on top so inactive slots (which
    keep stepping through every batched dispatch) always write
    somewhere harmless. Per-slot decode-SLA state (plan rows, totals,
    live-row LUT, counters) keeps the monolithic per-slot layout."""
    sla = cfg.sla
    if max_len % sla.block_kv:
        raise ValueError(
            f"paged cache needs block-aligned max_len (got {max_len} "
            f"for block_kv={sla.block_kv})")
    tn = max_len // sla.block_kv
    nl, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    pshape = (nl, num_pages, hkv, sla.block_kv, dh)
    cache = {"kp": jnp.zeros(pshape, dtype), "vp": jnp.zeros(pshape, dtype),
             "pt": jnp.zeros((batch, tn), jnp.int32),
             "pos": jnp.zeros((batch,), jnp.int32)}
    if decode_sla is None:
        decode_sla = sla.decode_mode == "sla"
    if decode_sla:
        _check_decode_grid(cfg, max_len, max_len)
        mc = jnp.full((nl, batch, cfg.num_heads, 0, 0), -1, jnp.int8)
        empty = jnp.zeros((nl, batch, hkv, 0, dh), dtype)
        st = _seed_decode_state(cfg, empty, empty, mc, max_len)
        st["rows"] = jnp.full((batch,), st["rows"], jnp.int32)
        for key in ("extends", "replans", "reuses", "retention"):
            st[key] = jnp.repeat(st[key][:, None], batch, axis=1)
        cache["slap"] = {
            "hblk": jnp.zeros((nl, num_pages, hkv, dh, dh), jnp.float32),
            "zblk": jnp.zeros((nl, num_pages, hkv, dh), jnp.float32),
            "kpool": jnp.zeros((nl, num_pages, hkv, dh), jnp.float32)}
        for key in PAGED_POOL_KEYS:
            del st[key]
        cache["sla"] = st
    return cache


def insert_slot_state_paged(cache: dict, single: dict, slot) -> dict:
    """Scatter only the PER-SLOT half of a batch-1 prefill into `slot`
    of a paged cache: pos plus (under decode-SLA) plan rows, running
    totals, pooled q and counters. Page contents are written separately
    by `insert_slot_paged` — or not at all when every prompt page was a
    prefix-cache hit (the full-prompt snapshot fast path)."""
    if ("sla" in cache) != ("sla" in single):
        raise ValueError(
            "decode-SLA 'sla' state mismatch: the paged cache and the "
            "prefill state must both (or neither) carry it")
    out = dict(cache)
    out["pos"] = cache["pos"].at[slot].set(single["pos"])
    if "sla" in cache:
        s, t = cache["sla"], single["sla"]

        def upd(live, one):
            return jax.lax.dynamic_update_slice_in_dim(
                live, one.astype(live.dtype), slot, axis=1)

        ns = {key: upd(s[key], t[key]) for key in PAGED_SLOT_KEYS}
        ns["plan"] = jax.tree_util.tree_map(upd, s["plan"], t["plan"])
        ns["rows"] = s["rows"].at[slot].set(t["rows"])
        for key in ("extends", "replans", "reuses", "retention"):
            ns[key] = s[key].at[:, slot].set(t[key])
        out["sla"] = ns
    return out


def slot_state_from_prefill(single: dict) -> dict:
    """The per-slot half of a batch-1 prefill cache (what
    `insert_slot_state_paged` consumes): everything except KV rows and
    per-block partials. This is the full-prompt snapshot the scheduler
    caches for exact prefix hits."""
    out = {"pos": single["pos"]}
    if "sla" in single:
        st = single["sla"]
        out["sla"] = {key: st[key] for key in st if key
                      not in PAGED_POOL_KEYS}
    return out


def insert_slot_paged(cache: dict, single: dict, slot, page_ids) -> dict:
    """Scatter a batch-1 prefill cache into `slot` of a paged cache.

    `page_ids` (n_prompt_pages,) int32 names the physical page for each
    prompt block, host-allocated/interned before the call. KV rows and
    (under decode-SLA) the per-block h/z/kpool partials land in the
    pools at those ids; the per-slot state goes through
    `insert_slot_state_paged`. Prefix-interned hit pages are rewritten
    with byte-identical contents (causal attention makes page j a pure
    function of the padded tokens below its end), which keeps admission
    a single static-shape jit per bucket size. The page table itself is
    host-owned and pushed separately."""
    if single["k"].shape[1] != 1:
        raise ValueError(
            f"insert_slot_paged takes a batch-1 prefill cache (got "
            f"batch {single['k'].shape[1]})")
    bkv = cache["kp"].shape[3]
    npp = page_ids.shape[0]
    if single["k"].shape[-2] < npp * bkv:
        raise ValueError(
            f"prefill cache holds {single['k'].shape[-2]} positions but "
            f"{npp} pages of {bkv} were requested")
    out = insert_slot_state_paged(cache, single, slot)
    nl, hkv = cache["kp"].shape[0], cache["kp"].shape[2]

    def kv_pages(x):  # (L, 1, Hkv, S, Dh) -> (L, npp, Hkv, bkv, Dh)
        xs = x[:, 0, :, :npp * bkv, :].reshape(nl, hkv, npp, bkv, -1)
        return jnp.moveaxis(xs, 1, 2)

    out["kp"] = cache["kp"].at[:, page_ids].set(
        kv_pages(single["k"]).astype(cache["kp"].dtype))
    out["vp"] = cache["vp"].at[:, page_ids].set(
        kv_pages(single["v"]).astype(cache["vp"].dtype))
    if "sla" in cache:

        def blk_pages(x):  # (L, 1, Hkv, Tn, ...) -> (L, npp, Hkv, ...)
            return jnp.moveaxis(x[:, 0, :, :npp], 1, 2)

        out["slap"] = {
            key: cache["slap"][key].at[:, page_ids].set(
                blk_pages(single["sla"][key]))
            for key in PAGED_POOL_KEYS}
    return out


def copy_page(cache: dict, dst, src) -> dict:
    """Device-side page copy `src -> dst` across every pool (KV and,
    under decode-SLA, the h/z/kpool partials). The scheduler's
    copy-on-write pass uses this both to duplicate a shared page before
    a divergent write and to ZERO a freshly allocated decode page
    (src = the permanent zero page — the h/z partials accumulate onto
    the page via gather/add/set, so recycled pages must start clean)."""
    out = dict(cache)
    for key in ("kp", "vp"):
        out[key] = cache[key].at[:, dst].set(cache[key][:, src])
    if "slap" in cache:
        out["slap"] = {k: v.at[:, dst].set(v[:, src])
                       for k, v in cache["slap"].items()}
    return out


def paged_dense_view(cfg: ArchConfig, cache: dict) -> dict:
    """Materialize the monolithic per-slot cache a paged cache
    represents (page-gathered KV slabs + per-block partials). Test /
    debugging aid: the paged-vs-monolithic parity suite compares active
    slots of this view bitwise against the unpaged scheduler's cache."""
    pt = cache["pt"]

    def kv(pool):  # (L, P, Hkv, bkv, Dh) -> (L, B, Hkv, S, Dh)
        g = jnp.moveaxis(jnp.take(pool, pt, axis=1), 3, 2)
        return g.reshape(g.shape[:3] + (g.shape[3] * g.shape[4],
                                        g.shape[5]))

    out = {"k": kv(cache["kp"]), "v": kv(cache["vp"]),
           "pos": cache["pos"]}
    if "sla" in cache:
        st = dict(cache["sla"])
        for key in PAGED_POOL_KEYS:
            st[key] = jnp.moveaxis(
                jnp.take(cache["slap"][key], pt, axis=1), 3, 2)
        out["sla"] = st
    return out
