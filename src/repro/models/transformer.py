"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are scanned (stacked params, single trace per layer kind) for
compile-time sanity at 88-layer scale. Per-layer attention kind is a
static-shaped int array consumed by lax.switch: 0=SLA, 1=full, 2=sliding
window (gemma3 local layers). SLA layers carry the learnable Proj.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import plan as plan_lib
from repro.distributed import ctx
from repro.models import moe as moe_lib
from repro.models.common import (NEG_INF, attention, chunked_softmax_xent,
                                 dense_init, embed_init, rms_norm, rope)

KIND_SLA, KIND_FULL, KIND_SWA = 0, 1, 2


def layer_kinds_list(cfg: ArchConfig) -> list:
    """Static per-layer attention kinds."""
    l = cfg.num_layers
    if cfg.local_global_pattern:
        p = cfg.local_global_pattern
        return [KIND_SLA if (i + 1) % p == 0 else KIND_SWA for i in range(l)]
    if cfg.attention_kind == "full":
        return [KIND_FULL] * l
    if cfg.attention_kind == "swa":
        return [KIND_SWA] * l
    return [KIND_SLA] * l


def layer_kinds(cfg: ArchConfig) -> jnp.ndarray:
    return jnp.asarray(layer_kinds_list(cfg), jnp.int32)


def _layer_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = list(jax.random.split(rng, 8))
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "wq": dense_init(r[0], d, h * dh, dtype),
        "wk": dense_init(r[1], d, hkv * dh, dtype),
        "wv": dense_init(r[2], d, hkv * dh, dtype),
        "wo": dense_init(r[3], h * dh, d, dtype),
        "sla_proj": jnp.zeros((h, dh, dh), dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = jnp.zeros((dh,), dtype)
        p["knorm"] = jnp.zeros((dh,), dtype)
    if cfg.num_experts:
        p["moe"] = moe_lib.moe_init(r[4], cfg, dtype)
    else:
        p["mlp_wi"] = dense_init(r[5], d, 2 * cfg.d_ff, dtype)
        p["mlp_wo"] = dense_init(r[6], cfg.d_ff, d, dtype)
    return p


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, cfg.num_layers + 2)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jnp.stack(r[: cfg.num_layers]))
    params = {
        "embed": embed_init(r[-1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(r[-2], cfg.vocab_size, cfg.d_model,
                                       dtype)
    return params


# --------------------------------------------------------------------------
# attention sub-block
# --------------------------------------------------------------------------
def _qkv(p, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wq"].astype(x.dtype), "col"))
    k = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wk"].astype(x.dtype), "col"))
    v = jnp.einsum("bsd,de->bse", x,
                   ctx.fsdp_gather(p["wv"].astype(x.dtype), "col"))
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn(p, x, kind, cfg: ArchConfig, positions, backend,
          layer_plan=None, drift_threshold=None, want_plan=False):
    """Returns (attn_out (B,S,d), k_cache, v_cache, plan, retention,
    replanned).

    Plan reuse for LM prefill (DESIGN.md "Plan lifetime & drift"):
    `want_plan=True` with layer_plan=None plans inline and returns the
    plan; a given `layer_plan` is reused — and, when `drift_threshold`
    is set, refreshed under `lax.cond` when its retained critical mass
    decays (same drift metric as the DiT sampler). The plan is built
    outside the kind switch so it rides the layer scan with static
    shapes even in mixed-kind stacks (non-SLA layers just carry it)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    sla_cfg = cfg.sla
    if cfg.sliding_window:
        sla_cfg = dataclasses.replace(sla_cfg, window=cfg.sliding_window)
    sla_params = {"proj": p["sla_proj"]}
    retention = jnp.float32(1.0)
    replanned = jnp.bool_(False)
    if want_plan or layer_plan is not None:
        plan_cfg = dataclasses.replace(sla_cfg, causal=True)
        if layer_plan is None:
            layer_plan = plan_lib.plan_attention(q, k, plan_cfg)
        elif drift_threshold is not None:
            layer_plan, retention, replanned = plan_lib.refresh_plan(
                layer_plan, q, k, plan_cfg, drift_threshold)

    def do_sla(q, k, v):
        return attention(sla_params, q, k, v, "sla", sla_cfg,
                         causal=True, backend=backend, plan=layer_plan)

    def do_full(q, k, v):
        return attention(None, q, k, v, "full", sla_cfg, causal=True)

    def do_swa(q, k, v):
        return attention(None, q, k, v, "swa", sla_cfg,
                         window=cfg.local_window or cfg.sliding_window,
                         causal=True)

    # Only compile branches that actually occur (a dead full-attention
    # branch would put N^2 temporaries into every lowered cell).
    branches = [do_sla, do_full, do_swa]
    used = sorted(set(layer_kinds_list(cfg)))
    if len(used) == 1:
        out = branches[used[0]](q, k, v)
    else:
        import numpy as np
        remap = np.zeros((3,), np.int32)
        for pos, orig in enumerate(used):
            remap[orig] = pos
        out = jax.lax.switch(jnp.asarray(remap)[kind],
                             [branches[u] for u in used], q, k, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out,
                     ctx.fsdp_gather(p["wo"].astype(x.dtype), "row"))
    return out, k, v, layer_plan, retention, replanned


def _ffn(p, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.num_experts:
        return moe_lib.moe_apply(p["moe"], x, cfg)
    h = jnp.einsum("bsd,df->bsf", x,
                   ctx.fsdp_gather(p["mlp_wi"].astype(x.dtype), "col"))
    g, u = jnp.split(h, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                     ctx.fsdp_gather(p["mlp_wo"].astype(x.dtype), "row"))
    return out, jnp.float32(0.0)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------
def forward(params, cfg: ArchConfig, tokens: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16, backend: str = "gather",
            return_cache: bool = False,
            plans=None, return_plans: bool = False,
            drift_threshold=None):
    """Returns hidden states (B, S, d); optionally the per-layer KV cache.

    VLM (cfg.frontend == "vision_stub"): prefix_embeds (B, P, d) are
    prepended to the token embeddings (patch positions share the rope
    position space, positions 0..P-1).

    LM-prefill plan reuse (DESIGN.md "Plan lifetime & drift"): with
    `return_plans=True` the per-layer SLAPlan stack rides out of the
    layer scan; pass it back as `plans=` on a later same-shape prefill
    to reuse the block structure, optionally with `drift_threshold=` to
    refresh drifted layers under `lax.cond`. Return value order:
    (x, aux[, caches][, plans][, drift info dict]).
    """
    emb = params["embed"]
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds.astype(compute_dtype))
    if tokens is not None:
        parts.append(jnp.take(emb, tokens, axis=0).astype(compute_dtype))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    kinds = layer_kinds(cfg)
    want_plan = return_plans or plans is not None
    adaptive = drift_threshold is not None and plans is not None

    def body(x, layer):
        if plans is not None:
            p, kind, layer_plan = layer
        else:
            (p, kind), layer_plan = layer, None
        a, k, v, layer_plan, ret, rep = _attn(
            p, rms_norm(x, p["ln1"]), kind, cfg, positions, backend,
            layer_plan=layer_plan, drift_threshold=drift_threshold,
            want_plan=want_plan)
        # constraining the block OUTPUT (pre-residual-add) turns the TP
        # boundary all-reduce into a reduce-scatter (half the wire bytes)
        x = ctx.shard_residual(x + ctx.shard_residual(a))
        f, aux = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        x = ctx.shard_residual(x + ctx.shard_residual(f))
        ys = (aux, (k, v) if return_cache else None,
              layer_plan if want_plan else None,
              (ret, rep) if adaptive else None)
        return x, ys

    xs = (params["layers"], kinds)
    if plans is not None:
        xs = xs + (plans,)
    x, (auxs, caches, out_plans, drift_ys) = jax.lax.scan(
        ctx.maybe_remat(body), x, xs)
    x = rms_norm(x, params["ln_f"])
    aux = jnp.sum(auxs)
    rets = (x, aux)
    if return_cache:
        rets += (caches,)  # caches: (k (L,B,Hkv,S,Dh), v ...)
    if return_plans:
        rets += (out_plans,)
    if adaptive:
        rets += ({"retention": drift_ys[0], "replanned": drift_ys[1]},)
    return rets


def loss_fn(params, cfg: ArchConfig, batch: dict,
            compute_dtype=jnp.bfloat16, backend: str = "gather") -> jax.Array:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, targets[, mask,
    patch_embeds]."""
    x, aux = forward(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("patch_embeds"),
                     compute_dtype=compute_dtype, backend=backend)
    npatch = 0
    if batch.get("patch_embeds") is not None:
        npatch = batch["patch_embeds"].shape[1]
        x = x[:, npatch:]
    table = params.get("unembed", params["embed"])
    loss = chunked_softmax_xent(x, table, batch["targets"],
                                batch.get("mask"))
    return loss + 0.01 * aux


# --------------------------------------------------------------------------
# serving: prefill + single-token decode over a static-size KV cache
# --------------------------------------------------------------------------
def prefill(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather", plans=None, drift_threshold=None,
            return_plans: bool = False):
    """Run the prompt; returns (last_hidden (B, d), cache dict).

    Plan reuse across prefill chunks (serving): `return_plans=True`
    additionally returns the per-layer SLAPlan stack; pass it back as
    `plans=` (with `drift_threshold=` for drift-gated refresh) on the
    next same-shape prefill chunk — the serving engine amortizes block
    planning across the request stream this way. Return value order:
    (last_hidden, cache[, plans][, drift info])."""
    out = forward(params, cfg, tokens, compute_dtype=compute_dtype,
                  backend=backend, return_cache=True, plans=plans,
                  return_plans=return_plans,
                  drift_threshold=drift_threshold)
    x, (kc, vc) = out[0], out[2]
    cache = {"k": kc, "v": vc, "pos": jnp.int32(tokens.shape[1])}
    return (x[:, -1], cache) + out[3:]


def decode_step(params, cfg: ArchConfig, token, cache,
                compute_dtype=jnp.bfloat16):
    """One decode step. token: (B,) int32; cache k/v: (L, B, Hkv, S, Dh);
    cache['pos'] is a scalar (static-batch serving, aligned sequences).

    The new KV is written at `pos` via dynamic_update_slice (O(1) write);
    attention runs masked over the full static cache (O(S) per token —
    exactly the decode_* cells' cost model).
    """
    emb = params["embed"]
    x = jnp.take(emb, token[:, None], axis=0).astype(compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]  # scalar int32
    kinds = layer_kinds(cfg)
    smax = cache["k"].shape[-2]

    def body(x, layer):
        p, kind, kc, vc = layer
        xn = rms_norm(x, p["ln1"])
        q, k_new, v_new = _qkv(p, xn, cfg,
                               jnp.full((b, 1), pos, jnp.int32))
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k_new.astype(kc.dtype), pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v_new.astype(vc.dtype), pos, axis=2)
        # GQA decode without materializing repeated KV: fold the head
        # group into the query ("bkgd" layout) — scores are
        # (B, Hkv, G, S) against the cache directly.
        h, hkv = q.shape[1], kc.shape[1]
        g = h // hkv
        qg = q[:, :, 0, :].reshape(b, hkv, g, cfg.head_dim)
        s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) * (cfg.head_dim**-0.5)
        idx = jnp.arange(smax)[None, None, None, :]
        ok = idx <= pos

        def swa_mask(s):
            w = cfg.local_window or cfg.sliding_window
            return jnp.where(idx > pos - w, s, NEG_INF)

        s = jnp.where(ok, s, NEG_INF)
        s = jax.lax.cond(kind == KIND_SWA, swa_mask, lambda s: s, s)
        p_attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bksd->bkgd", p_attn, vc.astype(jnp.float32))
        o = o.astype(x.dtype).reshape(b, 1, h * cfg.head_dim)
        x = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        f, _ = _ffn(p, rms_norm(x, p["ln2"]), cfg)
        return x + f, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["layers"], kinds, cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"])
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                        table.astype(jnp.float32))
    new_cache = {"k": kc, "v": vc, "pos": pos + 1}
    return logits, new_cache


def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.int32(0)}
