"""Diffusion Transformer (the paper's home architecture).

Wan2.1-style video DiT: patchified latent tokens, AdaLN-zero timestep
modulation, bidirectional self-attention (SLA's target workload), optional
cross-attention to text conditioning, flow-matching training objective.
Covers both `wan2_1_1_3b` (video, seq ~32K) and `lightningdit_1b`
(image, seq 1024).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import masks as masks_lib
from repro.core import plan as plan_lib
from repro.distributed import ctx
from repro.models.common import attention, dense_init, mse_loss, rms_norm


def _layer_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    r = list(jax.random.split(rng, 10))
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "wq": dense_init(r[0], d, h * dh, dtype),
        "wk": dense_init(r[1], d, cfg.num_kv_heads * dh, dtype),
        "wv": dense_init(r[2], d, cfg.num_kv_heads * dh, dtype),
        "wo": dense_init(r[3], h * dh, d, dtype),
        "sla_proj": jnp.zeros((h, dh, dh), dtype),
        "mlp_wi": dense_init(r[4], d, 2 * cfg.d_ff, dtype),
        "mlp_wo": dense_init(r[5], cfg.d_ff, d, dtype),
        # AdaLN-zero: 6 modulation vectors from the timestep embedding
        "ada": (jax.random.normal(r[6], (d, 6 * d), jnp.float32)
                * 0.01).astype(dtype),
    }
    if cfg.sla.routing_mode == "learned":
        # identity init reproduces the threshold router bitwise (no RNG
        # consumed — threshold-mode params are unchanged)
        p["routing"] = masks_lib.routing_init(h, dh, dtype)
    if cfg.cross_attn:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xq"] = dense_init(r[7], d, h * dh, dtype)
        p["xk"] = dense_init(r[8], d, cfg.num_kv_heads * dh, dtype)
        p["xv"] = dense_init(r[9], d, cfg.num_kv_heads * dh, dtype)
        p["xo"] = dense_init(r[7], h * dh, d, dtype)
    return p


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, cfg.num_layers + 3)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jnp.stack(r[: cfg.num_layers]))
    d = cfg.d_model
    return {
        "patch_in": dense_init(r[-1], cfg.patch_dim, d, dtype),
        "t_embed": dense_init(r[-2], 256, d, dtype),
        "layers": layers,
        "ln_f": jnp.zeros((d,), dtype),
        "patch_out": (jnp.zeros((d, cfg.patch_dim), dtype)),
    }


def _timestep_embedding(t: jax.Array, dim: int = 256) -> jax.Array:
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def forward(params, cfg: ArchConfig, latents, t,
            cond: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16, backend: str = "gather",
            sla_mode: Optional[str] = None,
            plans=None, return_plans: bool = False,
            drift_threshold=None, per_sample_refresh: bool = False):
    """latents: (B, N, patch_dim); t: per-sample (B,) diffusion time in
    [0,1], or a scalar broadcast to the batch — bitwise-equal to the
    equivalent uniform (B,) vector (the timestep embedding and AdaLN
    modulation are row-independent). Mixed-timestep batches are the
    serving case (serving/diffusion.py): each row denoises at its own t.
    cond: (B, Lc, d) stub text embeddings. Returns velocity prediction
    with the same shape as latents.

    sla_mode overrides cfg.sla.mode (used by the ablation benchmarks to
    run full / linear_only / sparse_only / l_plus_s variants).

    Cross-timestep plan reuse (DESIGN.md "Plan/execute split"): pass
    `return_plans=True` to also return the per-layer SLAPlan pytree
    (leading axis = layer, stacked by the layer scan); pass that pytree
    back as `plans=` on a later denoising step to skip block planning
    entirely. With plans given and drift_threshold=None, this function
    performs zero planning.

    Drift-adaptive refresh (DESIGN.md "Plan lifetime & drift"): with
    `plans=` AND `drift_threshold=` (float, traced scalar, or a
    per-layer (L,) array/tuple — each layer's refresh decision uses its
    own entry, never min-reduced across the stack), each layer measures
    the retained critical mass of its reused plan against the current
    (q, k) and re-plans under `lax.cond` only when drift reaches the
    threshold — jit-traceable, static shapes. The return value gains a
    trailing info dict {"retention": (L,), "replanned": (L,)}.

    Per-sample refresh (serving): with `per_sample_refresh=True` the
    drift decision decouples across batch rows
    (plan_lib.refresh_plan_per_sample) — `drift_threshold` broadcasts
    to (L, B) and the info dict carries (L, B) retention/replanned, so
    one slot's re-plan never rebuilds (or blocks) its neighbours'."""
    t = jnp.asarray(t, jnp.float32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (latents.shape[0],))
    x = jnp.einsum("bnp,pd->bnd", latents.astype(compute_dtype),
                   params["patch_in"].astype(compute_dtype))
    temb = jnp.einsum("be,ed->bd", _timestep_embedding(t * 1000.0),
                      params["t_embed"].astype(jnp.float32))
    temb = jax.nn.silu(temb).astype(compute_dtype)
    b, n, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    import dataclasses
    sla_cfg = dataclasses.replace(cfg.sla, causal=False)
    if sla_mode is not None:
        sla_cfg = dataclasses.replace(sla_cfg, mode=sla_mode)
    kind = "sla" if cfg.attention_kind == "sla" else cfg.attention_kind
    if sla_mode is not None:
        kind = "sla"
    # Self-attention needs a block plan only in the sparse SLA modes.
    plan_needed = (kind == "sla"
                   and sla_cfg.mode not in ("full", "linear_only"))
    adaptive = (drift_threshold is not None and plans is not None
                and plan_needed)
    if adaptive:
        thr_shape = ((cfg.num_layers, latents.shape[0])
                     if per_sample_refresh else (cfg.num_layers,))
        thresholds = jnp.broadcast_to(
            jnp.asarray(drift_threshold, jnp.float32), thr_shape)

    def body(x, xs):
        if adaptive:
            p, layer_plan, thr = xs
        else:
            p, layer_plan = xs
        if adaptive and per_sample_refresh:
            retention = jnp.ones((b,), jnp.float32)
            replanned = jnp.zeros((b,), bool)
        else:
            retention = jnp.float32(1.0)
            replanned = jnp.bool_(False)
        mod = jnp.einsum("bd,de->be", temb, p["ada"].astype(temb.dtype))
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        xn = rms_norm(x, p["ln1"]) * (1 + sc1[:, None]) + sh1[:, None]
        q = jnp.einsum("bsd,de->bse", xn, p["wq"].astype(x.dtype)) \
            .reshape(b, n, h, dh).transpose(0, 2, 1, 3)
        k = jnp.einsum("bsd,de->bse", xn, p["wk"].astype(x.dtype)) \
            .reshape(b, n, hkv, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,de->bse", xn, p["wv"].astype(x.dtype)) \
            .reshape(b, n, hkv, dh).transpose(0, 2, 1, 3)
        routing = p.get("routing") if sla_cfg.routing_mode == "learned" \
            else None
        if plan_needed and layer_plan is None:
            layer_plan = plan_lib.plan_attention(q, k, sla_cfg,
                                                 routing=routing)
        elif adaptive:
            refresh = (plan_lib.refresh_plan_per_sample
                       if per_sample_refresh else plan_lib.refresh_plan)
            layer_plan, retention, replanned = refresh(
                layer_plan, q, k, sla_cfg, thr, routing=routing)
        o = attention({"proj": p["sla_proj"]}, q, k, v, kind, sla_cfg,
                      causal=False, backend=backend,
                      plan=layer_plan if plan_needed else None,
                      routing=routing)
        o = o.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
        x = ctx.shard_residual(
            x + g1[:, None] * jnp.einsum("bse,ed->bsd", o,
                                         p["wo"].astype(x.dtype)))
        if cfg.cross_attn and cond is not None:
            cx = cond.astype(x.dtype)
            lc = cx.shape[1]
            xq = jnp.einsum("bsd,de->bse", rms_norm(x, p["ln_x"]),
                            p["xq"].astype(x.dtype)) \
                .reshape(b, n, h, dh).transpose(0, 2, 1, 3)
            xk = jnp.einsum("bsd,de->bse", cx, p["xk"].astype(x.dtype)) \
                .reshape(b, lc, hkv, dh).transpose(0, 2, 1, 3)
            xv = jnp.einsum("bsd,de->bse", cx, p["xv"].astype(x.dtype)) \
                .reshape(b, lc, hkv, dh).transpose(0, 2, 1, 3)
            xo = attention(None, xq, xk, xv, "full", sla_cfg, causal=False)
            xo = xo.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
            x = x + jnp.einsum("bse,ed->bsd", xo, p["xo"].astype(x.dtype))
        xn2 = rms_norm(x, p["ln2"]) * (1 + sc2[:, None]) + sh2[:, None]
        hmid = jnp.einsum("bsd,df->bsf", xn2, p["mlp_wi"].astype(x.dtype))
        g, u = jnp.split(hmid, 2, axis=-1)
        x = ctx.shard_residual(x + g2[:, None] * jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(g) * u, p["mlp_wo"].astype(x.dtype)))
        ys = (layer_plan if return_plans and plan_needed else None,
              (retention, replanned) if adaptive else None)
        return x, ys

    # `plans=None` cannot ride through scan xs (no leading layer axis), so
    # the no-plan path scans params only and the body plans inline.
    if plans is None:
        x, (out_plans, drift_ys) = jax.lax.scan(
            ctx.maybe_remat(lambda x, p: body(x, (p, None))),
            x, params["layers"])
    else:
        xs = ((params["layers"], plans, thresholds) if adaptive
              else (params["layers"], plans))
        x, (out_plans, drift_ys) = jax.lax.scan(
            ctx.maybe_remat(body), x, xs)
    x = rms_norm(x, params["ln_f"])
    out = jnp.einsum("bnd,dp->bnp", x, params["patch_out"].astype(x.dtype))
    rets = (out,)
    if return_plans:
        rets += (out_plans,)
    if adaptive:
        rets += ({"retention": drift_ys[0], "replanned": drift_ys[1]},)
    return rets if len(rets) > 1 else out


def sample(params, cfg: ArchConfig, noise, *, num_steps: int = 8,
           cond: Optional[jax.Array] = None, compute_dtype=jnp.bfloat16,
           backend: str = "gather",
           refresh_interval: Optional[int] = None,
           refresh_mode: Optional[str] = None,
           drift_threshold=None,
           t_start=None,
           return_trace: bool = False):
    """Euler rectified-flow sampler with cross-timestep plan reuse.

    Integrates dx/dt = v(x, t) from t=1 (noise, (B, N, patch_dim)) down
    to t=0 over `num_steps` uniform steps. `t_start` (scalar or (B,),
    default None = 1.0) starts the trajectory mid-way — SDEdit-style
    partial denoise, and the sequential reference for serving requests
    admitted at an arbitrary timestep: each sample integrates from its
    own t_start to 0 over `num_steps` steps of dt = t_start/num_steps.
    t_start=None keeps the original python-scalar dt path untouched.

    Plan refresh policy (`refresh_mode`, default
    cfg.sla.plan_refresh_mode):

    * "fixed": every `refresh_interval` steps (default
      cfg.sla.plan_refresh_interval) the forward pass re-plans each
      layer's block structure and the plans are reused in between —
      block-sparsity patterns are stable across adjacent denoising
      timesteps, so planning cost amortizes by ~1/K. With
      refresh_interval >= num_steps, each layer plans exactly once.
    * "adaptive": plan once on the first step, then carry
      (x, plans) through a `lax.scan` over the remaining steps; each
      layer measures the drift of its reused plan against the current
      (q, k) and re-plans under `lax.cond` only when drift reaches
      `drift_threshold` (default cfg.sla.plan_drift_threshold; may be a
      traced scalar — one jit covers every threshold). The per-step
      re-plan decision is data-dependent but fully jit-traceable: no
      python-level branching inside the scanned body.

    With `return_trace=True` also returns {"retention": (S-1, L),
    "replanned": (S-1, L), "replan_count": (L,)} — counts exclude the
    mandatory step-0 planning. In fixed mode the trace is the static
    schedule (retention is reported as 1, unmeasured).
    """
    mode = (cfg.sla.plan_refresh_mode if refresh_mode is None
            else refresh_mode)
    if mode not in ("fixed", "adaptive"):
        raise ValueError(f"unknown plan_refresh_mode {mode!r}; "
                         "expected 'fixed' or 'adaptive'")
    b = noise.shape[0]
    x = noise
    nl = cfg.num_layers

    if t_start is None:
        dt = 1.0 / num_steps

        def tvec(step):
            """(B,) diffusion time for a python-int or traced step."""
            return (jnp.full((b,), 1.0, jnp.float32)
                    - jnp.asarray(step, jnp.float32) * dt)

        def euler(x, vel):
            return x - dt * vel.astype(x.dtype)
    else:
        # per-sample start time: t(step) = t0 - step * (t0/num_steps),
        # computed positionally (not by iterated subtraction) so the
        # serving scheduler's host-side f32 bookkeeping reproduces the
        # same rounded values (serving/diffusion.py parity contract)
        t0 = jnp.broadcast_to(
            jnp.asarray(t_start, jnp.float32), (b,))
        dtv = t0 / jnp.float32(num_steps)

        def tvec(step):
            return t0 - jnp.asarray(step, jnp.float32) * dtv

        def euler(x, vel):
            return x - dtv[:, None, None] * vel.astype(x.dtype)

    def static_trace(replan_flags):
        """Trace dict for modes whose refresh schedule is static
        (retention is reported as 1, unmeasured). Flags cover steps
        1..num_steps-1 (step 0 always plans)."""
        rep = (jnp.asarray(replan_flags, bool)[:, None]
               .repeat(nl, 1).reshape(num_steps - 1, nl))
        return {"retention": jnp.ones((num_steps - 1, nl)),
                "replanned": rep,
                "replan_count": jnp.sum(rep, axis=0)}

    if mode == "fixed":
        k_refresh = (cfg.sla.plan_refresh_interval
                     if refresh_interval is None else refresh_interval)
        k_refresh = max(1, int(k_refresh))
        # rolled (ISSUE 6): step 0 plans outside the loop, then one
        # scanned body whose lax.cond either re-plans or reuses the
        # carried plans — the compiled graph is horizon-independent and
        # the planning pipeline traces exactly twice (once per branch)
        # no matter how many steps or refreshes run.
        vel, plans = forward(params, cfg, x, tvec(0), cond, compute_dtype,
                             backend, return_plans=True)
        x = euler(x, vel)
        if num_steps > 1:
            def fixed_body(carry, step):
                x, plans = carry

                def replan(_):
                    return forward(params, cfg, x, tvec(step), cond,
                                   compute_dtype, backend,
                                   return_plans=True)

                def reuse(_):
                    return (forward(params, cfg, x, tvec(step), cond,
                                    compute_dtype, backend, plans=plans),
                            plans)

                vel, new_plans = jax.lax.cond(step % k_refresh == 0,
                                              replan, reuse, None)
                return (euler(x, vel), new_plans), None

            (x, plans), _ = jax.lax.scan(fixed_body, (x, plans),
                                         jnp.arange(1, num_steps))
        if return_trace:
            return x, static_trace([s % k_refresh == 0
                                    for s in range(1, num_steps)])
        return x

    # adaptive: mandatory plan on step 0, then a scanned drift-gated loop
    thr = (cfg.sla.plan_drift_threshold if drift_threshold is None
           else drift_threshold)
    plan_needed = (cfg.attention_kind == "sla"
                   and cfg.sla.mode not in ("full", "linear_only"))
    if not plan_needed:
        # plan-free attention: nothing to refresh — one scanned Euler
        # body (rolled, ISSUE 6: horizon-independent compiled graph)
        def pf_body(x, step):
            return euler(x, forward(params, cfg, x, tvec(step), cond,
                                    compute_dtype, backend)), None

        x, _ = jax.lax.scan(pf_body, x, jnp.arange(num_steps))
        if return_trace:
            return x, static_trace([False] * (num_steps - 1))
        return x

    vel, plans = forward(params, cfg, x, tvec(0), cond, compute_dtype,
                         backend, return_plans=True)
    x = euler(x, vel)

    def step_body(carry, step):
        x, plans = carry
        vel, plans, info = forward(params, cfg, x, tvec(step), cond,
                                   compute_dtype, backend, plans=plans,
                                   return_plans=True, drift_threshold=thr)
        return (euler(x, vel), plans), (info["retention"],
                                        info["replanned"])

    (x, _), (rets, reps) = jax.lax.scan(
        step_body, (x, plans), jnp.arange(1, num_steps))
    if return_trace:
        trace = {"retention": rets, "replanned": reps,
                 "replan_count": jnp.sum(reps, axis=0)}
        return x, trace
    return x


# ---------------------------------------------------------------------------
# serving slot surgery (serving/diffusion.py; the DiT analogue of
# transformer.insert_slot — per-request state here is a latent row plus
# its per-layer plan rows, not a KV cache)
# ---------------------------------------------------------------------------
def insert_denoise_slot(latents, plans, slot: int, latent_row, plan_row):
    """Scatter one admitted request into batch slot `slot`.

    latents: (B, N, P) live pool; latent_row: (1, N, P). plans: stacked
    per-layer plan pytree with leaves (L, B, ...); plan_row: the same
    pytree with leaves (L, 1, ...) — scattered along the batch axis
    (axis 1, after the layer axis). Either plan argument may be None
    (plan-free attention modes carry no plan state)."""
    latents = jax.lax.dynamic_update_slice_in_dim(
        latents, latent_row.astype(latents.dtype), slot, axis=0)
    if plans is not None and plan_row is not None:
        plans = jax.tree_util.tree_map(
            lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                full, row.astype(full.dtype), slot, axis=1),
            plans, plan_row)
    return latents, plans


def retire_denoise_slot(latents, slot: int):
    """Read a finished request's final latent (N, P) out of the pool."""
    return latents[slot]


def take_slot_plans(plans, slot: int):
    """One slot's per-layer plan rows (leaves (L, 1, ...)) — the unit
    the cross-request plan cache stores per timestep bucket."""
    return jax.tree_util.tree_map(
        lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1),
        plans)


def loss_fn(params, cfg: ArchConfig, batch, compute_dtype=jnp.bfloat16,
            backend: str = "gather", sla_mode: Optional[str] = None):
    """Flow-matching (rectified flow): x_t = (1-t) x0 + t noise; the model
    predicts the velocity (noise - x0). batch: latents (B,N,P), noise,
    t (B,), cond (optional)."""
    x0 = batch["latents"]
    noise = batch["noise"]
    t = batch["t"]
    xt = (1.0 - t[:, None, None]) * x0 + t[:, None, None] * noise
    target = noise - x0
    pred = forward(params, cfg, xt, t, batch.get("cond"), compute_dtype,
                   backend, sla_mode)
    return mse_loss(pred, target)


def distill_loss_fn(params, cfg: ArchConfig, batch,
                    compute_dtype=jnp.bfloat16,
                    backend: str = "gather"):
    """End-to-end distillation (paper Sec. 5): MSE between the SLA
    student's velocity prediction and a gradient-stopped exact-attention
    teacher running the SAME params on the same noised latents.

    This is the fine-tuning objective that wires the learned routing
    head (DESIGN.md "Learned routing") to a training signal: sla_proj
    gets ordinary gradients and the routing parameters straight-through
    gradients via the plan's marginal gates, so a few steps at a fixed
    critical-block budget recover exact-attention quality. Use an
    autodiff backend ("gather"/"reference") — the fused kernel's
    custom_vjp treats the plan as a constant."""
    x0, noise, t = batch["latents"], batch["noise"], batch["t"]
    xt = (1.0 - t[:, None, None]) * x0 + t[:, None, None] * noise
    teacher = forward(params, cfg, xt, t, batch.get("cond"),
                      compute_dtype, backend, sla_mode="full")
    student = forward(params, cfg, xt, t, batch.get("cond"),
                      compute_dtype, backend)
    return mse_loss(student, jax.lax.stop_gradient(teacher))
