"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Faithful to arXiv:2404.05892 in structure: token-shift mixing, WKV6
recurrence with per-channel data-dependent decay w_t = -exp(lora(x)), bonus
u, per-head group norm, and squared-ReLU channel mix. Deviations (noted in
DESIGN.md): token-shift interpolation weights are static per channel (v6
uses a small data-dependent LoRA for them), and the decay LoRA is rank-32.

SLA is inapplicable here — no softmax attention exists (DESIGN.md §4
Arch-applicability); this arch is the linear-attention end of the paper's
spectrum.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx
from repro.models.common import dense_init, embed_init, rms_norm
from repro.models.linear_scan import (decayed_la_chunked, decayed_la_step)

LORA_RANK = 32


def _layer_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h = cfg.ssm_heads or cfg.num_heads
    dh = d // h
    r = list(jax.random.split(rng, 12))
    return {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        # token-shift mixes for r, k, v, w, g
        "mix": 0.5 * jnp.ones((5, d), dtype),
        "wr": dense_init(r[0], d, d, dtype),
        "wk": dense_init(r[1], d, d, dtype),
        "wv": dense_init(r[2], d, d, dtype),
        "wg": dense_init(r[3], d, d, dtype),
        "wo": dense_init(r[4], d, d, dtype),
        # decay: w = w0 + tanh(x A) B   (rank-32 lora)
        "w0": -6.0 * jnp.ones((d,), dtype),
        "wa": dense_init(r[5], d, LORA_RANK, dtype),
        "wb": dense_init(r[6], LORA_RANK, d, dtype) * 0.1,
        "u": jax.random.normal(r[7], (h, dh), jnp.float32).astype(dtype) * 0.1,
        "gn": jnp.zeros((d,), dtype),  # per-head group norm scale
        # channel mix
        "cmix": 0.5 * jnp.ones((1, d), dtype),
        "ck": dense_init(r[8], d, cfg.d_ff, dtype),
        "cv": dense_init(r[9], cfg.d_ff, d, dtype),
        "cr": dense_init(r[10], d, d, dtype),
    }


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, cfg.num_layers + 1)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jnp.stack(r[:-1]))
    return {
        "embed": embed_init(r[-1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` at t=0). x: (B, S, D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _time_mix(p, x, cfg: ArchConfig, prev=None, state=None):
    """WKV6 block. x: (B, S, d). Returns (out, (new_state, x_last))."""
    b, s, d = x.shape
    h = cfg.ssm_heads or cfg.num_heads
    dh = d // h
    xprev = _shift(x, prev)
    mix = p["mix"].astype(x.dtype)  # (5, d)
    xr, xk, xv, xw, xg = (mix[i] * x + (1 - mix[i]) * xprev
                          for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))
    lora = jnp.einsum("bsr,re->bse",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                                          p["wa"].astype(x.dtype))),
                      p["wb"].astype(x.dtype))
    logw = -jnp.exp(jnp.clip(
        p["w0"].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 5.0))
    heads = lambda t: t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    rh, kh, vh, wh = heads(r), heads(k), heads(v), heads(logw)
    u = p["u"].astype(jnp.float32)
    if s == 1 and state is not None:
        o, new_state = decayed_la_step(rh[:, :, 0], kh[:, :, 0], vh[:, :, 0],
                                       wh[:, :, 0], state, u=u)
        o = o[:, :, None, :]
    else:
        o, new_state = decayed_la_chunked(rh, kh, vh, wh, u=u, s0=state)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    # per-head group norm
    o = o.reshape(b, s, h, dh)
    mu = jnp.mean(o, -1, keepdims=True)
    var = jnp.var(o, -1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 1e-5)
    o = (o.reshape(b, s, d) * (1.0 + p["gn"].astype(jnp.float32)))
    o = (o * g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, p["wo"].astype(x.dtype))
    return out, (new_state, x[:, -1:])


def _channel_mix(p, x, prev=None):
    xprev = _shift(x, prev)
    mix = p["cmix"].astype(x.dtype)[0]
    xk = mix * x + (1 - mix) * xprev
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    rgate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xk, p["cr"].astype(x.dtype)))
    return rgate * jnp.einsum("bsf,fd->bsd", k, p["cv"].astype(x.dtype)), \
        x[:, -1:]


def forward(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather", return_cache: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)

    def body(x, p):
        a, (st, xl1) = _time_mix(p, rms_norm(x, p["ln1"]), cfg)
        x = ctx.shard_residual(x + a)
        f, xl2 = _channel_mix(p, rms_norm(x, p["ln2"]))
        x = ctx.shard_residual(x + f)
        ys = (st, xl1, xl2) if return_cache else None
        return x, ys

    x, caches = jax.lax.scan(ctx.maybe_remat(body), x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    if return_cache:
        return x, jnp.float32(0.0), caches
    return x, jnp.float32(0.0)


def loss_fn(params, cfg: ArchConfig, batch, compute_dtype=jnp.bfloat16,
            backend: str = "gather"):
    from repro.models.common import chunked_softmax_xent
    x, _ = forward(params, cfg, batch["tokens"], compute_dtype)
    return chunked_softmax_xent(x, params["embed"], batch["targets"],
                                batch.get("mask"))


def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    h = cfg.ssm_heads or cfg.num_heads
    dh = d // h
    l = cfg.num_layers
    return {
        "state": jnp.zeros((l, batch, h, dh, dh), jnp.float32),
        "x1": jnp.zeros((l, batch, 1, d), dtype),
        "x2": jnp.zeros((l, batch, 1, d), dtype),
        "pos": jnp.int32(0),
    }


def prefill(params, cfg: ArchConfig, tokens, compute_dtype=jnp.bfloat16,
            backend: str = "gather"):
    x, _, (st, x1, x2) = forward(params, cfg, tokens, compute_dtype,
                                 return_cache=True)
    cache = {"state": st, "x1": x1, "x2": x2,
             "pos": jnp.int32(tokens.shape[1])}
    return x[:, -1], cache


def decode_step(params, cfg: ArchConfig, token, cache,
                compute_dtype=jnp.bfloat16):
    """O(1)-state decode: the 'KV cache of seq_len' is a constant-size
    recurrent state (the SSM answer to the long_500k cell)."""
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(
        compute_dtype)

    def body(x, layer):
        p, st, x1, x2 = layer
        a, (st_new, x1n) = _time_mix(p, rms_norm(x, p["ln1"]), cfg,
                                     prev=x1, state=st)
        x = x + a
        f, x2n = _channel_mix(p, rms_norm(x, p["ln2"]), prev=x2)
        x = x + f
        return x, (st_new, x1n, x2n)

    x, (st, x1, x2) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["x1"],
                  cache["x2"]))
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return logits, {"state": st, "x1": x1, "x2": x2,
                    "pos": cache["pos"] + 1}
