"""Chunked decayed linear attention — the compute core of RWKV6 and Mamba2.

Two execution forms, both O(N) in sequence length:
  * `*_scan`    — naive per-token recurrence (oracle + decode step).
  * `*_chunked` — chunk-parallel form: inter-chunk state carried by a short
    scan, intra-chunk computed with matmuls (MXU-friendly).

Numerics: decay handled in log space. For *vector* (per-channel) decay
(RWKV6) the intra-chunk pair weights use the pairwise form
exp(cum_t - cum_s) with s <= t, whose exponent is always <= 0 — unlike the
factored q*exp(cum) / k*exp(-cum) form, it cannot overflow. For *scalar*
(per-head) decay (Mamba2/SSD) the pair weights collapse to a (C, C)
matrix and the intra part is a plain masked matmul.

Shapes: q, k, logw: (B, H, N, Dk); v: (B, H, N, Dv); state: (B, H, Dk, Dv).
RWKV convention ("exclusive + bonus"): o_t = q_t (S_{t-1} + (u?k_t)?v_t),
S_t = exp(logw_t)?S_{t-1} + k_t?v_t.  Mamba convention ("inclusive"):
S_t = exp(loga_t) S_{t-1} + k_t?v_t, o_t = q_t S_t.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def decayed_la_scan(q, k, v, logw, u: Optional[jax.Array] = None,
                    inclusive: bool = False, s0=None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Naive recurrence (oracle / decode). Returns (o, final_state)."""
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    s0 = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0

    def step(s, inp):
        qt, kt, vt, wt = inp  # (B, H, Dk) / (B, H, Dv) / (B, H, Dk)
        kv = kt[..., :, None] * vt[..., None, :]
        if inclusive:
            s = jnp.exp(wt)[..., None] * s + kv
            o = jnp.einsum("bhd,bhde->bhe", qt, s)
        else:
            att = s if u is None else s + (u[None, :, :] * kt)[..., None] \
                * vt[..., None, :]
            o = jnp.einsum("bhd,bhde->bhe", qt, att)
            s = jnp.exp(wt)[..., None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
               for t in (q, k, v, logw))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 2), sT


def decayed_la_chunked(q, k, v, logw, u: Optional[jax.Array] = None,
                       inclusive: bool = False, chunk: int = 64, s0=None,
                       scalar_decay: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel decayed linear attention. Returns (o, final_state).

    scalar_decay: logw is (B, H, N) per-head scalar (Mamba2) instead of
    (B, H, N, Dk). Intra-chunk then uses masked-matmul (MXU) form.
    chunk=64 default: swept {16, 32, 64, 128} on the rwkv6 x train_4k
    cell -> {23.1, 16.0, 14.4, 16.9} s memory-bound time — C=64 balances
    pair-tensor traffic (~C*Dk per token) against the N/C inter-chunk
    state updates (EXPERIMENTS.md §Perf).
    """
    b, h, n, dk = q.shape
    in_dtype = v.dtype if v.dtype in (jnp.bfloat16, jnp.float16) \
        else jnp.float32
    dv = v.shape[-1]
    chunk = min(chunk, n)
    while n % chunk:
        chunk -= 1
    nc = n // chunk
    s0 = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0
    f32 = lambda x: x.astype(jnp.float32)
    qc = f32(q).reshape(b, h, nc, chunk, dk)
    kc = f32(k).reshape(b, h, nc, chunk, dk)
    vc = f32(v).reshape(b, h, nc, chunk, dv)
    wshape = (b, h, nc, chunk) if scalar_decay else (b, h, nc, chunk, dk)
    wc = f32(logw).reshape(wshape)

    t_idx = jnp.arange(chunk)
    if inclusive:
        mask = t_idx[:, None] >= t_idx[None, :]  # s <= t
    else:
        mask = t_idx[:, None] > t_idx[None, :]  # s < t

    # Rematerialize each chunk in the backward: without this the scan
    # stacks every chunk's (C, C, Dk) pair tensor as a bwd residual —
    # 53 TB/device of the rwkv6 x train_4k cell's traffic (§Perf).
    @jax.checkpoint
    def body(s, inp):
        qi, ki, vi, wi = inp  # (B,H,C,Dk) etc
        cum = jnp.cumsum(wi, axis=2)  # inclusive cumulative log decay
        cum_q = cum if inclusive else cum - wi  # decay applied before o_t
        if scalar_decay:
            # inter-chunk: o += exp(cum_q) * (q S)
            o = jnp.exp(cum_q)[..., None] * jnp.einsum(
                "bhtd,bhde->bhte", qi, s)
            pair = jnp.exp(jnp.clip(
                cum_q[..., :, None] - cum[..., None, :], -60.0, 0.0))
            a = jnp.einsum("bhtd,bhsd->bhts", qi, ki) * pair
            a = jnp.where(mask, a, 0.0)
            o = o + jnp.einsum("bhts,bhse->bhte", a, vi)
            cC = cum[..., -1]
            kd = ki * jnp.exp(cC[..., None, None] - cum[..., None])
            s = jnp.exp(cC)[..., None, None] * s + jnp.einsum(
                "bhsd,bhse->bhde", kd, vi)
        else:
            o = jnp.einsum("bhtd,bhde->bhte", qi * jnp.exp(cum_q), s)
            # pairwise (t, s, d) weights — exponent <= 0, overflow-free.
            # The (C, C) attention matrix is cast to in_dtype for the AV
            # matmul: at C=16 this tensor family dominates HBM traffic of
            # the whole RWKV6 stack (EXPERIMENTS.md §Perf, rwkv6 cell).
            pair = jnp.exp(jnp.clip(
                cum_q[..., :, None, :] - cum[..., None, :, :], -60.0, 0.0))
            a = jnp.einsum("bhtd,bhsd,bhtsd->bhts", qi, ki, pair)
            a = jnp.where(mask, a, 0.0).astype(in_dtype)
            o = o + jnp.einsum("bhts,bhse->bhte", a,
                               vi.astype(in_dtype)).astype(jnp.float32)
            cC = cum[..., -1, :]
            kd = ki * jnp.exp(cC[..., None, :] - cum)
            s = jnp.exp(cC)[..., :, None] * s + jnp.einsum(
                "bhsd,bhse->bhde", kd, vi)
        if not inclusive and u is not None:
            bonus = jnp.einsum("bhtd,bhtd->bht", qi, u[None, :, None, :] * ki)
            o = o + bonus[..., None] * vi
        return s, o

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (qc, kc, vc, wc))
    sT, o = jax.lax.scan(body, s0, xs)
    o = jnp.moveaxis(o, 0, 2).reshape(b, h, n, dv)
    return o, sT


def decayed_la_step(qt, kt, vt, wt, s, u: Optional[jax.Array] = None,
                    inclusive: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. qt/kt/wt: (B,H,Dk); vt: (B,H,Dv); s: (B,H,Dk,Dv)."""
    f32 = lambda x: x.astype(jnp.float32)
    qt, kt, vt, wt = map(f32, (qt, kt, vt, wt))
    kv = kt[..., :, None] * vt[..., None, :]
    if inclusive:
        s = jnp.exp(wt)[..., None] * s + kv
        return jnp.einsum("bhd,bhde->bhe", qt, s), s
    att = s if u is None else s + (u[None] * kt)[..., None] * vt[..., None, :]
    o = jnp.einsum("bhd,bhde->bhe", qt, att)
    s = jnp.exp(wt)[..., None] * s + kv
    return o, s
