"""Model registry: family -> module, plus per-(arch x shape) input specs.

Every module exposes: init(rng, cfg), loss_fn(params, cfg, batch),
and for decoder families prefill / decode_step / make_cache.
`input_specs(cfg, shape)` returns the exact ShapeDtypeStruct pytree the
dry-run lowers against (the pattern: weak-type-correct, shardable, zero
allocation).
"""
from __future__ import annotations

import types
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import dit, encdec, hybrid, rwkv6, transformer

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": hybrid,
    "encdec": encdec,
    "dit": dit,
}


def get_model(cfg: ArchConfig) -> types.ModuleType:
    return _FAMILY[cfg.family]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "dit":
        return {
            "latents": _sds((b, s, cfg.patch_dim), jnp.float32),
            "noise": _sds((b, s, cfg.patch_dim), jnp.float32),
            "t": _sds((b,), jnp.float32),
            "cond": _sds((b, cfg.cond_len or 64, cfg.d_model), jnp.float32)
            if cfg.cross_attn else None,
        }
    if cfg.family == "encdec":
        st = max(s // 8, 8)
        return {
            "audio_embeds": _sds((b, s, cfg.d_model), jnp.float32),
            "tokens": _sds((b, st), jnp.int32),
            "targets": _sds((b, st), jnp.int32),
        }
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "targets": _sds((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model),
                                     jnp.float32)
        batch["tokens"] = _sds((b, s - cfg.num_patches), jnp.int32)
        batch["targets"] = _sds((b, s - cfg.num_patches), jnp.int32)
    return batch


def decode_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(token, cache) specs for serve_step at this shape."""
    b, s = shape.global_batch, shape.seq_len
    mdl = get_model(cfg)
    cache = jax.eval_shape(
        lambda: mdl.make_cache(cfg, b, s, dtype=jnp.bfloat16))
    token = _sds((b,), jnp.int32)
    return token, cache


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"audio_embeds": _sds((b, s, cfg.d_model), jnp.float32)}
    if cfg.family == "vlm":
        return {"tokens": _sds((b, s - cfg.num_patches), jnp.int32),
                "patch_embeds": _sds((b, cfg.num_patches, cfg.d_model),
                                     jnp.float32)}
    if cfg.family == "dit":
        # DiT "prefill" = one full denoising forward (its inference step)
        return {"latents": _sds((b, s, cfg.patch_dim), jnp.float32),
                "t": _sds((b,), jnp.float32),
                "cond": _sds((b, cfg.cond_len or 64, cfg.d_model),
                             jnp.float32) if cfg.cross_attn else None}
    return {"tokens": _sds((b, s), jnp.int32)}


def make_concrete_batch(rng, cfg: ArchConfig, shape: ShapeConfig):
    """Random concrete batch matching train_batch_specs (smoke tests)."""
    specs = train_batch_specs(cfg, shape)
    out = {}
    for key, sp in specs.items():
        if sp is None:
            continue
        rng, sub = jax.random.split(rng)
        if sp.dtype == jnp.int32:
            out[key] = jax.random.randint(sub, sp.shape, 0,
                                          max(cfg.vocab_size - 1, 2))
        else:
            out[key] = jax.random.normal(sub, sp.shape, sp.dtype)
        if key == "t":
            out[key] = jax.random.uniform(sub, sp.shape)
    return out
