"""Token-choice top-k Mixture-of-Experts FFN (scatter/gather dispatch).

Sort-free dispatch via cumsum position-in-expert + scatter-add into a
(E * capacity, d) buffer — no (tokens, E, capacity) one-hot is ever
materialized (that tensor is ~TBs at assigned shapes). Experts shard over
the "model" mesh axis (EP); the scatter/gather become all-to-alls under
GSPMD.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx
from repro.models.common import dense_init


def moe_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    r = jax.random.split(rng, 5)
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(r[0], d, e, dtype),
        "wi": dense_init(r[1], d, 2 * ff, dtype).reshape(1, d, 2 * ff)
        * jnp.ones((e, 1, 1), dtype),
        "wo": dense_init(r[2], ff, d, dtype).reshape(1, ff, d)
        * jnp.ones((e, 1, 1), dtype),
    }
    # break expert symmetry
    p["wi"] = p["wi"] + 0.02 * jax.random.normal(r[3], p["wi"].shape, dtype)
    if cfg.moe_shared_expert:
        p["shared_wi"] = dense_init(r[3], d, 2 * ff, dtype)
        p["shared_wo"] = dense_init(r[4], ff, d, dtype)
    return p


def _swiglu(x, wi, wo):
    h = jnp.einsum("...d,df->...f", x, wi)
    g, u = jnp.split(h, 2, axis=-1)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, wo)


def moe_apply(params: dict, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    cap = max(1, int(cfg.capacity_factor * t * k / e))

    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), 0)
    aux = e * jnp.sum(density * jnp.mean(probs, 0))

    # position of each (token, slot) within its expert via one-hot cumsum
    flat_e = eidx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    my_pos = jnp.sum(pos * onehot, axis=-1)  # (T*k,)
    keep = my_pos < cap
    dst = jnp.where(keep, flat_e * cap + my_pos, e * cap)  # drop row = e*cap

    sent = jnp.repeat(tokens, k, axis=0)  # (T*k, d)
    # dropped slots point out of bounds; scatter mode="drop" discards
    # them (no sentinel row — keeps E*cap divisible by the EP axis so the
    # buffer can be expert-sharded at the scatter itself)
    buf = jnp.zeros((e * cap, d), tokens.dtype)
    buf = buf.at[dst].add(sent * keep[:, None].astype(tokens.dtype),
                          mode="drop")
    eb = ctx.shard_expert_buf(buf.reshape(e, cap, d))
    h = jnp.einsum("ecd,edf->ecf", eb,
                   ctx.ep_gather(params["wi"].astype(eb.dtype)))
    g, u = jnp.split(h, 2, axis=-1)
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                       ctx.ep_gather(params["wo"].astype(eb.dtype)))
    out_buf = ctx.shard_expert_buf(out_e).reshape(e * cap, d)

    # dropped slots read 0 via fill-mode gather
    recv = jnp.take(out_buf, dst, axis=0, mode="fill", fill_value=0)
    w = (gate.reshape(-1) * keep.astype(jnp.float32)).astype(recv.dtype)
    y = jnp.sum((recv * w[:, None]).reshape(t, k, d), axis=1)

    if cfg.moe_shared_expert:
        y = y + _swiglu(tokens,
                        ctx.fsdp_gather(params["shared_wi"]
                                        .astype(tokens.dtype), "col"),
                        ctx.fsdp_gather(params["shared_wo"]
                                        .astype(tokens.dtype), "row"))
    return y.reshape(b, s, d), aux
