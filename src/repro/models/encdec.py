"""Whisper-style encoder-decoder backbone (audio frontend is a stub per
the assignment: input_specs provide precomputed frame embeddings).

Encoder: bidirectional self-attention over audio-frame embeddings — SLA
applies here (bidirectional is the paper's own DiT setting). Decoder:
causal self-attention over text + cross-attention into encoder states.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ctx
from repro.models.common import (attention, chunked_softmax_xent, dense_init,
                                 embed_init, rms_norm, rope)


def _block_init(rng, cfg: ArchConfig, cross: bool, dtype=jnp.float32):
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    r = list(jax.random.split(rng, 9))
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "wq": dense_init(r[0], d, h * dh, dtype),
        "wk": dense_init(r[1], d, cfg.num_kv_heads * dh, dtype),
        "wv": dense_init(r[2], d, cfg.num_kv_heads * dh, dtype),
        "wo": dense_init(r[3], h * dh, d, dtype),
        "sla_proj": jnp.zeros((h, dh, dh), dtype),
        "mlp_wi": dense_init(r[4], d, 2 * cfg.d_ff, dtype),
        "mlp_wo": dense_init(r[5], cfg.d_ff, d, dtype),
    }
    if cfg.sla.routing_mode == "learned" and not cross:
        # encoder blocks only: decode() runs exact attention for both
        # decoder self- and cross-attention, so a decoder routing head
        # would be dead weight (params + optimizer moments, no grads)
        from repro.core.masks import routing_init
        p["routing"] = routing_init(h, dh, dtype)
    if cross:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xq"] = dense_init(r[6], d, h * dh, dtype)
        p["xk"] = dense_init(r[7], d, cfg.num_kv_heads * dh, dtype)
        p["xv"] = dense_init(r[8], d, cfg.num_kv_heads * dh, dtype)
        p["xo"] = dense_init(r[6], h * dh, d, dtype)
    return p


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    el, dl = cfg.encoder_layers, cfg.decoder_layers
    r = jax.random.split(rng, 4)
    enc = jax.vmap(lambda k: _block_init(k, cfg, False, dtype))(
        jax.random.split(r[0], el))
    dec = jax.vmap(lambda k: _block_init(k, cfg, True, dtype))(
        jax.random.split(r[1], dl))
    return {
        "embed": embed_init(r[2], cfg.vocab_size, cfg.d_model, dtype),
        "enc": enc,
        "dec": dec,
        "ln_enc": jnp.zeros((cfg.d_model,), dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }


def _mha(p, pre, x, kv_x, cfg: ArchConfig, causal, kind, positions, backend):
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p[pre + "q"].astype(x.dtype)) \
        .reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    sk = kv_x.shape[1]
    k = jnp.einsum("bsd,de->bse", kv_x, p[pre + "k"].astype(x.dtype)) \
        .reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", kv_x, p[pre + "v"].astype(x.dtype)) \
        .reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(sk, dtype=jnp.int32), cfg.rope_theta)
    sla_params = {"proj": p["sla_proj"]} if kind == "sla" else None
    o = attention(sla_params, q, k, v, kind, cfg.sla, causal=causal,
                  backend=backend, routing=p.get("routing"))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", o, p[pre + "o"].astype(x.dtype))


def _mlp(p, x):
    hmid = jnp.einsum("bsd,df->bsf", x, p["mlp_wi"].astype(x.dtype))
    g, u = jnp.split(hmid, 2, axis=-1)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["mlp_wo"].astype(x.dtype))


def encode(params, cfg: ArchConfig, audio_embeds,
           compute_dtype=jnp.bfloat16, backend: str = "gather"):
    """audio_embeds: (B, T, d) stub frame embeddings -> encoder states."""
    x = audio_embeds.astype(compute_dtype)
    b, t = x.shape[:2]
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    kind = "sla" if cfg.attention_kind == "sla" else "full"

    def body(x, p):
        x = ctx.shard_residual(
            x + _mha(p, "w", rms_norm(x, p["ln1"]),
                     rms_norm(x, p["ln1"]), cfg, False, kind, pos, backend))
        x = ctx.shard_residual(x + _mlp(p, rms_norm(x, p["ln2"])))
        return x, None

    x, _ = jax.lax.scan(ctx.maybe_remat(body), x, params["enc"])
    return rms_norm(x, params["ln_enc"])


def decode(params, cfg: ArchConfig, tokens, enc_states,
           compute_dtype=jnp.bfloat16, backend: str = "gather"):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    b, s = x.shape[:2]
    pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    enc = enc_states.astype(compute_dtype)

    def body(x, p):
        xn = rms_norm(x, p["ln1"])
        x = ctx.shard_residual(
            x + _mha(p, "w", xn, xn, cfg, True, "full", pos, backend))
        x = ctx.shard_residual(
            x + _mha(p, "x", rms_norm(x, p["ln_x"]), enc, cfg, False,
                     "full", None, backend))
        x = ctx.shard_residual(x + _mlp(p, rms_norm(x, p["ln2"])))
        return x, None

    x, _ = jax.lax.scan(ctx.maybe_remat(body), x, params["dec"])
    return rms_norm(x, params["ln_f"])


def loss_fn(params, cfg: ArchConfig, batch, compute_dtype=jnp.bfloat16,
            backend: str = "gather"):
    """batch: audio_embeds (B,T,d), tokens (B,S), targets (B,S)."""
    enc = encode(params, cfg, batch["audio_embeds"], compute_dtype, backend)
    x = decode(params, cfg, batch["tokens"], enc, compute_dtype, backend)
    return chunked_softmax_xent(x, params["embed"], batch["targets"],
                                batch.get("mask"))


# --------------------------------------------------------------------------
# serving: cross-KV precomputed at prefill; decoder self-cache grows
# --------------------------------------------------------------------------
def make_cache(cfg: ArchConfig, batch: int, enc_len: int,
               dec_len: Optional[int] = None, dtype=jnp.bfloat16) -> dict:
    dec_len = dec_len or max(enc_len // 8, 64)
    dl, hkv, dh = cfg.decoder_layers, cfg.num_kv_heads, cfg.head_dim
    return {
        "self_k": jnp.zeros((dl, batch, hkv, dec_len, dh), dtype),
        "self_v": jnp.zeros((dl, batch, hkv, dec_len, dh), dtype),
        "cross_k": jnp.zeros((dl, batch, hkv, enc_len, dh), dtype),
        "cross_v": jnp.zeros((dl, batch, hkv, enc_len, dh), dtype),
        "pos": jnp.int32(0),
    }


def prefill(params, cfg: ArchConfig, batch, compute_dtype=jnp.bfloat16,
            backend: str = "gather", dec_len: Optional[int] = None):
    """Encode audio + precompute per-layer cross K/V."""
    enc = encode(params, cfg, batch["audio_embeds"], compute_dtype, backend)
    b, t, d = enc.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim

    def xkv(p):
        k = jnp.einsum("bsd,de->bse", enc, p["xk"].astype(enc.dtype)) \
            .reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,de->bse", enc, p["xv"].astype(enc.dtype)) \
            .reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
        return k, v

    ck, cv = jax.vmap(xkv)(params["dec"])
    cache = make_cache(cfg, b, t, dec_len, dtype=compute_dtype)
    cache["cross_k"], cache["cross_v"] = ck, cv
    return enc, cache


def decode_step(params, cfg: ArchConfig, token, cache,
                compute_dtype=jnp.bfloat16):
    """One text-token decode: causal self-attn over the (small) text cache
    + cross-attn over the (long) audio cross-KV."""
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(
        compute_dtype)
    b = x.shape[0]
    pos = cache["pos"]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = dh**-0.5

    def mha_cache(q, kc, vc, upto):
        kk = jnp.repeat(kc, h // hkv, 1) if hkv != h else kc
        vv = jnp.repeat(vc, h // hkv, 1) if hkv != h else vc
        s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
        if upto is not None:
            ok = jnp.arange(kc.shape[-2])[None, None, None, :] <= upto
            s = jnp.where(ok, s, -1e30)
        return jnp.einsum("bhqs,bhsd->bhqd", jax.nn.softmax(s, -1),
                          vv.astype(jnp.float32)).astype(q.dtype)

    def body(x, layer):
        p, sk, sv, ck, cv = layer
        xn = rms_norm(x, p["ln1"])
        q = jnp.einsum("bsd,de->bse", xn, p["wq"].astype(x.dtype)) \
            .reshape(b, 1, h, dh).transpose(0, 2, 1, 3)
        kn = jnp.einsum("bsd,de->bse", xn, p["wk"].astype(x.dtype)) \
            .reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
        vn = jnp.einsum("bsd,de->bse", xn, p["wv"].astype(x.dtype)) \
            .reshape(b, 1, hkv, dh).transpose(0, 2, 1, 3)
        q = rope(q, jnp.full((b, 1), pos, jnp.int32), cfg.rope_theta)
        kn = rope(kn, jnp.full((b, 1), pos, jnp.int32), cfg.rope_theta)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, kn.astype(sk.dtype),
                                                 pos, axis=2)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, vn.astype(sv.dtype),
                                                 pos, axis=2)
        o = mha_cache(q, sk, sv, pos)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
        x = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
        xq = jnp.einsum("bsd,de->bse", rms_norm(x, p["ln_x"]),
                        p["xq"].astype(x.dtype)) \
            .reshape(b, 1, h, dh).transpose(0, 2, 1, 3)
        xo = mha_cache(xq, ck, cv, None)
        xo = xo.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
        x = x + jnp.einsum("bse,ed->bsd", xo, p["xo"].astype(x.dtype))
        x = x + _mlp(p, rms_norm(x, p["ln2"]))
        return x, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    new_cache = dict(cache, self_k=sk, self_v=sv, pos=pos + 1)
    return logits, new_cache
