"""Model zoo."""
