"""Mamba2 (SSD) block — scalar-per-head decay state-space model.

Faithful structure: fused in_proj -> (z, x, B, C, dt), causal depthwise
conv over (x, B, C), SSD recurrence h_t = a_t h_{t-1} + b_t x_t with
a_t = exp(-softplus(dt_t + bias) * exp(A_log)), y_t = C_t h_t + D*x_t,
gated by silu(z), RMS-normed, out-projected. The recurrence runs through
the chunk-parallel masked-matmul path (linear_scan.decayed_la_chunked,
scalar_decay=True).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, rms_norm
from repro.models.linear_scan import decayed_la_chunked, decayed_la_step


def mamba_init(rng, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h = cfg.ssm_heads
    p_dim = cfg.ssm_head_dim
    n = cfg.ssm_state
    d_inner = h * p_dim
    r = list(jax.random.split(rng, 6))
    proj_out = 2 * d_inner + 2 * n + h  # z, x, B, C, dt
    return {
        "ln": jnp.zeros((d,), dtype),
        "in_proj": dense_init(r[0], d, proj_out, dtype),
        "conv": (jax.random.normal(r[1], (cfg.conv_kernel,
                                          d_inner + 2 * n), jnp.float32)
                 * 0.1).astype(dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(r[2], d_inner, d, dtype),
    }


def _causal_conv(x, w, tail: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); tail: (B, K-1, C)."""
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
           if tail is None else tail.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(k))
    return out, xp[:, -(k - 1):]


def mamba_apply(p, x, cfg: ArchConfig,
                conv_tail: Optional[jax.Array] = None,
                state: Optional[jax.Array] = None):
    """x: (B, S, d) -> (out, (new_state, new_conv_tail))."""
    b, s, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner = h * pd
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xc, bb, cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n,
                 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bb, cc], axis=-1)
    conv_out, tail = _causal_conv(conv_in, p["conv"], conv_tail)
    conv_out = jax.nn.silu(conv_out)
    xc, bb, cc = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt_soft = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"][None, None, :])  # (B,S,H)
    loga = -dt_soft * jnp.exp(p["a_log"])[None, None, :]
    heads = lambda t, dim: t.reshape(b, s, h, dim).transpose(0, 2, 1, 3)
    xh = heads(xc, pd)  # v-role: (B, H, S, P)
    # B, C shared across heads (single group)
    bh = jnp.broadcast_to(bb[:, None], (b, h, s, n))
    ch = jnp.broadcast_to(cc[:, None], (b, h, s, n))
    # fold dt into the input (standard SSD discretization)
    xin = xh * dt_soft.transpose(0, 2, 1)[..., None].astype(xh.dtype)
    la = loga.transpose(0, 2, 1)  # (B, H, S)
    if s == 1 and state is not None:
        y, new_state = decayed_la_step(
            ch[:, :, 0], bh[:, :, 0], xin[:, :, 0],
            jnp.broadcast_to(la[..., 0:1], ch[:, :, 0].shape),
            state, inclusive=True)
        y = y[:, :, None, :]
    else:
        y, new_state = decayed_la_chunked(ch, bh, xin, la, inclusive=True,
                                          scalar_decay=True, s0=state,
                                          chunk=64)
    y = y + p["d_skip"][None, :, None, None] * xh.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (new_state, tail)
