"""Sharded, atomic, async checkpointing with elastic (cross-mesh) restore.

Layout:
  <dir>/step_<N>.tmp/          being written
  <dir>/step_<N>/              committed (atomic rename)
      manifest.json            pytree structure + shapes + dtypes
      <leaf-path>.npy          one file per leaf (per host in multi-host)

Fault-tolerance properties:
  * atomic commit — a crash mid-save never corrupts the latest checkpoint
    (readers only ever see fully-renamed directories);
  * async save — a background thread serializes device arrays already
    fetched to host, so the train loop blocks only for the device->host
    copy;
  * keep-last-N garbage collection;
  * `latest_step()` + `restore()` give automatic resume-after-preemption;
  * elastic restore: leaves are saved unsharded-logical (full arrays in
    single-process; per-host shards with index metadata in multi-host),
    so a checkpoint written on mesh A restores onto mesh B with any
    device count — `restore(..., shardings=)` device_puts each leaf with
    the *new* mesh's sharding (tested cross-device-count in
    tests/test_distributed.py).
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(prefix + [str(k)], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(prefix + [str(i)], v)
        else:
            flat[_SEP.join(prefix)] = node

    walk([], tree)
    return flat


def _unflatten_into(template, flat: dict):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(prefix + [str(k)], v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(prefix + [str(i)], v)
                         for i, v in enumerate(node))
        if isinstance(node, list):
            return [walk(prefix + [str(i)], v)
                    for i, v in enumerate(node)]
        return flat[_SEP.join(prefix)]

    return walk([], template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Async by default: fetch to host now, write+commit in background."""
        self.wait()  # one in-flight save at a time
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {}
            for k, v in host.items():
                np.save(tmp / f"{k}.npy", v)
                manifest[k] = {"shape": list(v.shape), "dtype": str(v.dtype)}
            (tmp / "manifest.json").write_text(json.dumps(
                {"step": step, "leaves": manifest}))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -------------------------------------------------- restore
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Load a checkpoint; with `shardings`, device_put each leaf onto
        the *current* mesh (elastic restore across device counts)."""
        final = self.dir / f"step_{step}"
        flat_t = _flatten(template)
        flat = {}
        for k in flat_t:
            flat[k] = np.load(final / f"{k}.npy")
        if shardings is not None:
            flat_s = _flatten(shardings)
            flat = {k: jax.device_put(v, flat_s[k])
                    for k, v in flat.items()}
        return _unflatten_into(template, flat)
