"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert.
Deviation noted in DESIGN.md: uniform MoE layers (upstream alternates
dense/MoE) to keep the scanned layer stack homogeneous.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_d_ff=8192,
    moe_shared_expert=True,
    sla=SLAConfig(),
)
