"""wan2.1-1.3b — the paper's video DiT (seq ~32K, bidirectional attention,
cross-attn to text cond). [arXiv:2503.20314]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="wan2_1_1_3b", family="dit",
    num_layers=30, d_model=1536, num_heads=12, num_kv_heads=12,
    head_dim=128, d_ff=8960, vocab_size=0,
    patch_dim=64, cross_attn=True, cond_len=512,
    sla=SLAConfig(kh_frac=0.05, kl_frac=0.10, phi="softmax",
                  block_q=64, block_kv=64),
)
