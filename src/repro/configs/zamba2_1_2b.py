"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared SLA-attention block.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_heads=64, ssm_head_dim=64,  # d_inner = 2 * d_model
    attn_every=6,
    sla=SLAConfig(),
)
