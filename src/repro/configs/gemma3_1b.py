"""gemma3-1b [dense]: 5:1 local:global attention, 256-dim heads, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    head_dim=256, d_ff=6912, vocab_size=262144,
    local_global_pattern=6, local_window=512,  # 5 local : 1 global (SLA)
    rope_theta=1e6,
    sla=SLAConfig(),
)
