"""internvl2-1b [vlm]: InternViT (stub) + qwen2-0.5b-style LM backbone.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151655,
    frontend="vision_stub", num_patches=256,
    sla=SLAConfig(),
)
