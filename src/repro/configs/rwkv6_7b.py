"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay.
SLA inapplicable (no softmax attention) — DESIGN.md §4 Arch-applicability.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    head_dim=64, d_ff=14336, vocab_size=65536,
    ssm_heads=64, ssm_head_dim=64,
    attention_kind="none",
    sla=SLAConfig(),
)
