"""whisper-small [audio]: enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51865,
    encoder_layers=12, decoder_layers=12,
    frontend="audio_stub",
    sla=SLAConfig(),
)
