"""Architecture configuration schema + the assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import SLAConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | dit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention pattern
    attention_kind: str = "sla"  # per-layer default: sla | full | swa
    sliding_window: int = 0  # swa window (0 = unused)
    local_global_pattern: int = 0  # gemma3: every Nth layer is global
    local_window: int = 1024
    qk_norm: bool = False
    rope_theta: float = 1e4

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_shared_expert: bool = False

    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    attn_every: int = 0  # zamba2: shared attn block every N ssm layers
    conv_kernel: int = 4

    # enc-dec
    encoder_layers: int = 0
    decoder_layers: int = 0

    # frontends (stubs per assignment)
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_patches: int = 0  # vlm prefix length

    # DiT
    patch_dim: int = 0  # latent channel dim for DiT io
    cross_attn: bool = False
    cond_len: int = 0

    sla: SLAConfig = SLAConfig()
    tie_embeddings: bool = True

    # reduced config factory for smoke tests
    def smoke(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            sla=dataclasses.replace(self.sla, block_q=16, block_kv=16,
                                    kh_frac=0.25, kl_frac=0.25),
        )
        if self.num_experts:
            changes.update(num_experts=4, experts_per_token=min(
                2, self.experts_per_token), moe_d_ff=64)
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_heads=4, ssm_head_dim=32)
        if self.attn_every:
            changes.update(num_layers=4, attn_every=2)
        if self.encoder_layers:
            changes.update(encoder_layers=2, decoder_layers=2)
        if self.local_global_pattern:
            changes.update(num_layers=4, local_global_pattern=2,
                           local_window=32)
        if self.num_patches:
            changes.update(num_patches=16)
        if self.sliding_window:
            changes.update(sliding_window=64)
        if self.patch_dim:
            changes.update(patch_dim=16, cond_len=16)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Paper-arch extra cells (beyond the assigned 40): the paper's own models.
DIT_SHAPES = {
    "wan2_1_1_3b": ShapeConfig("dit_video_32k", 32768, 16, "train"),
    "lightningdit_1b": ShapeConfig("dit_image_1k", 1024, 256, "train"),
}

# Reduced shapes for CPU smoke tests (same kinds).
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 128, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 256, 1, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 256, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 512, 1, "decode"),
}
