"""lightningdit-1b — the paper's image DiT (ImageNet 512x512 -> seq 1024).
[Yao et al., 2025]"""
from repro.configs.base import ArchConfig
from repro.core.config import SLAConfig

CONFIG = ArchConfig(
    name="lightningdit_1b", family="dit",
    num_layers=28, d_model=1728, num_heads=16, num_kv_heads=16,
    head_dim=108, d_ff=6912, vocab_size=0,
    patch_dim=32, cross_attn=False,
    sla=SLAConfig(kh_frac=0.125, kl_frac=0.25, block_q=64, block_kv=64),
)
