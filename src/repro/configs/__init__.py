"""Config registry: --arch <id> resolution."""
from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES,
                                SMOKE_SHAPES)

_ARCH_MODULES = {
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "gemma3-1b": "gemma3_1b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen3-1.7b": "qwen3_1_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-small": "whisper_small",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "internvl2-1b": "internvl2_1b",
    "wan2_1_1_3b": "wan2_1_1_3b",
    "lightningdit_1b": "lightningdit_1b",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)[:10]  # the 10 assigned (x4 shapes)
PAPER_ARCHS = list(_ARCH_MODULES)[10:]  # the paper's own models


def get_arch(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str, smoke: bool = False) -> ShapeConfig:
    return (SMOKE_SHAPES if smoke else SHAPES)[name]


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "SMOKE_SHAPES",
           "ASSIGNED_ARCHS", "PAPER_ARCHS", "get_arch", "get_shape"]
