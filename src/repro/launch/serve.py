"""Serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --scheduler continuous --stream

Flag reference (each flag's argparse help is authoritative; see
examples/serve_routing.py and examples/serve_stream.py for worked
end-to-end examples):

  --arch / --smoke          model selection (+ CPU-runnable reduction)
  --requests/--batch/--prompt-len/--max-new/--seed
                            synthetic request stream shape
  --backend                 SLA execution backend (core.backends registry)
  --scheduler               static lockstep groups vs the v2
                            continuous-batching slot pool
                            (DESIGN.md "Serving API v2")
  --stream                  print per-token StreamEvents (continuous only)
  --plan-reuse              reuse prefill block plans across request
                            chunks (DESIGN.md "Plan lifetime & drift")
  --drift-threshold         per-layer drift level that forces a re-plan
  --decode-sla              decode-time SLA (DESIGN.md "Decode-time SLA")
  --routing-mode            threshold vs learned block routing
                            (DESIGN.md "Learned routing")
  --paged / --pool-pages    paged KV cache + prefix page cache
                            (DESIGN.md "Paged KV & prefix caching")
  --prefill-chunk           chunked admission prefill: admit long
                            prompts one N-block chunk per tick so the
                            other slots keep decoding (DESIGN.md
                            "Chunked admission prefill"; requires
                            --paged, continuous scheduler)
  --disagg                  disaggregated prefill/decode worker pools
                            with handoff + fault-tolerant requeue
                            (DESIGN.md "Disaggregated serving")
  --prefill-workers/--decode-workers
                            pool sizes for --disagg
  --workload                'lm' (default) or 'dit': the streaming DiT
                            denoise service — continuous batching of
                            denoise requests with cross-request plan
                            caching (DESIGN.md "Streaming DiT service")
  --num-steps/--seq-len/--t-start
                            dit workload: Euler steps, latent tokens
                            per request, and trajectory start time
  --refresh-mode            dit workload: per-slot plan refresh policy
  --plan-cache/--t-buckets/--cache-entries
                            dit workload: cross-request SLA plan cache
  --stats-json PATH         dump ServeStats + per-request metrics as
                            JSON after the run (every serving mode;
                            in-flight metrics stay null, never 0.0)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="static scheduler: decode group size; "
                         "continuous scheduler: number of decode slots")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="gather",
                    help="SLA execution backend from the core.backends "
                         "registry: 'gather' (LUT-gather XLA, true sparse "
                         "FLOPs — default), 'reference' (dense oracle), "
                         "'kernel' (fused Pallas; interpret mode off-TPU). "
                         "Unknown names fail loudly at startup")
    ap.add_argument("--scheduler", default="static",
                    choices=["static", "continuous"],
                    help="'static' decodes fixed groups in lockstep (v1 "
                         "engine); 'continuous' runs the v2 continuous-"
                         "batching scheduler — a fixed pool of decode "
                         "slots that turn over the moment a request "
                         "finishes, with real per-request TTFT/latency "
                         "and slot-occupancy stats (DESIGN.md 'Serving "
                         "API v2'). Greedy tokens are identical across "
                         "both")
    ap.add_argument("--stream", action="store_true",
                    help="print per-token StreamEvents as they are "
                         "produced (continuous scheduler only)")
    ap.add_argument("--plan-reuse", default="off",
                    choices=["off", "adaptive"],
                    help="'adaptive' pads every prefill chunk to one "
                         "static block-aligned bucket, plans the per-layer "
                         "SLA block structure once, and reuses it across "
                         "chunks of the request stream — re-planning a "
                         "layer only when its measured plan drift reaches "
                         "--drift-threshold (DESIGN.md 'Plan lifetime & "
                         "drift'). 'off' plans every chunk from scratch")
    ap.add_argument("--drift-threshold", default=None,
                    help="re-plan a layer when its plan drift "
                         "(1 - retained critical mass, in [0, 1]) reaches "
                         "this; 0.0 re-plans every chunk, 1.0 never "
                         "re-plans after the first. A comma-separated "
                         "list gives one threshold PER LAYER (applied "
                         "layer-by-layer, never min-reduced). Also gates "
                         "the decode-SLA live-row refresh. Default: "
                         "cfg.sla.plan_drift_threshold")
    ap.add_argument("--decode-sla", action="store_true",
                    help="decode with incremental SLA block plans + the "
                         "O(1) linear running state instead of dense "
                         "masked attention over the full cache — per-token "
                         "attention cost becomes critical-blocks + O(1) "
                         "instead of O(context) (DESIGN.md 'Decode-time "
                         "SLA'). Requires block-aligned prompt/cache "
                         "lengths (the engine rounds max_len up)")
    ap.add_argument("--paged", action="store_true",
                    help="page the per-slot KV cache: block_kv-sized "
                         "physical pages in a refcounted global pool, "
                         "per-slot page tables, prefix-interned prompt "
                         "pages shared copy-on-write across requests "
                         "(DESIGN.md 'Paged KV & prefix caching'). "
                         "Greedy tokens are bitwise-identical to the "
                         "unpaged scheduler. Requires --scheduler "
                         "continuous")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="total physical pages in the paged KV pool "
                         "(incl. the zero page and one scratch page per "
                         "slot). Default: full per-slot backing — "
                         "1 + slots + slots * (max_len / block_kv); "
                         "smaller values bank on prefix sharing and "
                         "fail loudly (PagePoolExhausted) when the bet "
                         "doesn't pay")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="BLOCKS",
                    help="chunked admission prefill: a request that "
                         "misses the full-prompt snapshot owns its slot "
                         "in PREFILLING state and advances BLOCKS SLA "
                         "blocks of prompt per tick while other slots "
                         "keep decoding — bounding the decode stall a "
                         "long prompt inflicts to one chunk's dispatch. "
                         "Tokens and cache contents stay bitwise equal "
                         "to blocking admission (DESIGN.md 'Chunked "
                         "admission prefill'). Requires --paged and "
                         "--scheduler continuous; lifts "
                         "sla.col_capacity_factor to None (printed) — "
                         "chunk classification is row-decomposable "
                         "only uncapped")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: a prefill worker pool "
                         "runs admission, a decode worker pool runs "
                         "token generation, with explicit handoff "
                         "bundles (prefill cache + decode-SLA state) "
                         "routed to the least-loaded decode worker and "
                         "fault-tolerant requeue of a lost worker's "
                         "in-flight requests (DESIGN.md 'Disaggregated "
                         "serving'). Greedy tokens are bitwise equal to "
                         "the single-Scheduler run. --batch sets slots "
                         "PER decode worker; incompatible with --stream "
                         "and --plan-reuse adaptive")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill pool size for --disagg")
    ap.add_argument("--decode-workers", type=int, default=2,
                    help="decode pool size for --disagg")
    ap.add_argument("--workload", default="lm", choices=["lm", "dit"],
                    help="'lm' serves autoregressive token generation "
                         "(all flags above); 'dit' serves streaming "
                         "diffusion denoising: many users' denoise "
                         "requests continuously batched into one "
                         "dit.forward per tick, each slot at its own "
                         "timestep, with validated cross-request SLA "
                         "plan caching (DESIGN.md 'Streaming DiT "
                         "service'). Requires a dit-family --arch")
    ap.add_argument("--num-steps", type=int, default=8,
                    help="dit: Euler denoise steps per request")
    ap.add_argument("--seq-len", type=int, default=None,
                    help="dit: latent tokens per request (block-"
                         "aligned). Default: 2 SLA query blocks")
    ap.add_argument("--t-start", type=float, default=1.0,
                    help="dit: trajectory start time in (0, 1]; < 1.0 "
                         "is SDEdit-style partial denoise")
    ap.add_argument("--refresh-mode", default=None,
                    choices=["fixed", "adaptive"],
                    help="dit: per-slot plan refresh policy — 'fixed' "
                         "re-plans every cfg.sla.plan_refresh_interval "
                         "steps, 'adaptive' re-plans a slot's layer "
                         "when its measured drift reaches "
                         "--drift-threshold. Default: "
                         "cfg.sla.plan_refresh_mode")
    ap.add_argument("--plan-cache", action="store_true",
                    help="dit: cross-request plan cache — admissions "
                         "look up per-(layer, timestep-bucket) SLAPlans "
                         "and validate them through the drift machinery "
                         "instead of planning from scratch "
                         "(serving/plan_cache.py)")
    ap.add_argument("--t-buckets", type=int, default=8,
                    help="dit: timestep buckets for --plan-cache keys")
    ap.add_argument("--cache-entries", type=int, default=256,
                    help="dit: LRU bound on --plan-cache entries "
                         "(per-layer, per-bucket)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="after the run, dump ServeStats + per-request "
                         "metrics as JSON to PATH (every serving mode). "
                         "Derived metrics of in-flight requests are "
                         "null, never 0.0")
    ap.add_argument("--routing-mode", default=None,
                    choices=["threshold", "learned"],
                    help="block-classification router: 'threshold' ranks "
                         "blocks by the paper's pooled P_c rule (Eq. 2-3); "
                         "'learned' ranks them with the trainable "
                         "SLA2-style per-head scorer (DESIGN.md 'Learned "
                         "routing'). Identity-initialized learned routing "
                         "reproduces threshold exactly, so fresh params "
                         "serve identically under either mode. Default: "
                         "cfg.sla.routing_mode")
    args = ap.parse_args(argv)
    if args.drift_threshold is not None:
        parts = [float(x) for x in str(args.drift_threshold).split(",")]
        args.drift_threshold = parts[0] if len(parts) == 1 else tuple(parts)
    if args.stream and args.scheduler != "continuous":
        ap.error("--stream requires --scheduler continuous")
    if args.paged and args.scheduler != "continuous" and not args.disagg:
        ap.error("--paged requires --scheduler continuous or --disagg")
    if args.prefill_chunk is not None and not args.paged \
            and not args.disagg:
        # in-process chunked admission lands through the page-table
        # scatter; the disaggregated prefill POOL chunks carry-side,
        # with no pages involved, so --disagg lifts the requirement
        ap.error("--prefill-chunk requires --paged (chunks land "
                 "through the page-table scatter) or --disagg")
    if args.disagg and args.stream:
        ap.error("--disagg prints pool stats, not a token stream; "
                 "drop --stream")
    if args.disagg and args.plan_reuse != "off":
        ap.error("--disagg requires --plan-reuse off: requeue replays "
                 "a lost worker's prefill, which must be a pure "
                 "function of the prompt")
    if args.workload == "dit" and (
            args.disagg or args.stream or args.paged
            or args.decode_sla or args.prefill_chunk is not None):
        ap.error("--workload dit serves denoise requests — "
                 "--disagg/--stream/--paged/--decode-sla/"
                 "--prefill-chunk are LM-serving flags")

    from repro.core import backends as backend_registry
    backend_registry.resolve(args.backend)  # unknown names fail here, loudly

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.routing_mode is not None:
        # before init: learned mode adds the routing head to the params
        cfg = dataclasses.replace(
            cfg, sla=cfg.sla.replace(routing_mode=args.routing_mode))
    if (args.prefill_chunk is not None
            and cfg.sla.col_capacity_factor is not None):
        # chunk plan rows are sliced from the full classification; the
        # column-capacity demotion pass couples rows, so chunked
        # admission requires the uncapped per-row regime. Lifting the
        # cap keeps strictly MORE critical columns — still a valid SLA
        # plan, applied to blocking admission identically.
        print("--prefill-chunk: lifting sla.col_capacity_factor "
              f"({cfg.sla.col_capacity_factor} -> None); chunked "
              "classification is row-decomposable only uncapped")
        cfg = dataclasses.replace(
            cfg, sla=cfg.sla.replace(col_capacity_factor=None))
    cfg.sla.validate()
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(args.seed), cfg)
    rs = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.max_new + 8

    if args.workload == "dit":
        return _run_dit(args, cfg, params, rs)
    if cfg.family == "dit":
        ap.error(f"--arch {args.arch} is a DiT; serve it with "
                 "--workload dit")

    if args.disagg:
        from repro.serving.api import SamplingParams
        from repro.serving.disagg import DisaggScheduler

        ds = DisaggScheduler(cfg, params,
                             prefill_workers=args.prefill_workers,
                             decode_workers=args.decode_workers,
                             slots_per_worker=args.batch,
                             max_len=max_len, backend=args.backend,
                             decode_sla=args.decode_sla or None,
                             paged=args.paged or None,
                             pool_pages=args.pool_pages,
                             prefill_chunk_blocks=args.prefill_chunk)
        t0 = time.time()
        for i in range(args.requests):
            ds.submit(
                rs.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32),
                SamplingParams(max_new_tokens=args.max_new))
        done = ds.drain()
        wall = time.time() - t0
        st = ds.stats
        print(f"{st.completed}/{st.submitted} requests in {wall:.1f}s "
              f"over {st.ticks} ticks | prefill pool "
              f"{args.prefill_workers}w occ "
              f"{st.prefill_occupancy():.2f} "
              f"({st.prefill_tokens} tok, {st.prefill_chunks} chunks) "
              f"| decode pool {args.decode_workers}w occ "
              f"{ds.decode_occupancy():.2f}")
        print(f"faults: {st.kills} kills, {st.requeues} requeues, "
              f"{st.straggler_drains} straggler drains, "
              f"{st.retries} retries | {st.handoffs} handoffs")
        for row in ds.pool_stats()["decode"]:
            print(f"  {row['worker']}: admitted {row['admitted']}, "
                  f"occupancy {row['occupancy']:.2f}, "
                  f"{row['decode_tokens']} decode tokens"
                  + (" [draining]" if row["draining"] else "")
                  + ("" if row["alive"] else " [dead]"))
        metrics = [r.metrics for r in done]
        from repro.serving.api import percentile as pct
        ttfts = [m.ttft_s for m in metrics if m.ttft_s is not None]
        if ttfts:
            print(f"per-request: TTFT p50 {pct(ttfts, 0.5)*1e3:.0f}ms "
                  f"/ p95 {pct(ttfts, 0.95)*1e3:.0f}ms")
        _maybe_stats_json(args, "disagg", st, done)
        return done

    if args.scheduler == "continuous" and args.stream:
        # drive the v2 API directly so events stream as they happen
        from repro.serving.api import SamplingParams, Scheduler

        sched = Scheduler(cfg, params, num_slots=args.batch,
                          max_len=max_len, backend=args.backend,
                          decode_sla=args.decode_sla or None,
                          plan_reuse=args.plan_reuse,
                          drift_threshold=args.drift_threshold,
                          paged=args.paged or None,
                          pool_pages=args.pool_pages,
                          prefill_chunk_blocks=args.prefill_chunk)
        t0 = time.time()
        for i in range(args.requests):
            sched.submit(
                rs.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32),
                SamplingParams(max_new_tokens=args.max_new))
        for ev in sched.stream():
            if ev.kind == "token":
                print(f"  [{ev.t - t0:7.3f}s] req {ev.rid} "
                      f"token[{ev.index}] = {ev.token}")
            else:
                print(f"  [{ev.t - t0:7.3f}s] req {ev.rid} {ev.kind}")
        done = sched.drain()
        st = sched.stats
        _print_stats(args, st, len(done), time.time() - t0,
                     [r.metrics for r in done], sched.drift_threshold)
        _maybe_stats_json(args, "continuous", st, done)
        return done

    reqs = [Request(rid=i,
                    prompt=rs.integers(0, cfg.vocab_size,
                                       size=args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_len=max_len,
                           backend=args.backend,
                           plan_reuse=args.plan_reuse,
                           drift_threshold=args.drift_threshold,
                           decode_sla=args.decode_sla,
                           scheduler=args.scheduler,
                           paged=args.paged or None,
                           pool_pages=args.pool_pages,
                           prefill_chunk_blocks=args.prefill_chunk)
    t0 = time.time()
    done = engine.run(reqs)
    _print_stats(args, engine.stats, len(done), time.time() - t0,
                 [r.metrics for r in done if r.metrics is not None],
                 engine.drift_threshold)
    _maybe_stats_json(args, args.scheduler, engine.stats, done)
    return done


def _run_dit(args, cfg, params, rs):
    """The dit workload: synthetic denoise requests through the
    DiffusionScheduler, mixed timesteps sharing every batched tick."""
    from repro.serving.api import percentile as pct
    from repro.serving.diffusion import DenoiseParams, DiffusionScheduler

    seq_len = (2 * cfg.sla.block_q if args.seq_len is None
               else args.seq_len)
    sched = DiffusionScheduler(
        cfg, params, num_slots=args.batch, seq_len=seq_len,
        backend=args.backend, refresh_mode=args.refresh_mode,
        drift_threshold=args.drift_threshold,
        plan_cache=args.plan_cache, t_buckets=args.t_buckets,
        cache_entries=args.cache_entries)
    t0 = time.time()
    for i in range(args.requests):
        sched.submit(
            rs.standard_normal((seq_len, cfg.patch_dim),
                               dtype=np.float32),
            DenoiseParams(num_steps=args.num_steps,
                          t_start=args.t_start))
    done = sched.drain()
    wall = time.time() - t0
    st = sched.stats
    print(f"{len(done)} denoise requests ({args.num_steps} steps, "
          f"{seq_len} latent tokens) in {wall:.1f}s | "
          f"{st.denoise_steps} denoise steps | slot occupancy "
          f"{st.occupancy():.2f} ({st.slot_steps_active}/"
          f"{st.slot_steps_total} slot-steps)")
    print(f"plans: {st.plan_builds} built, {st.plan_reuses} reused, "
          f"{st.plan_replans} re-plans | retention "
          f"{st.last_retention:.3f}")
    if sched.cache is not None:
        print(f"plan cache: {st.plan_cache_hits} hits / "
              f"{st.plan_cache_misses} misses, "
              f"{st.plan_cache_invalidations} drift invalidations, "
              f"{st.plan_cache_evictions} evictions "
              f"({len(sched.cache)} entries)")
    lats = [r.metrics.latency_s for r in done
            if r.metrics.latency_s is not None]
    if lats:
        print(f"per-request: latency p50 {pct(lats, 0.5)*1e3:.0f}ms / "
              f"p95 {pct(lats, 0.95)*1e3:.0f}ms")
    _maybe_stats_json(args, "dit", st, done)
    return done


def _maybe_stats_json(args, mode, st, requests):
    """--stats-json: one schema for every serving mode (satellite:
    None-safe — in-flight requests dump null derived metrics)."""
    if not args.stats_json:
        return
    from repro.serving.api import stats_json_payload

    payload = stats_json_payload(mode, st, requests)
    with open(args.stats_json, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"stats json -> {args.stats_json}")


def _print_stats(args, st, n_done, wall, metrics, drift_threshold):
    print(f"{n_done} requests in {wall:.1f}s | "
          f"prefill {st.prefill_tokens} tok / {st.prefill_s:.2f}s | "
          f"decode {st.decode_tokens} tok / {st.decode_s:.2f}s")
    if metrics:
        from repro.serving.api import percentile as pct

        # unfinished / never-prefilled requests report None, not 0.0
        ttfts = [m.ttft_s for m in metrics if m.ttft_s is not None]
        lats = [m.latency_s for m in metrics if m.latency_s is not None]
        if ttfts and lats:
            print(f"per-request: TTFT p50 {pct(ttfts, 0.5)*1e3:.0f}ms / "
                  f"p95 {pct(ttfts, 0.95)*1e3:.0f}ms | latency p50 "
                  f"{pct(lats, 0.5)*1e3:.0f}ms / p95 "
                  f"{pct(lats, 0.95)*1e3:.0f}ms")
    if st.slot_steps_total:
        print(f"scheduler: {st.admissions} admissions | decode-slot "
              f"occupancy {st.occupancy():.2f} "
              f"({st.slot_steps_active}/{st.slot_steps_total} slot-steps)")
    if getattr(args, "paged", False):
        print(f"paged KV: {st.pages_in_use} pages in use "
              f"(peak {st.pages_peak}) | {st.page_allocs} allocs, "
              f"{st.cow_copies} CoW copies | prefix cache "
              f"{st.prefix_hits} page hits / {st.prefix_misses} misses, "
              f"{st.prefix_full_hits} full-prompt hits")
    if getattr(args, "prefill_chunk", None):
        print(f"chunked admission: {st.chunked_admissions} requests in "
              f"{st.prefill_chunks} chunks | max inter-token gap "
              f"{st.max_decode_gap_s * 1e3:.0f}ms")
    if args.plan_reuse != "off":
        print(f"plan reuse: {st.plan_builds} built, {st.plan_reuses} "
              f"reused, {st.plan_replans} drift re-plans | retention "
              f"{st.last_retention:.3f} (threshold: drift >= "
              f"{drift_threshold})")
    if args.decode_sla:
        print(f"decode plans: {st.decode_plan_builds} layer plans built "
              f"at prefill, {st.decode_plan_extends} rows extended, "
              f"{st.decode_plan_reuses} live rows reused, "
              f"{st.decode_plan_replans} drift re-plans | retention "
              f"{st.decode_last_retention:.3f}")


if __name__ == "__main__":
    main()
