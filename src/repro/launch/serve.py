"""Serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="gather",
                    help="SLA execution backend (core.backends registry)")
    ap.add_argument("--plan-reuse", default="off",
                    choices=["off", "adaptive"],
                    help="reuse SLA prefill plans across request chunks, "
                         "refreshing on measured drift")
    ap.add_argument("--drift-threshold", default=None,
                    help="re-plan a layer when its plan drift "
                         "(1 - retained critical mass) reaches this; a "
                         "comma-separated list gives one threshold per "
                         "layer (default: cfg.sla.plan_drift_threshold)")
    ap.add_argument("--decode-sla", action="store_true",
                    help="decode with incremental SLA block plans + the "
                         "O(1) linear running state instead of dense "
                         "masked attention over the full cache")
    args = ap.parse_args(argv)
    if args.drift_threshold is not None:
        parts = [float(x) for x in str(args.drift_threshold).split(",")]
        args.drift_threshold = parts[0] if len(parts) == 1 else tuple(parts)

    from repro.core import backends as backend_registry
    backend_registry.resolve(args.backend)  # unknown names fail here, loudly

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(args.seed), cfg)
    rs = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rs.integers(0, cfg.vocab_size,
                                       size=args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_len=args.prompt_len + args.max_new + 8,
                           backend=args.backend,
                           plan_reuse=args.plan_reuse,
                           drift_threshold=args.drift_threshold,
                           decode_sla=args.decode_sla)
    t0 = time.time()
    done = engine.run(reqs)
    st = engine.stats
    print(f"{len(done)} requests in {time.time()-t0:.1f}s | "
          f"prefill {st.prefill_tokens} tok / {st.prefill_s:.2f}s | "
          f"decode {st.decode_tokens} tok / {st.decode_s:.2f}s")
    if args.plan_reuse != "off":
        print(f"plan reuse: {st.plan_builds} built, {st.plan_reuses} "
              f"reused, {st.plan_replans} drift re-plans | retention "
              f"{st.last_retention:.3f} (threshold: drift >= "
              f"{engine.drift_threshold})")
    if args.decode_sla:
        print(f"decode plans: {st.decode_plan_builds} layer plans built "
              f"at prefill, {st.decode_plan_extends} rows extended, "
              f"{st.decode_plan_reuses} live rows reused, "
              f"{st.decode_plan_replans} drift re-plans | retention "
              f"{st.decode_last_retention:.3f}")
    return done


if __name__ == "__main__":
    main()
