"""train/prefill/serve step builders, uniform across families."""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.optim import adamw


def cast_params_bf16(params):
    """One-shot f32->bf16 compute-copy of the parameters (mixed precision:
    f32 master weights live only in the optimizer path). Doing this ONCE
    before the layer scan keeps every weight all-gather / dynamic-slice on
    bf16 buffers — XLA otherwise hoists the f32->bf16 converts above the
    per-layer collectives and doubles their wire bytes (measured;
    EXPERIMENTS.md §Perf)."""
    return jax.tree.map(
        lambda t: t.astype(jnp.bfloat16)
        if t.dtype == jnp.float32 else t, params)


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    backend: str = "gather") -> Callable:
    mdl = registry.get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_of(p):
            return mdl.loss_fn(cast_params_bf16(p), cfg, batch, backend=backend)

        loss, grads = jax.value_and_grad(loss_of)(params)
        params, opt_state, metrics = adamw.update(params, grads, opt_state,
                                                  opt_cfg)
        return params, opt_state, loss, metrics["grad_norm"]

    return train_step


def make_prefill_step(cfg: ArchConfig, backend: str = "gather") -> Callable:
    mdl = registry.get_model(cfg)

    if cfg.family == "encdec":
        def prefill_step(params, batch):
            return mdl.prefill(params, cfg, batch, backend=backend)
    elif cfg.family == "dit":
        def prefill_step(params, batch):
            # DiT "prefill" = one denoising forward (its inference step)
            return mdl.forward(params, cfg, batch["latents"], batch["t"],
                               batch.get("cond"), backend=backend)
    elif cfg.family == "vlm":
        def prefill_step(params, batch):
            x, _, (kc, vc) = mdl.forward(
                params, cfg, batch["tokens"],
                prefix_embeds=batch["patch_embeds"], backend=backend,
                return_cache=True)
            cache = {"k": kc, "v": vc,
                     "pos": jnp.int32(batch["tokens"].shape[1]
                                      + cfg.num_patches)}
            return x[:, -1], cache
    else:
        def prefill_step(params, batch):
            return mdl.prefill(params, cfg, batch["tokens"], backend=backend)

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    mdl = registry.get_model(cfg)

    def serve_step(params, token, cache):
        return mdl.decode_step(params, cfg, token, cache)

    return serve_step


def abstract_state(cfg: ArchConfig) -> Tuple:
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    mdl = registry.get_model(cfg)
    params = jax.eval_shape(
        lambda: mdl.init(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(adamw.init, params)
    return params, opt
