"""Production mesh definitions (TPU v5e).

single-pod: (data=16, model=16) = 256 chips.
multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading "pod"
axis carries only data parallelism (cross-pod DCI is the slow hop; see
optim/compression.py for the pod-axis gradient compressor).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
