"""Production mesh definitions (TPU v5e).

single-pod: (data=16, model=16) = 256 chips.
multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading "pod"
axis carries only data parallelism (cross-pod DCI is the slow hop; see
optim/compression.py for the pod-axis gradient compressor).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return _make_mesh((data, model), ("data", "model"))
