import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
# against the production mesh with ShapeDtypeStruct inputs (zero
# allocation), and record memory / cost / collective statistics for the
# roofline analysis (EXPERIMENTS.md §Dry-run, §Roofline).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#       --mesh both --out artifacts/dryrun
import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_arch,
                           get_shape)  # noqa: E402
from repro.configs.base import DIT_SHAPES, SHAPES  # noqa: E402
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (abstract_state, make_prefill_step,
                                make_serve_step,
                                make_train_step)  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.roofline.analysis import (model_flops,
                                     roofline_terms)  # noqa: E402
from repro.roofline.hlo_cost import (analyze as hlo_analyze,  # noqa: E402
                                     xla_cost_analysis)

# Cells that are skipped by design (DESIGN.md §4 Arch-applicability).
SKIPS = {
    ("whisper-small", "long_500k"):
        "enc-dec: 500K-token decoder cache exceeds the model's structural "
        "audio context (1.5K frames); skipped per DESIGN.md",
}


def build_cell(cfg, shape, mesh, backend: str = "gather"):
    """Returns (fn, args, in_shardings, out_shardings)."""
    params, opt = abstract_state(cfg)
    p_shard = param_shardings(mesh, params)
    if shape.kind == "train":
        batch = registry.train_batch_specs(cfg, shape)
        batch = {k: v for k, v in batch.items() if v is not None}
        b_shard = batch_shardings(mesh, batch, shape.global_batch)
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": NamedSharding(mesh, P())}
        fn = make_train_step(cfg, AdamWConfig(), backend=backend)
        return (fn, (params, opt, batch),
                (p_shard, opt_shard, b_shard),
                (p_shard, opt_shard, NamedSharding(mesh, P()),
                 NamedSharding(mesh, P())))
    if shape.kind == "prefill":
        batch = registry.prefill_specs(cfg, shape)
        batch = {k: v for k, v in batch.items() if v is not None}
        b_shard = batch_shardings(mesh, batch, shape.global_batch)
        fn = make_prefill_step(cfg, backend=backend)
        return fn, (params, batch), (p_shard, b_shard), None
    # decode — the cache is donated (in-place update; see jit below)
    token, cache = registry.decode_specs(cfg, shape)
    t_shard = batch_shardings(mesh, token, shape.global_batch)
    c_shard = cache_shardings(mesh, cache, shape.global_batch)
    fn = make_serve_step(cfg)
    return (fn, (params, token, cache),
            (p_shard, t_shard, c_shard), (None, c_shard))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, backend: str = "gather") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    out_path = out_dir / f"{tag}.json"
    if (arch, shape_name) in SKIPS:
        rec = {"cell": tag, "status": "skipped",
               "reason": SKIPS[(arch, shape_name)]}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    cfg = get_arch(arch)
    shape = (DIT_SHAPES[arch] if arch in DIT_SHAPES
             else get_shape(shape_name))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    try:
        from repro.distributed import ctx as actx
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
        rspec = actx.default_residual_spec(mesh, shape.global_batch,
                                           shape.seq_len)
        # donation: decode donates its cache (arg 2); train donates params
        # + optimizer state (args 0, 1) — halves state memory via aliasing.
        donate = ((2,) if shape.kind == "decode"
                  else (0, 1) if shape.kind == "train" else ())
        with mesh, actx.activation_sharding(mesh, rspec, remat=True):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = xla_cost_analysis(compiled)
            hlo_text = compiled.as_text()
        # loop-aware cost model (XLA cost_analysis counts scan bodies
        # ONCE — ~88x undercount on deep stacks; see roofline/hlo_cost.py)
        parsed = hlo_analyze(hlo_text)
        import gzip
        (out_dir / f"{tag}.hlo.txt.gz").write_bytes(
            gzip.compress(hlo_text.encode()))
        flops_dev = float(parsed["flops"])
        bytes_dev = float(parsed["bytes"])
        coll = {k.replace("coll_", ""): v for k, v in parsed.items()
                if k.startswith("coll_")}
        coll["total"] = parsed["collective_bytes"]
        terms = roofline_terms(flops_dev, bytes_dev, coll["total"], 1)
        mflops = model_flops(cfg, shape)
        rec = {
            "cell": tag,
            "status": "ok",
            "arch": arch, "shape": shape_name,
            "mesh": [int(mesh.shape[a]) for a in mesh.axis_names],
            "chips": chips,
            "seconds_lower": round(t_lower, 2),
            "seconds_compile": round(t_compile, 2),
            "memory": {
                "argument_bytes_per_device": mem.argument_size_in_bytes,
                "output_bytes_per_device": mem.output_size_in_bytes,
                "temp_bytes_per_device": mem.temp_size_in_bytes,
                "alias_bytes_per_device": mem.alias_size_in_bytes,
                "peak_estimate_gib": round(
                    (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes
                     - mem.alias_size_in_bytes) / 2**30, 3),
            },
            "cost": {"flops_per_device": flops_dev,
                     "bytes_per_device": bytes_dev,
                     "xla_flops_loopbody_once": float(
                         cost.get("flops", 0.0)),
                     "xla_bytes_loopbody_once": float(
                         cost.get("bytes accessed", 0.0))},
            "collectives": {k: v for k, v in coll.items()},
            "roofline": terms,
            "model_flops_total": mflops,
            "useful_flops_ratio": (
                mflops / (flops_dev * chips) if flops_dev else 0.0),
        }
    except Exception as e:  # a failing cell is a bug in the system
        rec = {"cell": tag, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--include-paper-archs", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir",
                      str(out_dir / ".jax_cache"))

    archs = (ASSIGNED_ARCHS if args.arch == "all" else [args.arch])
    if args.include_paper_archs and args.arch == "all":
        archs = archs + PAPER_ARCHS
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        shapes = (["dit"] if arch in DIT_SHAPES else
                  (list(SHAPES) if args.shape == "all" else [args.shape]))
        for shape_name in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape_name, multi, out_dir)
                status = rec["status"]
                n_ok += status in ("ok", "skipped")
                n_fail += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} "
                             f"bound={r['bound_s']:.4f}s "
                             f"mem={rec['memory']['peak_estimate_gib']}GiB "
                             f"[lower {rec['seconds_lower']}s, "
                             f"compile {rec['seconds_compile']}s]")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"{rec['cell']:60s} {status}{extra}", flush=True)
    print(f"\n{n_ok} ok/skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
