"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt

Wires together: config -> mesh -> sharded params/opt -> deterministic data
pipeline -> jitted train_step (remat + SP context) -> atomic async
checkpoints with auto-resume -> straggler watchdog + NaN guard ->
optional error-feedback gradient compression on the (pod-)DP axis.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, get_shape
from repro.data.pipeline import DataConfig, make_iterator
from repro.distributed import ctx as actx
from repro.distributed.fault_tolerance import NaNGuard, StragglerWatchdog
from repro.distributed.sharding import batch_shardings, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim import adamw
from repro.optim.compression import ef_compress_decompress, ef_init


ROUTING_WARM_EPS = 1e-3


def routing_warm_init(params):
    """Replace the zero-initialized per-head Proj merge (`sla_proj`)
    with an epsilon-scaled identity (`ROUTING_WARM_EPS * I`).

    Opt-in escape hatch for the learned-routing dead point (see
    `check_routing_dead_point`): a tiny but nonzero Proj lets the
    straight-through routing gradients through from step 0 while
    perturbing the model's output by only O(eps * ||o_l||)."""
    layers = dict(params["layers"])
    proj = layers["sla_proj"]
    eye = jnp.eye(proj.shape[-1], dtype=proj.dtype)
    layers["sla_proj"] = jnp.broadcast_to(eye, proj.shape) * ROUTING_WARM_EPS
    return dict(params, layers=layers)


def check_routing_dead_point(params, mask):
    """Warn loudly when a fine-tune is pinned at the learned-routing
    dead point: the routing head is trainable but every `sla_proj` is
    exactly zero. Routing parameters only receive gradients through the
    straight-through marginal gates of the LINEAR branch, and that
    branch's output is multiplied by `sla_proj` (Eq. 6) — so all-zero
    Proj multiplies every routing gradient by exact zero and
    `--train-only routing` silently flatlines. Returns True iff the
    warning fired (tests assert both paths)."""
    import warnings

    flat_m = jax.tree_util.tree_leaves_with_path(mask)
    trains_routing = any("routing" in jax.tree_util.keystr(path) and t
                         for path, t in flat_m)
    proj = params.get("layers", {}).get("sla_proj")
    if not trains_routing or proj is None:
        return False
    if bool(jnp.any(proj != 0)):
        return False
    warnings.warn(
        "learned-routing dead point: --train-only includes the routing "
        "head, but every sla_proj is exactly zero (the paper's init). "
        "Routing gradients flow only through the linear branch, whose "
        "output is multiplied by sla_proj — they are therefore all "
        "exactly zero and routing will never move. Pass "
        "--routing-warm-init to seed sla_proj with an epsilon identity, "
        "or include 'sla_proj' in --train-only and train the merge off "
        "zero first.")
    return True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--distill", action="store_true",
                    help="fine-tune against the model family's end-to-end "
                         "distillation loss (exact-attention teacher, SLA "
                         "student; paper Sec. 5) instead of the training "
                         "loss")
    ap.add_argument("--routing-mode", default=None,
                    choices=["threshold", "learned"],
                    help="override SLAConfig.routing_mode: 'learned' adds "
                         "the trainable SLA2-style routing head "
                         "(identity-initialized to reproduce 'threshold' "
                         "exactly; DESIGN.md 'Learned routing')")
    ap.add_argument("--train-only", default=None,
                    help="comma-separated parameter-name substrings to "
                         "train (e.g. 'routing,sla_proj'); everything "
                         "else is frozen — the fixed-FLOP-budget "
                         "fine-tuning recipe")
    ap.add_argument("--routing-warm-init", action="store_true",
                    help="seed every layer's sla_proj with a small "
                         "epsilon-scaled identity (1e-3) instead of the "
                         "paper's zero init. Breaks the learned-routing "
                         "dead point: routing gradients flow only "
                         "through the straight-through marginal gates "
                         "into the LINEAR branch, whose output is "
                         "multiplied by sla_proj — all-zero sla_proj "
                         "therefore multiplies every routing gradient "
                         "by exact zero, and '--train-only routing' "
                         "cannot move (a fresh checkpoint warns loudly "
                         "instead of silently flatlining)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.routing_mode is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, sla=cfg.sla.replace(routing_mode=args.routing_mode))
    shape = get_shape(args.shape, smoke=args.smoke)
    mdl = registry.get_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 10, 1))
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)

    rng = jax.random.PRNGKey(args.seed)
    params = mdl.init(rng, cfg)
    if args.routing_warm_init:
        params = routing_warm_init(params)
    opt_state = adamw.init(params)
    from jax.sharding import NamedSharding, PartitionSpec as P
    p_shard = param_shardings(mesh, jax.eval_shape(lambda: params))
    o_shard = {"m": p_shard, "v": p_shard,
               "step": NamedSharding(mesh, P())}
    params = jax.device_put(params, p_shard)
    opt_state = jax.device_put(opt_state, o_shard)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        start_step = mgr.latest_step()
        state = mgr.restore(start_step,
                            {"params": params, "opt": opt_state},
                            {"params": p_shard, "opt": o_shard})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start_step}")

    data = make_iterator(cfg, shape, DataConfig(seed=args.seed),
                         start_step=start_step)
    ef_error = ef_init(params) if args.compress_grads else None

    loss_impl = mdl.loss_fn
    if args.distill:
        loss_impl = getattr(mdl, "distill_loss_fn", None)
        if loss_impl is None:
            raise ValueError(
                f"--distill: model family {cfg.family!r} has no "
                "distill_loss_fn")
    mask = None
    if args.train_only:
        mask = adamw.trainable_mask(
            params, tuple(s for s in args.train_only.split(",") if s))
        n_train = sum(p.size for p, t in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(mask)) if t)
        if n_train == 0:
            raise ValueError(
                f"--train-only {args.train_only!r} matches no parameters")
        print(f"training {n_train} of "
              f"{sum(p.size for p in jax.tree_util.tree_leaves(params))} "
              f"params ({args.train_only})")
        check_routing_dead_point(params, mask)

    def loss_of(p, batch):
        return loss_impl(p, cfg, batch)

    @jax.jit
    def grad_step(p, batch):
        return jax.value_and_grad(loss_of)(p, batch)

    @jax.jit
    def apply_update(p, g, o):
        return adamw.update(p, g, o, opt_cfg, trainable=mask)

    if args.compress_grads:
        @jax.jit
        def compress(g, e):
            return ef_compress_decompress(g, e)

    watchdog = StragglerWatchdog()
    guard = NaNGuard()
    rspec = actx.default_residual_spec(mesh, shape.global_batch,
                                       shape.seq_len)
    losses = []
    with mesh, actx.activation_sharding(mesh, rspec, remat=True):
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            loss, grads = grad_step(params, batch)
            if not guard.check(loss):
                print(f"step {step}: non-finite loss, update skipped")
                continue
            if args.compress_grads:
                grads, ef_error, cstats = compress(grads, ef_error)
            params, opt_state, metrics = apply_update(params, grads,
                                                      opt_state)
            jax.block_until_ready(loss)
            dt = time.time() - t0
            slow = watchdog.record(dt)
            losses.append(float(loss))
            if step % args.log_every == 0 or step == args.steps - 1:
                extra = " STRAGGLER" if slow else ""
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.2f}s{extra}",
                      flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
        if mgr is not None:
            mgr.save(args.steps, {"params": params, "opt": opt_state},
                     blocking=True)
    if watchdog.flagged:
        print(f"stragglers flagged: {len(watchdog.flagged)}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
