"""Deterministic synthetic data pipeline.

Design for 1000+ hosts: every batch is a pure function of
(seed, global_step, host_id) — no coordinator, no state to checkpoint
beyond the step counter, bit-identical restart after preemption, and
hosts never exchange data. Each host produces only its local shard of the
global batch (`host_batch = global_batch // num_hosts`).

Token streams are Zipf-distributed n-gram chains (so the LM loss has
learnable structure); DiT latents are low-rank Gaussian fields (so the
flow-matching loss has learnable structure).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


def _batch_rng(dc: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, dc.host_id]))


def token_batch(cfg: ArchConfig, shape: ShapeConfig, dc: DataConfig,
                step: int) -> Dict[str, np.ndarray]:
    """Markov-chain tokens: x_{t+1} = (a * x_t + noise) mod V (learnable)."""
    rng = _batch_rng(dc, step)
    b = max(shape.global_batch // dc.num_hosts, 1)
    s = shape.seq_len
    v = cfg.vocab_size
    seq_dim = s
    if cfg.family == "vlm":
        seq_dim = s - cfg.num_patches
    x = np.empty((b, seq_dim + 1), np.int64)
    x[:, 0] = rng.integers(0, v, size=b)
    noise = rng.integers(0, 17, size=(b, seq_dim))
    for t in range(seq_dim):
        x[:, t + 1] = (x[:, t] * 31 + noise[:, t]) % v
    batch = {
        "tokens": x[:, :-1].astype(np.int32),
        "targets": x[:, 1:].astype(np.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model), np.float32)
    if cfg.family == "encdec":
        st = max(seq_dim // 8, 8)
        batch = {
            "audio_embeds": rng.standard_normal(
                (b, seq_dim, cfg.d_model), np.float32),
            "tokens": batch["tokens"][:, :st],
            "targets": batch["targets"][:, :st],
        }
    return batch


def latent_batch(cfg: ArchConfig, shape: ShapeConfig, dc: DataConfig,
                 step: int, rank: int = 8) -> Dict[str, np.ndarray]:
    """DiT batch: low-rank latent 'videos' + noise + uniform t."""
    rng = _batch_rng(dc, step)
    b = max(shape.global_batch // dc.num_hosts, 1)
    n, p = shape.seq_len, cfg.patch_dim
    u = rng.standard_normal((b, n, rank)).astype(np.float32)
    w = rng.standard_normal((rank, p)).astype(np.float32)
    batch = {
        "latents": (u @ w) / np.sqrt(rank),
        "noise": rng.standard_normal((b, n, p)).astype(np.float32),
        "t": rng.uniform(0.02, 0.98, size=(b,)).astype(np.float32),
    }
    if cfg.cross_attn:
        batch["cond"] = rng.standard_normal(
            (b, cfg.cond_len or 64, cfg.d_model)).astype(np.float32)
    return batch


def make_iterator(cfg: ArchConfig, shape: ShapeConfig,
                  dc: Optional[DataConfig] = None,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    dc = dc or DataConfig()
    step = start_step
    fn = latent_batch if cfg.family == "dit" else token_batch
    while True:
        yield fn(cfg, shape, dc, step)
        step += 1
