"""Streaming DiT denoise service (DESIGN.md "Streaming DiT service").

The second served workload: many users submit latents to denoise, the
`DiffusionScheduler` continuously batches them into ONE `dit.forward`
launch per tick. Requests at *different* timesteps share the batch —
the timestep embedding, AdaLN modulation, and attention are all
row-independent, so a mixed-timestep batch computes each row exactly
what a batch-1 run at that row's t would (the bitwise
batched-vs-sequential parity pinned by tests/test_dit_serving.py).

Shape of the loop (mirrors the LM Scheduler's fixed-pool design):

  submit -> queue -> [admission: batch-1 step-0 forward plans the
  request's per-layer SLAPlans (or validates cached ones) and scatters
  (latent, plans) into a free slot] -> per tick, ONE batched forward +
  Euler update advances every active slot one denoising step at its own
  (t, dt) -> a slot that reaches its request's num_steps retires: the
  final latent is read out, the slot frees for the next admission.

Plan refresh inside the batched tick uses the per-sample drift path
(`plan_lib.refresh_plan_per_sample` via `dit.forward(...,
per_sample_refresh=True)`): each slot keeps/rebuilds its own plans on
its own schedule — "fixed" intervals become a per-slot 0/1 threshold
vector, "adaptive" measures real drift — so one slot's refresh never
couples to its neighbours', which is what makes the batched trajectory
bitwise-equal to `dit.sample` per request.

Cross-request plan cache (`serving/plan_cache.py`): admission looks up
the request's timestep bucket; on a hit the first forward *validates*
the cached per-layer stack through the drift machinery instead of
planning from scratch — layers whose structure still fits are planning
work saved fleet-wide (Sparse-vDiT: patterns repeat across requests),
layers that drifted re-plan and write back. Mid-flight, a slot crossing
into an unpopulated bucket donates its current plans, so the first few
requests populate the whole timestep axis for everyone behind them.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import plan as plan_lib
from repro.models import dit
from repro.serving.api import (RequestMetrics, RequestState, ServeStats,
                               StreamEvent, normalize_drift_threshold)
from repro.serving.plan_cache import PlanCache

__all__ = ["DenoiseParams", "DenoiseRequest", "DiffusionScheduler"]


@dataclasses.dataclass
class DenoiseParams:
    """Per-request denoise policy (the DiT analogue of SamplingParams).

    num_steps Euler steps from t_start down to 0 (dt = t_start /
    num_steps). t_start < 1.0 is SDEdit-style partial denoise — and the
    reason admissions land in different plan-cache buckets."""

    num_steps: int = 8
    t_start: float = 1.0

    def validate(self) -> "DenoiseParams":
        if self.num_steps < 1:
            raise ValueError(
                f"num_steps must be >= 1 (got {self.num_steps})")
        if not 0.0 < self.t_start <= 1.0:
            raise ValueError(
                f"t_start must be in (0, 1] (got {self.t_start})")
        return self


@dataclasses.dataclass
class DenoiseRequest:
    """A denoise request inside the scheduler (cf. api.ServedRequest)."""

    rid: int
    latent: np.ndarray  # (N, patch_dim) noise / partially-denoised input
    params: DenoiseParams
    cond: Optional[np.ndarray] = None  # (Lc, d_model) text embeddings
    state: RequestState = RequestState.QUEUED
    steps_done: int = 0
    metrics: RequestMetrics = dataclasses.field(
        default_factory=RequestMetrics)
    slot: Optional[int] = None
    result: Optional[np.ndarray] = None  # (N, patch_dim) final latent


class DiffusionScheduler:
    """Continuous batching for DiT denoising over a fixed slot pool.

    One jitted batched (forward + Euler) trace serves every tick; one
    jitted batch-1 admission trace plans (or validates) each incoming
    request's SLAPlans. Per-request trajectories are bitwise-equal to
    sequential `dit.sample(..., t_start=...)` runs when the plan cache
    is off; with the cache on, admissions reuse validated cross-request
    structure and outputs stay within the conformance-matrix tolerances
    (drift below threshold means the cached classification still
    captures the sample's critical mass).
    """

    def __init__(self, cfg: ArchConfig, params, *, num_slots: int = 4,
                 seq_len: int = 64, backend: str = "gather",
                 compute_dtype=jnp.float32,
                 refresh_mode: Optional[str] = None,
                 refresh_interval: Optional[int] = None,
                 drift_threshold=None,
                 plan_cache=None, t_buckets: int = 8,
                 cache_entries: int = 256):
        from repro.core import backends as backend_registry
        backend = backend_registry.resolve(backend)
        if cfg.family != "dit":
            raise ValueError(
                f"DiffusionScheduler serves the dit family only "
                f"(got family={cfg.family!r}; the LM families go "
                f"behind serving.Scheduler)")
        cfg.sla.validate()
        self.cfg = cfg
        self.params = params
        self.num_slots = int(num_slots)
        self.seq_len = int(seq_len)
        self.backend = backend
        self.compute_dtype = compute_dtype
        self.sla_cfg = dataclasses.replace(cfg.sla, causal=False)
        if seq_len % self.sla_cfg.block_q or seq_len % self.sla_cfg.block_kv:
            raise ValueError(
                f"seq_len={seq_len} must be a multiple of the SLA block "
                f"sizes ({self.sla_cfg.block_q}, {self.sla_cfg.block_kv}) "
                "— the plan grid is block-aligned")
        mode = (cfg.sla.plan_refresh_mode if refresh_mode is None
                else refresh_mode)
        if mode not in ("fixed", "adaptive"):
            raise ValueError(f"unknown refresh_mode {mode!r}; "
                             "expected 'fixed' or 'adaptive'")
        self.refresh_mode = mode
        self.refresh_interval = max(1, int(
            cfg.sla.plan_refresh_interval if refresh_interval is None
            else refresh_interval))
        nl = cfg.num_layers
        thr = normalize_drift_threshold(cfg, drift_threshold)
        self._thr_layers = np.broadcast_to(
            np.asarray(thr, np.float32), (nl,)).copy()
        self.plan_needed = (cfg.attention_kind == "sla"
                            and self.sla_cfg.mode
                            not in ("full", "linear_only"))
        # cross-request plan cache: False/None = off, True = build one,
        # or pass a shared PlanCache instance (fleet-wide amortization)
        if plan_cache is True:
            plan_cache = PlanCache(self.sla_cfg, nl, t_buckets=t_buckets,
                                   max_entries=cache_entries)
        # identity checks, not truthiness: an empty PlanCache has
        # len() == 0 and must still count as "cache on"
        self.cache: Optional[PlanCache] = (
            plan_cache if (isinstance(plan_cache, PlanCache)
                           and self.plan_needed) else None)

        # live batched state: one latent row + one per-layer plan row
        # per slot; host-side f32 (t0, dt) bookkeeping per slot
        self._lat = jnp.zeros((num_slots, seq_len, cfg.patch_dim),
                              jnp.float32)
        self._cond = (jnp.zeros((num_slots, cfg.cond_len, cfg.d_model),
                                jnp.float32)
                      if cfg.cross_attn else None)
        if self.plan_needed:
            tm = seq_len // self.sla_cfg.block_q
            tn = seq_len // self.sla_cfg.block_kv
            proto = plan_lib.empty_plan(self.sla_cfg, num_slots,
                                        cfg.num_heads, tm, tn)
            self._plans = jax.tree_util.tree_map(
                lambda leaf: jnp.stack([leaf] * nl), proto)
        else:
            self._plans = None
        self._t0 = np.zeros((num_slots,), np.float32)
        self._dt = np.zeros((num_slots,), np.float32)
        self._bucket = [None] * num_slots  # last plan-cache bucket seen

        self._queue: Deque[DenoiseRequest] = deque()
        self._requests: List[DenoiseRequest] = []
        self._slots: List[Optional[DenoiseRequest]] = [None] * num_slots
        self._next_rid = 0
        self.stats = ServeStats()
        self._build_jits()

    # -- jitted kernels --------------------------------------------------
    def _build_jits(self):
        cfg, dtype, backend = self.cfg, self.compute_dtype, self.backend
        plan_needed, cross = self.plan_needed, self.cfg.cross_attn

        def admit_fresh(params, lat1, t1, dt1, cond1):
            """Step 0 of the request's trajectory: plan + first Euler
            step, exactly `dit.sample`'s pre-loop head at batch 1."""
            out = dit.forward(params, cfg, lat1, t1,
                              cond1 if cross else None, dtype, backend,
                              return_plans=plan_needed)
            vel, plans = out if plan_needed else (out, None)
            new = lat1 - dt1[:, None, None] * vel.astype(lat1.dtype)
            return new, plans

        def admit_cached(params, lat1, t1, dt1, cond1, plans, thr):
            """Step 0 against a cached plan stack: the drift machinery
            validates each layer's cached structure; `replanned` flags
            the invalidated layers (written back to the cache)."""
            vel, plans, info = dit.forward(
                params, cfg, lat1, t1, cond1 if cross else None, dtype,
                backend, plans=plans, return_plans=True,
                drift_threshold=thr)
            new = lat1 - dt1[:, None, None] * vel.astype(lat1.dtype)
            return new, plans, info

        def tick(params, latents, tv, dtv, cond, plans, thr, mask):
            """ONE batched denoise step for every active slot: mixed
            per-slot (t, dt), per-sample plan refresh, masked commit so
            retired/free rows keep their state bitwise-untouched."""
            if plan_needed:
                vel, new_plans, info = dit.forward(
                    params, cfg, latents, tv, cond if cross else None,
                    dtype, backend, plans=plans, return_plans=True,
                    drift_threshold=thr, per_sample_refresh=True)
            else:
                vel = dit.forward(params, cfg, latents, tv,
                                  cond if cross else None, dtype, backend)
                new_plans, info = None, None
            new_lat = latents - dtv[:, None, None] * vel.astype(
                latents.dtype)
            latents = jnp.where(mask[:, None, None], new_lat, latents)
            if plan_needed:
                def sel(n, o):
                    m = mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                    return jnp.where(m, n, o)
                plans = jax.tree_util.tree_map(sel, new_plans, plans)
            return latents, plans, info

        self._admit_fresh_jit = jax.jit(admit_fresh)
        self._admit_cached_jit = jax.jit(admit_cached)
        self._tick_jit = jax.jit(tick)

    # -- request surface -------------------------------------------------
    def submit(self, latent, params: Optional[DenoiseParams] = None,
               cond=None) -> int:
        """Enqueue one denoise request; returns its rid. Never blocks."""
        params = (params or DenoiseParams()).validate()
        latent = np.asarray(latent, np.float32)
        if latent.shape != (self.seq_len, self.cfg.patch_dim):
            raise ValueError(
                f"latent shape {latent.shape} != scheduler's "
                f"({self.seq_len}, {self.cfg.patch_dim})")
        if cond is not None:
            if not self.cfg.cross_attn:
                raise ValueError(
                    f"{self.cfg.name} has no cross-attention; cond must "
                    "be None")
            cond = np.asarray(cond, np.float32)
            want = (self.cfg.cond_len, self.cfg.d_model)
            if cond.shape != want:
                raise ValueError(f"cond shape {cond.shape} != {want}")
        r = DenoiseRequest(rid=self._next_rid, latent=latent,
                           params=params, cond=cond)
        r.metrics.submit_t = time.time()
        self._next_rid += 1
        self._queue.append(r)
        self._requests.append(r)
        return r.rid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self._slots)

    def active_timesteps(self) -> List[Optional[float]]:
        """Current diffusion time per slot (None = free) — observability
        for the mixed-timestep claim; tests assert heterogeneity."""
        out: List[Optional[float]] = []
        for j, r in enumerate(self._slots):
            out.append(float(self._slot_t(j)) if r is not None else None)
        return out

    # -- host-side time bookkeeping ---------------------------------------
    def _slot_t(self, j: int) -> np.float32:
        """t for slot j's NEXT step, positionally (t0 - steps*dt in f32)
        — the same rounded value `dit.sample`'s tvec(step) computes, so
        host bookkeeping never drifts from the device trajectory."""
        r = self._slots[j]
        return np.float32(self._t0[j]
                          - np.float32(r.steps_done) * self._dt[j])

    # -- admission ---------------------------------------------------------
    def _admit_next(self, slot: int, events: List[StreamEvent]):
        r = self._queue.popleft()
        r.state = RequestState.PREFILLING
        r.slot = slot
        t0 = time.time()
        r.metrics.admit_t = t0
        t_start = np.float32(r.params.t_start)
        dt = np.float32(t_start / np.float32(r.params.num_steps))
        lat1 = jnp.asarray(r.latent[None])
        t1 = jnp.full((1,), t_start, jnp.float32)
        dt1 = jnp.full((1,), dt, jnp.float32)
        cond1 = (jnp.asarray(
            (r.cond if r.cond is not None
             else np.zeros((self.cfg.cond_len, self.cfg.d_model),
                           np.float32))[None])
            if self.cfg.cross_attn else None)
        nl = self.cfg.num_layers
        cached = bucket = None
        if self.cache is not None:
            bucket = self.cache.bucket(float(t_start))
            cached = self.cache.get(bucket)
        if not self.plan_needed:
            new_lat, plan_row = self._admit_fresh_jit(
                self.params, lat1, t1, dt1, cond1)
        elif cached is None:
            new_lat, plan_row = self._admit_fresh_jit(
                self.params, lat1, t1, dt1, cond1)
            self.stats.plan_builds += nl
            if self.cache is not None:
                self.cache.put(bucket, plan_row)
        else:
            new_lat, plan_row, info = self._admit_cached_jit(
                self.params, lat1, t1, dt1, cond1, cached,
                jnp.asarray(self._thr_layers))
            replanned = np.asarray(info["replanned"]).reshape(nl)
            n_replan = int(replanned.sum())
            self.stats.plan_replans += n_replan
            self.stats.plan_reuses += nl - n_replan
            self.stats.last_retention = float(
                np.min(np.asarray(info["retention"])))
            if n_replan:
                self.cache.update(bucket, plan_row, replanned)
        self._lat, self._plans = dit.insert_denoise_slot(
            self._lat, self._plans, slot, new_lat, plan_row)
        if self._cond is not None:
            self._cond = self._cond.at[slot].set(
                cond1[0] if cond1 is not None else 0.0)
        self._t0[slot] = t_start
        self._dt[slot] = dt
        self._bucket[slot] = bucket
        self._slots[slot] = r
        r.steps_done = 1
        r.metrics.decode_tokens = 1
        r.state = RequestState.DECODING
        now = time.time()
        r.metrics.first_token_t = now
        self.stats.admissions += 1
        self.stats.denoise_steps += 1
        events.append(StreamEvent(rid=r.rid, kind="start", t=t0))
        events.append(StreamEvent(rid=r.rid, kind="step", t=now, index=0))
        if r.steps_done >= r.params.num_steps:
            self._finish(slot, events)
        self._sync_cache_stats()

    def _finish(self, slot: int, events: List[StreamEvent]):
        r = self._slots[slot]
        r.result = np.asarray(dit.retire_denoise_slot(self._lat, slot))
        r.state = RequestState.FINISHED
        r.metrics.finish_t = time.time()
        r.slot = None
        self._slots[slot] = None
        self._bucket[slot] = None
        events.append(StreamEvent(rid=r.rid, kind="finish",
                                  t=r.metrics.finish_t))

    def _sync_cache_stats(self):
        if self.cache is None:
            return
        self.stats.plan_cache_hits = self.cache.hits
        self.stats.plan_cache_misses = self.cache.misses
        self.stats.plan_cache_invalidations = self.cache.invalidations
        self.stats.plan_cache_evictions = self.cache.evictions

    # -- the tick ----------------------------------------------------------
    def step(self) -> List[StreamEvent]:
        """Admit queued requests into free slots, then run ONE batched
        denoise step over every active slot. Returns the events."""
        events: List[StreamEvent] = []
        for slot in range(self.num_slots):
            if self._slots[slot] is None and self._queue:
                self._admit_next(slot, events)
        active = [j for j in range(self.num_slots)
                  if self._slots[j] is not None]
        if not active:
            return events
        nl, ns = self.cfg.num_layers, self.num_slots
        tv = np.zeros((ns,), np.float32)
        mask = np.zeros((ns,), bool)
        thr = np.ones((nl, ns), np.float32)  # >= 1.0: inert rows
        for j in active:
            r = self._slots[j]
            tv[j] = self._slot_t(j)
            mask[j] = True
            if self.refresh_mode == "fixed":
                # the upcoming step index is steps_done; 0.0 forces the
                # row's re-plan, 1.0 pins reuse — dit.sample's static
                # schedule expressed per slot
                thr[:, j] = (0.0 if r.steps_done % self.refresh_interval
                             == 0 else 1.0)
            else:
                thr[:, j] = self._thr_layers
        t_wall = time.time()
        self._lat, self._plans, info = self._tick_jit(
            self.params, self._lat, jnp.asarray(tv),
            jnp.asarray(self._dt), self._cond, self._plans,
            jnp.asarray(thr), jnp.asarray(mask))
        self.stats.decode_s += time.time() - t_wall
        if info is not None:
            rep = np.asarray(info["replanned"])[:, active]
            n_replan = int(rep.sum())
            self.stats.plan_replans += n_replan
            self.stats.plan_reuses += nl * len(active) - n_replan
            self.stats.last_retention = float(
                np.min(np.asarray(info["retention"])[:, active]))
        self.stats.slot_steps_active += len(active)
        self.stats.slot_steps_total += self.num_slots
        self.stats.denoise_steps += len(active)
        now = time.time()
        for j in active:
            r = self._slots[j]
            r.steps_done += 1
            r.metrics.decode_tokens += 1
            events.append(StreamEvent(rid=r.rid, kind="step", t=now,
                                      index=r.steps_done - 1))
            if self.cache is not None and r.steps_done < r.params.num_steps:
                nb = self.cache.bucket(float(self._slot_t(j)))
                if nb != self._bucket[j]:
                    # crossing into a new timestep bucket: donate this
                    # slot's current plans if the bucket is unpopulated
                    self._bucket[j] = nb
                    self.cache.put_if_absent(
                        nb, dit.take_slot_plans(self._plans, j))
            if r.steps_done >= r.params.num_steps:
                self._finish(j, events)
        self._sync_cache_stats()
        return events

    def drain(self) -> List[DenoiseRequest]:
        """Run until every submitted request has finished; returns all
        requests in submission order."""
        while self.has_work:
            self.step()
        return list(self._requests)

    def stream(self) -> Iterator[StreamEvent]:
        """Generator draining the scheduler one tick at a time, yielding
        events as they happen (cf. api.Scheduler.stream)."""
        while self.has_work:
            for ev in self.step():
                yield ev
