"""Batched serving engine: request queue -> SLA prefill -> batched decode.

Two scheduling policies behind one `run()` surface (DESIGN.md "Serving
API v2"):

  * "static" — the v1 path: requests are grouped into fixed-size decode
    batches; prefill runs per group, then tokens are decoded in
    lockstep until each request's budget. Slot-level finish masking
    lets short requests exit early (their logits keep computing but
    sampling freezes). Kept as the bit-reproducible baseline the
    continuous scheduler is verified against.
  * "continuous" — a thin compatibility wrapper over
    `repro.serving.api.Scheduler`: every request is submitted to the
    continuous-batching slot pool and `run()` drains it. Per-request
    TTFT/latency (`Request.metrics`) and slot-occupancy counters come
    back on the same `ServeStats`.

Prefill plan reuse (DESIGN.md "Plan lifetime & drift"): with
`plan_reuse="adaptive"` the engine pads every prefill chunk to one
static (batch, length) bucket, plans the per-layer SLA block structure
once on the first chunk, and reuses it across subsequent chunks of the
request stream — re-planning a layer only when the measured plan drift
(1 - retained critical mass) reaches `drift_threshold`. Block-sparsity
structure is dominated by positional/locality patterns, so consecutive
prefill chunks share most of it; the drift metric catches the ones that
don't.

Decode-time SLA (DESIGN.md "Decode-time SLA"): with `decode_sla=True`
(or cfg.sla.decode_mode == "sla") prefill seeds a static-grid
incremental block plan plus the linear branch's running H/Z state, and
every decode step attends only to the live row's critical KV blocks +
an O(1) linear term instead of the full O(S) cache. ServeStats tracks
decode-plan builds (prompt rows), extends (rows appended at block
boundaries), and replans/reuses (drift-gated live-row refreshes, with
per-layer thresholds).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.models.common import logits_from_hidden
from repro.serving.api import (RequestMetrics, SamplingParams, Scheduler,
                               ServeStats, block_bucket,
                               check_serving_family,
                               normalize_drift_threshold,
                               prefill_with_plan_reuse)

__all__ = ["Request", "ServeStats", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    tokens_out: Optional[List[int]] = None
    latency_s: float = 0.0  # = metrics.latency_s (kept for v1 callers)
    metrics: Optional[RequestMetrics] = None


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, batch_size: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 backend: str = "gather", plan_reuse: str = "off",
                 drift_threshold=None, decode_sla: bool = False,
                 scheduler: str = "static",
                 paged: Optional[bool] = None,
                 pool_pages: Optional[int] = None,
                 prefill_chunk_blocks: Optional[int] = None):
        from repro.core import backends as backend_registry
        backend = backend_registry.resolve(backend)  # fail loudly, early
        cfg.sla.validate()
        if plan_reuse not in ("off", "adaptive"):
            raise ValueError(
                f"unknown plan_reuse mode {plan_reuse!r}; expected "
                "'off' or 'adaptive'")
        if scheduler not in ("static", "continuous"):
            raise ValueError(
                f"unknown scheduler {scheduler!r}; expected 'static' or "
                "'continuous'")
        if paged is None:
            paged = cfg.sla.paged
        if paged and scheduler != "continuous":
            raise ValueError(
                "paged KV caching requires the continuous-batching "
                "scheduler (the static engine decodes group-local "
                "caches; there is no shared pool to page)")
        if prefill_chunk_blocks is None:
            prefill_chunk_blocks = cfg.sla.prefill_chunk_blocks
        if prefill_chunk_blocks is not None and scheduler != "continuous":
            raise ValueError(
                "chunked admission prefill (prefill_chunk_blocks) "
                "requires the continuous-batching scheduler — the "
                "static engine has no decode to interleave chunks with")
        self.paged = paged
        self.cfg = cfg
        self.params = params
        self.mdl = registry.get_model(cfg)
        self.batch_size = batch_size
        self.greedy = greedy
        self.backend = backend
        self.plan_reuse = plan_reuse
        self.scheduler = scheduler
        self.decode_sla = decode_sla or cfg.sla.decode_mode == "sla"
        self.drift_threshold = normalize_drift_threshold(cfg,
                                                         drift_threshold)
        if self.decode_sla:
            # decode-SLA block grids are static: the cache length must be
            # a whole number of SLA blocks (DESIGN.md "Decode-time SLA")
            max_len = block_bucket(max_len, cfg.sla.block_q)
        self.max_len = max_len
        self.stats = ServeStats()
        self._plans = None
        self._bucket: Optional[int] = None  # static prefill (len) bucket
        check_serving_family(cfg, self.mdl, plan_reuse, self.decode_sla,
                             continuous=scheduler == "continuous")

        if scheduler == "continuous":
            # run() becomes a thin wrapper: one slot per static-batch
            # lane, same bucket policy, SAME ServeStats object so v1
            # callers read the counters they always did
            self._sched = Scheduler(
                cfg, params, num_slots=batch_size, max_len=max_len,
                backend=backend, decode_sla=self.decode_sla,
                plan_reuse=plan_reuse, drift_threshold=drift_threshold,
                paged=paged, pool_pages=pool_pages,
                prefill_chunk_blocks=prefill_chunk_blocks)
            self._sched.stats = self.stats
            return

        mdl, backend_, thr = self.mdl, backend, self.drift_threshold
        # decode-SLA prefills seed the decode state against the final
        # cache length; plain prefills are grown by _grow_cache instead
        dml = self.max_len if self.decode_sla else None
        dkw = {"decode_max_len": dml} if dml is not None else {}

        @jax.jit
        def _prefill(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               **dkw)

        @jax.jit
        def _prefill_plan(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               return_plans=True, **dkw)

        @jax.jit
        def _prefill_reuse(params, tokens, plans):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               plans=plans, drift_threshold=thr,
                               return_plans=True, **dkw)

        if self.decode_sla:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache,
                                       backend=backend_,
                                       drift_threshold=thr)
        else:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache)

        _decode = jax.jit(_one)
        max_len_cap = self.max_len

        # rolled decode (ISSUE 6): a traced-length fori_loop (lowered
        # to while_loop) greedy-decodes n steps in one dispatch — the
        # compiled graph is horizon-independent, so every segment
        # length reuses the single compilation
        @jax.jit
        def _decode_loop(params, token, cache, nsteps):
            buf = jnp.zeros((max_len_cap, token.shape[0]), jnp.int32)

            def body(i, carry):
                token, cache, buf = carry
                logits, cache = _one(params, token, cache)
                token = jnp.argmax(logits, -1).astype(jnp.int32)
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, token[None], i, axis=0)
                return token, cache, buf

            return jax.lax.fori_loop(0, nsteps, body, (token, cache, buf))

        self._prefill = _prefill
        self._prefill_plan = _prefill_plan
        self._prefill_reuse = _prefill_reuse
        self._decode = _decode
        self._decode_loop = _decode_loop

    # cache leaves _grow_cache knows how to handle: "k"/"v" are the
    # (L, B, H, S, D) KV slabs padded along their sequence axis; the
    # rest pass through untouched. Keyed by NAME, not rank — a rank
    # test ("leaf.ndim == 5") would silently zero-pad any future
    # rank-5 leaf as if it were KV (or skip a reshaped KV leaf).
    _GROW_KV_KEYS = ("k", "v")
    _GROW_PASS_KEYS = ("pos", "sla")

    def _grow_cache(self, cache):
        """Pad the prefill cache out to max_len decode slots."""
        grown = {}
        for key, leaf in cache.items():
            if key in self._GROW_KV_KEYS:
                extra = self.max_len - leaf.shape[3]
                if extra > 0:
                    pad = [(0, 0)] * 3 + [(0, extra), (0, 0)]
                    leaf = jnp.pad(leaf, pad)
                grown[key] = leaf
            elif key in self._GROW_PASS_KEYS:
                grown[key] = leaf
            else:
                raise ValueError(
                    f"_grow_cache: unknown cache leaf {key!r} — add it "
                    f"to _GROW_KV_KEYS (sequence-padded KV) or "
                    f"_GROW_PASS_KEYS (passed through) so it cannot be "
                    f"silently mis-padded")
        return grown

    def _prefill_bucket(self, requests: List[Request]) -> int:
        """Static prefill length shared by every chunk (plan-reuse mode):
        the longest prompt rounded up to a whole number of SLA query
        blocks, so reused plans always see the same block grid."""
        plen = max(len(r.prompt) for r in requests)
        return block_bucket(plen, self.cfg.sla.block_q)

    def run(self, requests: List[Request]) -> List[Request]:
        # submission time is run() entry (unless the caller pre-stamped
        # real arrival times) — groups after the first then report their
        # wait behind earlier groups as queue time, symmetric with the
        # continuous scheduler's submit()-time stamp
        t_submit = time.time()
        for r in requests:
            if r.metrics is None:
                r.metrics = RequestMetrics(submit_t=t_submit)
        if self.scheduler == "continuous":
            return self._run_continuous(requests)
        if self.plan_reuse != "off" or self.decode_sla:
            # both plan reuse and decode-SLA need block-aligned static
            # prefill shapes (reused plans / the decode block grid)
            bucket = self._prefill_bucket(requests)
            if self._bucket is None or bucket > self._bucket:
                # a longer prompt grows the bucket; cached plans are for
                # the old block grid, so they die with it
                self._plans = None
                self._bucket = bucket
            budget = max(r.max_new_tokens for r in requests)
            if self._bucket + budget > self.max_len:
                # past this point decode would write beyond the cache and
                # dynamic_update_slice would clamp onto the last slot —
                # silent token corruption, so fail loudly instead
                raise ValueError(
                    f"max_len={self.max_len} cannot hold the prefill "
                    f"bucket ({self._bucket} tokens — longest prompt "
                    f"rounded up to sla.block_q={self.cfg.sla.block_q}) "
                    f"plus {budget} decode tokens; raise max_len to >= "
                    f"{self._bucket + budget}")
        done: List[Request] = []
        for i in range(0, len(requests), self.batch_size):
            group = requests[i: i + self.batch_size]
            done.extend(self._run_group(group))
        return done

    def _run_continuous(self, requests: List[Request]) -> List[Request]:
        """v1 compatibility wrapper over the continuous scheduler."""
        rid_map = {}
        for r in requests:
            sid = self._sched.submit(
                r.prompt, SamplingParams(max_new_tokens=r.max_new_tokens))
            rid_map[sid] = r
        for sr in self._sched.drain():
            if sr.rid not in rid_map:
                continue  # finished in an earlier run() call
            r = rid_map[sr.rid]
            # keep the caller's (or run()'s) submission stamp — it
            # predates the scheduler's own submit() stamp
            sr.metrics.submit_t = r.metrics.submit_t
            r.tokens_out = list(sr.tokens_out)
            r.metrics = sr.metrics
            r.latency_s = sr.metrics.latency_s
        return requests

    def _run_prefill(self, toks: jnp.ndarray):
        """Prefill one chunk, routing through the plan-reuse path when
        enabled. Returns last_hidden, cache."""
        if self.decode_sla:
            # each layer's decode plan is seeded (all prompt rows) here
            self.stats.decode_plan_builds += self.cfg.num_layers
        if self.plan_reuse == "off":
            return self._prefill(self.params, toks)
        last_hidden, cache, self._plans = prefill_with_plan_reuse(
            self._prefill_plan, self._prefill_reuse, self.params, toks,
            self._plans, self.stats, self.cfg.num_layers)
        return last_hidden, cache

    def _run_group(self, group: List[Request]) -> List[Request]:
        b = len(group)
        if self.plan_reuse == "off" and not self.decode_sla:
            bpad, plen = b, max(len(r.prompt) for r in group)
        else:
            # one static (batch, len) bucket so every chunk shares the
            # reused plans' shapes; surplus rows decode into the void
            bpad, plen = self.batch_size, self._bucket
        toks = np.zeros((bpad, plen), np.int32)
        for j, r in enumerate(group):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        for j in range(b, bpad):
            # surplus rows cycle real prompts: all-zero rows would feed
            # the min-over-batch drift metric garbage (q, k) and force
            # spurious re-plans on every partial chunk
            toks[j] = toks[j % b]
        budget = max(r.max_new_tokens for r in group)
        t0 = time.time()
        for r in group:
            r.metrics.admit_t = t0  # submit_t was stamped in run()
        self.stats.admissions += b
        last_hidden, cache = self._run_prefill(jnp.asarray(toks))
        if not self.decode_sla:
            # decode-SLA prefill already sized the cache (and its block
            # state) for max_len; only plain caches need growing
            cache = self._grow_cache(cache)
        jax.block_until_ready(last_hidden)
        self.stats.prefill_tokens += b * plen
        self.stats.prefill_s += time.time() - t0

        # first token from the last hidden state
        logits = logits_from_hidden(self.params, last_hidden)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [[] for _ in group]
        alive = np.array([r.max_new_tokens for r in group])
        t0 = time.time()
        stream = [np.asarray(token)]  # token produced at step i
        now = time.time()  # np.asarray synced the first token
        for j, r in enumerate(group):
            r.metrics.first_token_t = now
        # rolled decode (ISSUE 6): one traced-length loop dispatch per
        # SEGMENT between distinct request finish steps — finish_t
        # stays per-request, decode_step traces exactly once, and the
        # host loop runs len(distinct budgets) times instead of budget
        done = 0
        for fin in sorted(set(int(a) for a in alive)):
            n = fin - 1 - done
            if n > 0:
                token, cache, buf = self._decode_loop(
                    self.params, token, cache, jnp.int32(n))
                stream.extend(np.asarray(buf)[:n])  # syncs the segment
                done = fin - 1
            now = time.time()
            for j, r in enumerate(group):
                if alive[j] == fin:
                    r.metrics.finish_t = now
        for step in range(budget):
            for j in range(b):
                if step < alive[j]:
                    outs[j].append(int(stream[step][j]))
        # per-step accounting, replayed from the static schedule: each
        # decode produces the step token — useful for exactly the
        # requests that consume it (the same accounting as the
        # scheduler, where a slot decodes budget-1 useful steps per
        # request); finished requests, surplus pad rows, and lanes a
        # partial group never filled all burn slot-steps over the
        # CONFIGURED pool (batch_size lanes) until the group drains
        for step in range(1, budget):
            active = int((step < alive).sum())
            self.stats.decode_tokens += active
            self.stats.slot_steps_active += active
            self.stats.slot_steps_total += self.batch_size
        jax.block_until_ready(token)
        self.stats.decode_s += time.time() - t0
        if self.decode_sla:
            # harvest this group's decode-plan counters (cumulative in
            # the group-local cache since prefill zeroed them)
            stc = cache["sla"]
            self.stats.decode_plan_extends += int(
                np.sum(np.asarray(stc["extends"])))
            self.stats.decode_plan_replans += int(
                np.sum(np.asarray(stc["replans"])))
            self.stats.decode_plan_reuses += int(
                np.sum(np.asarray(stc["reuses"])))
            self.stats.decode_last_retention = float(
                np.min(np.asarray(stc["retention"])))
        for j, r in enumerate(group):
            r.tokens_out = outs[j][: r.max_new_tokens]
            r.metrics.decode_tokens = len(r.tokens_out)
            r.latency_s = r.metrics.latency_s
        return group
