"""Batched serving engine: request queue -> SLA prefill -> batched decode.

Static-batch continuous serving: requests are grouped into fixed-size
decode batches; prefill runs per group (SLA attention — the paper's
kernel accelerates exactly this long-context prefill), then tokens are
decoded until each request's budget. Slot-level finish masking lets short
requests exit early (their logits keep computing but sampling freezes —
the static-shape analogue of continuous batching).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    tokens_out: Optional[List[int]] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, batch_size: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 backend: str = "gather"):
        self.cfg = cfg
        self.params = params
        self.mdl = registry.get_model(cfg)
        self.batch_size = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.backend = backend
        self.stats = ServeStats()

        mdl, backend_ = self.mdl, backend

        @jax.jit
        def _prefill(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend_)

        @jax.jit
        def _decode(params, token, cache):
            return mdl.decode_step(params, cfg, token, cache)

        self._prefill = _prefill
        self._decode = _decode

    def _grow_cache(self, cache):
        """Pad the prefill cache out to max_len decode slots."""
        def pad(path_unused, leaf):
            if hasattr(leaf, "ndim") and leaf.ndim == 5:
                # (L, B, H, S, D) kv cache
                extra = self.max_len - leaf.shape[3]
                if extra > 0:
                    pad_blk = jnp.zeros(leaf.shape[:3] + (extra,)
                                        + leaf.shape[4:], leaf.dtype)
                    return jnp.concatenate([leaf, pad_blk], axis=3)
            return leaf
        return jax.tree_util.tree_map_with_path(pad, cache)

    def run(self, requests: List[Request]) -> List[Request]:
        done: List[Request] = []
        for i in range(0, len(requests), self.batch_size):
            group = requests[i: i + self.batch_size]
            done.extend(self._run_group(group))
        return done

    def _run_group(self, group: List[Request]) -> List[Request]:
        b = len(group)
        plen = max(len(r.prompt) for r in group)
        budget = max(r.max_new_tokens for r in group)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(group):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.time()
        last_hidden, cache = self._prefill(self.params, jnp.asarray(toks))
        cache = self._grow_cache(cache)
        jax.block_until_ready(last_hidden)
        self.stats.prefill_tokens += b * plen
        self.stats.prefill_s += time.time() - t0

        # first token from the last hidden state
        table = self.params.get("unembed", self.params["embed"])
        logits = jnp.einsum("bd,vd->bv", last_hidden.astype(jnp.float32),
                            table.astype(jnp.float32))
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [[] for _ in group]
        alive = np.array([r.max_new_tokens for r in group])
        t0 = time.time()
        for step in range(budget):
            for j in range(b):
                if step < alive[j]:
                    outs[j].append(int(token[j]))
            if (step + 1 >= alive).all():
                break
            logits, cache = self._decode(self.params, token, cache)
            token = jnp.argmax(logits, -1).astype(jnp.int32)
            self.stats.decode_tokens += int((step < alive).sum())
        jax.block_until_ready(token)
        self.stats.decode_s += time.time() - t0
        for j, r in enumerate(group):
            r.tokens_out = outs[j][: r.max_new_tokens]
            r.latency_s = self.stats.prefill_s + self.stats.decode_s
        return group
