"""Serving API v2: continuous-batching scheduler with streaming requests.

The v1 surface (`ServingEngine.run(List[Request])`) decodes fixed groups
in lockstep: finished slots keep computing frozen logits, queued
requests wait for the whole group, and per-request latency collapses to
cumulative engine time. This module is the request-level redesign
(DESIGN.md "Serving API v2"):

  * `SamplingParams` / `RequestState` / `StreamEvent` / `RequestMetrics`
    — the typed request surface (greedy or temperature sampling, stop
    tokens, QUEUED -> PREFILLING -> DECODING -> FINISHED lifecycle, and
    real per-request TTFT / queue-time / latency).
  * `Scheduler` — a fixed pool of decode slots over ONE live per-slot
    cache (`make_cache(per_slot=True)`). `submit()` enqueues;  `step()`
    admits queued requests into free slots (each prefilled in its own
    block-aligned `(1, bucket)` call, then scattered into the slot via
    `insert_slot`: KV rows, decode-SLA incremental plan rows, H/Z
    linear state, pooled q/k features) and runs one batched decode step
    with per-slot positions; `drain()` runs to completion; `stream()`
    yields `StreamEvent`s as they happen.

Admission happens at SLA block boundaries by construction: the prefill
bucket is a whole number of `block_q` blocks, so an admitted slot's
position starts block-aligned and the static-grid invariants of
`plan_extend` (rows appended monotonically, each exactly once) hold per
slot. Cross-request plan reuse (`plan_reuse="adaptive"`) and decode-time
SLA (`decode_sla=True`) both ride along — this is where they pay off
hardest, because slots turn over continuously instead of waiting for
the slowest group member.

Chunked admission prefill (DESIGN.md "Chunked admission prefill"): with
`prefill_chunk_blocks` set, a paged admission that misses the
full-prompt snapshot becomes a multi-tick `_PrefillJob` — the request
owns its slot in PREFILLING state (masked out of decode dispatch like a
finished-budget slot) and advances one block-aligned chunk per tick
through `transformer.prefill_chunk`, so other slots keep emitting
tokens while a long prompt prefills. Completion runs blocking
admission's tail verbatim (finalize -> page-table scatter -> snapshot),
which keeps chunked tokens and cache leaves bitwise equal to blocking's.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import math
import time
from typing import Deque, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.models.common import logits_from_hidden


# ---------------------------------------------------------------------------
# typed request surface
# ---------------------------------------------------------------------------
class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling policy.

    temperature == 0.0 is greedy argmax (bit-reproducible against the
    static-batch engine); > 0 samples from softmax(logits / T) with a
    per-request deterministic host RNG (`seed`). Generation stops at
    `max_new_tokens` or on the first token in `stop_tokens` (the stop
    token itself is kept, matching the budget-truncation semantics of
    the v1 engine)."""

    max_new_tokens: int = 16
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    seed: int = 0

    def validate(self) -> "SamplingParams":
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1 (got {self.max_new_tokens})")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0 (got {self.temperature})")
        return self


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock request accounting (absolute times from time.time()).

    queue_s / ttft_s / latency_s are derived and measured per request —
    the v1 engine assigned every request the engine's cumulative
    prefill+decode seconds instead. Each derived metric is None until
    the event it measures has actually happened (an unfinished request
    has no latency, a never-prefilled one no TTFT); clamping them to
    0.0 silently reported in-flight requests as instantaneous."""

    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    decode_tokens: int = 0  # total generated tokens (incl. the prefill one)

    @property
    def queue_s(self) -> Optional[float]:
        if self.admit_t == 0.0:
            return None
        return self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t == 0.0:
            return None
        return self.first_token_t - self.submit_t

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_t == 0.0:
            return None
        return self.finish_t - self.submit_t


@dataclasses.dataclass
class StreamEvent:
    """One streaming output event.

    kind: "start" (request admitted to a slot), "token" (one generated
    token; `token`/`index` set), "finish" (request complete)."""

    rid: int
    kind: str
    t: float
    token: Optional[int] = None
    index: Optional[int] = None


@dataclasses.dataclass
class ServedRequest:
    """A request inside the scheduler (the v2 analogue of engine.Request)."""

    rid: int
    prompt: np.ndarray
    sampling: SamplingParams
    state: RequestState = RequestState.QUEUED
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = dataclasses.field(
        default_factory=RequestMetrics)
    slot: Optional[int] = None


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # plan-reuse accounting (layer granularity; DESIGN.md "Plan
    # lifetime & drift"): builds = first-chunk plans, replans =
    # drift-triggered rebuilds, reuses = layers served by a stale plan.
    plan_builds: int = 0
    plan_replans: int = 0
    plan_reuses: int = 0
    last_retention: float = 1.0
    # decode-plan accounting (layer granularity; DESIGN.md "Decode-time
    # SLA"): builds = decode plans seeded at prefill (one per layer per
    # chunk, covering all prompt rows), extends = completed rows
    # appended via plan_extend, replans = live rows re-classified at a
    # block boundary (drift over that layer's threshold), reuses = live
    # rows inheriting the previous row's structure.
    decode_plan_builds: int = 0
    decode_plan_extends: int = 0
    decode_plan_replans: int = 0
    decode_plan_reuses: int = 0
    decode_last_retention: float = 1.0
    # continuous-batching accounting (DESIGN.md "Serving API v2"):
    # admissions = requests scattered into a slot, slot_steps_active /
    # slot_steps_total = decode-slot occupancy (active slots vs pool
    # size, summed over decode steps; the static engine counts its
    # lockstep groups the same way, so the two paths are comparable).
    admissions: int = 0
    slot_steps_active: int = 0
    slot_steps_total: int = 0
    # paged-KV accounting (DESIGN.md "Paged KV & prefix caching"):
    # pages_in_use / pages_peak = referenced physical pages (current /
    # high-water), page_allocs = pool allocations, prefix_hits/misses =
    # per-page prefix-cache lookups at admission, prefix_full_hits =
    # whole-prompt snapshot hits (prefill compute skipped entirely),
    # cow_copies = copy-on-write duplications of a shared page.
    pages_in_use: int = 0
    pages_peak: int = 0
    page_allocs: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_full_hits: int = 0
    cow_copies: int = 0
    # chunked-admission accounting (DESIGN.md "Chunked admission
    # prefill"): chunked_admissions = requests admitted through the
    # multi-tick chunk machine, prefill_chunks = chunk dispatches that
    # actually ran (prefix-resumed chunks are skipped and never
    # counted), max_decode_gap_s = largest wall-clock gap between
    # consecutive token emissions — the decode-stall metric chunked
    # admission exists to shrink.
    chunked_admissions: int = 0
    prefill_chunks: int = 0
    max_decode_gap_s: float = 0.0
    # streaming-DiT accounting (DESIGN.md "Streaming DiT service"):
    # denoise_steps = per-request Euler steps executed (the DiT analogue
    # of decode_tokens); plan_cache_* mirror the cross-request
    # PlanCache's own counters at the scheduler level — hits/misses are
    # whole-bucket admission lookups, invalidations are cached layers
    # whose drift validation re-planned, evictions are LRU drops.
    denoise_steps: int = 0
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_cache_invalidations: int = 0
    plan_cache_evictions: int = 0

    def occupancy(self) -> float:
        """Decode-slot utilization in [0, 1]."""
        return self.slot_steps_active / max(1, self.slot_steps_total)


# ---------------------------------------------------------------------------
# shared serving helpers (engine + scheduler)
# ---------------------------------------------------------------------------
def block_bucket(length: int, block: int) -> int:
    """`length` rounded up to a whole number of SLA query blocks."""
    block = max(block, 1)
    return max(block, ((length + block - 1) // block) * block)


def normalize_drift_threshold(cfg: ArchConfig, drift_threshold):
    """CLI/user drift threshold -> scalar or per-layer tuple."""
    if drift_threshold is None:
        return cfg.sla.plan_drift_threshold
    if isinstance(drift_threshold, (tuple, list)):
        return tuple(float(t) for t in drift_threshold)
    return float(drift_threshold)


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (the serving-metrics convention used by
    both `launch/serve.py` and `benchmarks/fig_serving.py`): the
    smallest element with at least ceil(p * n) of the values at or
    below it, i.e. sorted(xs)[ceil(p * n) - 1]. The previous
    `int(p * n)` index sat one element HIGH of the nearest rank
    whenever p * n was not integral (p95 of 20 samples read xs[19],
    the max, instead of xs[18]) and only p == 1.0 was saved by the
    min clamp; tests/test_serving.py pins the exact ranks."""
    xs = sorted(xs)
    if not xs:
        raise ValueError("percentile() of an empty sequence")
    rank = min(len(xs), max(1, math.ceil(p * len(xs))))
    return xs[rank - 1]


def stats_json_payload(mode: str, stats, requests=()) -> dict:
    """JSON-ready dump of a stats dataclass + per-request metrics.

    Serves `launch/serve.py --stats-json` for every serving mode —
    `stats` is any stats dataclass (ServeStats, disagg.DisaggStats);
    `requests` any iterable of objects carrying `.rid`, `.state`, and
    `.metrics` (ServedRequest, engine.Request, DenoiseRequest). Derived
    metrics stay None (JSON null) for in-flight requests — the PR 7
    convention: an unfinished request has no latency, a never-admitted
    one no queue time; clamping to 0.0 would report them as
    instantaneous."""
    rows = []
    for r in requests:
        m = getattr(r, "metrics", None)
        state = getattr(r, "state", None)
        if state is None and m is not None:
            # v1 engine.Request carries no state enum; a finished
            # timestamp is the authoritative signal
            state = "finished" if m.finish_t else "in_flight"
        row = {"rid": getattr(r, "rid", None),
               "state": getattr(state, "value", state)}
        if m is not None:
            row.update(queue_s=m.queue_s, ttft_s=m.ttft_s,
                       latency_s=m.latency_s,
                       decode_tokens=m.decode_tokens)
        rows.append(row)
    return {"mode": mode, "stats": dataclasses.asdict(stats),
            "requests": rows}


def prefill_with_plan_reuse(prefill_plan, prefill_reuse, params, toks,
                            plans, stats: "ServeStats", num_layers: int):
    """Shared plan-reuse prefill step (DESIGN.md "Plan lifetime &
    drift"): build the per-layer plan stack on the first chunk, reuse
    it with drift-gated refresh afterwards, and account builds /
    replans / reuses / retention on `stats`. Returns
    (last_hidden, cache, plans)."""
    if plans is None:
        last_hidden, cache, plans = prefill_plan(params, toks)
        stats.plan_builds += num_layers
    else:
        last_hidden, cache, plans, info = prefill_reuse(params, toks,
                                                        plans)
        replans = int(np.sum(np.asarray(info["replanned"])))
        stats.plan_replans += replans
        stats.plan_reuses += num_layers - replans
        stats.last_retention = float(
            np.min(np.asarray(info["retention"])))
    return last_hidden, cache, plans


def check_serving_family(cfg: ArchConfig, mdl, plan_reuse: str,
                         decode_sla: bool, continuous: bool = False):
    """Loudly reject model families without the capabilities a serving
    mode needs (plan-aware prefill, decode-SLA prefill, slot caches)."""
    import inspect

    prefill_fn = getattr(mdl, "prefill", None)
    if plan_reuse != "off":
        if (prefill_fn is None
                or "plans" not in inspect.signature(prefill_fn).parameters):
            raise ValueError(
                f"plan_reuse={plan_reuse!r} requires a model family with "
                f"plan-aware prefill (got family {cfg.family!r})")
    if decode_sla:
        if (prefill_fn is None or "decode_max_len" not in
                inspect.signature(prefill_fn).parameters):
            raise ValueError(
                f"decode_sla requires a model family with decode-SLA "
                f"prefill (got family {cfg.family!r})")
    if continuous and getattr(mdl, "insert_slot", None) is None:
        raise ValueError(
            f"the continuous-batching scheduler requires a model family "
            f"with per-slot caches (make_cache(per_slot=True) + "
            f"insert_slot); family {cfg.family!r} has neither")


# ---------------------------------------------------------------------------
# the prefill engine (worker-reusable prefill compute)
# ---------------------------------------------------------------------------
class PrefillEngine:
    """The worker-reusable prefill half of the scheduler (DESIGN.md
    "Disaggregated serving"): the jitted (1, bucket) prefill closures
    (fresh / plan-build / drift-gated reuse), the chunked-prefill
    chunk / finalize dispatches, the zero-carry prototypes per bucket,
    and the LRU of chunk-boundary carry snapshots.

    The Scheduler owns one for in-process admissions; a disaggregated
    prefill worker pool (serving/disagg.py) shares ONE across workers,
    so jit caches and carry snapshots amortize across the pool and a
    requeued request re-prefills bitwise-identically on any worker —
    prefill here is a pure function of (padded prompt bytes, bucket)
    whenever plan_reuse is off."""

    def __init__(self, cfg: ArchConfig, params, mdl, *, backend: str,
                 compute_dtype, decode_sla: bool, max_len: int,
                 drift_threshold, plan_reuse: str = "off",
                 chunk_tokens: int = 0):
        self.cfg = cfg
        self.params = params
        self.mdl = mdl
        self.compute_dtype = compute_dtype
        self.decode_sla = decode_sla
        self.max_len = max_len
        self.plan_reuse = plan_reuse
        self.chunk_tokens = chunk_tokens
        dkw = {"decode_max_len": max_len} if decode_sla else {}
        thr = drift_threshold

        @jax.jit
        def _prefill(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend,
                               compute_dtype=compute_dtype, **dkw)

        @jax.jit
        def _prefill_plan(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend,
                               compute_dtype=compute_dtype,
                               return_plans=True, **dkw)

        @jax.jit
        def _prefill_reuse(params, tokens, plans):
            return mdl.prefill(params, cfg, tokens, backend=backend,
                               compute_dtype=compute_dtype, plans=plans,
                               drift_threshold=thr, return_plans=True,
                               **dkw)

        self._prefill = _prefill
        self._prefill_plan = _prefill_plan
        self._prefill_reuse = _prefill_reuse

        if chunk_tokens:
            dmx = max_len if decode_sla else None

            # `start` is a TRACED int32, so one compiled graph covers
            # every chunk index of a given (bucket, chunk) shape pair
            @jax.jit
            def _chunk(params, tokens, carry, start):
                return mdl.prefill_chunk(params, cfg, tokens, carry,
                                         start,
                                         compute_dtype=compute_dtype,
                                         backend=backend,
                                         decode_max_len=dmx)

            @jax.jit
            def _finalize(carry):
                return mdl.finalize_chunked_prefill(cfg, carry,
                                                    decode_max_len=dmx)

            self._chunk_jit = _chunk
            self._finalize_jit = _finalize

        self._carry_protos: dict = {}
        self._carry_snaps = collections.OrderedDict()
        self._carry_cap = 16

    def run(self, toks: jnp.ndarray, plans, stats: ServeStats,
            num_layers: int):
        """(1, bucket) prefill. With plan_reuse off, `plans` passes
        through untouched; otherwise the shared drift-gated reuse path
        runs and the updated plan stack comes back. Returns
        (last_hidden, cache, plans)."""
        if self.plan_reuse == "off":
            last_hidden, cache = self._prefill(self.params, toks)
            return last_hidden, cache, plans
        return prefill_with_plan_reuse(
            self._prefill_plan, self._prefill_reuse, self.params, toks,
            plans, stats, num_layers)

    def logits(self, last_hidden) -> np.ndarray:
        """(1, vocab) first-token logits row, on the host."""
        return np.asarray(logits_from_hidden(self.params, last_hidden))

    # -- chunked prefill (DESIGN.md "Chunked admission prefill") -----------
    def chunk(self, toks_span: jnp.ndarray, carry, start):
        """Run ONE prefill chunk; returns (carry, last_hidden)."""
        return self._chunk_jit(self.params, toks_span, carry, start)

    def finalize(self, carry):
        """Finalize a completed carry into blocking prefill's cache."""
        return self._finalize_jit(carry)

    def carry_proto(self, bucket: int):
        """Zero chunked-prefill carry for `bucket` (cached; the arrays
        are immutable, so every job can start from the same one)."""
        proto = self._carry_protos.get(bucket)
        if proto is None:
            proto = self.mdl.make_prefill_carry(
                self.cfg, bucket, compute_dtype=self.compute_dtype,
                decode_sla=self.decode_sla)
            self._carry_protos[bucket] = proto
        return proto

    def carry_get(self, key):
        """LRU lookup of a chunk-boundary carry snapshot (touches)."""
        snap = self._carry_snaps.get(key)
        if snap is not None:
            self._carry_snaps.move_to_end(key)
        return snap

    def carry_put(self, key, carry):
        self._carry_snaps[key] = carry
        self._carry_snaps.move_to_end(key)
        while len(self._carry_snaps) > self._carry_cap:
            self._carry_snaps.popitem(last=False)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _PrefillJob:
    """One in-flight chunked admission (DESIGN.md "Chunked admission
    prefill"). The request owns `slot` in PREFILLING state while its
    prompt advances one chunk per tick; `carry` is the model-side
    chunked-prefill carry (KV written so far, pooled block features,
    decode-grid rows), `pids` the pool refs claimed page by page as
    chunks land (handed over to `_set_slot_pages` at completion), and
    `dispatched` the prompt tokens that actually ran (prefix-resumed
    chunks are skipped)."""

    r: ServedRequest
    slot: int
    toks: np.ndarray        # (1, bucket) left-padded prompt
    keys: List[bytes]       # page intern keys for every prompt page
    bucket: int             # admission-time bucket (survives later growth)
    carry: object
    num_chunks: int
    t0: float               # admission wall-clock (metrics.admit_t)
    next_chunk: int = 0
    dispatched: int = 0
    pids: List[int] = dataclasses.field(default_factory=list)
    last_hidden: object = None


class Scheduler:
    """Continuous-batching scheduler over a fixed pool of decode slots.

    One live per-slot cache holds `num_slots` independent sequences
    (per-slot positions, per-slot decode-SLA plan/state). Slots turn
    over continuously: the moment a request finishes, the next queued
    request is prefilled in its own `(1, bucket)` call and scattered
    into the freed slot — no request ever waits for a group.

    Greedy tokens are bit-identical to the static-batch engine's when
    the prefill bucket and slot count match (per-request numerics
    depend only on (prompt, bucket, batch width); verified by
    tests/test_serving.py).
    """

    def __init__(self, cfg: ArchConfig, params, num_slots: int = 4,
                 max_len: int = 512, backend: str = "gather",
                 decode_sla: Optional[bool] = None,
                 plan_reuse: str = "off", drift_threshold=None,
                 prefill_bucket: Optional[int] = None,
                 compute_dtype=jnp.bfloat16,
                 paged: Optional[bool] = None,
                 pool_pages: Optional[int] = None,
                 prefill_chunk_blocks: Optional[int] = None):
        from repro.core import backends as backend_registry

        backend = backend_registry.resolve(backend)
        cfg.sla.validate()
        if plan_reuse not in ("off", "adaptive"):
            raise ValueError(
                f"unknown plan_reuse mode {plan_reuse!r}; expected "
                "'off' or 'adaptive'")
        if decode_sla is None:
            decode_sla = cfg.sla.decode_mode == "sla"
        if paged is None:
            paged = cfg.sla.paged
        if paged and plan_reuse == "adaptive":
            # prefix pages are interned by prompt BYTES; adaptive plan
            # reuse makes a prefill depend on every earlier request's
            # plans, so identical bytes would no longer mean identical
            # page contents
            raise ValueError(
                "paged=True is incompatible with plan_reuse='adaptive': "
                "cross-request plan state breaks content-keyed prefix "
                "page interning (use plan_reuse='off')")
        if paged and cfg.sla.block_q != cfg.sla.block_kv:
            raise ValueError(
                f"paged KV pages are block_kv-sized and admission is "
                f"block_q-aligned; the grids must match (got block_q="
                f"{cfg.sla.block_q}, block_kv={cfg.sla.block_kv})")
        if prefill_chunk_blocks is None:
            prefill_chunk_blocks = cfg.sla.prefill_chunk_blocks
        if prefill_chunk_blocks is not None:
            if prefill_chunk_blocks < 1:
                raise ValueError(
                    f"prefill_chunk_blocks must be >= 1 (got "
                    f"{prefill_chunk_blocks})")
            if not paged:
                raise ValueError(
                    "prefill_chunk_blocks requires paged=True: chunked "
                    "admission lands its pages through the page-table "
                    "scatter and the prefix page cache")
        self.cfg = cfg
        self.params = params
        self.mdl = registry.get_model(cfg)
        check_serving_family(cfg, self.mdl, plan_reuse, decode_sla,
                             continuous=True)
        if prefill_chunk_blocks is not None:
            chk = getattr(self.mdl, "check_chunked_prefill", None)
            if chk is None:
                raise ValueError(
                    f"prefill_chunk_blocks requires a model family with "
                    f"chunked prefill (prefill_chunk / "
                    f"finalize_chunked_prefill); family {cfg.family!r} "
                    f"has none")
            chk(cfg, backend)  # loud eligibility (all-SLA, no col-cap, ...)
        self.num_slots = num_slots
        self.backend = backend
        self.decode_sla = decode_sla
        self.paged = paged
        self.plan_reuse = plan_reuse
        self.drift_threshold = normalize_drift_threshold(cfg,
                                                         drift_threshold)
        self.block = max(cfg.sla.block_q, 1)
        # admission at block boundaries: cache length and prefill
        # buckets are whole numbers of blocks, so every slot's position
        # starts block-aligned and plan_extend's static-grid invariants
        # hold per slot (paged mode block-aligns unconditionally — the
        # page pool is carved into block_kv-sized pages)
        self.max_len = block_bucket(max_len, self.block) \
            if (decode_sla or paged) else max_len
        self.compute_dtype = compute_dtype
        self.stats = ServeStats()

        self._queue: Deque[ServedRequest] = collections.deque()
        self._slots: List[Optional[ServedRequest]] = [None] * num_slots
        self._tokens = np.zeros((num_slots,), np.int32)
        self._next_rid = 0
        self._requests: List[ServedRequest] = []  # submission order
        self._bucket = (block_bucket(prefill_bucket, self.block)
                        if prefill_bucket else None)
        self._plans = None  # (1, bucket) plan stack for plan_reuse
        self._stat_base = [None] * num_slots  # decode-SLA counter bases
        # chunked-admission state (DESIGN.md "Chunked admission
        # prefill"): one optional in-flight _PrefillJob per slot; the
        # carry prototypes and boundary-snapshot LRU live on the
        # PrefillEngine below
        self.prefill_chunk_blocks = prefill_chunk_blocks
        self._chunk_tokens = ((prefill_chunk_blocks or 0) * self.block)
        self._job_by_slot: List[Optional[_PrefillJob]] = \
            [None] * num_slots
        self._last_token_t: Optional[float] = None

        if paged:
            from repro.serving.pages import PagePool, ZERO_PAGE

            if getattr(self.mdl, "make_paged_cache", None) is None:
                raise ValueError(
                    f"paged=True requires a model family with a paged "
                    f"decode cache (make_paged_cache / insert_slot_paged)"
                    f"; family {cfg.family!r} has none")
            tn = self.max_len // self.block
            # full per-slot backing + one pinned scratch page per slot +
            # the permanent zero page: exactly enough for zero sharing,
            # so any override below this trades capacity for the prefix
            # cache actually paying off
            default_pool = 1 + num_slots + num_slots * tn
            if pool_pages is None:
                pool_pages = (cfg.sla.page_pool_size
                              if cfg.sla.page_pool_size is not None
                              else default_pool)
            self.pool_pages = pool_pages
            self._pool = PagePool(pool_pages)
            self._zero_page = ZERO_PAGE
            # one pinned scratch page per slot: inactive slots keep
            # stepping through every batched dispatch, and their garbage
            # writes must land somewhere harmless
            self._scratch = [self._pool.alloc() for _ in range(num_slots)]
            self._pt_host = np.zeros((num_slots, tn), np.int32)
            for j in range(num_slots):
                self._pt_host[j, :] = self._scratch[j]
            self._slot_pids: List[List[int]] = [[] for _ in
                                                range(num_slots)]
            self._slot_base = [0] * num_slots  # prefill bucket at admit
            # full-prompt snapshots: (bucket, padded bytes) -> (per-slot
            # prefill state, first-token logits); exact hits skip the
            # prefill dispatch entirely
            self._snapshots = collections.OrderedDict()
            self._snapshot_cap = 32

        # the prefill half lives in a worker-reusable engine (also the
        # unit a disaggregated prefill pool shares; serving/disagg.py)
        self._pf = PrefillEngine(
            cfg, params, self.mdl, backend=backend,
            compute_dtype=compute_dtype, decode_sla=decode_sla,
            max_len=self.max_len, drift_threshold=self.drift_threshold,
            plan_reuse=plan_reuse, chunk_tokens=self._chunk_tokens)

        mdl, backend_, thr = self.mdl, backend, self.drift_threshold

        if decode_sla:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache,
                                       compute_dtype=compute_dtype,
                                       backend=backend_,
                                       drift_threshold=thr)
        else:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache,
                                       compute_dtype=compute_dtype)

        _decode = jax.jit(_one)
        max_len_ = self.max_len

        # rolled multi-step greedy decode (ISSUE 6): nsteps is a traced
        # scalar, so fori_loop lowers to while_loop and ONE trace covers
        # every segment length drain() ever requests
        @jax.jit
        def _decode_multi(params, token, cache, nsteps):
            buf = jnp.zeros((max_len_, token.shape[0]), jnp.int32)

            def body(i, carry):
                token, cache, buf = carry
                logits, cache = _one(params, token, cache)
                token = jnp.argmax(logits, -1).astype(jnp.int32)
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, token[None], i, axis=0)
                return token, cache, buf

            return jax.lax.fori_loop(0, nsteps, body, (token, cache, buf))

        @jax.jit
        def _admit(live, single, slot):
            grow = max_len_ - single["k"].shape[-2]
            if grow > 0:  # dense prefill caches stop at the bucket
                pad = [(0, 0)] * 3 + [(0, grow), (0, 0)]
                single = dict(single, k=jnp.pad(single["k"], pad),
                              v=jnp.pad(single["v"], pad))
            return mdl.insert_slot(live, single, slot)

        # masked decode pair for MIXED drain ticks (some active slots
        # need per-token host control, the rest are pure-greedy): each
        # dispatch computes the full batch but commits cache/token
        # updates only where `mask` is set, so host-controlled slots
        # stay frozen through the greedy roll and vice versa. Per-slot
        # decode is batch-independent, so committed trajectories are
        # bitwise the ones per-token step() would have produced.
        nsl = num_slots

        def _mask_leaves(mask, new, old):
            def sel(n, o):
                if n.ndim == 1 and n.shape[0] == nsl:
                    return jnp.where(mask, n, o)
                if n.ndim >= 2 and n.shape[1] == nsl:
                    m = mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                    return jnp.where(m, n, o)
                if n.ndim >= 2 and n.shape[0] == nsl:
                    m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
                    return jnp.where(m, n, o)
                return n
            return jax.tree_util.tree_map(sel, new, old)

        @jax.jit
        def _decode_mask(params, token, cache, mask):
            logits, new_cache = _one(params, token, cache)
            return logits, _mask_leaves(mask, new_cache, cache)

        @jax.jit
        def _decode_multi_mask(params, token, cache, nsteps, mask):
            buf = jnp.zeros((max_len_, token.shape[0]), jnp.int32)

            def body(i, carry):
                token, cache, buf = carry
                logits, new_cache = _one(params, token, cache)
                cache = _mask_leaves(mask, new_cache, cache)
                new_tok = jnp.argmax(logits, -1).astype(jnp.int32)
                token = jnp.where(mask, new_tok, token)
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, token[None], i, axis=0)
                return token, cache, buf

            return jax.lax.fori_loop(0, nsteps, body, (token, cache, buf))

        self._decode = _decode
        self._decode_multi = _decode_multi
        self._decode_mask = _decode_mask
        self._decode_multi_mask = _decode_multi_mask
        self._admit_jit = _admit
        if paged:
            self._admit_paged_jit = jax.jit(mdl.insert_slot_paged)
            self._admit_state_jit = jax.jit(mdl.insert_slot_state_paged)
            self._copy_page_jit = jax.jit(mdl.copy_page)
            self._live = mdl.make_paged_cache(cfg, num_slots, self.max_len,
                                              pool_pages,
                                              dtype=compute_dtype,
                                              decode_sla=decode_sla)
            self._push_pt()
        else:
            self._live = mdl.make_cache(cfg, num_slots, self.max_len,
                                        dtype=compute_dtype,
                                        decode_sla=decode_sla,
                                        per_slot=True)

    # -- public API --------------------------------------------------------
    def submit(self, prompt, sampling: Optional[SamplingParams] = None
               ) -> int:
        """Enqueue one request; returns its rid. O(1), never blocks."""
        sampling = (sampling or SamplingParams()).validate()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        # capacity check against the SHARED prefill bucket (every
        # admission pads to it, so a long earlier prompt raises the
        # floor for everyone); _admit_next re-checks after any growth
        # that happens while this request is queued
        bucket = max(block_bucket(len(prompt), self.block),
                     self._bucket or 0)
        need = bucket + sampling.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"max_len={self.max_len} cannot hold a {len(prompt)}-token "
                f"prompt (shared prefill bucket {bucket}) plus "
                f"{sampling.max_new_tokens} new tokens; raise max_len "
                f"to >= {need}")
        r = ServedRequest(rid=self._next_rid, prompt=prompt,
                          sampling=sampling)
        r.metrics.submit_t = time.time()
        self._next_rid += 1
        self._queue.append(r)
        self._requests.append(r)
        return r.rid

    def free_slots(self) -> List[int]:
        """Slots with neither a resident request nor an in-flight
        chunked-prefill job — the ones an external admission may take."""
        return [j for j in range(self.num_slots)
                if self._slots[j] is None and self._job_by_slot[j] is None]

    def admit_external(self, r: ServedRequest, slot: int, cache, logits,
                       toks: np.ndarray, bucket: int, *, prefilled: int,
                       plan_built: bool = True,
                       start_emitted: bool = True) -> List[StreamEvent]:
        """Admit an externally-prefilled request into `slot` — the
        disaggregated handoff path (serving/disagg.py): a prefill
        worker ran the (1, bucket) prefill and hands over the bundle
        (the batch-1 prefill cache, the (1, vocab) first-token logits
        row, and the left-padded prompt). Runs blocking admission's
        tail verbatim — paged: page claim -> page-table scatter ->
        full-prompt snapshot; unpaged: `insert_slot` — so the slot's
        cache leaves and every subsequent greedy token are bitwise what
        self-admission of the same prompt at the same bucket would have
        produced. Re-admitting the SAME bundle after a worker loss
        replays the same trajectory (requeue parity).

        `prefilled` is the prompt-token count the prefill actually
        dispatched (0 for a replayed bundle — nothing was recomputed);
        `plan_built` gates decode-plan-build accounting the same way."""
        if self._slots[slot] is not None \
                or self._job_by_slot[slot] is not None:
            raise ValueError(
                f"slot {slot} is occupied; admit_external needs a slot "
                f"from free_slots()")
        r.state = RequestState.PREFILLING
        r.slot = slot
        t0 = time.time()
        if r.metrics.admit_t == 0.0:
            r.metrics.admit_t = t0
        # the handoff bucket raises this scheduler's shared floor
        # exactly like a self-admitted long prompt would
        if self._bucket is None or bucket > self._bucket:
            self._bucket = bucket
            self._plans = None
        if bucket + r.sampling.max_new_tokens > self.max_len:
            r.state = RequestState.QUEUED
            r.slot = None
            raise ValueError(
                f"max_len={self.max_len} cannot hold handoff request "
                f"{r.rid}: bucket {bucket} plus "
                f"{r.sampling.max_new_tokens} new tokens does not fit; "
                f"raise max_len to >= "
                f"{bucket + r.sampling.max_new_tokens}")
        if self.paged:
            padded = np.asarray(toks[0])
            keys = self._page_keys(padded)
            pids = [self._claim_page(key) for key in keys]
            self._live = self._admit_paged_jit(
                self._live, cache, slot, jnp.asarray(pids, jnp.int32))
            self._set_slot_pages(slot, pids, bucket=bucket)
            self._store_snapshot((bucket, padded.tobytes()), cache,
                                 logits)
            self._sync_page_stats()
        else:
            self._live = self._admit_jit(self._live, cache, slot)
        events: List[StreamEvent] = []
        self._finish_admission(r, slot, logits, t0, events,
                               prefilled=prefilled,
                               plan_built=plan_built,
                               start_emitted=start_emitted)
        return events

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self._slots)

    def step(self) -> List[StreamEvent]:
        """Advance in-flight chunked prefills by one chunk and admit
        queued requests into free slots, then run ONE batched decode
        step over the live cache. Returns the events produced."""
        events: List[StreamEvent] = []
        self._tick_admit(events)
        return events + self._decode_tick()

    def _tick_admit(self, events: List[StreamEvent]):
        """Shared tick head: every in-flight chunked-prefill job
        advances ONE chunk (a completion hands its slot to this very
        tick's decode), then queued requests fill free slots."""
        for slot in range(self.num_slots):
            if self._job_by_slot[slot] is not None:
                self._advance_job(slot, events)
        for slot in range(self.num_slots):
            if self._slots[slot] is None and self._queue:
                self._admit_next(slot, events)

    def _decoding(self) -> List[int]:
        """Slots eligible for decode dispatch: occupied AND past their
        prefill. PREFILLING job slots are masked out exactly like
        freed slots — their page-table rows still point at the pinned
        scratch page, so the batched dispatch's garbage writes land
        harmlessly until completion scatters the real pages in."""
        return [j for j in range(self.num_slots)
                if self._slots[j] is not None
                and self._slots[j].state is RequestState.DECODING]

    def _decode_tick(self) -> List[StreamEvent]:
        """ONE batched decode step over the live cache."""
        events: List[StreamEvent] = []
        active = self._decoding()
        if not active:
            return events
        if self.paged:
            for j in active:
                self._ensure_decode_pages(j, 1)
        t0 = time.time()
        logits, self._live = self._decode(
            self.params, jnp.asarray(self._tokens), self._live)
        # greedy slots argmax on device (a (B,) transfer); the full
        # (B, vocab) logits matrix only crosses to the host when some
        # active request actually samples
        greedy_toks = np.asarray(jnp.argmax(logits, -1))  # host sync
        larr = None
        if any(self._slots[j].sampling.temperature > 0.0 for j in active):
            larr = np.asarray(logits)
        now = time.time()
        self.stats.decode_s += now - t0
        self.stats.decode_tokens += len(active)
        self.stats.slot_steps_active += len(active)
        self.stats.slot_steps_total += self.num_slots
        self._note_gap(now)
        for j in active:
            r = self._slots[j]
            tok = int(greedy_toks[j]) if r.sampling.temperature <= 0.0 \
                else self._sample(r, larr[j])
            self._tokens[j] = tok
            r.tokens_out.append(tok)
            r.metrics.decode_tokens += 1
            events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                      token=tok,
                                      index=len(r.tokens_out) - 1))
            if self._is_done(r):
                self._finish(r, j, now, events)
        return events

    def drain(self) -> List[ServedRequest]:
        """Run the scheduler until every submitted request has finished;
        returns all requests in submission order.

        Greedy slots decode in ROLLED segments: one `_decode_multi`
        dispatch covers min-remaining-budget steps across the active
        slots, so host round-trips scale with the number of admission /
        finish boundaries, not the token horizon. Any active request
        that samples (temperature > 0) or watches stop tokens needs
        per-token host control, so those ticks fall back to `step()`."""
        while self.has_work:
            self._drain_tick()
        return list(self._requests)

    def _drain_tick(self) -> List[StreamEvent]:
        """One drain iteration: admit, then decode one rolled segment.

        Active slots are PARTITIONED: pure-greedy slots (no sampling, no
        stop tokens) always take a rolled multi-step dispatch, while
        host-controlled slots (temperature > 0 or stop tokens) take one
        masked single step. A tick where every active slot is greedy
        uses the original unmasked `_decode_multi` trace; a mixed tick
        uses the masked pair, so one sampling request no longer drags
        every greedy slot down to per-token host round-trips."""
        events: List[StreamEvent] = []
        self._tick_admit(events)
        active = self._decoding()
        if not active:
            return events
        ctl = [j for j in active
               if self._slots[j].sampling.temperature > 0.0
               or self._slots[j].sampling.stop_tokens]
        greedy = [j for j in active if j not in ctl]
        if ctl and self.paged:
            # page-pool leaves have no batch axis to mask on (distinct
            # slots write distinct pages inside ONE dispatch), so a
            # masked commit can't keep a slot's pool writes out —
            # per-token lockstep is the correct fallback
            return events + self._decode_tick()
        if ctl and greedy:
            events += self._masked_ctl_step(ctl)
            # a ctl slot may have finished and freed a slot; greedy
            # slots are untouched by the masked step
            return events + self._greedy_roll(greedy, masked=True)
        if ctl:
            return events + self._decode_tick()
        return events + self._greedy_roll(greedy, masked=False)

    def _masked_ctl_step(self, ctl: List[int]) -> List[StreamEvent]:
        """One decode step committed only for the host-controlled slots
        in `ctl` (sampling / stop-token requests)."""
        events: List[StreamEvent] = []
        mask = np.zeros((self.num_slots,), bool)
        mask[ctl] = True
        t0 = time.time()
        logits, self._live = self._decode_mask(
            self.params, jnp.asarray(self._tokens), self._live,
            jnp.asarray(mask))
        larr = np.asarray(logits)  # host sync; ctl slots sample anyway
        now = time.time()
        self.stats.decode_s += now - t0
        self.stats.decode_tokens += len(ctl)
        self.stats.slot_steps_active += len(ctl)
        self.stats.slot_steps_total += self.num_slots
        self._note_gap(now)
        for j in ctl:
            r = self._slots[j]
            tok = self._sample(r, larr[j])
            self._tokens[j] = tok
            r.tokens_out.append(tok)
            r.metrics.decode_tokens += 1
            events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                      token=tok,
                                      index=len(r.tokens_out) - 1))
            if self._is_done(r):
                self._finish(r, j, now, events)
        return events

    def _greedy_roll(self, greedy: List[int],
                     masked: bool) -> List[StreamEvent]:
        """Rolled multi-step greedy decode over the slots in `greedy`:
        nothing can finish before the smallest remaining budget, so run
        exactly that many steps in one traced-length dispatch (masked
        when host-controlled slots share the batch and must not move)."""
        events: List[StreamEvent] = []
        nsteps = min(self._slots[j].sampling.max_new_tokens
                     - len(self._slots[j].tokens_out) for j in greedy)
        if any(job is not None for job in self._job_by_slot):
            # a chunked prefill is in flight: cap the roll so its next
            # chunk interleaves at per-token granularity instead of
            # stalling behind a multi-step dispatch
            nsteps = 1
        if self.paged:
            for j in greedy:
                self._ensure_decode_pages(j, nsteps)
        t0 = time.time()
        if masked:
            mask = np.zeros((self.num_slots,), bool)
            mask[greedy] = True
            token, self._live, buf = self._decode_multi_mask(
                self.params, jnp.asarray(self._tokens), self._live,
                jnp.int32(nsteps), jnp.asarray(mask))
        else:
            token, self._live, buf = self._decode_multi(
                self.params, jnp.asarray(self._tokens), self._live,
                jnp.int32(nsteps))
        toks = np.asarray(buf)[:nsteps]  # host sync
        now = time.time()
        self.stats.decode_s += now - t0
        self.stats.decode_tokens += nsteps * len(greedy)
        self.stats.slot_steps_active += nsteps * len(greedy)
        self.stats.slot_steps_total += nsteps * self.num_slots
        self._note_gap(now)
        for j in greedy:
            r = self._slots[j]
            for i in range(nsteps):
                tok = int(toks[i][j])
                self._tokens[j] = tok
                r.tokens_out.append(tok)
                r.metrics.decode_tokens += 1
                events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                          token=tok,
                                          index=len(r.tokens_out) - 1))
            if self._is_done(r):
                self._finish(r, j, now, events)
        return events

    def stream(self) -> Iterator[StreamEvent]:
        """Yield StreamEvents as they are produced, until drained."""
        while self.has_work:
            yield from self.step()

    # -- internals ---------------------------------------------------------
    def _round_bucket(self, plen: int) -> int:
        return block_bucket(plen, self.block)

    def _admit_next(self, slot: int, events: List[StreamEvent]):
        r = self._queue.popleft()
        r.state = RequestState.PREFILLING
        r.slot = slot
        t0 = time.time()
        r.metrics.admit_t = t0
        plen = len(r.prompt)
        if self._bucket is None or plen > self._bucket:
            # a longer prompt grows the bucket; cached (1, bucket) plans
            # are for the old block grid, so they die with it
            self._bucket = self._round_bucket(plen)
            self._plans = None
        if self._bucket + r.sampling.max_new_tokens > self.max_len:
            # the shared bucket grew past this request's submit-time
            # check; past this point decode would write beyond the cache
            # and dynamic_update_slice would clamp onto the last slot —
            # silent token corruption, so fail loudly instead. The
            # request goes back to the queue head first, so a caller
            # that catches the error still sees it (and can cancel it)
            # rather than losing it in a half-admitted limbo state
            self._queue.appendleft(r)
            r.state = RequestState.QUEUED
            r.slot = None
            raise ValueError(
                f"max_len={self.max_len} cannot hold request {r.rid}: "
                f"the shared prefill bucket grew to {self._bucket} "
                f"(longest admitted prompt, block-aligned) and "
                f"{r.sampling.max_new_tokens} new tokens no longer fit; "
                f"raise max_len to >= "
                f"{self._bucket + r.sampling.max_new_tokens}")
        toks = np.zeros((1, self._bucket), np.int32)
        toks[0, self._bucket - plen:] = r.prompt  # left-pad
        if self.paged:
            padded = toks[0]
            keys = self._page_keys(padded)
            # precedence: full-prompt snapshot > chunked machine >
            # blocking dispatch (the snapshot fast path short-circuits
            # the whole chunk state machine)
            logits = self._try_snapshot(padded, keys, slot)
            if logits is not None:
                self._finish_admission(r, slot, logits, t0, events,
                                       prefilled=0, plan_built=False)
                return
            if self._chunk_tokens:
                self._start_job(r, slot, toks, keys, t0, events)
                return
            logits = self._dispatch_paged(toks, keys, slot)
        else:
            last_hidden, cache = self._run_prefill(jnp.asarray(toks))
            logits = np.asarray(
                logits_from_hidden(self.params, last_hidden))
            self._live = self._admit_jit(self._live, cache, slot)
        self._finish_admission(r, slot, logits, t0, events,
                               prefilled=self._bucket, plan_built=True)

    def _finish_admission(self, r: ServedRequest, slot: int, logits,
                          t0: float, events: List[StreamEvent], *,
                          prefilled: int, plan_built: bool,
                          start_emitted: bool = False):
        """Common admission tail (blocking, snapshot-hit and chunked
        completions): decode-SLA accounting — gated on whether a
        prefill actually dispatched, a snapshot fast-path hit builds no
        plans and prefills no tokens — then first-token sampling,
        events, and the slot hand-off to DECODING."""
        if self.decode_sla:
            if plan_built:
                self.stats.decode_plan_builds += self.cfg.num_layers
            self._stat_base[slot] = self._slot_counters(slot)
        tok = self._sample(r, logits[0])
        self._tokens[slot] = tok
        now = time.time()
        self.stats.admissions += 1
        self.stats.prefill_tokens += prefilled
        self.stats.prefill_s += now - t0
        r.metrics.first_token_t = now
        r.state = RequestState.DECODING
        r.tokens_out.append(tok)
        r.metrics.decode_tokens += 1
        if not start_emitted:
            events.append(StreamEvent(rid=r.rid, kind="start", t=t0))
        self._note_gap(now)
        events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                  token=tok, index=0))
        self._slots[slot] = r
        if self._is_done(r):
            self._finish(r, slot, now, events)

    def _note_gap(self, now: float):
        """Track the largest wall-clock gap between consecutive token
        emissions (`ServeStats.max_decode_gap_s`) — the decode-stall
        metric chunked admission exists to shrink: a blocking long
        prefill freezes every decoding slot for the whole dispatch,
        chunked admission bounds the freeze to one chunk."""
        if self._last_token_t is not None:
            gap = now - self._last_token_t
            if gap > self.stats.max_decode_gap_s:
                self.stats.max_decode_gap_s = gap
        self._last_token_t = now

    def _run_prefill(self, toks: jnp.ndarray):
        """(1, bucket) prefill, through the plan-reuse path if enabled."""
        last_hidden, cache, self._plans = self._pf.run(
            toks, self._plans, self.stats, self.cfg.num_layers)
        return last_hidden, cache

    # -- paged KV internals (DESIGN.md "Paged KV & prefix caching") --------
    def _page_keys(self, padded: np.ndarray) -> List[bytes]:
        """One intern key per prompt page: the raw bytes of the padded
        prompt up to that page's END. Causal attention over absolute
        positions makes page j's KV rows and h/z partials a pure
        function of the tokens below (j+1)*block_kv, so identical bytes
        mean bitwise-identical page contents — across requests and even
        across prefill buckets (the left-pad layout is part of the
        bytes, so differently-padded prompts simply never match)."""
        bkv = self.block
        return [padded[:(j + 1) * bkv].tobytes()
                for j in range(padded.size // bkv)]

    def _push_pt(self):
        """Publish the host-owned page table to the device cache. `pt`
        is read-only inside every jitted decode/admit dispatch; the
        scheduler owns it here and overwrites it between dispatches."""
        self._live = dict(self._live)
        self._live["pt"] = jnp.asarray(self._pt_host)

    def _sync_page_stats(self):
        ps, st = self._pool.stats, self.stats
        st.pages_in_use = self._pool.in_use()
        st.pages_peak = max(st.pages_peak, st.pages_in_use)
        st.page_allocs = ps.allocs
        st.prefix_hits = ps.prefix_hits
        st.prefix_misses = ps.prefix_misses
        st.cow_copies = ps.cow_copies

    def _set_slot_pages(self, slot: int, pids: List[int],
                        bucket: Optional[int] = None):
        """Point `slot`'s page-table row at its prompt pages (one
        pool ref each, already taken); the decode tail reads the
        permanent zero page until the CoW pass privatizes it. `bucket`
        defaults to the shared prefill bucket — a chunked completion
        passes its own admission-time bucket, which may predate a
        growth triggered by a later queued prompt."""
        npp = len(pids)
        self._pt_host[slot, :npp] = pids
        self._pt_host[slot, npp:] = self._zero_page
        self._slot_pids[slot] = list(pids)
        self._slot_base[slot] = self._bucket if bucket is None else bucket
        self._push_pt()

    def _try_snapshot(self, padded: np.ndarray, keys: List[bytes],
                      slot: int) -> Optional[np.ndarray]:
        """Full-prompt snapshot fast path: an exact (bucket,
        padded-prompt-bytes) snapshot hit whose prompt pages are all
        still interned skips the prefill dispatch entirely — the
        per-slot state and first-token logits were cached when the
        prompt was first seen, and the pages already hold its
        KV/partials. Returns the logits row, or None on a miss."""
        snap_key = (self._bucket, padded.tobytes())
        snap = self._snapshots.get(snap_key)
        if snap is None:
            return None
        pids, ok = [], True
        for key in keys:
            pid = self._pool.lookup(key)
            if pid is None:  # a page was evicted since the snapshot
                ok = False
                break
            pids.append(pid)
        if not ok:
            for pid in pids:  # partial hit: hand the taken refs back
                self._pool.release(pid)
            return None
        self._snapshots.move_to_end(snap_key)
        state, logits = snap
        self._live = self._admit_state_jit(self._live, state, slot)
        self._set_slot_pages(slot, pids)
        self.stats.prefix_full_hits += 1
        self._sync_page_stats()
        return logits

    def _claim_page(self, key: bytes) -> int:
        """Lookup-or-alloc one prompt page by its prefix-bytes intern
        key; the returned pool ref belongs to the caller."""
        pid = self._pool.lookup(key)
        if pid is None:
            pid = self._pool.alloc()
            self._pool.intern(key, pid)
        return pid

    def _store_snapshot(self, snap_key, cache, logits):
        self._snapshots[snap_key] = (
            self.mdl.slot_state_from_prefill(cache), logits)
        self._snapshots.move_to_end(snap_key)
        while len(self._snapshots) > self._snapshot_cap:
            self._snapshots.popitem(last=False)

    def _dispatch_paged(self, toks: np.ndarray, keys: List[bytes],
                        slot: int) -> np.ndarray:
        """Blocking page-granular admission: one (1, bucket) prefill,
        each prompt page interned by its prefix bytes; pages that hit
        are REWRITTEN with byte-identical contents, which keeps
        admission a single static-shape jit. Returns the first-token
        logits row."""
        last_hidden, cache = self._run_prefill(jnp.asarray(toks))
        logits = np.asarray(logits_from_hidden(self.params, last_hidden))
        pids = [self._claim_page(key) for key in keys]
        self._live = self._admit_paged_jit(
            self._live, cache, slot, jnp.asarray(pids, jnp.int32))
        self._set_slot_pages(slot, pids)
        self._store_snapshot((self._bucket, toks[0].tobytes()), cache,
                             logits)
        self._sync_page_stats()
        return logits

    # -- chunked admission (DESIGN.md "Chunked admission prefill") ---------
    def _claim_job_pages(self, job: _PrefillJob, lo: int, hi: int):
        """Intern-or-alloc the pages covering padded tokens [lo, hi) —
        one pool ref each, held by the job until `_set_slot_pages`
        takes them over at completion. Interned hits count prefix hits
        exactly once per page, as in blocking admission; page CONTENTS
        land at completion's full byte-identical rewrite, which is safe
        because nothing reads a slot's pages before its own completion
        scatter (snapshot fast-path hits require a stored snapshot, and
        snapshots are only stored after such a rewrite)."""
        bkv = self.block
        for j in range(lo // bkv, hi // bkv):
            job.pids.append(self._claim_page(job.keys[j]))
        self._sync_page_stats()

    def _start_job(self, r: ServedRequest, slot: int, toks: np.ndarray,
                   keys: List[bytes], t0: float,
                   events: List[StreamEvent]):
        """Claim `slot` for a multi-tick chunked admission. The request
        sits in PREFILLING state (masked out of decode dispatch) while
        `_tick_admit` advances it one chunk per tick; its first chunk
        runs within THIS tick. If a carry snapshot survives for a
        chunk-boundary prefix of the padded prompt, the job resumes
        past those chunks — a shared prefix skips its chunks, its pages
        claimed by intern lookup instead of recomputation."""
        bucket, ct = self._bucket, self._chunk_tokens
        job = _PrefillJob(r=r, slot=slot, toks=toks, keys=keys,
                          bucket=bucket,
                          carry=self._pf.carry_proto(bucket),
                          num_chunks=-(-bucket // ct), t0=t0)
        for c in range(job.num_chunks - 1, 0, -1):
            ckey = (bucket, toks[0, :c * ct].tobytes())
            snap = self._pf.carry_get(ckey)
            if snap is not None:
                job.carry = snap
                job.next_chunk = c
                self._claim_job_pages(job, 0, c * ct)
                break
        self.stats.chunked_admissions += 1
        self._job_by_slot[slot] = job
        self._slots[slot] = r  # owns the slot; PREFILLING masks decode
        events.append(StreamEvent(rid=r.rid, kind="start", t=t0))
        self._advance_job(slot, events)

    def _advance_job(self, slot: int, events: List[StreamEvent]):
        """Run ONE prefill chunk for the job occupying `slot`: the
        chunk's KV/pooled rows land in the carry, its pages are claimed
        from the pool, and the boundary carry is snapshotted for future
        prefix resumes. The final chunk hands the slot to decode."""
        job = self._job_by_slot[slot]
        ct = self._chunk_tokens
        lo = job.next_chunk * ct
        hi = min(lo + ct, job.bucket)
        t0 = time.time()
        carry, last_hidden = self._pf.chunk(
            jnp.asarray(job.toks[:, lo:hi]), job.carry, jnp.int32(lo))
        carry = jax.block_until_ready(carry)
        self.stats.prefill_s += time.time() - t0
        self.stats.prefill_chunks += 1
        job.carry = carry
        job.last_hidden = last_hidden
        job.dispatched += hi - lo
        self._claim_job_pages(job, lo, hi)
        if hi < job.bucket:  # full-prompt resume is the snapshot's job
            self._pf.carry_put((job.bucket, job.toks[0, :hi].tobytes()),
                               carry)
        job.next_chunk += 1
        if job.next_chunk >= job.num_chunks:
            self._complete_job(slot, job, events)

    def _complete_job(self, slot: int, job: _PrefillJob,
                      events: List[StreamEvent]):
        """Blocking admission's tail, verbatim: finalize the carry into
        the cache dict blocking prefill returns (decode state rebuilt
        with `_seed_decode_state`, so every leaf is bitwise blocking's),
        scatter it into `slot` through the page table, store the
        full-prompt snapshot, emit the first token."""
        t0 = time.time()
        cache = self._pf.finalize(job.carry)
        logits = np.asarray(
            logits_from_hidden(self.params, job.last_hidden))
        self._live = self._admit_paged_jit(
            self._live, cache, slot, jnp.asarray(job.pids, jnp.int32))
        self._set_slot_pages(slot, job.pids, bucket=job.bucket)
        self._store_snapshot((job.bucket, job.toks[0].tobytes()), cache,
                             logits)
        self._sync_page_stats()
        self._job_by_slot[slot] = None
        self._finish_admission(job.r, slot, logits, t0, events,
                               prefilled=job.dispatched, plan_built=True,
                               start_emitted=True)

    def _ensure_decode_pages(self, slot: int, nsteps: int):
        """Copy-on-write pass before a decode dispatch: every page in
        `slot`'s write range for the next `nsteps` tokens must be
        private (refcount 1, not the zero page) before the jitted step
        touches it. Fresh decode pages start as a copy of the permanent
        zero page — the h/z partials ACCUMULATE into them, so a
        recycled page must be cleaned; shared (prefix-interned or
        CoW-shared) pages are duplicated on first divergent write."""
        r = self._slots[slot]
        pos = self._slot_base[slot] + len(r.tokens_out) - 1
        bkv = self.block
        tn = self._pt_host.shape[1]
        first = min(pos // bkv, tn - 1)
        last = min((pos + nsteps - 1) // bkv, tn - 1)
        changed = False
        for blk in range(first, last + 1):
            pid = int(self._pt_host[slot, blk])
            if pid != self._zero_page and self._pool.refs(pid) == 1:
                continue  # already exclusively ours
            new, src = self._pool.ensure_private(pid)
            self._live = self._copy_page_jit(self._live, new, src)
            own = self._slot_pids[slot]
            if pid in own:
                own[own.index(pid)] = new
            else:
                own.append(new)  # the zero page was never slot-owned
            self._pt_host[slot, blk] = new
            changed = True
        if changed:
            self._push_pt()
            self._sync_page_stats()

    def _slot_counters(self, slot: int) -> dict:
        st = self._live["sla"]
        return {key: np.asarray(st[key][:, slot])
                for key in ("extends", "replans", "reuses")}

    def _sample(self, r: ServedRequest, logits_row: np.ndarray) -> int:
        if r.sampling.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng(
            (r.sampling.seed, r.rid, len(r.tokens_out)))
        z = logits_row.astype(np.float64) / r.sampling.temperature
        z -= z.max()
        p = np.exp(z)
        return int(rng.choice(len(p), p=p / p.sum()))

    def _is_done(self, r: ServedRequest) -> bool:
        if len(r.tokens_out) >= r.sampling.max_new_tokens:
            return True
        return bool(r.tokens_out) and \
            r.tokens_out[-1] in r.sampling.stop_tokens

    def _finish(self, r: ServedRequest, slot: int, now: float,
                events: List[StreamEvent]):
        r.state = RequestState.FINISHED
        r.metrics.finish_t = now
        self._slots[slot] = None
        if self.paged:
            # drop this slot's page refs (interned prefix pages stay
            # resident under the index's own ref until LRU-evicted) and
            # point the row back at the pinned scratch page so the
            # now-idle slot's garbage writes land somewhere harmless
            for pid in self._slot_pids[slot]:
                self._pool.release(pid)
            self._slot_pids[slot] = []
            self._pt_host[slot, :] = self._scratch[slot]
            self._push_pt()
            self._sync_page_stats()
        if self.decode_sla and self._stat_base[slot] is not None:
            base, cur = self._stat_base[slot], self._slot_counters(slot)
            self.stats.decode_plan_extends += int(
                (cur["extends"] - base["extends"]).sum())
            self.stats.decode_plan_replans += int(
                (cur["replans"] - base["replans"]).sum())
            self.stats.decode_plan_reuses += int(
                (cur["reuses"] - base["reuses"]).sum())
            self.stats.decode_last_retention = float(
                np.min(np.asarray(self._live["sla"]["retention"][:, slot])))
            self._stat_base[slot] = None
        events.append(StreamEvent(rid=r.rid, kind="finish", t=now))
