"""Serving API v2: continuous-batching scheduler with streaming requests.

The v1 surface (`ServingEngine.run(List[Request])`) decodes fixed groups
in lockstep: finished slots keep computing frozen logits, queued
requests wait for the whole group, and per-request latency collapses to
cumulative engine time. This module is the request-level redesign
(DESIGN.md "Serving API v2"):

  * `SamplingParams` / `RequestState` / `StreamEvent` / `RequestMetrics`
    — the typed request surface (greedy or temperature sampling, stop
    tokens, QUEUED -> PREFILLING -> DECODING -> FINISHED lifecycle, and
    real per-request TTFT / queue-time / latency).
  * `Scheduler` — a fixed pool of decode slots over ONE live per-slot
    cache (`make_cache(per_slot=True)`). `submit()` enqueues;  `step()`
    admits queued requests into free slots (each prefilled in its own
    block-aligned `(1, bucket)` call, then scattered into the slot via
    `insert_slot`: KV rows, decode-SLA incremental plan rows, H/Z
    linear state, pooled q/k features) and runs one batched decode step
    with per-slot positions; `drain()` runs to completion; `stream()`
    yields `StreamEvent`s as they happen.

Admission happens at SLA block boundaries by construction: the prefill
bucket is a whole number of `block_q` blocks, so an admitted slot's
position starts block-aligned and the static-grid invariants of
`plan_extend` (rows appended monotonically, each exactly once) hold per
slot. Cross-request plan reuse (`plan_reuse="adaptive"`) and decode-time
SLA (`decode_sla=True`) both ride along — this is where they pay off
hardest, because slots turn over continuously instead of waiting for
the slowest group member.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Deque, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.models.common import logits_from_hidden


# ---------------------------------------------------------------------------
# typed request surface
# ---------------------------------------------------------------------------
class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling policy.

    temperature == 0.0 is greedy argmax (bit-reproducible against the
    static-batch engine); > 0 samples from softmax(logits / T) with a
    per-request deterministic host RNG (`seed`). Generation stops at
    `max_new_tokens` or on the first token in `stop_tokens` (the stop
    token itself is kept, matching the budget-truncation semantics of
    the v1 engine)."""

    max_new_tokens: int = 16
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    seed: int = 0

    def validate(self) -> "SamplingParams":
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1 (got {self.max_new_tokens})")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0 (got {self.temperature})")
        return self


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock request accounting (absolute times from time.time()).

    queue_s / ttft_s / latency_s are derived and measured per request —
    the v1 engine assigned every request the engine's cumulative
    prefill+decode seconds instead."""

    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    decode_tokens: int = 0  # total generated tokens (incl. the prefill one)

    @property
    def queue_s(self) -> float:
        return max(0.0, self.admit_t - self.submit_t)

    @property
    def ttft_s(self) -> float:
        return max(0.0, self.first_token_t - self.submit_t)

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finish_t - self.submit_t)


@dataclasses.dataclass
class StreamEvent:
    """One streaming output event.

    kind: "start" (request admitted to a slot), "token" (one generated
    token; `token`/`index` set), "finish" (request complete)."""

    rid: int
    kind: str
    t: float
    token: Optional[int] = None
    index: Optional[int] = None


@dataclasses.dataclass
class ServedRequest:
    """A request inside the scheduler (the v2 analogue of engine.Request)."""

    rid: int
    prompt: np.ndarray
    sampling: SamplingParams
    state: RequestState = RequestState.QUEUED
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = dataclasses.field(
        default_factory=RequestMetrics)
    slot: Optional[int] = None


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # plan-reuse accounting (layer granularity; DESIGN.md "Plan
    # lifetime & drift"): builds = first-chunk plans, replans =
    # drift-triggered rebuilds, reuses = layers served by a stale plan.
    plan_builds: int = 0
    plan_replans: int = 0
    plan_reuses: int = 0
    last_retention: float = 1.0
    # decode-plan accounting (layer granularity; DESIGN.md "Decode-time
    # SLA"): builds = decode plans seeded at prefill (one per layer per
    # chunk, covering all prompt rows), extends = completed rows
    # appended via plan_extend, replans = live rows re-classified at a
    # block boundary (drift over that layer's threshold), reuses = live
    # rows inheriting the previous row's structure.
    decode_plan_builds: int = 0
    decode_plan_extends: int = 0
    decode_plan_replans: int = 0
    decode_plan_reuses: int = 0
    decode_last_retention: float = 1.0
    # continuous-batching accounting (DESIGN.md "Serving API v2"):
    # admissions = requests scattered into a slot, slot_steps_active /
    # slot_steps_total = decode-slot occupancy (active slots vs pool
    # size, summed over decode steps; the static engine counts its
    # lockstep groups the same way, so the two paths are comparable).
    admissions: int = 0
    slot_steps_active: int = 0
    slot_steps_total: int = 0

    def occupancy(self) -> float:
        """Decode-slot utilization in [0, 1]."""
        return self.slot_steps_active / max(1, self.slot_steps_total)


# ---------------------------------------------------------------------------
# shared serving helpers (engine + scheduler)
# ---------------------------------------------------------------------------
def block_bucket(length: int, block: int) -> int:
    """`length` rounded up to a whole number of SLA query blocks."""
    block = max(block, 1)
    return max(block, ((length + block - 1) // block) * block)


def normalize_drift_threshold(cfg: ArchConfig, drift_threshold):
    """CLI/user drift threshold -> scalar or per-layer tuple."""
    if drift_threshold is None:
        return cfg.sla.plan_drift_threshold
    if isinstance(drift_threshold, (tuple, list)):
        return tuple(float(t) for t in drift_threshold)
    return float(drift_threshold)


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (the serving-metrics convention used by
    both `launch/serve.py` and `benchmarks/fig_serving.py`)."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * len(xs)))]


def prefill_with_plan_reuse(prefill_plan, prefill_reuse, params, toks,
                            plans, stats: "ServeStats", num_layers: int):
    """Shared plan-reuse prefill step (DESIGN.md "Plan lifetime &
    drift"): build the per-layer plan stack on the first chunk, reuse
    it with drift-gated refresh afterwards, and account builds /
    replans / reuses / retention on `stats`. Returns
    (last_hidden, cache, plans)."""
    if plans is None:
        last_hidden, cache, plans = prefill_plan(params, toks)
        stats.plan_builds += num_layers
    else:
        last_hidden, cache, plans, info = prefill_reuse(params, toks,
                                                        plans)
        replans = int(np.sum(np.asarray(info["replanned"])))
        stats.plan_replans += replans
        stats.plan_reuses += num_layers - replans
        stats.last_retention = float(
            np.min(np.asarray(info["retention"])))
    return last_hidden, cache, plans


def check_serving_family(cfg: ArchConfig, mdl, plan_reuse: str,
                         decode_sla: bool, continuous: bool = False):
    """Loudly reject model families without the capabilities a serving
    mode needs (plan-aware prefill, decode-SLA prefill, slot caches)."""
    import inspect

    prefill_fn = getattr(mdl, "prefill", None)
    if plan_reuse != "off":
        if (prefill_fn is None
                or "plans" not in inspect.signature(prefill_fn).parameters):
            raise ValueError(
                f"plan_reuse={plan_reuse!r} requires a model family with "
                f"plan-aware prefill (got family {cfg.family!r})")
    if decode_sla:
        if (prefill_fn is None or "decode_max_len" not in
                inspect.signature(prefill_fn).parameters):
            raise ValueError(
                f"decode_sla requires a model family with decode-SLA "
                f"prefill (got family {cfg.family!r})")
    if continuous and getattr(mdl, "insert_slot", None) is None:
        raise ValueError(
            f"the continuous-batching scheduler requires a model family "
            f"with per-slot caches (make_cache(per_slot=True) + "
            f"insert_slot); family {cfg.family!r} has neither")


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------
class Scheduler:
    """Continuous-batching scheduler over a fixed pool of decode slots.

    One live per-slot cache holds `num_slots` independent sequences
    (per-slot positions, per-slot decode-SLA plan/state). Slots turn
    over continuously: the moment a request finishes, the next queued
    request is prefilled in its own `(1, bucket)` call and scattered
    into the freed slot — no request ever waits for a group.

    Greedy tokens are bit-identical to the static-batch engine's when
    the prefill bucket and slot count match (per-request numerics
    depend only on (prompt, bucket, batch width); verified by
    tests/test_serving.py).
    """

    def __init__(self, cfg: ArchConfig, params, num_slots: int = 4,
                 max_len: int = 512, backend: str = "gather",
                 decode_sla: Optional[bool] = None,
                 plan_reuse: str = "off", drift_threshold=None,
                 prefill_bucket: Optional[int] = None,
                 compute_dtype=jnp.bfloat16):
        from repro.core import backends as backend_registry

        backend = backend_registry.resolve(backend)
        cfg.sla.validate()
        if plan_reuse not in ("off", "adaptive"):
            raise ValueError(
                f"unknown plan_reuse mode {plan_reuse!r}; expected "
                "'off' or 'adaptive'")
        if decode_sla is None:
            decode_sla = cfg.sla.decode_mode == "sla"
        self.cfg = cfg
        self.params = params
        self.mdl = registry.get_model(cfg)
        check_serving_family(cfg, self.mdl, plan_reuse, decode_sla,
                             continuous=True)
        self.num_slots = num_slots
        self.backend = backend
        self.decode_sla = decode_sla
        self.plan_reuse = plan_reuse
        self.drift_threshold = normalize_drift_threshold(cfg,
                                                         drift_threshold)
        self.block = max(cfg.sla.block_q, 1)
        # admission at block boundaries: cache length and prefill
        # buckets are whole numbers of blocks, so every slot's position
        # starts block-aligned and plan_extend's static-grid invariants
        # hold per slot
        self.max_len = block_bucket(max_len, self.block) if decode_sla \
            else max_len
        self.compute_dtype = compute_dtype
        self.stats = ServeStats()

        self._queue: Deque[ServedRequest] = collections.deque()
        self._slots: List[Optional[ServedRequest]] = [None] * num_slots
        self._tokens = np.zeros((num_slots,), np.int32)
        self._next_rid = 0
        self._requests: List[ServedRequest] = []  # submission order
        self._bucket = (block_bucket(prefill_bucket, self.block)
                        if prefill_bucket else None)
        self._plans = None  # (1, bucket) plan stack for plan_reuse
        self._stat_base = [None] * num_slots  # decode-SLA counter bases

        mdl, backend_, thr = self.mdl, backend, self.drift_threshold
        dkw = {"decode_max_len": self.max_len} if decode_sla else {}

        @jax.jit
        def _prefill(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               compute_dtype=compute_dtype, **dkw)

        @jax.jit
        def _prefill_plan(params, tokens):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               compute_dtype=compute_dtype,
                               return_plans=True, **dkw)

        @jax.jit
        def _prefill_reuse(params, tokens, plans):
            return mdl.prefill(params, cfg, tokens, backend=backend_,
                               compute_dtype=compute_dtype, plans=plans,
                               drift_threshold=thr, return_plans=True,
                               **dkw)

        if decode_sla:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache,
                                       compute_dtype=compute_dtype,
                                       backend=backend_,
                                       drift_threshold=thr)
        else:
            def _one(params, token, cache):
                return mdl.decode_step(params, cfg, token, cache,
                                       compute_dtype=compute_dtype)

        _decode = jax.jit(_one)
        max_len_ = self.max_len

        # rolled multi-step greedy decode (ISSUE 6): nsteps is a traced
        # scalar, so fori_loop lowers to while_loop and ONE trace covers
        # every segment length drain() ever requests
        @jax.jit
        def _decode_multi(params, token, cache, nsteps):
            buf = jnp.zeros((max_len_, token.shape[0]), jnp.int32)

            def body(i, carry):
                token, cache, buf = carry
                logits, cache = _one(params, token, cache)
                token = jnp.argmax(logits, -1).astype(jnp.int32)
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, token[None], i, axis=0)
                return token, cache, buf

            return jax.lax.fori_loop(0, nsteps, body, (token, cache, buf))

        @jax.jit
        def _admit(live, single, slot):
            grow = max_len_ - single["k"].shape[-2]
            if grow > 0:  # dense prefill caches stop at the bucket
                pad = [(0, 0)] * 3 + [(0, grow), (0, 0)]
                single = dict(single, k=jnp.pad(single["k"], pad),
                              v=jnp.pad(single["v"], pad))
            return mdl.insert_slot(live, single, slot)

        self._prefill = _prefill
        self._prefill_plan = _prefill_plan
        self._prefill_reuse = _prefill_reuse
        self._decode = _decode
        self._decode_multi = _decode_multi
        self._admit_jit = _admit
        self._live = mdl.make_cache(cfg, num_slots, self.max_len,
                                    dtype=compute_dtype,
                                    decode_sla=decode_sla, per_slot=True)

    # -- public API --------------------------------------------------------
    def submit(self, prompt, sampling: Optional[SamplingParams] = None
               ) -> int:
        """Enqueue one request; returns its rid. O(1), never blocks."""
        sampling = (sampling or SamplingParams()).validate()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        # capacity check against the SHARED prefill bucket (every
        # admission pads to it, so a long earlier prompt raises the
        # floor for everyone); _admit_next re-checks after any growth
        # that happens while this request is queued
        bucket = max(block_bucket(len(prompt), self.block),
                     self._bucket or 0)
        need = bucket + sampling.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"max_len={self.max_len} cannot hold a {len(prompt)}-token "
                f"prompt (shared prefill bucket {bucket}) plus "
                f"{sampling.max_new_tokens} new tokens; raise max_len "
                f"to >= {need}")
        r = ServedRequest(rid=self._next_rid, prompt=prompt,
                          sampling=sampling)
        r.metrics.submit_t = time.time()
        self._next_rid += 1
        self._queue.append(r)
        self._requests.append(r)
        return r.rid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self._slots)

    def step(self) -> List[StreamEvent]:
        """Admit queued requests into free slots, then run ONE batched
        decode step over the live cache. Returns the events produced."""
        events: List[StreamEvent] = []
        for slot in range(self.num_slots):
            if self._slots[slot] is None and self._queue:
                self._admit_next(slot, events)
        active = [j for j in range(self.num_slots)
                  if self._slots[j] is not None]
        if not active:
            return events
        t0 = time.time()
        logits, self._live = self._decode(
            self.params, jnp.asarray(self._tokens), self._live)
        # greedy slots argmax on device (a (B,) transfer); the full
        # (B, vocab) logits matrix only crosses to the host when some
        # active request actually samples
        greedy_toks = np.asarray(jnp.argmax(logits, -1))  # host sync
        larr = None
        if any(self._slots[j].sampling.temperature > 0.0 for j in active):
            larr = np.asarray(logits)
        now = time.time()
        self.stats.decode_s += now - t0
        self.stats.decode_tokens += len(active)
        self.stats.slot_steps_active += len(active)
        self.stats.slot_steps_total += self.num_slots
        for j in active:
            r = self._slots[j]
            tok = int(greedy_toks[j]) if r.sampling.temperature <= 0.0 \
                else self._sample(r, larr[j])
            self._tokens[j] = tok
            r.tokens_out.append(tok)
            r.metrics.decode_tokens += 1
            events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                      token=tok,
                                      index=len(r.tokens_out) - 1))
            if self._is_done(r):
                self._finish(r, j, now, events)
        return events

    def drain(self) -> List[ServedRequest]:
        """Run the scheduler until every submitted request has finished;
        returns all requests in submission order.

        Greedy slots decode in ROLLED segments: one `_decode_multi`
        dispatch covers min-remaining-budget steps across the active
        slots, so host round-trips scale with the number of admission /
        finish boundaries, not the token horizon. Any active request
        that samples (temperature > 0) or watches stop tokens needs
        per-token host control, so those ticks fall back to `step()`."""
        while self.has_work:
            self._drain_tick()
        return list(self._requests)

    def _drain_tick(self) -> List[StreamEvent]:
        """One drain iteration: admit, then decode one rolled segment
        (or one `step()` when per-token host control is required)."""
        events: List[StreamEvent] = []
        for slot in range(self.num_slots):
            if self._slots[slot] is None and self._queue:
                self._admit_next(slot, events)
        active = [j for j in range(self.num_slots)
                  if self._slots[j] is not None]
        if not active:
            return events
        if any(self._slots[j].sampling.temperature > 0.0
               or self._slots[j].sampling.stop_tokens for j in active):
            return events + self.step()
        # every active request is greedy with a pure token budget:
        # nothing can finish before the smallest remaining budget, so
        # run exactly that many steps in one traced-length dispatch
        nsteps = min(self._slots[j].sampling.max_new_tokens
                     - len(self._slots[j].tokens_out) for j in active)
        t0 = time.time()
        token, self._live, buf = self._decode_multi(
            self.params, jnp.asarray(self._tokens), self._live,
            jnp.int32(nsteps))
        toks = np.asarray(buf)[:nsteps]  # host sync
        now = time.time()
        self.stats.decode_s += now - t0
        self.stats.decode_tokens += nsteps * len(active)
        self.stats.slot_steps_active += nsteps * len(active)
        self.stats.slot_steps_total += nsteps * self.num_slots
        for j in active:
            r = self._slots[j]
            for i in range(nsteps):
                tok = int(toks[i][j])
                self._tokens[j] = tok
                r.tokens_out.append(tok)
                r.metrics.decode_tokens += 1
                events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                          token=tok,
                                          index=len(r.tokens_out) - 1))
            if self._is_done(r):
                self._finish(r, j, now, events)
        return events

    def stream(self) -> Iterator[StreamEvent]:
        """Yield StreamEvents as they are produced, until drained."""
        while self.has_work:
            yield from self.step()

    # -- internals ---------------------------------------------------------
    def _round_bucket(self, plen: int) -> int:
        return block_bucket(plen, self.block)

    def _admit_next(self, slot: int, events: List[StreamEvent]):
        r = self._queue.popleft()
        r.state = RequestState.PREFILLING
        r.slot = slot
        t0 = time.time()
        r.metrics.admit_t = t0
        plen = len(r.prompt)
        if self._bucket is None or plen > self._bucket:
            # a longer prompt grows the bucket; cached (1, bucket) plans
            # are for the old block grid, so they die with it
            self._bucket = self._round_bucket(plen)
            self._plans = None
        if self._bucket + r.sampling.max_new_tokens > self.max_len:
            # the shared bucket grew past this request's submit-time
            # check; past this point decode would write beyond the cache
            # and dynamic_update_slice would clamp onto the last slot —
            # silent token corruption, so fail loudly instead. The
            # request goes back to the queue head first, so a caller
            # that catches the error still sees it (and can cancel it)
            # rather than losing it in a half-admitted limbo state
            self._queue.appendleft(r)
            r.state = RequestState.QUEUED
            r.slot = None
            raise ValueError(
                f"max_len={self.max_len} cannot hold request {r.rid}: "
                f"the shared prefill bucket grew to {self._bucket} "
                f"(longest admitted prompt, block-aligned) and "
                f"{r.sampling.max_new_tokens} new tokens no longer fit; "
                f"raise max_len to >= "
                f"{self._bucket + r.sampling.max_new_tokens}")
        toks = np.zeros((1, self._bucket), np.int32)
        toks[0, self._bucket - plen:] = r.prompt  # left-pad
        last_hidden, cache = self._run_prefill(jnp.asarray(toks))
        logits = np.asarray(logits_from_hidden(self.params, last_hidden))
        self._live = self._admit_jit(self._live, cache, slot)
        if self.decode_sla:
            self.stats.decode_plan_builds += self.cfg.num_layers
            self._stat_base[slot] = self._slot_counters(slot)
        tok = self._sample(r, logits[0])
        self._tokens[slot] = tok
        now = time.time()
        self.stats.admissions += 1
        self.stats.prefill_tokens += self._bucket
        self.stats.prefill_s += now - t0
        r.metrics.first_token_t = now
        r.state = RequestState.DECODING
        r.tokens_out.append(tok)
        r.metrics.decode_tokens += 1
        events.append(StreamEvent(rid=r.rid, kind="start", t=t0))
        events.append(StreamEvent(rid=r.rid, kind="token", t=now,
                                  token=tok, index=0))
        if self._is_done(r):
            self._finish(r, slot, now, events)
        else:
            self._slots[slot] = r

    def _run_prefill(self, toks: jnp.ndarray):
        """(1, bucket) prefill, through the plan-reuse path if enabled."""
        if self.plan_reuse == "off":
            return self._prefill(self.params, toks)
        last_hidden, cache, self._plans = prefill_with_plan_reuse(
            self._prefill_plan, self._prefill_reuse, self.params, toks,
            self._plans, self.stats, self.cfg.num_layers)
        return last_hidden, cache

    def _slot_counters(self, slot: int) -> dict:
        st = self._live["sla"]
        return {key: np.asarray(st[key][:, slot])
                for key in ("extends", "replans", "reuses")}

    def _sample(self, r: ServedRequest, logits_row: np.ndarray) -> int:
        if r.sampling.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng(
            (r.sampling.seed, r.rid, len(r.tokens_out)))
        z = logits_row.astype(np.float64) / r.sampling.temperature
        z -= z.max()
        p = np.exp(z)
        return int(rng.choice(len(p), p=p / p.sum()))

    def _is_done(self, r: ServedRequest) -> bool:
        if len(r.tokens_out) >= r.sampling.max_new_tokens:
            return True
        return bool(r.tokens_out) and \
            r.tokens_out[-1] in r.sampling.stop_tokens

    def _finish(self, r: ServedRequest, slot: int, now: float,
                events: List[StreamEvent]):
        r.state = RequestState.FINISHED
        r.metrics.finish_t = now
        self._slots[slot] = None
        if self.decode_sla and self._stat_base[slot] is not None:
            base, cur = self._stat_base[slot], self._slot_counters(slot)
            self.stats.decode_plan_extends += int(
                (cur["extends"] - base["extends"]).sum())
            self.stats.decode_plan_replans += int(
                (cur["replans"] - base["replans"]).sum())
            self.stats.decode_plan_reuses += int(
                (cur["reuses"] - base["reuses"]).sum())
            self.stats.decode_last_retention = float(
                np.min(np.asarray(self._live["sla"]["retention"][:, slot])))
            self._stat_base[slot] = None
        events.append(StreamEvent(rid=r.rid, kind="finish", t=now))
