"""Global KV page pool: refcounted block_kv-sized pages + prefix interning.

This is the host-side bookkeeping half of paged serving (DESIGN.md
"Paged KV & prefix caching").  Device state lives in the paged cache
built by ``transformer.make_paged_cache`` — per-layer page pools
``kp``/``vp`` (and, under decode-SLA, pooled per-block H/Z partials)
indexed by ONE per-slot page table ``pt[slot, logical_block] ->
physical_page``.  This module owns the allocation story:

  * ``PagePool`` — a fixed set of physical page ids with reference
    counts.  Page 0 is the permanent all-zero page (never allocated,
    never written); the scheduler additionally pins one private
    *scratch* page per slot so inactive slots — which keep stepping
    through every batched decode dispatch by design — always have a
    harmless write target.
  * Prefix interning — prompt prefixes are keyed by the raw bytes of
    the left-padded token prefix up to each page boundary (exact
    content match, no hash collisions).  Causal attention makes page
    ``j``'s KV (and its plan row / h/z partials) a pure function of
    the padded tokens below ``(j+1)*page_size`` at fixed positions, so
    two prompts sharing those bytes may share the physical page.  The
    index holds its own reference on every interned page so shared
    prefixes survive request turnover; index-only pages are evicted
    LRU under pool pressure.
  * Copy-on-write — a slot that is about to WRITE into a page it does
    not own exclusively (refs > 1, or the zero page) asks
    ``ensure_private`` for a fresh page id; the scheduler then copies
    the old page's contents on device.  Fresh decode pages are CoW
    copies of the zero page: per-block H/Z partials accumulate onto the
    page (gather/add/set), so a recycled page MUST start zeroed.

Exhaustion is loud: ``alloc`` raises ``PagePoolExhausted`` once every
page is referenced and nothing is evictable — pages are never silently
reused while referenced.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

ZERO_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Raised when an allocation cannot be served: every physical page
    is referenced and the prefix index has nothing evictable."""


@dataclasses.dataclass
class PageStats:
    """Host-side page accounting (mirrored into ServeStats)."""

    allocs: int = 0
    frees: int = 0
    cow_copies: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    evictions: int = 0


class PagePool:
    """Refcounted physical-page allocator with byte-keyed prefix interning.

    ``num_pages`` counts ALL physical pages including the zero page;
    ids are ``0 .. num_pages - 1``.  The pool never touches device
    memory — callers translate (old_pid, new_pid) decisions into jitted
    page copies/zero-fills against the device pools.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(
                f"page pool needs >= 2 pages (zero page + 1), got {num_pages}")
        self.num_pages = int(num_pages)
        self._refs = [0] * self.num_pages
        self._refs[ZERO_PAGE] = 1  # permanently pinned
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        # prefix interning: key bytes -> pid; the index holds one ref per
        # entry.  _lru orders index-only candidates for eviction.
        self._index: Dict[bytes, int] = {}
        self._by_pid: Dict[int, bytes] = {}
        self._lru: "collections.OrderedDict[bytes, None]" = (
            collections.OrderedDict())
        self.stats = PageStats()

    # -- core refcounting ---------------------------------------------------
    def refs(self, pid: int) -> int:
        return self._refs[pid]

    def in_use(self) -> int:
        """Pages with at least one reference (including zero page)."""
        return sum(1 for r in self._refs if r > 0)

    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Return a fresh page id with refcount 1.

        Evicts least-recently-used index-only interned pages if the
        free list is empty; raises PagePoolExhausted when nothing can
        be evicted."""
        if not self._free and not self._evict_one():
            raise PagePoolExhausted(
                f"page pool exhausted: all {self.num_pages} pages "
                f"referenced (no evictable interned pages)")
        pid = self._free.pop()
        assert self._refs[pid] == 0, (pid, self._refs[pid])
        self._refs[pid] = 1
        self.stats.allocs += 1
        return pid

    def retain(self, pid: int) -> int:
        if self._refs[pid] <= 0:
            raise ValueError(f"retain on unreferenced page {pid}")
        self._refs[pid] += 1
        return pid

    def release(self, pid: int) -> None:
        if pid == ZERO_PAGE:
            return
        if self._refs[pid] <= 0:
            raise ValueError(f"release on unreferenced page {pid}")
        self._refs[pid] -= 1
        if self._refs[pid] == 0:
            key = self._by_pid.get(pid)
            if key is not None:
                # should not happen: the index holds its own ref
                raise AssertionError(
                    f"interned page {pid} dropped to refcount 0")
            self._free.append(pid)
            self.stats.frees += 1
        elif self._refs[pid] == 1 and pid in self._by_pid:
            # only the index references it now -> eviction candidate
            self._lru[self._by_pid[pid]] = None

    # -- prefix interning ---------------------------------------------------
    def lookup(self, key: bytes) -> Optional[int]:
        """Return the interned pid for `key` (retaining it for the
        caller) or None on miss."""
        pid = self._index.get(key)
        if pid is None:
            self.stats.prefix_misses += 1
            return None
        self.stats.prefix_hits += 1
        self._lru.pop(key, None)  # referenced again: not evictable
        self._refs[pid] += 1
        return pid

    def intern(self, key: bytes, pid: int) -> None:
        """Publish `pid` (caller holds a ref) under `key`.  The index
        takes its own reference so the page outlives the request."""
        if key in self._index:
            return  # raced with itself across buckets; keep first
        if self._refs[pid] <= 0:
            raise ValueError(f"intern of unreferenced page {pid}")
        self._index[key] = pid
        self._by_pid[pid] = key
        self._refs[pid] += 1

    def _evict_one(self) -> bool:
        while self._lru:
            key, _ = self._lru.popitem(last=False)
            pid = self._index.get(key)
            if pid is None or self._refs[pid] != 1:
                continue  # stale candidate
            del self._index[key]
            del self._by_pid[pid]
            self._refs[pid] = 0
            self._free.append(pid)
            self.stats.frees += 1
            self.stats.evictions += 1
            return True
        return False

    # -- invariants ---------------------------------------------------------
    def check_invariants(self) -> None:
        """Assert every structural invariant the pool is built on; the
        property-test suite (tests/test_paged.py) calls this after
        every randomized operation.  Raises AssertionError with the
        violated condition spelled out.

        1. refcounts are never negative;
        2. the zero page is permanently pinned: refs >= 1, never on
           the free list, never interned;
        3. the free list is exactly the refcount-0 pages, each once;
        4. the intern index is a bijection (key <-> pid both ways) and
           every interned page holds at least the index's own ref;
        5. every LRU eviction candidate is index-only (refs == 1 and
           interned) or stale (already evicted/re-referenced — those
           are skipped lazily by _evict_one)."""
        assert all(r >= 0 for r in self._refs), \
            f"negative refcount: {self._refs}"
        assert self._refs[ZERO_PAGE] >= 1, "zero page lost its pin"
        assert ZERO_PAGE not in self._free, "zero page on the free list"
        assert ZERO_PAGE not in self._by_pid, "zero page interned"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), \
            f"duplicate pids on the free list: {sorted(self._free)}"
        zero_ref = {pid for pid in range(self.num_pages)
                    if self._refs[pid] == 0}
        assert free_set == zero_ref, \
            (f"free list {sorted(free_set)} != refcount-0 pages "
             f"{sorted(zero_ref)}")
        assert len(self._index) == len(self._by_pid), \
            "intern index and reverse map disagree in size"
        for key, pid in self._index.items():
            assert self._by_pid.get(pid) == key, \
                f"intern bijection broken for pid {pid}"
            assert self._refs[pid] >= 1, \
                f"interned page {pid} has no reference"
        for key in self._lru:
            pid = self._index.get(key)
            if pid is not None:  # stale entries are legal (lazy purge)
                assert self._refs[pid] >= 1, \
                    f"LRU candidate {pid} unreferenced"

    # -- copy-on-write ------------------------------------------------------
    def ensure_private(self, pid: int) -> Tuple[int, Optional[int]]:
        """Make `pid` exclusively owned by the caller before a write.

        Returns (new_pid, copy_src): copy_src is None when the page was
        already private, else the page whose device contents must be
        copied into new_pid (the zero page for fresh decode pages —
        h/z partials accumulate onto the page, so recycled pages must
        start zeroed).  The caller's ref on the old page is released."""
        if self._refs[pid] == 1 and pid != ZERO_PAGE:
            return pid, None
        new_pid = self.alloc()
        self.release(pid)
        self.stats.cow_copies += 1
        return new_pid, pid
