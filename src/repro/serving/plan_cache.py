"""Cross-request SLA plan cache (DESIGN.md "Streaming DiT service").

Sparse-vDiT (arXiv:2506.03065) shows per-(layer, head) block-sparsity
patterns repeat across *requests*, not just across adjacent timesteps —
so a multi-user denoise service can amortize SLA's planning cost
fleet-wide. This module is that amortization: a small LRU store of
per-(layer, timestep-bucket) `SLAPlan` rows, keyed by
`core.plan.plan_compat_key` (the config + shape fields under which two
plans are interchangeable) plus a coarse timestep bucket.

Reuse is *validated*, never blind: the DiffusionScheduler hands a
cached stack to the request's first forward with a drift threshold, and
the existing `plan_drift`/`refresh_plan` machinery decides per layer
whether the cached structure still fits the new sample's (q, k). Layers
that re-plan count as **invalidations** (and their fresh rows are
written back); layers that hold count as validated reuse. Entries are
stored serialized (`core.plan.serialize_plan` — host numpy, no device
memory) and round-trip bitwise through `deserialize_plan`.

Granularity: keys are (compat, layer, bucket) — per-layer, matching the
observation that sparsity structure is a per-layer property — but the
scheduler always reads/writes whole per-layer stacks, so `get` hits
only when every layer of a bucket is present (LRU may evict a bucket
partially; the next lookup then misses and repopulates it whole).
"""
from __future__ import annotations

import collections
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core.config import SLAConfig


class PlanCache:
    """LRU cache of per-(layer, timestep-bucket) serialized SLAPlan rows.

    Counters (all monotonic):
      hits / misses      — whole-bucket lookups at request admission
      invalidations      — cached layers whose drift validation re-planned
      evictions          — per-(layer, bucket) entries dropped by the LRU
      puts               — per-(layer, bucket) entries written
    """

    def __init__(self, cfg: SLAConfig, num_layers: int, *,
                 t_buckets: int = 8, max_entries: int = 256):
        if t_buckets < 1:
            raise ValueError(f"t_buckets must be >= 1 (got {t_buckets})")
        if max_entries < num_layers:
            raise ValueError(
                f"max_entries ({max_entries}) < num_layers ({num_layers}) "
                "— the LRU could never hold one complete bucket")
        self.cfg = cfg
        self.num_layers = int(num_layers)
        self.t_buckets = int(t_buckets)
        self.max_entries = int(max_entries)
        # key (compat, layer, bucket) -> serialized plan dict; ordered
        # oldest-first (OrderedDict.move_to_end marks recency)
        self._entries: "collections.OrderedDict[tuple, dict]" = \
            collections.OrderedDict()
        self._compat: Optional[tuple] = None
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.evictions = 0
        self.puts = 0

    def __len__(self) -> int:
        return len(self._entries)

    def bucket(self, t: float) -> int:
        """Timestep t in (0, 1] -> bucket id in [0, t_buckets)."""
        return int(min(max(float(t), 0.0) * self.t_buckets,
                       self.t_buckets - 1))

    def _compat_of(self, plan_row) -> tuple:
        # leaves are (L, 1, H, Tm, Tn) — static facts off the mc leaf
        _, _, h, tm, tn = plan_row.mc.shape
        return plan_lib.plan_compat_key(self.cfg, h, tm, tn)

    def _check_compat(self, plan_row) -> tuple:
        key = self._compat_of(plan_row)
        if self._compat is None:
            self._compat = key
        elif key != self._compat:
            raise ValueError(
                f"plan incompatible with cache: {key} != {self._compat}")
        return key

    # -- whole-stack API (what the DiffusionScheduler speaks) ------------
    def get(self, bucket: int):
        """Stacked per-layer plans (leaves (L, 1, ...)) for `bucket`, or
        None. Counts one hit or miss; a hit refreshes LRU recency for
        every layer of the bucket."""
        if self._compat is None:
            self.misses += 1
            return None
        keys = [(self._compat, layer, bucket)
                for layer in range(self.num_layers)]
        if not all(k in self._entries for k in keys):
            self.misses += 1
            return None
        self.hits += 1
        rows = []
        for k in keys:
            self._entries.move_to_end(k)
            rows.append(plan_lib.deserialize_plan(self._entries[k]))
        return jax.tree_util.tree_map(
            lambda *ls: jnp.concatenate(ls, axis=0), *rows)

    def put(self, bucket: int, plan_stack) -> None:
        """Store a per-layer stack (leaves (L, 1, ...)) under `bucket`,
        overwriting any existing layers and evicting LRU overflow."""
        self._check_compat(plan_stack)
        for layer in range(self.num_layers):
            row = jax.tree_util.tree_map(
                lambda leaf: leaf[layer:layer + 1], plan_stack)
            self._store(layer, bucket, row)
        self._evict()

    def put_if_absent(self, bucket: int, plan_stack) -> bool:
        """`put` unless the bucket is already fully present (does not
        count a hit/miss — this is opportunistic population as requests
        cross bucket boundaries mid-flight, not a lookup)."""
        if self._compat is not None and all(
                (self._compat, layer, bucket) in self._entries
                for layer in range(self.num_layers)):
            return False
        self.put(bucket, plan_stack)
        return True

    def update(self, bucket: int, plan_stack, replanned) -> int:
        """Write back drift-invalidated layers after a validated reuse.

        `replanned`: (L,) bools from the forward's drift info — True
        layers had their cached structure rejected and rebuilt; their
        fresh rows replace the cached entries and count as
        invalidations. Returns the invalidation count."""
        self._check_compat(plan_stack)
        flags = np.asarray(replanned).reshape(self.num_layers, -1)
        flags = flags.any(axis=1)
        n = 0
        for layer in range(self.num_layers):
            if not flags[layer]:
                continue
            row = jax.tree_util.tree_map(
                lambda leaf: leaf[layer:layer + 1], plan_stack)
            self._store(layer, bucket, row)
            n += 1
        self.invalidations += n
        self._evict()
        return n

    # -- internals -------------------------------------------------------
    def _store(self, layer: int, bucket: int, plan_row) -> None:
        key = (self._compat, layer, bucket)
        self._entries[key] = plan_lib.serialize_plan(plan_row)
        self._entries.move_to_end(key)
        self.puts += 1

    def _evict(self) -> None:
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "evictions": self.evictions, "puts": self.puts,
                "entries": len(self._entries)}
