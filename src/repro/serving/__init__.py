"""Serving layer: v2 continuous-batching API, the disaggregated
prefill/decode worker pools, and the v1 static engine."""
from repro.serving.api import (PrefillEngine, RequestMetrics,
                               RequestState, SamplingParams, Scheduler,
                               ServedRequest, ServeStats, StreamEvent)
from repro.serving.disagg import (DecodeWorker, DisaggScheduler,
                                  DisaggStats, HandoffBundle,
                                  PrefillWorker, least_loaded)
from repro.serving.engine import Request, ServingEngine

__all__ = [
    "DecodeWorker", "DisaggScheduler", "DisaggStats", "HandoffBundle",
    "PrefillEngine", "PrefillWorker", "Request", "RequestMetrics",
    "RequestState", "SamplingParams", "Scheduler", "ServedRequest",
    "ServeStats", "ServingEngine", "StreamEvent", "least_loaded",
]
