"""Serving layer: v2 continuous-batching API + the v1 static engine."""
from repro.serving.api import (RequestMetrics, RequestState, SamplingParams,
                               Scheduler, ServedRequest, ServeStats,
                               StreamEvent)
from repro.serving.engine import Request, ServingEngine

__all__ = [
    "Request", "RequestMetrics", "RequestState", "SamplingParams",
    "Scheduler", "ServedRequest", "ServeStats", "ServingEngine",
    "StreamEvent",
]
