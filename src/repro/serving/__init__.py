"""Serving layer: v2 continuous-batching API, the disaggregated
prefill/decode worker pools, the streaming DiT denoise service, and
the v1 static engine."""
from repro.serving.api import (PrefillEngine, RequestMetrics,
                               RequestState, SamplingParams, Scheduler,
                               ServedRequest, ServeStats, StreamEvent,
                               stats_json_payload)
from repro.serving.diffusion import (DenoiseParams, DenoiseRequest,
                                     DiffusionScheduler)
from repro.serving.disagg import (DecodeWorker, DisaggScheduler,
                                  DisaggStats, HandoffBundle,
                                  PrefillWorker, least_loaded)
from repro.serving.engine import Request, ServingEngine
from repro.serving.plan_cache import PlanCache

__all__ = [
    "DecodeWorker", "DenoiseParams", "DenoiseRequest",
    "DiffusionScheduler", "DisaggScheduler", "DisaggStats",
    "HandoffBundle", "PlanCache", "PrefillEngine", "PrefillWorker",
    "Request", "RequestMetrics", "RequestState", "SamplingParams",
    "Scheduler", "ServedRequest", "ServeStats", "ServingEngine",
    "StreamEvent", "least_loaded", "stats_json_payload",
]
