"""Disaggregated prefill/decode serving (DESIGN.md "Disaggregated
serving").

The continuous-batching Scheduler runs admission (quadratic prefill)
and decode (O(1)-state per token with decode-SLA) on ONE worker, so a
long prompt and the token stream fight for the same dispatch queue.
This module splits them into two worker pools with an explicit state
handoff:

  * `PrefillWorker` — runs the (1, bucket) prefill (blocking, or one
    chunk per tick through PR 8's chunked-prefill machinery) on a
    shared `PrefillEngine`, and produces a `HandoffBundle`: the batch-1
    prefill cache (KV rows + decode-SLA plan rows, pooled q/k features,
    H/Z linear state — exactly the leaves `insert_slot` /
    `insert_slot_paged` scatter), the first-token logits row, and the
    padded prompt.
  * `DecodeWorker` — wraps a full `Scheduler` whose queue stays empty:
    admission happens only through `Scheduler.admit_external`, which
    runs blocking admission's tail verbatim, so tokens are bitwise what
    a single-Scheduler run would produce. Decode runs the existing
    rolled `_decode_multi` drain ticks (or per-token steps).
  * `DisaggScheduler` — the control plane: a tick-driven loop that
    assigns queued requests to idle prefill workers, routes finished
    bundles to the least-loaded decode worker, and drives the fault
    machinery from `distributed/fault_tolerance.py`:

      - a `FaultPlan` injects deterministic kill / straggle / flake
        events by tick;
      - every worker tick runs under `run_with_retries` (flakes are
        absorbed with recorded backoff);
      - measured decode-tick durations feed a shared
        `StragglerWatchdog`; a flagged worker is DRAINED — it finishes
        its in-flight requests but takes no new ones;
      - a killed decode worker's in-flight requests REQUEUE from their
        retained handoff bundles (a killed prefill worker's from
        scratch). Greedy decode is deterministic, so a replayed bundle
        reproduces the lost trajectory bitwise. Exceeding
        `max_requeues` returns the request to the queue (state QUEUED,
        no slot — the PR 5 no-half-admitted-limbo invariant) and raises
        loudly.

Requeue determinism requires prefill be a pure function of (padded
prompt bytes, bucket), so `plan_reuse` must stay "off" here — adaptive
plan reuse would make a re-prefill depend on every request served
since, and a requeued request could come back with different tokens.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterator, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.distributed.fault_tolerance import (FaultEvent, FaultPlan,
                                              StragglerWatchdog,
                                              run_with_retries)
from repro.serving.api import (PrefillEngine, RequestState,
                               SamplingParams, Scheduler, ServedRequest,
                               StreamEvent, block_bucket,
                               check_serving_family,
                               normalize_drift_threshold)


@dataclasses.dataclass
class HandoffBundle:
    """Everything a decode worker needs to adopt a prefilled request —
    and everything a REQUEUE needs to replay it after the worker dies.

    `cache` is the batch-1 prefill cache pytree ({"k", "v", "pos"} and,
    with decode-SLA, the "sla" state: per-block h/z partials, pooled
    q/k features, live-row LUTs, plan rows) — the exact argument
    `insert_slot` / `insert_slot_paged` scatter into a slot. Bundles
    are retained by the DisaggScheduler until the request finishes;
    they are immutable (jitted scatters never mutate their inputs), so
    one bundle can be replayed any number of times."""

    rid: int
    toks: np.ndarray      # (1, bucket) left-padded prompt
    bucket: int
    cache: object         # batch-1 prefill cache pytree
    logits: np.ndarray    # (1, vocab) first-token logits row
    prefilled: int        # prompt tokens the prefill actually dispatched


@dataclasses.dataclass
class DisaggStats:
    """Control-plane accounting; per-pool decode counters live on each
    DecodeWorker's own Scheduler stats (see `DisaggScheduler.pool_stats`)."""

    ticks: int = 0
    submitted: int = 0
    completed: int = 0
    handoffs: int = 0
    requeues: int = 0
    kills: int = 0
    straggler_drains: int = 0
    retries: int = 0
    drain_fallbacks: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0
    prefill_s: float = 0.0
    # prefill-pool occupancy: busy worker-ticks over live worker-ticks
    prefill_busy_steps: int = 0
    prefill_steps_total: int = 0

    def prefill_occupancy(self) -> float:
        return self.prefill_busy_steps / max(1, self.prefill_steps_total)


@dataclasses.dataclass
class _PrefillTask:
    """One request's prefill in flight on a worker (the pool-side
    analogue of api._PrefillJob, minus pages — a prefill worker owns no
    PagePool; pages are claimed by the decode worker at admission)."""

    r: ServedRequest
    toks: np.ndarray        # (1, bucket) left-padded prompt
    bucket: int
    carry: object = None
    num_chunks: int = 0
    next_chunk: int = 0
    dispatched: int = 0
    last_hidden: object = None


class PrefillWorker:
    """One prefill lane over the pool-shared PrefillEngine: blocking
    (whole prompt in one tick) or chunked (one block-aligned chunk per
    tick, with carry-snapshot resume at shared prefixes)."""

    def __init__(self, wid: int, engine: PrefillEngine):
        self.wid = wid
        self.engine = engine
        self.alive = True
        self.straggle_factor = 1.0
        self.flakes_pending = 0
        self.task: Optional[_PrefillTask] = None

    @property
    def name(self) -> str:
        return f"prefill:{self.wid}"

    @property
    def busy(self) -> bool:
        return self.task is not None

    def assign(self, r: ServedRequest, toks: np.ndarray, bucket: int):
        assert self.task is None, f"{self.name} already busy"
        task = _PrefillTask(r=r, toks=toks, bucket=bucket)
        ct = self.engine.chunk_tokens
        if ct:
            task.carry = self.engine.carry_proto(bucket)
            task.num_chunks = -(-bucket // ct)
            # resume past any chunk-boundary prefix another worker (or
            # an earlier request) already computed — carries are bitwise
            # recomputation, so resume preserves parity (PR 8)
            for c in range(task.num_chunks - 1, 0, -1):
                snap = self.engine.carry_get(
                    (bucket, toks[0, :c * ct].tobytes()))
                if snap is not None:
                    task.carry = snap
                    task.next_chunk = c
                    task.dispatched = 0
                    break
        self.task = task

    def tick(self, stats: DisaggStats
             ) -> Optional[Tuple[ServedRequest, HandoffBundle]]:
        """Advance the task one step: the whole prompt (blocking) or
        one chunk (chunked). Returns (request, bundle) on completion."""
        task = self.task
        eng = self.engine
        ct = eng.chunk_tokens
        t0 = time.time()
        if not ct:
            last_hidden, cache, _ = eng.run(jnp.asarray(task.toks),
                                            None, None, 0)
            task.dispatched = task.bucket
        else:
            lo = task.next_chunk * ct
            hi = min(lo + ct, task.bucket)
            carry, last_hidden = eng.chunk(
                jnp.asarray(task.toks[:, lo:hi]), task.carry,
                jnp.int32(lo))
            carry = jax.block_until_ready(carry)
            stats.prefill_chunks += 1
            task.carry = carry
            task.last_hidden = last_hidden
            task.dispatched += hi - lo
            if hi < task.bucket:
                eng.carry_put((task.bucket, task.toks[0, :hi].tobytes()),
                              carry)
            task.next_chunk += 1
            if task.next_chunk < task.num_chunks:
                stats.prefill_s += time.time() - t0
                return None
            cache = eng.finalize(task.carry)
            last_hidden = task.last_hidden
        bundle = HandoffBundle(rid=task.r.rid, toks=task.toks,
                               bucket=task.bucket, cache=cache,
                               logits=eng.logits(last_hidden),
                               prefilled=task.dispatched)
        stats.prefill_s += time.time() - t0
        stats.prefill_tokens += task.dispatched
        self.task = None
        return task.r, bundle


class DecodeWorker:
    """One decode pool member: a full Scheduler whose queue stays
    empty — requests enter only through `admit_external` and leave by
    finishing (or by the worker dying, in which case the whole
    Scheduler — slots, PagePool, live cache — is abandoned, like a
    lost host's HBM)."""

    def __init__(self, wid: int, sched: Scheduler,
                 step_mode: str = "roll"):
        if step_mode not in ("roll", "token"):
            raise ValueError(f"unknown decode step_mode {step_mode!r}; "
                             "expected 'roll' or 'token'")
        self.wid = wid
        self.sched = sched
        self.step_mode = step_mode
        self.alive = True
        self.draining = False
        self.straggle_factor = 1.0
        self.flakes_pending = 0
        self.admitted = 0

    @property
    def name(self) -> str:
        return f"decode:{self.wid}"

    @property
    def load(self) -> int:
        return sum(1 for r in self.sched._slots if r is not None)

    def free_slots(self) -> List[int]:
        return self.sched.free_slots()

    def in_flight(self) -> List[ServedRequest]:
        """Resident requests in slot order (deterministic requeue order)."""
        return [r for r in self.sched._slots if r is not None]

    def admit(self, r: ServedRequest, bundle: HandoffBundle, *,
              plan_built: bool, prefilled: int) -> List[StreamEvent]:
        slot = self.free_slots()[0]
        self.admitted += 1
        return self.sched.admit_external(
            r, slot, bundle.cache, bundle.logits, bundle.toks,
            bundle.bucket, prefilled=prefilled, plan_built=plan_built,
            start_emitted=True)

    def tick(self) -> List[StreamEvent]:
        """One decode advance: a rolled drain tick (`_decode_multi`
        over min-remaining-budget steps) or one per-token step — the
        two are bitwise-equivalent per slot (PR 6), 'token' just gives
        fault tests per-token kill granularity."""
        if self.step_mode == "roll":
            return self.sched._drain_tick()
        return self.sched.step()


def least_loaded(workers) -> Optional[object]:
    """Deterministic least-loaded pick: fewest resident requests, ties
    to the lowest worker id. Returns None if `workers` is empty."""
    best = None
    for w in workers:
        if best is None or (w.load, w.wid) < (best.load, best.wid):
            best = w
    return best


class DisaggScheduler:
    """Disaggregated prefill/decode serving control plane.

    The public surface mirrors the Scheduler: `submit()` enqueues,
    `tick()` advances every pool one step, `drain()` runs to
    completion, `stream()` yields events. Faults are injected
    deterministically via `fault_plan`; `clock` and `sleep` are
    injectable so fault tests measure virtual seconds and never
    actually back off."""

    def __init__(self, cfg: ArchConfig, params, *,
                 prefill_workers: int = 1, decode_workers: int = 2,
                 slots_per_worker: int = 2, max_len: int = 512,
                 backend: str = "gather",
                 decode_sla: Optional[bool] = None,
                 prefill_bucket: Optional[int] = None,
                 compute_dtype=jnp.bfloat16,
                 paged: Optional[bool] = None,
                 pool_pages: Optional[int] = None,
                 prefill_chunk_blocks: Optional[int] = None,
                 decode_step_mode: str = "roll",
                 fault_plan: Optional[FaultPlan] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 max_requeues: int = 1, max_retries: int = 2,
                 clock=time.time, sleep=time.sleep):
        from repro.core import backends as backend_registry

        if prefill_workers < 1 or decode_workers < 1:
            raise ValueError("need at least one worker per pool (got "
                             f"prefill={prefill_workers}, "
                             f"decode={decode_workers})")
        backend = backend_registry.resolve(backend)
        cfg.sla.validate()
        if decode_sla is None:
            decode_sla = cfg.sla.decode_mode == "sla"
        if paged is None:
            paged = cfg.sla.paged
        if prefill_chunk_blocks is None:
            prefill_chunk_blocks = cfg.sla.prefill_chunk_blocks
        self.cfg = cfg
        self.params = params
        self.mdl = registry.get_model(cfg)
        check_serving_family(cfg, self.mdl, "off", decode_sla,
                             continuous=True)
        self.backend = backend
        self.decode_sla = decode_sla
        self.paged = paged
        self.block = max(cfg.sla.block_q, 1)
        self.max_len = block_bucket(max_len, self.block) \
            if (decode_sla or paged) else max_len
        self.compute_dtype = compute_dtype
        if prefill_chunk_blocks is not None:
            if prefill_chunk_blocks < 1:
                raise ValueError(
                    f"prefill_chunk_blocks must be >= 1 (got "
                    f"{prefill_chunk_blocks})")
            chk = getattr(self.mdl, "check_chunked_prefill", None)
            if chk is None:
                raise ValueError(
                    f"prefill_chunk_blocks requires a model family with "
                    f"chunked prefill; family {cfg.family!r} has none")
            chk(cfg, backend)
        self._chunk_tokens = (prefill_chunk_blocks or 0) * self.block

        # ONE engine shared by every prefill worker: jit caches and
        # chunk-carry snapshots amortize across the pool, and prefill
        # stays a pure function of (padded prompt, bucket) — plan_reuse
        # is pinned off (see module docstring: requeue determinism)
        self._engine = PrefillEngine(
            cfg, params, self.mdl, backend=backend,
            compute_dtype=compute_dtype, decode_sla=decode_sla,
            max_len=self.max_len,
            drift_threshold=normalize_drift_threshold(cfg, None),
            plan_reuse="off", chunk_tokens=self._chunk_tokens)
        self._prefill_pool = [PrefillWorker(i, self._engine)
                              for i in range(prefill_workers)]
        # decode workers own their Schedulers outright — separate slot
        # pools, separate PagePools, separate live caches (one "host"
        # each). A worker's Scheduler never sees prefill_chunk: chunking
        # happens on the prefill pool; admission here is bundle-only —
        # so the workers get a cfg with the chunk default nulled out.
        dcfg = dataclasses.replace(
            cfg, sla=cfg.sla.replace(prefill_chunk_blocks=None))
        self._decode_pool = [
            DecodeWorker(
                i,
                Scheduler(dcfg, params, num_slots=slots_per_worker,
                          max_len=self.max_len, backend=backend,
                          decode_sla=decode_sla, plan_reuse="off",
                          prefill_bucket=prefill_bucket,
                          compute_dtype=compute_dtype, paged=paged,
                          pool_pages=pool_pages),
                step_mode=decode_step_mode)
            for i in range(decode_workers)]
        self.slots_per_worker = slots_per_worker

        self.stats = DisaggStats()
        self._faults = fault_plan or FaultPlan()
        self._watchdog = watchdog or StragglerWatchdog()
        self._max_requeues = max_requeues
        self._max_retries = max_retries
        self._clock = clock
        self._sleep = sleep
        self._tick_no = 0
        self._stall_ticks = 0

        self._queue: Deque[ServedRequest] = collections.deque()
        self._requests: List[ServedRequest] = []
        self._handoffs: Deque[Tuple[ServedRequest, HandoffBundle]] = \
            collections.deque()
        self._bundles: Dict[int, HandoffBundle] = {}
        self._owner: Dict[int, DecodeWorker] = {}
        self._requeue_counts: Dict[int, int] = {}
        self._started: Set[int] = set()
        self._admitted_once: Set[int] = set()
        self._next_rid = 0
        self._bucket = (block_bucket(prefill_bucket, self.block)
                        if prefill_bucket else None)

    # -- public API --------------------------------------------------------
    def submit(self, prompt, sampling: Optional[SamplingParams] = None
               ) -> int:
        """Enqueue one request; returns its rid. O(1), never blocks."""
        sampling = (sampling or SamplingParams()).validate()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        bucket = max(block_bucket(len(prompt), self.block),
                     self._bucket or 0)
        need = bucket + sampling.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"max_len={self.max_len} cannot hold a "
                f"{len(prompt)}-token prompt (shared prefill bucket "
                f"{bucket}) plus {sampling.max_new_tokens} new tokens; "
                f"raise max_len to >= {need}")
        r = ServedRequest(rid=self._next_rid, prompt=prompt,
                          sampling=sampling)
        r.metrics.submit_t = time.time()
        self._next_rid += 1
        self._queue.append(r)
        self._requests.append(r)
        self.stats.submitted += 1
        return r.rid

    @property
    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self._handoffs)
                or any(w.busy for w in self._prefill_pool if w.alive)
                or any(w.load for w in self._decode_pool if w.alive))

    def tick(self) -> List[StreamEvent]:
        """One control-plane step: fire due faults, advance the prefill
        pool one step each, route finished bundles to decode workers,
        advance every loaded decode worker one step (watchdogged)."""
        self._tick_no += 1
        self.stats.ticks += 1
        events: List[StreamEvent] = []
        for ev in self._faults.due(self._tick_no):
            self._apply_fault(ev)
        self._prefill_tick(events)
        self._assign_handoffs(events)
        self._decode_tick(events)
        if events:
            self._stall_ticks = 0
        else:
            self._stall_ticks += 1
            if self._stall_ticks > 10_000 and self.has_work:
                raise RuntimeError(
                    "disaggregated scheduler made no progress for "
                    "10000 ticks with work pending — a pool is wedged "
                    "(all workers draining with full slots, or a fault "
                    "left no capacity)")
        return events

    def drain(self) -> List[ServedRequest]:
        """Run to completion; returns all requests in submission order."""
        while self.has_work:
            self.tick()
        return list(self._requests)

    def stream(self) -> Iterator[StreamEvent]:
        while self.has_work:
            yield from self.tick()

    def decode_occupancy(self) -> float:
        """Pool-wide decode-slot utilization: active slot-steps over
        total slot-steps, summed across every decode worker that ever
        stepped (dead workers' history included — their steps happened)."""
        act = sum(w.sched.stats.slot_steps_active
                  for w in self._decode_pool)
        tot = sum(w.sched.stats.slot_steps_total
                  for w in self._decode_pool)
        return act / max(1, tot)

    def pool_stats(self) -> dict:
        """Per-worker breakdown for reporting (benchmarks, serve CLI)."""
        return {
            "prefill": [{"worker": w.name, "alive": w.alive,
                         "busy": w.busy}
                        for w in self._prefill_pool],
            "decode": [{"worker": w.name, "alive": w.alive,
                        "draining": w.draining, "admitted": w.admitted,
                        "occupancy": w.sched.stats.occupancy(),
                        "decode_tokens": w.sched.stats.decode_tokens}
                       for w in self._decode_pool],
        }

    # -- fault machinery ---------------------------------------------------
    def _apply_fault(self, ev: FaultEvent):
        pool = (self._prefill_pool if ev.pool == "prefill"
                else self._decode_pool)
        if not (0 <= ev.worker < len(pool)):
            raise ValueError(
                f"FaultPlan names {ev.pool} worker {ev.worker}, but the "
                f"pool has {len(pool)} workers")
        w = pool[ev.worker]
        if ev.kind == "straggle":
            w.straggle_factor = ev.factor
        elif ev.kind == "flake":
            w.flakes_pending += ev.failures
        elif ev.kind == "kill":
            self._kill_worker(ev.pool, w)

    def _kill_worker(self, pool: str, w):
        """Hard worker loss: the worker's compute state (slots, pages,
        live cache / prefill carry) is abandoned wholesale, and every
        in-flight request is reset to an un-admitted state and requeued
        — from its retained handoff bundle if one exists (decode loss),
        from scratch otherwise (prefill loss). A request over its
        requeue budget goes back to the QUEUE (never a half-admitted
        slot) and the loss is raised loudly."""
        if not w.alive:
            return
        w.alive = False
        self.stats.kills += 1
        lost: List[Tuple[ServedRequest, Optional[HandoffBundle]]] = []
        if pool == "prefill":
            if w.task is not None:
                lost.append((w.task.r, None))
                w.task = None
        else:
            lost = [(r, self._bundles.get(r.rid))
                    for r in w.in_flight()]
        over: List[int] = []
        for r, bundle in reversed(lost):  # appendleft preserves order
            self._owner.pop(r.rid, None)
            n = self._requeue_counts.get(r.rid, 0) + 1
            self._requeue_counts[r.rid] = n
            # reset to exactly the pre-admission state so a replay (or
            # a re-prefill) regenerates the trajectory from token 0
            r.state = RequestState.QUEUED
            r.slot = None
            r.tokens_out.clear()
            r.metrics.decode_tokens = 0
            r.metrics.first_token_t = 0.0
            r.metrics.finish_t = 0.0
            if n > self._max_requeues:
                self._bundles.pop(r.rid, None)
                self._queue.appendleft(r)
                over.append(r.rid)
                continue
            self.stats.requeues += 1
            if bundle is not None:
                self._handoffs.appendleft((r, bundle))
            else:
                self._queue.appendleft(r)
        if over:
            raise RuntimeError(
                f"request(s) {over} lost worker {w.name} after "
                f"exceeding max_requeues={self._max_requeues}; they "
                f"were returned to the queue (state QUEUED, no slot, "
                f"no partial tokens) — restore capacity and drain "
                f"again, nothing is half-admitted")

    def _worker_tick(self, w, fn):
        """Run one worker step under the retry contract: pending
        injected flakes surface as transient RuntimeErrors, absorbed by
        `run_with_retries` with the injected sleep."""
        def attempt():
            if w.flakes_pending > 0:
                w.flakes_pending -= 1
                raise RuntimeError(
                    f"injected transient fault: {w.name} at tick "
                    f"{self._tick_no}")
            return fn()
        return run_with_retries(attempt, max_retries=self._max_retries,
                                on_retry=self._note_retry,
                                sleep=self._sleep)

    def _note_retry(self, attempt: int, exc: Exception):
        self.stats.retries += 1

    # -- prefill pool ------------------------------------------------------
    def _round_bucket(self, plen: int) -> int:
        return block_bucket(plen, self.block)

    def _prefill_tick(self, events: List[StreamEvent]):
        alive = [w for w in self._prefill_pool if w.alive]
        if not alive:
            if self._queue or any(w.busy for w in self._prefill_pool):
                raise RuntimeError(
                    "every prefill worker is dead with requests still "
                    "queued — no admission path remains")
            return
        for w in alive:
            if not w.busy and self._queue:
                self._assign_prefill(w, self._queue.popleft(), events)
        for w in alive:
            self.stats.prefill_steps_total += 1
            if not w.busy:
                continue
            self.stats.prefill_busy_steps += 1
            done = self._worker_tick(w, lambda w=w: w.tick(self.stats))
            if done is not None:
                r, bundle = done
                self.stats.handoffs += 1
                self._bundles[r.rid] = bundle
                self._handoffs.append((r, bundle))

    def _assign_prefill(self, w: PrefillWorker, r: ServedRequest,
                        events: List[StreamEvent]):
        r.state = RequestState.PREFILLING
        t0 = time.time()
        r.metrics.admit_t = t0
        plen = len(r.prompt)
        if self._bucket is None or plen > self._bucket:
            self._bucket = self._round_bucket(plen)
        if self._bucket + r.sampling.max_new_tokens > self.max_len:
            # same loud no-limbo contract as Scheduler._admit_next: the
            # request goes back to the queue head BEFORE the raise
            self._queue.appendleft(r)
            r.state = RequestState.QUEUED
            raise ValueError(
                f"max_len={self.max_len} cannot hold request {r.rid}: "
                f"the shared prefill bucket grew to {self._bucket} and "
                f"{r.sampling.max_new_tokens} new tokens no longer "
                f"fit; raise max_len to >= "
                f"{self._bucket + r.sampling.max_new_tokens}")
        toks = np.zeros((1, self._bucket), np.int32)
        toks[0, self._bucket - plen:] = r.prompt  # left-pad
        w.assign(r, toks, self._bucket)
        if r.rid not in self._started:
            self._started.add(r.rid)
            events.append(StreamEvent(rid=r.rid, kind="start", t=t0))

    # -- decode pool -------------------------------------------------------
    def _pick_decode_worker(self) -> Optional[DecodeWorker]:
        """Least-loaded alive worker with a free slot; draining workers
        are skipped unless they are the ONLY live capacity (zero lost
        requests beats a clean drain)."""
        ready = [w for w in self._decode_pool
                 if w.alive and not w.draining and w.free_slots()]
        if ready:
            return least_loaded(ready)
        if not any(w.alive and not w.draining
                   for w in self._decode_pool):
            fallback = [w for w in self._decode_pool
                        if w.alive and w.free_slots()]
            if fallback:
                self.stats.drain_fallbacks += 1
                return least_loaded(fallback)
        return None

    def _assign_handoffs(self, events: List[StreamEvent]):
        while self._handoffs:
            if not any(w.alive for w in self._decode_pool):
                raise RuntimeError(
                    "every decode worker is dead with prefilled "
                    "requests awaiting handoff — no decode path "
                    "remains")
            w = self._pick_decode_worker()
            if w is None:
                return  # no free slot this tick; bundles wait
            r, bundle = self._handoffs.popleft()
            first = r.rid not in self._admitted_once
            self._admitted_once.add(r.rid)
            self._owner[r.rid] = w
            evs = w.admit(r, bundle, plan_built=first,
                          prefilled=bundle.prefilled if first else 0)
            self._collect(evs, events)

    def _decode_tick(self, events: List[StreamEvent]):
        for w in self._decode_pool:
            if not w.alive or w.load == 0:
                continue
            t0 = self._clock()
            evs = self._worker_tick(w, w.tick)
            dur = (self._clock() - t0) * w.straggle_factor
            self._collect(evs, events)
            if self._watchdog.record(dur, host_id=w.wid) \
                    and not w.draining:
                w.draining = True
                self.stats.straggler_drains += 1

    def _collect(self, evs: List[StreamEvent],
                 events: List[StreamEvent]):
        for ev in evs:
            if ev.kind == "finish":
                self.stats.completed += 1
                self._bundles.pop(ev.rid, None)
                self._owner.pop(ev.rid, None)
        events.extend(evs)
