"""Pure-jnp reference (oracle) for SLA — exact Algorithm 1 semantics.

Dense formulation used to validate the Pallas kernels and as the CPU
fallback path. All accumulation in f32.

Shapes: q, k, v: (B, H, N, D); qp = phi(q), kp = phi(k) same shape (f32).
mc: (B, H, Tm, Tn) int8 in {-1, 0, +1}.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.masks import NEG_INF, expand_mask

EPS = 1e-6


def _safe_div(num: jax.Array, den: jax.Array) -> jax.Array:
    """NaN-free (also under autodiff) num/den with 0 where den <= EPS.

    The double-`where` keeps the untaken branch finite so its zero
    cotangent never multiplies an inf/NaN (f32 1/den**2 underflow)."""
    live = den > EPS
    safe = jnp.where(live, den, 1.0)
    return jnp.where(live, num / safe, 0.0)


def sparse_component(
    q: jax.Array, k: jax.Array, v: jax.Array, mc: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """O^s: softmax attention restricted to critical blocks.

    Returns (o_s (B,H,N,D) f32, lse (B,H,N) f32) — lse is the log-sum-exp
    over critical entries (Alg. 1 line 16, used by the backward pass).
    """
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("...nd,...md->...nm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    keep = expand_mask(mc == 1, cfg.block_q, cfg.block_kv)
    if cfg.causal:
        # Token-level causal mask inside critical blocks (the diagonal block
        # is always critical in causal mode; see masks.classify_blocks).
        n, m = s.shape[-2], s.shape[-1]
        keep = jnp.logical_and(keep, jnp.tril(jnp.ones((n, m), bool)))
    s = jnp.where(keep, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o_s = jnp.einsum("...nm,...md->...nd", p / l, v.astype(jnp.float32))
    lse = (m + jnp.log(l))[..., 0]
    return o_s, lse


def linear_component(
    qp: jax.Array, kp: jax.Array, v: jax.Array, mc: jax.Array, cfg: SLAConfig,
    a: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O^l: per-row-aggregated linear attention over marginal blocks (Eq. 5).

    Returns (o_l (B,H,N,D) f32, H (B,H,Tm,D,D) f32, Z (B,H,Tm,D) f32).
    Rows whose marginal set is empty produce exact zeros. `a` overrides
    the aggregation matrix (a plan's `marginal` leaf — value-identical
    to the mc-derived indicator, but it can carry the learned-routing
    straight-through gradients; DESIGN.md "Learned routing").
    """
    bq, bkv = cfg.block_q, cfg.block_kv
    n, d = v.shape[-2], v.shape[-1]
    tn = n // bkv
    kpb = kp.astype(jnp.float32).reshape(*kp.shape[:-2], tn, bkv, d)
    vb = v.astype(jnp.float32).reshape(*v.shape[:-2], tn, bkv, d)
    # Per KV block: h_j = phi(K_j)^T V_j (d x d), z_j = rowsum(phi(K_j)^T) (d,)
    h = jnp.einsum("...nkd,...nke->...nde", kpb, vb)
    z = jnp.sum(kpb, axis=-2)
    # Aggregate marginal blocks per query row — the TPU-native dense-matmul
    # form of the paper's App. A.3 pre-aggregation (see DESIGN.md).
    if a is None:
        a = (mc == 0).astype(jnp.float32)
    hi = jnp.einsum("...mn,...nde->...mde", a, h)
    zi = jnp.einsum("...mn,...nd->...md", a, z)
    tm = hi.shape[-3]
    qpb = qp.astype(jnp.float32).reshape(*qp.shape[:-2], tm, bq, d)
    num = jnp.einsum("...mqd,...mde->...mqe", qpb, hi)
    den = jnp.einsum("...mqd,...md->...mq", qpb, zi)[..., None]
    o_l = _safe_div(num, den)
    o_l = o_l.reshape(*qp.shape[:-2], n, d)
    return o_l, hi, zi


def full_linear(qp: jax.Array, kp: jax.Array, v: jax.Array) -> jax.Array:
    """Standard O(N d^2) linear attention over ALL tokens (baselines)."""
    kp32, v32, qp32 = (x.astype(jnp.float32) for x in (kp, v, qp))
    h = jnp.einsum("...nd,...ne->...de", kp32, v32)
    z = jnp.sum(kp32, axis=-2)
    num = jnp.einsum("...nd,...de->...ne", qp32, h)
    den = jnp.einsum("...nd,...d->...n", qp32, z)[..., None]
    return _safe_div(num, den)


def full_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
    scale: float | None = None,
) -> jax.Array:
    """Exact softmax attention (f32), the quality reference."""
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("...nd,...md->...nm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        n, m = s.shape[-2], s.shape[-1]
        s = jnp.where(jnp.tril(jnp.ones((n, m), bool)), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...nm,...md->...nd", p, v.astype(jnp.float32))


def sla_forward_reference(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array, mc: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
    marginal: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Reference forward: returns (O^s, O^l), both (B, H, N, D) f32.

    The caller combines them as O = O^s + Proj(O^l)  (Eq. 6).
    `marginal` optionally supplies the plan's aggregation matrix (see
    `linear_component`).
    """
    o_s, _ = sparse_component(q, k, v, mc, cfg, scale)
    o_l, _, _ = linear_component(qp, kp, v, mc, cfg, a=marginal)
    return o_s, o_l
