"""Attention execution backends: one registry, one `execute` interface.

The plan/execute split (DESIGN.md): `core/plan.py` classifies blocks and
builds LUTs once; this module runs the actual attention math given that
plan. Three built-in backends, all returning (O^s, O^l):

  reference  dense pure-jnp oracle (autodiff; O(N^2) compiled FLOPs —
             validation only)
  gather     LUT-gather XLA path whose compiled FLOPs equal the true
             sparse cost (training / dry-run / any-backend production)
  kernel     fused Pallas TPU kernels with custom_vjp (interpret mode
             on CPU)

`execute(plan, params, q, k, v, cfg, backend=...)` is the single entry
point every model goes through — it owns mode dispatch ("sla" /
"sparse_only" / "linear_only" / "l_plus_s" / "full"), the phi feature
maps, GQA head broadcast, and the learned Proj merge (Eq. 6). New
backends register with `@register_backend("name")`.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.phi import phi
from repro.core.plan import SLAPlan, plan_attention
from repro.core import reference as ref

Params = Dict[str, jax.Array]
# A backend maps (plan, q, k, v, qp, kp, cfg, scale) -> (O^s, O^l).
BackendFn = Callable[..., Tuple[jax.Array, jax.Array]]

_BACKENDS: Dict[str, BackendFn] = {}

# Legacy spellings from the pre-registry stringly-typed API.
_ALIASES = {"pallas": "kernel", "xla": "gather", "dense": "reference"}


def register_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    """Decorator: register `fn` as the SLA execution backend `name`."""

    def deco(fn: BackendFn) -> BackendFn:
        _BACKENDS[name] = fn
        return fn

    return deco


def resolve(name: str) -> str:
    """Canonical backend name for `name` (resolving legacy aliases).

    The ONE validation/error path for stringly-typed backend selection:
    drivers, benchmarks, and examples call this at entry so an unknown
    `backend=` fails loudly up front instead of silently falling back
    (or failing deep inside a jit trace)."""
    key = _ALIASES.get(name, name)
    if key not in _BACKENDS:
        raise ValueError(
            f"unknown SLA backend {name!r}; available: "
            f"{sorted(_BACKENDS)} (aliases: "
            f"{ {a: t for a, t in sorted(_ALIASES.items())} })")
    return key


def get_backend(name: str) -> BackendFn:
    return _BACKENDS[resolve(name)]


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


@register_backend("reference")
def _reference_backend(plan, q, k, v, qp, kp, cfg, scale):
    return ref.sla_forward_reference(q, k, v, qp, kp, plan.mc, cfg, scale)


@register_backend("gather")
def _gather_backend(plan, q, k, v, qp, kp, cfg, scale):
    from repro.core.block_sparse_xla import sla_forward_gather
    return sla_forward_gather(q, k, v, qp, kp, plan, cfg, scale)


@register_backend("kernel")
def _kernel_backend(plan, q, k, v, qp, kp, cfg, scale):
    from repro.kernels import ops as kops
    # interpret=True on CPU hosts; on a real TPU the kernel is compiled.
    interpret = jax.default_backend() != "tpu"
    return kops.sla_attention_core(q, k, v, qp, kp, plan, cfg,
                                   scale=scale, interpret=interpret)


def _repeat_kv(x: jax.Array, num_q_heads: int) -> jax.Array:
    """GQA: broadcast KV heads to match Q heads. (B, Hkv, N, D) -> (B, H, N, D)."""
    hkv = x.shape[1]
    if hkv == num_q_heads:
        return x
    assert num_q_heads % hkv == 0
    return jnp.repeat(x, num_q_heads // hkv, axis=1)


def execute(
    plan: Optional[SLAPlan],
    params: Optional[Params],
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: SLAConfig,
    scale: Optional[float] = None,
    backend: str = "reference",
) -> jax.Array:
    """Run SLA attention under `cfg.mode` with the given execution backend.

    q: (B, H, N, D); k, v: (B, Hkv, N, D) with Hkv | H. `plan` is the
    precomputed SLAPlan for (q, k); pass None to plan inline (the
    classic fused path — planning then costs on every call). Modes that
    need no block structure ("full", "linear_only") ignore the plan.

    Returns (B, H, N, D) in q.dtype.
    """
    backend = resolve(backend)  # fail loudly even in plan-free modes
    in_dtype = q.dtype
    h = q.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)

    if cfg.mode == "full":
        return ref.full_attention(q, k, v, cfg.causal, scale).astype(in_dtype)

    if cfg.mode == "linear_only":
        qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
        o = ref.full_linear(qp, kp, v)
        if params is not None:
            o = jnp.einsum("bhnd,hde->bhne", o, params["proj"].astype(jnp.float32))
        return o.astype(in_dtype)

    if plan is None:
        plan = plan_attention(q, k, cfg, scale)
    else:
        tm, tn = q.shape[2] // cfg.block_q, k.shape[2] // cfg.block_kv
        if plan.mc.shape[-2:] != (tm, tn):
            raise ValueError(
                f"stale SLAPlan: plan is for {plan.mc.shape[-2:]} blocks "
                f"but (q, k) need ({tm}, {tn}) — re-plan with "
                f"plan_attention(q, k, cfg)")

    if cfg.mode == "sparse_only":
        o_s, _ = ref.sparse_component(q, k, v, plan.mc, cfg, scale)
        return o_s.astype(in_dtype)

    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)

    if cfg.mode == "l_plus_s":
        o_s, _ = ref.sparse_component(q, k, v, plan.mc, cfg, scale)
        o_l = ref.full_linear(qp, kp, v)
        return (o_s + o_l).astype(in_dtype)

    if cfg.mode != "sla":
        raise ValueError(f"unknown SLA mode {cfg.mode!r}")

    o_s, o_l = get_backend(backend)(plan, q, k, v, qp, kp, cfg, scale)

    proj = params["proj"].astype(jnp.float32)
    o = o_s + jnp.einsum("bhnd,hde->bhne", o_l, proj)
    return o.astype(in_dtype)
