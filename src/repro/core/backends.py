"""Attention execution backends: one registry, one `execute` interface.

The plan/execute split (DESIGN.md): `core/plan.py` classifies blocks and
builds LUTs once; this module runs the actual attention math given that
plan. Three built-in backends, all returning (O^s, O^l):

  reference  dense pure-jnp oracle (autodiff; O(N^2) compiled FLOPs —
             validation only)
  gather     LUT-gather XLA path whose compiled FLOPs equal the true
             sparse cost (training / dry-run / any-backend production)
  kernel     fused Pallas TPU kernels with custom_vjp (interpret mode
             on CPU)

`execute(plan, params, q, k, v, cfg, backend=...)` is the single entry
point every model goes through — it owns mode dispatch ("sla" /
"sparse_only" / "linear_only" / "l_plus_s" / "full"), the phi feature
maps, GQA head broadcast, and the learned Proj merge (Eq. 6). New
backends register with `@register_backend("name")`.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.phi import phi
from repro.core.plan import SLAPlan, plan_attention
from repro.core import reference as ref

Params = Dict[str, jax.Array]
# A backend maps (plan, q, k, v, qp, kp, cfg, scale) -> (O^s, O^l).
BackendFn = Callable[..., Tuple[jax.Array, jax.Array]]

_BACKENDS: Dict[str, BackendFn] = {}

# Legacy spellings from the pre-registry stringly-typed API.
_ALIASES = {"pallas": "kernel", "xla": "gather", "dense": "reference"}


def register_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    """Decorator: register `fn` as the SLA execution backend `name`."""

    def deco(fn: BackendFn) -> BackendFn:
        _BACKENDS[name] = fn
        return fn

    return deco


def resolve(name: str) -> str:
    """Canonical backend name for `name` (resolving legacy aliases).

    The ONE validation/error path for stringly-typed backend selection:
    drivers, benchmarks, and examples call this at entry so an unknown
    `backend=` fails loudly up front instead of silently falling back
    (or failing deep inside a jit trace)."""
    key = _ALIASES.get(name, name)
    if key not in _BACKENDS:
        raise ValueError(
            f"unknown SLA backend {name!r}; available: "
            f"{sorted(_BACKENDS)} (aliases: "
            f"{ {a: t for a, t in sorted(_ALIASES.items())} })")
    return key


def get_backend(name: str) -> BackendFn:
    return _BACKENDS[resolve(name)]


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


@register_backend("reference")
def _reference_backend(plan, q, k, v, qp, kp, cfg, scale):
    # plan.marginal is value-identical to (mc == 0) but carries the
    # learned-routing straight-through gradients when present
    return ref.sla_forward_reference(q, k, v, qp, kp, plan.mc, cfg, scale,
                                     marginal=plan.marginal)


@register_backend("gather")
def _gather_backend(plan, q, k, v, qp, kp, cfg, scale):
    from repro.core.block_sparse_xla import sla_forward_gather
    return sla_forward_gather(q, k, v, qp, kp, plan, cfg, scale)


@register_backend("kernel")
def _kernel_backend(plan, q, k, v, qp, kp, cfg, scale):
    from repro.kernels import ops as kops
    # interpret=True on CPU hosts; on a real TPU the kernel is compiled.
    interpret = jax.default_backend() != "tpu"
    return kops.sla_attention_core(q, k, v, qp, kp, plan, cfg,
                                   scale=scale, interpret=interpret)


def _repeat_kv(x: jax.Array, num_q_heads: int) -> jax.Array:
    """GQA: broadcast KV heads to match Q heads. (B, Hkv, N, D) -> (B, H, N, D)."""
    hkv = x.shape[1]
    if hkv == num_q_heads:
        return x
    assert num_q_heads % hkv == 0
    return jnp.repeat(x, num_q_heads // hkv, axis=1)


def execute(
    plan: Optional[SLAPlan],
    params: Optional[Params],
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: SLAConfig,
    scale: Optional[float] = None,
    backend: str = "reference",
    routing: Optional[Params] = None,
) -> jax.Array:
    """Run SLA attention under `cfg.mode` with the given execution backend.

    q: (B, H, N, D); k, v: (B, Hkv, N, D) with Hkv | H. `plan` is the
    precomputed SLAPlan for (q, k); pass None to plan inline (the
    classic fused path — planning then costs on every call). Modes that
    need no block structure ("full", "linear_only") ignore the plan.
    `routing` holds the learned-routing scorer parameters for inline
    planning under cfg.routing_mode == "learned" (ignored when a plan
    is given — the plan already encodes its routing decisions).

    Returns (B, H, N, D) in q.dtype.
    """
    backend = resolve(backend)  # fail loudly even in plan-free modes
    cfg.validate()
    in_dtype = q.dtype
    h = q.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)

    if cfg.mode == "full":
        return ref.full_attention(q, k, v, cfg.causal, scale).astype(in_dtype)

    if cfg.mode == "linear_only":
        qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
        o = ref.full_linear(qp, kp, v)
        if params is not None:
            o = jnp.einsum("bhnd,hde->bhne", o, params["proj"].astype(jnp.float32))
        return o.astype(in_dtype)

    if plan is None:
        plan = plan_attention(q, k, cfg, scale, routing=routing)
    else:
        tm, tn = q.shape[2] // cfg.block_q, k.shape[2] // cfg.block_kv
        if plan.mc.shape[-2:] != (tm, tn):
            raise ValueError(
                f"stale SLAPlan: plan is for {plan.mc.shape[-2:]} blocks "
                f"but (q, k) need ({tm}, {tn}) — re-plan with "
                f"plan_attention(q, k, cfg)")

    if cfg.mode == "sparse_only":
        o_s, _ = ref.sparse_component(q, k, v, plan.mc, cfg, scale)
        return o_s.astype(in_dtype)

    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)

    if cfg.mode == "l_plus_s":
        o_s, _ = ref.sparse_component(q, k, v, plan.mc, cfg, scale)
        o_l = ref.full_linear(qp, kp, v)
        return (o_s + o_l).astype(in_dtype)

    if cfg.mode != "sla":
        raise ValueError(f"unknown SLA mode {cfg.mode!r}")

    o_s, o_l = get_backend(backend)(plan, q, k, v, qp, kp, cfg, scale)

    proj = params["proj"].astype(jnp.float32)
    o = o_s + jnp.einsum("bhnd,hde->bhne", o_l, proj)
    return o.astype(in_dtype)


# ---------------------------------------------------------------------------
# decode execution: one token against the static decode cache
# (DESIGN.md "Decode-time SLA")
# ---------------------------------------------------------------------------
# A decode backend maps (state, qg, qpg, pos, cfg, scale) -> (O^s, O^l),
# both (B, Hkv, G, D) f32, where G = H // Hkv is the GQA group size and
# `state` is the per-layer decode-cache slice:
#   k, v   : (B, Hkv, Smax, D)   static KV cache (Smax = Tn * block_kv)
#   hblk   : (B, Hkv, Tn, D, D)  per-block running  h_j = sum phi(k) v^T
#   zblk   : (B, Hkv, Tn, D)     per-block running  z_j = sum phi(k)
#   htot   : (B, Hkv, D, D)      running total      H   = sum_j h_j
#   ztot   : (B, Hkv, D)         running total      Z   = sum_j z_j
#   lut    : (B, H, K) int32     live row's critical block ids
#   cnt    : (B, H)    int32     live entries in lut
#   marg   : (B, H)    int32     live row's marginal block count
# The linear branch is the subtractive aggregation (paper App. A.3):
#   H_marg = htot - sum_{j in lut} hblk[j]
# exact because the decode plan classifies with kl_frac = 0 (every valid
# non-critical block is marginal; SLAConfig.decode_plan_cfg).
_DECODE_BACKENDS: Dict[str, BackendFn] = {}

# "kernel" is the real fused Pallas decode kernel (kernels/sla_decode);
# "xla" names the un-fused gather/einsum chain explicitly.
_DECODE_ALIASES = {"pallas": "kernel", "xla": "gather", "dense": "reference"}

# one-line warning (once per process) when the Pallas decode kernel has
# no TPU and falls back to interpret mode
_warned_interpret_decode = False


def register_decode_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    def deco(fn: BackendFn) -> BackendFn:
        _DECODE_BACKENDS[name] = fn
        return fn

    return deco


def resolve_decode(name: str) -> str:
    """Canonical decode-backend name (loud failure, like `resolve`)."""
    key = _DECODE_ALIASES.get(name, name)
    if key not in _DECODE_BACKENDS:
        raise ValueError(
            f"unknown SLA decode backend {name!r}; available: "
            f"{sorted(_DECODE_BACKENDS)} (aliases: "
            f"{ {a: t for a, t in sorted(_DECODE_ALIASES.items())} })")
    return key


def _group_heads(x: jax.Array, hkv: int) -> jax.Array:
    """(B, H, ...) -> (B, Hkv, G, ...): the same head layout jnp.repeat
    produces (q head h <-> (h // G, h % G))."""
    b, h = x.shape[:2]
    return x.reshape(b, hkv, h // hkv, *x.shape[2:])


def _gather_state(x: jax.Array, idx: jax.Array, k_sel: int) -> jax.Array:
    """x: (B, Hkv, Tn, ...); idx: (B, Hkv, G*K) -> (B, Hkv, G, K, ...)."""
    b, hkv = x.shape[:2]
    pad = (1,) * (x.ndim - 3)
    out = jnp.take_along_axis(x, idx.reshape(b, hkv, -1, *pad), axis=2)
    return out.reshape(b, hkv, -1, k_sel, *x.shape[3:])


def _gather_pool(pool: jax.Array, idx: jax.Array, k_sel: int) -> jax.Array:
    """Paged analogue of `_gather_state`: pool (P, Hkv, ...) gathered by
    PHYSICAL page ids idx (B, Hkv, G*K) -> (B, Hkv, G, K, ...).

    The ids come from routing the logical LUT through the page table
    (`plut = pt[b, lut]`), so the gathered blocks are byte-identical to
    what the monolithic layout's take_along_axis would read. Dead LUT
    entries (beyond `cnt`) may land on arbitrary live pages — exactly
    like the monolithic path they are masked to exact zeros downstream."""
    out = jax.vmap(lambda pn, ixn: pn[ixn], in_axes=(1, 1), out_axes=1)(
        pool, idx)
    return out.reshape(out.shape[0], out.shape[1], -1, k_sel,
                       *pool.shape[2:])


def _physical_lut(pt: jax.Array, lut: jax.Array) -> jax.Array:
    """Logical block ids -> physical page ids: pt (B, Tn), lut
    (B, H, K) -> (B, H, K)."""
    return jax.vmap(lambda row, l: row[l])(pt, lut)


def _paged_dense_state(state, bkv: int):
    """Materialize a monolithic decode-state slice from a paged one
    (page-gathered KV + per-block partials) for backends that want the
    contiguous layout (the dense reference oracle)."""
    pt = state["pt"]

    def blk(pool):  # (P, Hkv, ...) -> (B, Hkv, Tn, ...)
        return jnp.moveaxis(jnp.take(pool, pt, axis=0), 2, 1)

    out = {k: v for k, v in state.items() if k != "pt"}
    kd, vd = blk(state["k"]), blk(state["v"])
    out["k"] = kd.reshape(kd.shape[:2] + (-1, kd.shape[-1]))
    out["v"] = vd.reshape(vd.shape[:2] + (-1, vd.shape[-1]))
    out["hblk"] = blk(state["hblk"])
    out["zblk"] = blk(state["zblk"])
    return out


@register_decode_backend("gather")
def _decode_gather_backend(state, qg, qpg, pos, cfg, scale):
    """O(K * bkv * d) sparse + O(K * d^2) subtractive linear per token.

    Paged decode state (`"pt"` present; DESIGN.md "Paged KV & prefix
    caching") gathers the SAME K critical blocks straight out of the
    global page pools through the page table — physical ids replace
    logical ones at the gather and nowhere else (masking math keeps the
    logical LUT), so paged and monolithic outputs are bitwise equal."""
    paged = "pt" in state
    kc, vc = state["k"], state["v"]
    bkv = cfg.block_kv
    if paged:
        b, tn = state["pt"].shape
        hkv, d = kc.shape[1], kc.shape[-1]
    else:
        b, hkv, smax, d = kc.shape
        tn = smax // bkv
    lutg = _group_heads(state["lut"], hkv)          # (B, Hkv, G, K)
    cntg = _group_heads(state["cnt"], hkv)          # (B, Hkv, G)
    k_sel = lutg.shape[-1]
    if paged:
        pidx = _group_heads(_physical_lut(state["pt"], state["lut"]),
                            hkv).reshape(b, hkv, -1)
        kg = _gather_pool(kc, pidx, k_sel)
        vg = _gather_pool(vc, pidx, k_sel)
    else:
        idx = lutg.reshape(b, hkv, -1)
        kg = _gather_state(kc.reshape(b, hkv, tn, bkv, d), idx, k_sel)
        vg = _gather_state(vc.reshape(b, hkv, tn, bkv, d), idx, k_sel)
    s = jnp.einsum("bngd,bngkvd->bngkv", qg,
                   kg.astype(jnp.float32)) * scale
    cols = lutg[..., None] * bkv + jnp.arange(bkv)  # (B, Hkv, G, K, bkv)
    live = jnp.arange(k_sel) < cntg[..., None]      # (B, Hkv, G, K)
    # pos: scalar (static-batch decode) or (B,) per-slot positions
    # (continuous-batching scheduler; DESIGN.md "Serving API v2")
    posc = pos if jnp.ndim(pos) == 0 else pos[:, None, None, None, None]
    s = jnp.where(jnp.logical_and(cols <= posc, live[..., None]), s, -1e30)
    sf = s.reshape(b, hkv, -1, k_sel * bkv)
    m = jnp.max(sf, axis=-1, keepdims=True)
    p = jnp.exp(sf - m)
    o_s = jnp.einsum("bngk,bngkd->bngd", p / jnp.sum(p, -1, keepdims=True),
                     vg.reshape(b, hkv, -1, k_sel * bkv, d)
                     .astype(jnp.float32))
    # subtractive marginal aggregation from the running state
    if paged:
        hg = _gather_pool(state["hblk"], pidx, k_sel)
        zg = _gather_pool(state["zblk"], pidx, k_sel)
    else:
        hg = _gather_state(state["hblk"], idx, k_sel)  # (B,Hkv,G,K,D,D)
        zg = _gather_state(state["zblk"], idx, k_sel)  # (B,Hkv,G,K,D)
    hg = jnp.where(live[..., None, None], hg, 0.0)
    zg = jnp.where(live[..., None], zg, 0.0)
    h_m = state["htot"][:, :, None] - jnp.sum(hg, axis=3)
    z_m = state["ztot"][:, :, None] - jnp.sum(zg, axis=3)
    num = jnp.einsum("bngd,bngde->bnge", qpg, h_m)
    den = jnp.einsum("bngd,bngd->bng", qpg, z_m)[..., None]
    o_l = ref._safe_div(num, den)
    # rows with an empty marginal set produce exact zeros (the residual
    # of the subtraction is f32 noise; never divide noise by noise)
    margg = _group_heads(state["marg"], hkv)
    o_l = jnp.where(margg[..., None] > 0, o_l, 0.0)
    return o_s, o_l


@register_decode_backend("kernel")
def _decode_kernel_backend(state, qg, qpg, pos, cfg, scale):
    """Fused Pallas decode kernel (kernels/sla_decode): one launch for
    sparse softmax over the LUT pages + the subtractive marginal linear
    branch. Interpret-mode fallback keeps CPU CI honest (identical
    numerics, no Mosaic lowering)."""
    from repro.kernels import sla_decode

    interpret = jax.default_backend() != "tpu"
    if interpret:
        global _warned_interpret_decode
        if not _warned_interpret_decode:
            _warned_interpret_decode = True
            warnings.warn("SLA decode kernel: no TPU backend — running "
                          "Pallas in interpret mode", stacklevel=2)
    o_s, o_l = sla_decode.decode_attention(
        state, qg[..., None, :], qpg[..., None, :], pos, cfg, scale,
        interpret=interpret)
    return o_s[..., 0, :], o_l[..., 0, :]


@register_decode_backend("reference")
def _decode_reference_backend(state, qg, qpg, pos, cfg, scale):
    """Dense O(S) oracle: expands the live row's block structure to a
    token mask and aggregates marginal blocks directly (validation).
    Paged state is densified up front (the oracle wants the contiguous
    layout anyway — it reads every position)."""
    if "pt" in state:
        state = _paged_dense_state(state, cfg.block_kv)
    kc, vc = state["k"], state["v"]
    b, hkv, smax, d = kc.shape
    bkv = cfg.block_kv
    tn = smax // bkv
    lutg = _group_heads(state["lut"], hkv)
    cntg = _group_heads(state["cnt"], hkv)
    k_sel = lutg.shape[-1]
    live = jnp.arange(k_sel) < cntg[..., None]
    crit_blk = jnp.any(
        jnp.logical_and(lutg[..., None] == jnp.arange(tn), live[..., None]),
        axis=3)                                     # (B, Hkv, G, Tn)
    crit_tok = jnp.repeat(crit_blk, bkv, axis=-1)   # (B, Hkv, G, Smax)
    s = jnp.einsum("bngd,bnsd->bngs", qg, kc.astype(jnp.float32)) * scale
    # pos: scalar or (B,) per-slot positions (continuous batching)
    post = pos if jnp.ndim(pos) == 0 else pos[:, None, None, None]
    keep = jnp.logical_and(crit_tok, jnp.arange(smax) <= post)
    s = jnp.where(keep, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o_s = jnp.einsum("bngs,bnsd->bngd", p / jnp.sum(p, -1, keepdims=True),
                     vc.astype(jnp.float32))
    valid = jnp.arange(tn) <= post // bkv
    marg = jnp.logical_and(valid, ~crit_blk).astype(jnp.float32)
    h_m = jnp.einsum("bngt,bntde->bngde", marg, state["hblk"])
    z_m = jnp.einsum("bngt,bntd->bngd", marg, state["zblk"])
    num = jnp.einsum("bngd,bngde->bnge", qpg, h_m)
    den = jnp.einsum("bngd,bngd->bng", qpg, z_m)[..., None]
    return o_s, ref._safe_div(num, den)


def decode_execute(
    state: Dict[str, jax.Array],
    params: Optional[Params],
    q: jax.Array, pos, cfg: SLAConfig,
    scale: Optional[float] = None,
    backend: str = "gather",
) -> jax.Array:
    """One-token SLA attention against the decode cache state.

    q: (B, H, 1, D) the new token's query; `pos` its (traced) position —
    a scalar (static-batch decode: every row shares it) or a (B,) vector
    of per-slot positions (continuous-batching scheduler; DESIGN.md
    "Serving API v2"). Returns (B, H, D) in q.dtype — O^s + Proj(O^l)
    under cfg.mode "sla", O^s alone under "sparse_only".
    """
    backend = resolve_decode(backend)
    cfg.validate()
    in_dtype = q.dtype
    b, h, _, d = q.shape
    hkv = state["k"].shape[1]
    scale = (d**-0.5) if scale is None else scale
    qg = _group_heads(q[:, :, 0, :].astype(jnp.float32), hkv)
    qpg = _group_heads(phi(q[:, :, 0, :], cfg.phi), hkv)
    o_s, o_l = _DECODE_BACKENDS[backend](state, qg, qpg, pos, cfg, scale)
    o_s = o_s.reshape(b, h, d)
    if cfg.mode == "sparse_only":
        return o_s.astype(in_dtype)
    if cfg.mode != "sla":
        raise ValueError(
            f"decode_execute supports modes 'sla'/'sparse_only', got "
            f"{cfg.mode!r}")
    proj = params["proj"].astype(jnp.float32)
    o = o_s + jnp.einsum("bhd,hde->bhe", o_l.reshape(b, h, d), proj)
    return o.astype(in_dtype)


def decode_execute_chunk(
    state: Dict[str, jax.Array],
    params: Optional[Params],
    q: jax.Array, pos, cfg: SLAConfig,
    scale: Optional[float] = None,
    backend: str = "gather",
) -> jax.Array:
    """C-token chunked SLA attention against the decode cache state.

    q: (B, H, C, D) chunk queries; `pos` the (traced) base position —
    token c sits at pos + c. Unlike the single-token path, `state`
    carries *per-token* plan rows and linear-state snapshots: lut
    (B, H, C, K), cnt/marg (B, H, C), htot (B, Hkv, C, D, D), ztot
    (B, Hkv, C, D) — the at-time-c values each token attends with
    (transformer.decode_chunk builds them in one scan). One kernel
    launch (backend "kernel") or one gather chain (backend "gather" /
    "reference" — both run the same chunk-aware math, fully
    differentiable) covers the whole chunk. Returns (B, H, C, D) in
    q.dtype.
    """
    backend = resolve_decode(backend)
    cfg.validate()
    in_dtype = q.dtype
    b, h, cdim, d = q.shape
    hkv = state["k"].shape[1]
    scale = (d**-0.5) if scale is None else scale
    qg = _group_heads(q.astype(jnp.float32), hkv)
    qpg = _group_heads(phi(q, cfg.phi), hkv)
    if backend == "kernel":
        o_s, o_l = _decode_kernel_backend_chunk(state, qg, qpg, pos, cfg,
                                                scale)
    else:
        from repro.kernels import sla_decode

        o_s, o_l = sla_decode._decode_math(
            qg, qpg, state["k"], state["v"], state["hblk"], state["zblk"],
            state["hdiag"], state["zdiag"], state["htot"], state["ztot"],
            _group_heads(state["lut"], hkv),
            _group_heads(state["cnt"], hkv), _group_heads(state["marg"], hkv),
            jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,)), cfg, scale)
    o_s = o_s.reshape(b, h, cdim, d)
    if cfg.mode == "sparse_only":
        return o_s.astype(in_dtype)
    if cfg.mode != "sla":
        raise ValueError(
            f"decode_execute_chunk supports modes 'sla'/'sparse_only', got "
            f"{cfg.mode!r}")
    proj = params["proj"].astype(jnp.float32)
    o = o_s + jnp.einsum("bhcd,hde->bhce", o_l.reshape(b, h, cdim, d), proj)
    return o.astype(in_dtype)


def _decode_kernel_backend_chunk(state, qg, qpg, pos, cfg, scale):
    from repro.kernels import sla_decode

    interpret = jax.default_backend() != "tpu"
    if interpret:
        global _warned_interpret_decode
        if not _warned_interpret_decode:
            _warned_interpret_decode = True
            warnings.warn("SLA decode kernel: no TPU backend — running "
                          "Pallas in interpret mode", stacklevel=2)
    return sla_decode.decode_attention(state, qg, qpg, pos, cfg, scale,
                                       interpret=interpret)
