"""SLA configuration.

Block-size / classification hyper-parameters follow the paper (Sec. 6.1):
b_q = b_kv = 64, k_h = 5% critical, k_l = 10% negligible, phi = softmax.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SLAConfig:
    """Hyper-parameters for Sparse-Linear Attention.

    Attributes:
      block_q: query block size b_q (token rows per block).
      block_kv: key/value block size b_kv.
      kh_frac: fraction of KV blocks per query row classified *critical*
        (computed with exact block-sparse attention). Paper default 5%.
      kl_frac: fraction of KV blocks per query row classified *negligible*
        (skipped entirely). Paper default 10%.
      phi: feature map for the linear branch: "softmax" | "elu1" | "relu".
      mode: "sla" (paper), "sparse_only", "linear_only", "l_plus_s"
        (ablation baselines of Table 2), or "full" (exact attention).
      causal: causal (LM) vs bidirectional (DiT) attention.
      force_diagonal: force the diagonal block critical (guarantees every
        query row has >= 1 critical block; standard in block-sparse attn).
      fixed_budget: if set, overrides kh_frac with a *constant* number of
        critical blocks per row -> O(N) total sparse cost (beyond-paper
        long-context variant; see DESIGN.md).
      proj_init: init for the learnable Proj on the linear branch:
        "zeros" (SLA starts as pure sparse; compensation is learned) or
        "identity".
      col_capacity_factor: TPU adaptation (DESIGN.md §3): cap the number of
        critical blocks per KV *column* at cf * (average per-column count).
        Rows over capacity demote their lowest-score critical blocks to
        *marginal* (still covered by the linear branch — graceful, not
        lossy-skip). Gives the dK/dV kernel a static column-LUT width.
        None disables (pure-paper mask; reference path only).
      plan_refresh_interval: cross-timestep plan reuse (DESIGN.md
        "Plan/execute split"): during diffusion sampling, recompute the
        per-layer SLAPlan every this-many denoising steps and reuse it in
        between (DiT block-sparsity patterns are stable across adjacent
        timesteps). 1 = plan every step (exact paper behavior). Only
        consulted when plan_refresh_mode == "fixed".
      plan_refresh_mode: "fixed" re-plans on the static
        plan_refresh_interval schedule; "adaptive" measures plan drift
        (core/plan.plan_drift — the critical-mass retention of the
        reused structure) every step and re-plans a layer only when its
        drift reaches plan_drift_threshold (DESIGN.md "Plan lifetime &
        drift").
      plan_drift_threshold: drift level (1 - retention, in [0, 1]) at
        which an adaptive refresh rebuilds the plan. 0.0 re-plans every
        step (exact paper behavior); 1.0 never re-plans after the first
        (blind reuse).
    """

    block_q: int = 64
    block_kv: int = 64
    kh_frac: float = 0.05
    kl_frac: float = 0.10
    phi: str = "softmax"
    mode: str = "sla"
    causal: bool = False
    force_diagonal: bool = True
    fixed_budget: Optional[int] = None
    proj_init: str = "zeros"
    col_capacity_factor: Optional[float] = 2.0
    plan_refresh_interval: int = 1
    plan_refresh_mode: str = "fixed"
    plan_drift_threshold: float = 0.1
    window: int = 0  # sliding-window constraint in TOKENS (0 = none);
    #                  applied at block granularity: out-of-window blocks are
    #                  forced negligible (exact-zero weight under SWA).

    def num_critical(self, num_kv_blocks: int) -> int:
        """Number of critical blocks per query row (static)."""
        if self.fixed_budget is not None:
            return max(1, min(self.fixed_budget, num_kv_blocks))
        return max(1, round(self.kh_frac * num_kv_blocks))

    def num_negligible(self, num_kv_blocks: int) -> int:
        return max(0, round(self.kl_frac * num_kv_blocks))

    def col_capacity(self, num_q_blocks: int, num_kv_blocks: int) -> int:
        """Static per-column critical budget (dK/dV column-LUT width)."""
        k_sel = self.num_critical(num_kv_blocks)
        if self.col_capacity_factor is None:
            return num_q_blocks
        avg = num_q_blocks * k_sel / num_kv_blocks
        return max(1, min(num_q_blocks, round(self.col_capacity_factor * avg)))

    def replace(self, **kw) -> "SLAConfig":
        return dataclasses.replace(self, **kw)
