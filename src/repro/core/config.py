"""SLA configuration.

Block-size / classification hyper-parameters follow the paper (Sec. 6.1):
b_q = b_kv = 64, k_h = 5% critical, k_l = 10% negligible, phi = softmax.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class SLAConfig:
    """Hyper-parameters for Sparse-Linear Attention.

    Attributes:
      block_q: query block size b_q (token rows per block).
      block_kv: key/value block size b_kv.
      kh_frac: fraction of KV blocks per query row classified *critical*
        (computed with exact block-sparse attention). Paper default 5%.
      kl_frac: fraction of KV blocks per query row classified *negligible*
        (skipped entirely). Paper default 10%.
      phi: feature map for the linear branch: "softmax" | "elu1" | "relu".
      mode: "sla" (paper), "sparse_only", "linear_only", "l_plus_s"
        (ablation baselines of Table 2), or "full" (exact attention).
      causal: causal (LM) vs bidirectional (DiT) attention.
      force_diagonal: force the diagonal block critical (guarantees every
        query row has >= 1 critical block; standard in block-sparse attn).
      fixed_budget: if set, overrides kh_frac with a *constant* number of
        critical blocks per row -> O(N) total sparse cost (beyond-paper
        long-context variant; see DESIGN.md).
      proj_init: init for the learnable Proj on the linear branch:
        "zeros" (SLA starts as pure sparse; compensation is learned) or
        "identity".
      col_capacity_factor: TPU adaptation (DESIGN.md §3): cap the number of
        critical blocks per KV *column* at cf * (average per-column count).
        Rows over capacity demote their lowest-score critical blocks to
        *marginal* (still covered by the linear branch — graceful, not
        lossy-skip). Gives the dK/dV kernel a static column-LUT width.
        None disables (pure-paper mask; reference path only).
      plan_refresh_interval: cross-timestep plan reuse (DESIGN.md
        "Plan/execute split"): during diffusion sampling, recompute the
        per-layer SLAPlan every this-many denoising steps and reuse it in
        between (DiT block-sparsity patterns are stable across adjacent
        timesteps). 1 = plan every step (exact paper behavior). Only
        consulted when plan_refresh_mode == "fixed".
      plan_refresh_mode: "fixed" re-plans on the static
        plan_refresh_interval schedule; "adaptive" measures plan drift
        (core/plan.plan_drift — the critical-mass retention of the
        reused structure) every step and re-plans a layer only when its
        drift reaches plan_drift_threshold (DESIGN.md "Plan lifetime &
        drift").
      plan_drift_threshold: drift level (1 - retention, in [0, 1]) at
        which an adaptive refresh rebuilds the plan. 0.0 re-plans every
        step (exact paper behavior); 1.0 never re-plans after the first
        (blind reuse). A tuple gives one threshold PER LAYER (applied
        layer-by-layer, not min-reduced across the stack; see
        `drift_thresholds`).
      decode_mode: autoregressive decode attention path: "dense" runs
        masked softmax over the full static KV cache (O(S) per token);
        "sla" runs decode-time SLA — incremental block plans
        (`core/plan.plan_extend`) + an O(1)-per-token running linear
        state (DESIGN.md "Decode-time SLA").
      decode_budget: number of critical KV blocks per decode query row
        (the static decode LUT width). None derives it from kh_frac at
        the decode cache's maximum block count. A *fixed* budget keeps
        the incremental row classification invariant to the block-grid
        width, which is what makes `plan_extend` provably equal to
        `plan_from_mask` on the full mask.
      routing_mode: how (query-block, kv-block) pairs are scored before
        the top-k classification (DESIGN.md "Learned routing"):
        "threshold" ranks the paper's pooled map P_c (Eq. 2);
        "learned" ranks a trainable SLA2-style per-head scorer
        (`core/masks.predict_routing` — pooled Q/K projected through
        learnable per-head maps). Identity-initialized learned routing
        reproduces the threshold rule bitwise, so every conformance /
        parity guarantee holds unchanged at init; fine-tuning then
        moves the routing with the model (straight-through gradients
        through the plan's marginal aggregation matrix).
      routing_temp: temperature of the straight-through sigmoid
        relaxation around the top-k cuts (learned routing only).
        Smaller = sharper surrogate gradients near the cut.
    """

    block_q: int = 64
    block_kv: int = 64
    kh_frac: float = 0.05
    kl_frac: float = 0.10
    phi: str = "softmax"
    mode: str = "sla"
    causal: bool = False
    force_diagonal: bool = True
    fixed_budget: Optional[int] = None
    proj_init: str = "zeros"
    col_capacity_factor: Optional[float] = 2.0
    plan_refresh_interval: int = 1
    plan_refresh_mode: str = "fixed"
    plan_drift_threshold: Union[float, Tuple[float, ...]] = 0.1
    decode_mode: str = "dense"
    decode_budget: Optional[int] = None
    routing_mode: str = "threshold"
    routing_temp: float = 1.0
    window: int = 0  # sliding-window constraint in TOKENS (0 = none);
    #                  applied at block granularity: out-of-window blocks are
    #                  forced negligible (exact-zero weight under SWA).
    paged: bool = False  # serving: page the per-slot KV cache into a global
    #                      pool of block_kv-sized pages with copy-on-write
    #                      prefix sharing (DESIGN.md "Paged KV & prefix
    #                      caching"); consulted by Scheduler/ServingEngine.
    page_pool_size: Optional[int] = None  # total physical pages in the pool
    #                      (incl. the zero page and per-slot scratch pages);
    #                      None derives a safe default from num_slots*max_len.
    prefill_chunk_blocks: Optional[int] = None  # serving: admission prefill
    #                      advances this many block_q-sized chunks per
    #                      scheduler tick instead of one blocking prefill
    #                      (DESIGN.md "Chunked admission prefill"). Requires
    #                      paged serving; None keeps blocking admission.

    # knob-string vocabularies (validate() is the ONE place that rejects
    # typos; keep these in sync with the dispatch sites they gate —
    # except phi, whose vocabulary lives with its dispatch in core/phi.py)
    MODES = ("sla", "sparse_only", "linear_only", "l_plus_s", "full")
    ROUTING_MODES = ("threshold", "learned")
    PLAN_REFRESH_MODES = ("fixed", "adaptive")
    DECODE_MODES = ("dense", "sla")

    @property
    def PHIS(self) -> Tuple[str, ...]:
        from repro.core.phi import PHI_KINDS
        return PHI_KINDS

    def validate(self) -> "SLAConfig":
        """Loudly reject invalid knob combinations, in one place.

        Every serving/planning entry point (`plan_attention`,
        `backends.execute`/`decode_execute`, `ServingEngine`,
        `Scheduler`) calls this so a typo'd mode string or an impossible
        combination fails at the API boundary with a named field, not
        deep inside a jit trace. Returns self so call sites can chain.
        """
        def _enum(field: str, value: str, allowed: Tuple[str, ...]):
            if value not in allowed:
                raise ValueError(
                    f"SLAConfig.{field}={value!r} is not one of {allowed}")

        _enum("mode", self.mode, self.MODES)
        _enum("phi", self.phi, self.PHIS)
        _enum("routing_mode", self.routing_mode, self.ROUTING_MODES)
        _enum("plan_refresh_mode", self.plan_refresh_mode,
              self.PLAN_REFRESH_MODES)
        _enum("decode_mode", self.decode_mode, self.DECODE_MODES)
        if self.block_q <= 0 or self.block_kv <= 0:
            raise ValueError(
                f"SLAConfig block sizes must be positive (block_q="
                f"{self.block_q}, block_kv={self.block_kv})")
        if not (0.0 <= self.kh_frac <= 1.0 and 0.0 <= self.kl_frac <= 1.0):
            raise ValueError(
                f"SLAConfig.kh_frac/kl_frac must lie in [0, 1] (got "
                f"{self.kh_frac}, {self.kl_frac})")
        if self.plan_refresh_interval < 1:
            raise ValueError(
                f"SLAConfig.plan_refresh_interval must be >= 1 (got "
                f"{self.plan_refresh_interval})")
        if self.window < 0:
            raise ValueError(
                f"SLAConfig.window must be >= 0 (got {self.window})")
        if self.window > 0 and self.decode_mode == "sla":
            # the decode-time subtractive linear state cannot exclude
            # out-of-window past blocks (DESIGN.md "Decode-time SLA")
            raise ValueError(
                "SLAConfig.window > 0 is incompatible with decode_mode="
                "'sla': the subtractive running state covers ALL past "
                "blocks and cannot honor a sliding-window constraint; "
                "use decode_mode='dense' for window-constrained configs")
        if self.decode_mode == "sla" and self.block_q != self.block_kv:
            raise ValueError(
                f"decode_mode='sla' requires block_q == block_kv (got "
                f"{self.block_q} vs {self.block_kv}); the decode grid "
                f"appends one query row per completed KV block")
        if self.page_pool_size is not None and self.page_pool_size < 2:
            raise ValueError(
                f"SLAConfig.page_pool_size must be >= 2 (zero page + at "
                f"least one allocatable page), got {self.page_pool_size}")
        if self.paged and self.block_q != self.block_kv:
            raise ValueError(
                f"paged serving requires block_q == block_kv (pages are "
                f"block_kv-sized and admission is block_q-aligned; got "
                f"{self.block_q} vs {self.block_kv})")
        if self.prefill_chunk_blocks is not None:
            if self.prefill_chunk_blocks < 1:
                raise ValueError(
                    f"SLAConfig.prefill_chunk_blocks must be >= 1 (got "
                    f"{self.prefill_chunk_blocks})")
            if self.block_q != self.block_kv:
                raise ValueError(
                    f"chunked admission prefill requires block_q == "
                    f"block_kv (chunks are whole pages; got "
                    f"{self.block_q} vs {self.block_kv})")
        return self

    def num_critical(self, num_kv_blocks: int) -> int:
        """Number of critical blocks per query row (static)."""
        if self.fixed_budget is not None:
            return max(1, min(self.fixed_budget, num_kv_blocks))
        return max(1, round(self.kh_frac * num_kv_blocks))

    def num_negligible(self, num_kv_blocks: int) -> int:
        return max(0, round(self.kl_frac * num_kv_blocks))

    def col_capacity(self, num_q_blocks: int, num_kv_blocks: int) -> int:
        """Static per-column critical budget (dK/dV column-LUT width)."""
        k_sel = self.num_critical(num_kv_blocks)
        if self.col_capacity_factor is None:
            return num_q_blocks
        avg = num_q_blocks * k_sel / num_kv_blocks
        return max(1, min(num_q_blocks, round(self.col_capacity_factor * avg)))

    def drift_thresholds(self, num_layers: int) -> Tuple[float, ...]:
        """Per-layer drift thresholds, normalized to a length-L tuple.

        A scalar `plan_drift_threshold` is broadcast to every layer; a
        tuple must already have one entry per layer. Callers apply each
        layer's threshold to that layer's own drift (the ROADMAP
        "per-layer, not min-reduced" semantics)."""
        t = self.plan_drift_threshold
        if isinstance(t, (tuple, list)):
            if len(t) != num_layers:
                raise ValueError(
                    f"plan_drift_threshold has {len(t)} entries but the "
                    f"model has {num_layers} layers")
            return tuple(float(x) for x in t)
        return (float(t),) * num_layers

    def decode_plan_cfg(self, num_kv_blocks: int) -> "SLAConfig":
        """Classification config for decode-time incremental plans.

        Decode rows are classified causal with a *static* critical
        budget (row classification becomes invariant to the block-grid
        width — required for `plan_extend` == `plan_from_mask`), no
        negligible class (at decode the linear branch is O(1) running
        state, so skipping blocks saves nothing and would change
        numerics vs the subtractive aggregation), and no column
        capacity (the column LUT feeds only the training backward
        pass; capping it would make row classification depend on other
        rows and break incremental append)."""
        budget = self.decode_budget
        if budget is None:
            budget = self.num_critical(num_kv_blocks)
        return dataclasses.replace(
            self, causal=True, kl_frac=0.0, col_capacity_factor=None,
            fixed_budget=budget, window=0)

    def replace(self, **kw) -> "SLAConfig":
        return dataclasses.replace(self, **kw)
