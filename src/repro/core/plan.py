"""SLA planning subsystem: classify once, execute many times.

SLA's cost model (PAPER.md Eq. 2-3) splits attention into a cheap
*planning* step — pool(Q) pool(K)^T -> P_c -> three-way block
classification -> row/column lookup tables — and the *execution* step
that consumes the resulting block structure.  This module owns the
planning step end to end: `plan_attention(q, k, cfg)` returns an
`SLAPlan`, an immutable pytree carrying every derived structure any
backend (reference / gather / Pallas kernel) needs, so

  * the backward pass reuses the forward's LUTs (threaded through the
    `custom_vjp` residuals in kernels/ops.py — never rebuilt), and
  * a plan computed at one diffusion timestep can be reused for the
    next K steps (`SLAConfig.plan_refresh_interval`; DiT block-sparsity
    patterns are stable across adjacent denoising steps — see
    DESIGN.md "Plan/execute split").

This is the ONLY place LUTs are constructed; `core/masks.py` keeps the
classification math (P_c, M_c) and `core/backends.py` the execution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.masks import classify_blocks, routing_gates, score_map

EPS = 1e-12


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SLAPlan:
    """Immutable result of SLA block planning — a pure-array pytree.

    Shapes (B = batch, H = q heads, Tm/Tn = q/kv block counts):
      mc:         (B, H, Tm, Tn) int8   three-way classification (Eq. 3)
      lut:        (B, H, Tm, K)  int32  critical block ids per query row
      counts:     (B, H, Tm)     int32  live entries per row LUT
      col_lut:    (B, H, Tn, W)  int32  critical row ids per KV column
                                        (dK/dV kernel; capacity-capped)
      col_counts: (B, H, Tn)     int32  live entries per column LUT
      marginal:   (B, H, Tm, Tn) f32    aggregation matrix A (1 where a
                                        block is marginal; the App. A.3
                                        pre-aggregation matmul operand)

    All leaves are arrays, so a plan jit-traces, shards, and scans like
    any activation; static facts (K, W, block sizes) are recovered from
    leaf shapes + the SLAConfig at execution time.
    """

    mc: jax.Array
    lut: jax.Array
    counts: jax.Array
    col_lut: jax.Array
    col_counts: jax.Array
    marginal: jax.Array

    @property
    def k_sel(self) -> int:
        return self.lut.shape[-1]

    @property
    def w_col(self) -> int:
        return self.col_lut.shape[-1]

    @property
    def num_q_blocks(self) -> int:
        return self.mc.shape[-2]

    @property
    def num_kv_blocks(self) -> int:
        return self.mc.shape[-1]

    def stats(self) -> dict:
        """Sparsity statistics (fractions of each block class)."""
        total = self.mc.size
        crit = jnp.sum(self.mc == 1) / total
        marg = jnp.sum(self.mc == 0) / total
        neg = jnp.sum(self.mc == -1) / total
        return {
            "critical_frac": crit,
            "marginal_frac": marg,
            "negligible_frac": neg,
            "sparsity": 1.0 - crit,  # paper: 1 - computed fraction
        }


def build_lut(mc: jax.Array, k_sel: int) -> Tuple[jax.Array, jax.Array]:
    """Static-shape critical-block lookup table for the TPU kernel.

    Args:
      mc: (..., Tm, Tn) int8 classification.
      k_sel: static LUT width (>= max #critical per row; use
        cfg.num_critical(Tn)).

    Returns:
      lut:    (..., Tm, k_sel) int32 — critical block indices, ascending,
              padded with the row's first critical index (always valid).
      counts: (..., Tm) int32 — number of live entries per row.
    """
    tn = mc.shape[-1]
    is_crit = (mc == 1).astype(jnp.int32)
    counts = jnp.sum(is_crit, axis=-1)
    # Sort key: critical blocks first (ascending j), then the rest.
    j = jnp.arange(tn, dtype=jnp.int32)
    key = is_crit * (2 * tn) - j
    idx = jnp.argsort(-key, axis=-1, stable=True)[..., :k_sel].astype(jnp.int32)
    slot = jnp.arange(k_sel, dtype=jnp.int32)
    live = slot < counts[..., None]
    pad = idx[..., :1]  # first critical index — always a real block
    lut = jnp.where(live, idx, pad)
    return lut, counts


def build_col_lut(mc: jax.Array, w_col: int) -> Tuple[jax.Array, jax.Array]:
    """Column LUT for the dK/dV kernel: per KV column, the critical row idxs.

    Requires the column-capacity constraint (counts <= w_col by construction).
    Returns (col_lut (..., Tn, w_col) int32, col_counts (..., Tn) int32).
    """
    tm = mc.shape[-2]
    is_crit = (mc == 1).astype(jnp.int32)
    counts = jnp.sum(is_crit, axis=-2)
    i = jnp.arange(tm, dtype=jnp.int32)[:, None]
    key = is_crit * (2 * tm) - i
    idx = jnp.argsort(-key, axis=-2, stable=True)[..., :w_col, :].astype(jnp.int32)
    idx = jnp.swapaxes(idx, -1, -2)  # (..., Tn, w_col)
    slot = jnp.arange(w_col, dtype=jnp.int32)
    live = slot < counts[..., None]
    pad = idx[..., :1]
    lut = jnp.where(live, idx, pad)
    return lut, counts


def plan_from_mask(mc: jax.Array, cfg: SLAConfig,
                   col_width: Optional[int] = None,
                   pc: Optional[jax.Array] = None) -> SLAPlan:
    """Derive every execution structure from a classification M_c.

    `col_width` overrides the column-LUT width (cfg.col_capacity).
    Inference-only consumers that never run the dK/dV backward pass —
    the decode cache — pass 1 so the plan does not carry a dead
    O(Tm x Tn)-per-head structure.

    `pc` (learned routing only): the routing probability map `mc` was
    classified from. When given, the plan's marginal aggregation
    matrix carries the straight-through gates (`masks.routing_gates`)
    — forward-identical to the hard indicator, but differentiable
    w.r.t. the routing parameters."""
    tm, tn = mc.shape[-2], mc.shape[-1]
    lut, counts = build_lut(mc, cfg.num_critical(tn))
    col_lut, col_counts = build_col_lut(
        mc, cfg.col_capacity(tm, tn) if col_width is None else col_width)
    if pc is not None and cfg.routing_mode == "learned":
        marginal = routing_gates(pc, mc, cfg)
    else:
        marginal = (mc == 0).astype(jnp.float32)
    return SLAPlan(mc=mc, lut=lut, counts=counts,
                   col_lut=col_lut, col_counts=col_counts,
                   marginal=marginal)


def plan_attention(
    q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> SLAPlan:
    """Build an SLAPlan from (q, k): score map -> M_c -> LUTs -> A.

    q: (B, H, N, D); k: (B, Hkv, N, D) with Hkv | H (GQA heads are
    broadcast so the plan always has one row of structure per q head).
    (q, k) are gradient-stopped — the block structure is a constant
    w.r.t. the loss (TopK is not differentiated, matching the paper).
    With cfg.routing_mode == "learned", `routing` (the per-head scorer
    from `masks.routing_init`) ranks the blocks instead of the raw
    pooled P_c, and the plan's marginal matrix carries straight-through
    gradients to the routing parameters (DESIGN.md "Learned routing").
    """
    cfg.validate()  # typo'd knob strings die here, not deep in a trace
    h = q.shape[1]
    if k.shape[1] != h:
        assert h % k.shape[1] == 0
        k = jnp.repeat(k, h // k.shape[1], axis=1)
    pc = score_map(routing, jax.lax.stop_gradient(q),
                   jax.lax.stop_gradient(k), cfg, scale)
    return plan_from_mask(classify_blocks(pc, cfg), cfg, pc=pc)


# ---------------------------------------------------------------------------
# incremental plan maintenance (decode-time SLA; DESIGN.md "Decode-time SLA")
# ---------------------------------------------------------------------------
def empty_plan(
    cfg: SLAConfig, batch: int, heads: int, tm: int, tn: int,
) -> SLAPlan:
    """All-negligible plan over a static (tm, tn) block grid — the
    decode-time starting point that `plan_extend` appends rows into."""
    mc = jnp.full((batch, heads, tm, tn), -1, jnp.int8)
    return plan_from_mask(mc, cfg)


def plan_extend(plan: SLAPlan, mc_row: jax.Array, row) -> SLAPlan:
    """Append one query-block row to a plan: O(Tn * K), no argsort rebuild.

    mc_row: (..., Tn) int8 classification of row `row` (a python int or
    traced scalar). Precondition: `row` is the first unwritten row of
    the plan (rows are appended monotonically, each exactly once — the
    decode path crosses each block boundary once), so the column-LUT
    update is a pure append at each column's current fill level.

    Equality contract (tests/test_decode_sla.py property suite):
    starting from `empty_plan` and appending rows 0..R-1 of a full
    classification M_c reproduces `plan_from_mask(M_c)` exactly on
    `mc`, `lut`, `counts`, `col_counts`, and `marginal`, and on every
    *live* `col_lut` slot (slot < col_counts). Dead col_lut padding
    slots may differ — plan_from_mask pads with the column's first
    critical row id, the incremental path leaves stale values — and no
    backend reads them (every consumer gates on counts).
    """
    nd = plan.mc.ndim
    row = jnp.asarray(row, jnp.int32)
    mc_row = mc_row.astype(plan.mc.dtype)
    mc = jax.lax.dynamic_update_slice_in_dim(
        plan.mc, mc_row[..., None, :], row, axis=nd - 2)
    lut_r, cnt_r = build_lut(mc_row[..., None, :], plan.k_sel)
    lut = jax.lax.dynamic_update_slice_in_dim(
        plan.lut, lut_r, row, axis=nd - 2)
    counts = jax.lax.dynamic_update_slice_in_dim(
        plan.counts, cnt_r, row, axis=nd - 2)
    # Column-LUT append: the new row becomes the *last* critical entry of
    # every column it is critical in (rows arrive in ascending order, and
    # build_col_lut lists critical rows ascending, so live entries agree).
    is_crit = mc_row == 1  # (..., Tn)
    cc = plan.col_counts
    can = jnp.logical_and(is_crit, cc < plan.w_col)
    slot_hit = jnp.arange(plan.w_col, dtype=cc.dtype) == cc[..., None]
    write = jnp.logical_and(can[..., None], slot_hit)
    col_lut = jnp.where(write, row.astype(plan.col_lut.dtype),
                        plan.col_lut)
    col_counts = cc + can.astype(plan.col_counts.dtype)
    marginal = jax.lax.dynamic_update_slice_in_dim(
        plan.marginal,
        (mc_row == 0).astype(plan.marginal.dtype)[..., None, :],
        row, axis=nd - 2)
    return SLAPlan(mc=mc, lut=lut, counts=counts, col_lut=col_lut,
                   col_counts=col_counts, marginal=marginal)


# ---------------------------------------------------------------------------
# plan lifetime: drift measurement + adaptive refresh
# (DESIGN.md "Plan lifetime & drift")
# ---------------------------------------------------------------------------
def plan_retention(
    plan: SLAPlan, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> jax.Array:
    """Critical-mass retention of a (possibly stale) plan at (q, k).

    Recomputes the pooled compressed map P_c for the *current* (q, k)
    (cheap: O(T^2) in blocks, not tokens) and measures what fraction of
    the P_c mass a fresh critical set would capture is still covered by
    the stale plan's critical set:

        r = sum(P_c * [mc_stale == +1]) / sum(P_c * [mc_fresh == +1])

    clipped to [0, 1]. r == 1.0 exactly when (q, k) still classify to
    the plan's structure; r decays toward 0 as the denoising trajectory
    (or prefill content) moves away from the state the plan was built
    on. Drift is `1 - r` (see `plan_drift`).

    Under learned routing (cfg.routing_mode == "learned", `routing`
    given) both the stale-mass numerator and the fresh classification
    use the learned scorer's map, so drift is measured against the
    structure the router would actually build today.

    Gradient-stopped like planning itself. Returns (B, H) float32.
    """
    return _retention_and_fresh_mc(plan, q, k, cfg, scale, routing)[0]


def _retention_and_fresh_mc(
    plan: SLAPlan, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Retention (B, H) plus the fresh classification M_c it was measured
    against — `refresh_plan` rebuilds from the latter so a drift-triggered
    re-plan never recomputes the pool/score-map/top-k front half. The
    third element is the score map itself under learned routing (None
    otherwise), so the rebuild can carry straight-through gates.

    Like every scoring path, learned mode REQUIRES the routing params
    (loud failure in `score_map`) — drift must be measured with the
    same scorer the plan was built with, never a silent P_c fallback."""
    h = q.shape[1]
    if k.shape[1] != h:
        assert h % k.shape[1] == 0
        k = jnp.repeat(k, h // k.shape[1], axis=1)
    q = jax.lax.stop_gradient(q)
    k = jax.lax.stop_gradient(k)
    learned = cfg.routing_mode == "learned"
    pc = score_map(routing, q, k, cfg, scale)  # (B, H, Tm, Tn) f32
    if pc.shape[-2:] != plan.mc.shape[-2:]:
        raise ValueError(
            f"stale SLAPlan: plan is for {plan.mc.shape[-2:]} blocks but "
            f"(q, k) pool to {pc.shape[-2:]} — shapes must match to "
            f"measure drift")
    stale = jnp.sum(pc * (plan.mc == 1), axis=(-2, -1))
    mc_fresh = classify_blocks(pc, cfg)
    fresh = jnp.sum(pc * (mc_fresh == 1), axis=(-2, -1))
    r = stale / jnp.maximum(fresh, EPS)
    return jnp.clip(r, 0.0, 1.0), mc_fresh, (pc if learned else None)


def plan_drift(
    plan: SLAPlan, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> jax.Array:
    """Plan drift `1 - plan_retention(...)` in [0, 1], shape (B, H).

    0 means the reused plan still captures everything a fresh plan
    would; 1 means the stale critical set covers none of the current
    P_c mass. `SLAConfig.plan_drift_threshold` gates re-planning on
    this value (re-plan when drift >= threshold)."""
    return 1.0 - plan_retention(plan, q, k, cfg, scale, routing)


def refresh_plan(
    plan: SLAPlan, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    threshold, scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> Tuple[SLAPlan, jax.Array, jax.Array]:
    """Drift-gated re-plan: keep `plan` while it retains critical mass.

    Measures `plan_drift` (reduced with max over batch/heads — one
    over-drifted head forces the re-plan, the conservative choice) and
    rebuilds the plan under `lax.cond` when drift >= threshold, so the
    planning pipeline only runs when the structure has actually moved
    and the whole decision stays jit-traceable with static shapes.

    `threshold` may be a python float or a traced scalar:
      0.0 -> re-plan on every call (exact paper behavior),
      1.0 -> never re-plan after the first (blind reuse).

    Returns (plan', retention_scalar f32, replanned bool).
    """
    r, mc_fresh, pc = _retention_and_fresh_mc(plan, q, k, cfg, scale,
                                              routing)
    retention = jnp.min(r)
    # threshold >= 1.0 means "never", even at the clipped drift == 1.0
    # extreme — the docs' blind-reuse contract beats the >= comparison
    replanned = jnp.logical_and((1.0 - retention) >= threshold,
                                jnp.asarray(threshold) < 1.0)
    # the drift metric already classified the fresh structure; the
    # rebuild only derives LUTs from it (and is guaranteed to match the
    # classification the decision was based on)
    new_plan = jax.lax.cond(
        replanned,
        lambda ops: plan_from_mask(ops[0], cfg, pc=pc),
        lambda ops: ops[1],
        (mc_fresh, plan))
    return new_plan, retention, replanned


def refresh_plan_per_sample(
    plan: SLAPlan, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    thresholds, scale: Optional[float] = None,
    routing: Optional[dict] = None,
) -> Tuple[SLAPlan, jax.Array, jax.Array]:
    """Per-sample drift-gated re-plan: each batch row decides alone.

    `refresh_plan` min-reduces retention over batch AND heads, coupling
    the refresh decision across every row of the batch — correct for a
    single request, wrong for a serving batch where each slot holds an
    unrelated request at its own timestep. Here retention is reduced
    over heads only, giving a (B,) decision vector; replanned rows take
    the freshly classified structure, kept rows carry their old leaves
    bitwise-unchanged via a per-row select. Because every structure in
    `plan_from_mask` is per-(batch, head) independent, a row's refresh
    here is bitwise-identical to `refresh_plan` on that row alone — the
    DiffusionScheduler's batched-vs-sequential parity rests on this.

    `thresholds`: (B,) float drift thresholds (broadcast from a scalar).
    Per-row schedule override: 0.0 forces that row's re-plan, >= 1.0
    pins blind reuse — the fixed refresh interval is expressed as a
    0/1 threshold vector, so one traced step covers both modes.

    Unlike `refresh_plan`'s `lax.cond`, the rebuild always runs (the
    select needs fresh leaves for any subset of rows) — the extra cost
    is the LUT argsorts, O(T log T) in blocks, dwarfed by attention.

    Returns (plan', retention (B,), replanned (B,) bool).
    """
    r, mc_fresh, pc = _retention_and_fresh_mc(plan, q, k, cfg, scale,
                                              routing)
    retention = jnp.min(r, axis=-1)  # (B,) — min over heads only
    thr = jnp.broadcast_to(jnp.asarray(thresholds, jnp.float32),
                           retention.shape)
    replanned = jnp.logical_and((1.0 - retention) >= thr, thr < 1.0)
    fresh = plan_from_mask(mc_fresh, cfg, pc=pc)

    def sel(new_leaf, old_leaf):
        m = replanned.reshape(
            replanned.shape + (1,) * (new_leaf.ndim - replanned.ndim))
        return jnp.where(m, new_leaf, old_leaf)

    new_plan = jax.tree_util.tree_map(sel, fresh, plan)
    return new_plan, retention, replanned


# ---------------------------------------------------------------------------
# plan serialization + config compatibility (serving/plan_cache.py)
# ---------------------------------------------------------------------------
_PLAN_WIRE_VERSION = 1
_PLAN_LEAVES = ("mc", "lut", "counts", "col_lut", "col_counts", "marginal")


def plan_compat_key(cfg: SLAConfig, heads: int, tm: int, tn: int) -> tuple:
    """Hashable key under which two SLAPlans are interchangeable.

    Two plans built under configs that agree on every field this key
    names produce the same leaf shapes/dtypes AND the same
    classification semantics, so a cached plan may be handed to a
    request that never saw the original (q, k). Fields that only affect
    execution (phi, proj_init, decode_*) are deliberately absent —
    changing them must NOT invalidate cached structure."""
    return (
        "sla-plan-v%d" % _PLAN_WIRE_VERSION,
        cfg.block_q, cfg.block_kv, cfg.kh_frac, cfg.kl_frac, cfg.mode,
        bool(cfg.causal), bool(cfg.force_diagonal), cfg.fixed_budget,
        cfg.col_capacity_factor, cfg.routing_mode, cfg.window,
        int(heads), int(tm), int(tn),
    )


def serialize_plan(plan: SLAPlan) -> dict:
    """SLAPlan -> device-free dict of numpy leaves (+ wire version).

    The inverse of `deserialize_plan`; round-trips bitwise. Host numpy
    (not bytes) so a cache entry costs one device->host copy and no
    codec, yet holds no device memory."""
    import numpy as np
    out = {"__version__": _PLAN_WIRE_VERSION}
    for name in _PLAN_LEAVES:
        out[name] = np.asarray(getattr(plan, name))
    return out


def deserialize_plan(data: dict) -> SLAPlan:
    """Dict from `serialize_plan` -> SLAPlan with device arrays."""
    v = data.get("__version__")
    if v != _PLAN_WIRE_VERSION:
        raise ValueError(
            f"serialized SLAPlan wire version {v!r} != "
            f"{_PLAN_WIRE_VERSION} — refusing to guess leaf layout")
    return SLAPlan(**{name: jnp.asarray(data[name])
                      for name in _PLAN_LEAVES})
