"""Block classification for SLA (paper Sec. 4, Eq. 2-3).

Predicts a compressed attention map P_c = softmax(pool(Q) pool(K)^T / sqrt(d))
over (T_m x T_n) blocks and classifies every block into
  critical (+1, top k_h% per row)  -> exact block-sparse attention,
  negligible (-1, bottom k_l%)     -> skipped,
  marginal (0, the rest)           -> linear attention.

The static-shape lookup tables (LUTs) consumed by the execution backends
are built from M_c in `core/plan.py` (`plan_attention` / `SLAPlan`; see
DESIGN.md "Plan/execute split") — this module is classification math only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig

NEG_INF = -1e30


def pool_blocks(x: jax.Array, block: int) -> jax.Array:
    """Mean-pool tokens into blocks. (..., N, D) -> (..., N // block, D)."""
    n, d = x.shape[-2], x.shape[-1]
    assert n % block == 0, f"seq len {n} not divisible by block {block}"
    xb = x.reshape(*x.shape[:-2], n // block, block, d)
    return jnp.mean(xb.astype(jnp.float32), axis=-2)


def block_causal_valid(tm: int, tn: int, block_q: int, block_kv: int) -> jax.Array:
    """(tm, tn) bool: block (i, j) contains at least one valid causal pair."""
    qi = (jnp.arange(tm) + 1) * block_q - 1  # last query row in block i
    kj = jnp.arange(tn) * block_kv  # first key col in block j
    return qi[:, None] >= kj[None, :]


def block_valid(cfg: SLAConfig, tm: int, tn: int) -> jax.Array:
    """(tm, tn) bool validity combining causal + sliding-window constraints
    (window applied at block granularity; see SLAConfig.window)."""
    valid = jnp.ones((tm, tn), bool)
    if cfg.causal:
        valid = jnp.logical_and(
            valid, block_causal_valid(tm, tn, cfg.block_q, cfg.block_kv))
    if cfg.window:
        qi = jnp.arange(tm)[:, None] * cfg.block_q
        kj = jnp.arange(tn)[None, :] * cfg.block_kv
        dist = jnp.abs(qi - kj)
        valid = jnp.logical_and(valid, dist < cfg.window + cfg.block_kv)
    return valid


def predict_pc(
    q: jax.Array, k: jax.Array, cfg: SLAConfig, scale: float | None = None
) -> jax.Array:
    """Compressed attention map P_c (Eq. 2). q,k: (B, H, N, D) -> (B, H, Tm, Tn)."""
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    qp = pool_blocks(q, cfg.block_q)
    kp = pool_blocks(k, cfg.block_kv)
    s = jnp.einsum("...md,...nd->...mn", qp, kp) * scale
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, s.shape[-2], s.shape[-1])
        s = jnp.where(valid, s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def classify_blocks(pc: jax.Array, cfg: SLAConfig) -> jax.Array:
    """Three-way block classification M_c (Eq. 3). pc: (..., Tm, Tn) -> int8.

    +1 critical / 0 marginal / -1 negligible. Causal-invalid blocks are -1.
    The diagonal block is forced critical when cfg.force_diagonal (guarantees
    the sparse softmax of every row is well defined).
    """
    tm, tn = pc.shape[-2], pc.shape[-1]
    n_crit = cfg.num_critical(tn)
    n_neg = cfg.num_negligible(tn)

    score = pc
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, tm, tn)
        score = jnp.where(valid, score, -1.0)  # push invalid to the very bottom
    force_diag = cfg.force_diagonal or cfg.causal
    if cfg.causal:
        # The diagonal block is the only partially-valid causal block; it must
        # be critical so the linear branch only ever sees fully-past blocks.
        assert cfg.block_q == cfg.block_kv, "causal SLA requires b_q == b_kv"
    if force_diag and tm <= tn:
        # Give the (block-)diagonal an infinitely large score so TopK keeps it.
        diag = jnp.eye(tm, tn, k=0, dtype=bool)
        if cfg.block_q != cfg.block_kv:
            qi = jnp.arange(tm) * cfg.block_q // cfg.block_kv
            diag = jax.nn.one_hot(qi, tn, dtype=jnp.bool_)
        score = jnp.where(diag, 2.0, score)

    # Descending rank of every block within its row (stable; O(Tn log Tn)).
    order = jnp.argsort(-score, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)

    mc = jnp.zeros(pc.shape, jnp.int8)
    mc = jnp.where(rank < n_crit, jnp.int8(1), mc)
    if n_neg > 0:
        mc = jnp.where(rank >= tn - n_neg, jnp.int8(-1), mc)
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, tm, tn)
        mc = jnp.where(valid, mc, jnp.int8(-1))
        # Rows near the start may have fewer valid blocks than n_crit; the
        # rank<n_crit rule already keeps all their valid blocks critical.

    if cfg.col_capacity_factor is not None:
        # TPU adaptation: enforce a static per-column critical budget so the
        # dK/dV backward kernel has a fixed-width column LUT (DESIGN.md §3).
        # Over-budget blocks demote to *marginal* (linear branch still covers
        # them). The boosted `score` keeps forced-diagonal blocks first.
        cap = cfg.col_capacity(tm, tn)
        is_crit = mc == 1
        col_key = jnp.where(is_crit, score, -2.0)
        col_order = jnp.argsort(-col_key, axis=-2, stable=True)
        col_rank = jnp.argsort(col_order, axis=-2, stable=True)
        demote = jnp.logical_and(is_crit, col_rank >= cap)
        mc = jnp.where(demote, jnp.int8(0), mc)
    return mc


# ---------------------------------------------------------------------------
# row-local classification (decode-time incremental plans; DESIGN.md
# "Decode-time SLA"). `row` may be a python int or a traced scalar, so the
# same code serves one-shot tests and the jitted decode step.
# ---------------------------------------------------------------------------
def row_valid(row, tn: int, cfg: SLAConfig) -> jax.Array:
    """(tn,) bool validity of one query-block row — the row `row` slice of
    `block_valid` (causal + window constraints)."""
    j = jnp.arange(tn)
    valid = jnp.ones((tn,), bool)
    if cfg.causal:
        valid = jnp.logical_and(
            valid, (row + 1) * cfg.block_q - 1 >= j * cfg.block_kv)
    if cfg.window:
        dist = jnp.abs(row * cfg.block_q - j * cfg.block_kv)
        valid = jnp.logical_and(valid, dist < cfg.window + cfg.block_kv)
    return valid


def predict_pc_row(
    qpool_row: jax.Array, kpool: jax.Array, row, cfg: SLAConfig,
    scale: float | None = None,
) -> jax.Array:
    """One row of the compressed map P_c from already-pooled inputs.

    qpool_row: (..., D) mean-pooled q of block `row`; kpool: (..., Tn, D)
    mean-pooled k per KV block (entries of invalid blocks are ignored).
    Equals `predict_pc(q, k, cfg)[..., row, :]` when the pools match
    `pool_blocks` of the same (q, k)."""
    d = qpool_row.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("...d,...nd->...n", qpool_row.astype(jnp.float32),
                   kpool.astype(jnp.float32)) * scale
    if cfg.causal or cfg.window:
        s = jnp.where(row_valid(row, kpool.shape[-2], cfg), s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def classify_row(pc_row: jax.Array, row, cfg: SLAConfig) -> jax.Array:
    """Classify one query-block row: `classify_blocks(pc, cfg)[..., row, :]`.

    pc_row: (..., Tn) f32 -> (..., Tn) int8. Row classification is
    row-local only without the column-capacity pass, so this requires
    cfg.col_capacity_factor is None (use `SLAConfig.decode_plan_cfg`).
    """
    assert cfg.col_capacity_factor is None, (
        "classify_row is row-local; column capacity couples rows — "
        "classify with SLAConfig.decode_plan_cfg(...)")
    tn = pc_row.shape[-1]
    n_crit = cfg.num_critical(tn)
    n_neg = cfg.num_negligible(tn)
    valid = row_valid(row, tn, cfg)
    score = jnp.where(valid, pc_row, -1.0)
    if cfg.causal:
        assert cfg.block_q == cfg.block_kv, "causal SLA requires b_q == b_kv"
    if cfg.force_diagonal or cfg.causal:
        diag_col = row * cfg.block_q // cfg.block_kv
        score = jnp.where(jnp.arange(tn) == diag_col, 2.0, score)
    order = jnp.argsort(-score, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)
    mc = jnp.zeros(pc_row.shape, jnp.int8)
    mc = jnp.where(rank < n_crit, jnp.int8(1), mc)
    if n_neg > 0:
        mc = jnp.where(rank >= tn - n_neg, jnp.int8(-1), mc)
    return jnp.where(valid, mc, jnp.int8(-1))


def compute_mask(
    q: jax.Array, k: jax.Array, cfg: SLAConfig, scale: float | None = None
) -> jax.Array:
    """P_c prediction + classification. Gradient-stopped (mask is a constant
    w.r.t. the loss, matching the paper: TopK is not differentiated)."""
    pc = predict_pc(jax.lax.stop_gradient(q), jax.lax.stop_gradient(k), cfg, scale)
    return classify_blocks(pc, cfg)


def expand_mask(mc: jax.Array, block_q: int, block_kv: int) -> jax.Array:
    """Expand (..., Tm, Tn) block classification to (..., N, M) element level."""
    out = jnp.repeat(mc, block_q, axis=-2)
    return jnp.repeat(out, block_kv, axis=-1)


def sparsity_stats(mc: jax.Array) -> dict:
    """Fractions of critical / marginal / negligible blocks (over valid)."""
    total = mc.size
    crit = jnp.sum(mc == 1) / total
    marg = jnp.sum(mc == 0) / total
    neg = jnp.sum(mc == -1) / total
    return {
        "critical_frac": crit,
        "marginal_frac": marg,
        "negligible_frac": neg,
        "sparsity": 1.0 - crit,  # paper: sparsity = 1 - computed fraction
    }
