"""Block classification for SLA (paper Sec. 4, Eq. 2-3).

Predicts a compressed attention map P_c = softmax(pool(Q) pool(K)^T / sqrt(d))
over (T_m x T_n) blocks and classifies every block into
  critical (+1, top k_h% per row)  -> exact block-sparse attention,
  negligible (-1, bottom k_l%)     -> skipped,
  marginal (0, the rest)           -> linear attention.

Two routers produce the score map the classification ranks
(`SLAConfig.routing_mode`; DESIGN.md "Learned routing"):
  "threshold"  the paper's hand-tuned rule on the pooled P_c (Eq. 2-3);
  "learned"    a trainable SLA2-style per-head scorer
               (`predict_routing`): pooled Q/K pass through learnable
               per-head projections before the score map. Identity
               init reproduces the threshold rule bitwise; gradients
               reach the routing parameters through a straight-through
               relaxation of the top-k cuts (`routing_gates`), carried
               on the plan's marginal aggregation matrix.

The static-shape lookup tables (LUTs) consumed by the execution backends
are built from M_c in `core/plan.py` (`plan_attention` / `SLAPlan`; see
DESIGN.md "Plan/execute split") — this module is classification math only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig

NEG_INF = -1e30


def pool_blocks(x: jax.Array, block: int) -> jax.Array:
    """Mean-pool tokens into blocks. (..., N, D) -> (..., N // block, D)."""
    n, d = x.shape[-2], x.shape[-1]
    assert n % block == 0, f"seq len {n} not divisible by block {block}"
    xb = x.reshape(*x.shape[:-2], n // block, block, d)
    return jnp.mean(xb.astype(jnp.float32), axis=-2)


def block_causal_valid(tm: int, tn: int, block_q: int, block_kv: int) -> jax.Array:
    """(tm, tn) bool: block (i, j) contains at least one valid causal pair."""
    qi = (jnp.arange(tm) + 1) * block_q - 1  # last query row in block i
    kj = jnp.arange(tn) * block_kv  # first key col in block j
    return qi[:, None] >= kj[None, :]


def block_valid(cfg: SLAConfig, tm: int, tn: int) -> jax.Array:
    """(tm, tn) bool validity combining causal + sliding-window constraints
    (window applied at block granularity; see SLAConfig.window)."""
    valid = jnp.ones((tm, tn), bool)
    if cfg.causal:
        valid = jnp.logical_and(
            valid, block_causal_valid(tm, tn, cfg.block_q, cfg.block_kv))
    if cfg.window:
        qi = jnp.arange(tm)[:, None] * cfg.block_q
        kj = jnp.arange(tn)[None, :] * cfg.block_kv
        dist = jnp.abs(qi - kj)
        valid = jnp.logical_and(valid, dist < cfg.window + cfg.block_kv)
    return valid


def _pooled_scores(qp: jax.Array, kp: jax.Array, cfg: SLAConfig,
                   scale: float) -> jax.Array:
    """Shared scoring tail over already-pooled block features: the
    pooled dot-product map, validity masking, row softmax. Both routers
    end here, so full-map and pooled-carry callers share ONE set of ops
    (bitwise-identical score maps either way)."""
    s = jnp.einsum("...md,...nd->...mn", qp, kp) * scale
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, s.shape[-2], s.shape[-1])
        s = jnp.where(valid, s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def predict_pc(
    q: jax.Array, k: jax.Array, cfg: SLAConfig, scale: float | None = None
) -> jax.Array:
    """Compressed attention map P_c (Eq. 2). q,k: (B, H, N, D) -> (B, H, Tm, Tn)."""
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    qp = pool_blocks(q, cfg.block_q)
    kp = pool_blocks(k, cfg.block_kv)
    return _pooled_scores(qp, kp, cfg, scale)


# ---------------------------------------------------------------------------
# learned routing (SLA2-style, arXiv:2602.12675; DESIGN.md "Learned
# routing"): a trainable per-head scorer over pooled (Q, K) block
# features replaces the raw pooled dot product as the ranking score.
# ---------------------------------------------------------------------------
def check_routing_mode(cfg: SLAConfig, routing: dict | None = ...) -> None:
    """The ONE loud-failure path for stringly-typed routing selection.

    Pass `routing` to additionally require the learned head's
    parameters under routing_mode == "learned" (every scoring entry
    point does, via `score_map`/`score_row`)."""
    if cfg.routing_mode not in ("threshold", "learned"):
        raise ValueError(
            f"unknown routing_mode {cfg.routing_mode!r}; expected "
            "'threshold' or 'learned'")
    if routing is None and cfg.routing_mode == "learned":
        raise ValueError(
            "routing_mode='learned' needs routing parameters "
            "(core.masks.routing_init) — none were passed")


def routing_init(num_heads: int, head_dim: int, dtype=jnp.float32) -> dict:
    """Learnable routing-head parameters: per-head projections applied to
    the pooled block features before scoring.

    Identity init makes `predict_routing` equal `predict_pc` bitwise
    (x @ I adds only exact zeros in f32), so a learned-routing model
    starts from the paper's threshold rule exactly and every existing
    conformance/parity guarantee applies unchanged at init.
    """
    eye = jnp.tile(jnp.eye(head_dim, dtype=dtype)[None],
                   (num_heads, 1, 1))
    return {"wq": eye, "wk": eye}


def predict_routing(
    routing: dict, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
) -> jax.Array:
    """Learned-routing score map: softmax of projected-pooled scores.

    q, k: (B, H, N, D) -> (B, H, Tm, Tn). Drop-in replacement for
    `predict_pc` when cfg.routing_mode == "learned"; `routing` is the
    per-head parameter pytree from `routing_init` (wq/wk: (H, D, D)).
    """
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    qp = pool_blocks(q, cfg.block_q)  # (B, H, Tm, D) f32
    kp = pool_blocks(k, cfg.block_kv)
    qp = jnp.einsum("bhmd,hde->bhme", qp,
                    routing["wq"].astype(jnp.float32))
    kp = jnp.einsum("bhnd,hde->bhne", kp,
                    routing["wk"].astype(jnp.float32))
    return _pooled_scores(qp, kp, cfg, scale)


def routing_gates(pc: jax.Array, mc: jax.Array, cfg: SLAConfig) -> jax.Array:
    """Straight-through marginal-aggregation gates for learned routing.

    Forward value is EXACTLY the hard indicator (mc == 0) — the
    soft term cancels itself bitwise (x - x == 0) — so execution
    numerics are unchanged. The backward pass instead sees a sigmoid
    relaxation of the two per-row top-k cuts, so routing parameters
    receive gradients through the linear branch's `A @ h` aggregation
    matmul (the gather/reference backends consume `plan.marginal`
    differentiably; the fused kernel's custom_vjp treats the plan as a
    constant — fine-tune routing with backend="gather" or
    "reference").

    pc: (..., Tm, Tn) routing probabilities; mc the hard classification
    derived from them. The cut levels are the n-th order statistics of
    the raw pc row (gradient-stopped, standard straight-through
    practice); forced-diagonal / column-capacity overrides live only in
    the hard path.
    """
    tn = pc.shape[-1]
    n_crit = cfg.num_critical(tn)
    n_neg = cfg.num_negligible(tn)
    temp = max(float(cfg.routing_temp), 1e-6)
    hard = (mc == 0).astype(jnp.float32)
    srt = jax.lax.stop_gradient(jnp.sort(pc, axis=-1))  # ascending
    tau_crit = srt[..., tn - n_crit][..., None]
    soft = 1.0 - jax.nn.sigmoid((pc - tau_crit) / temp)
    if n_neg > 0:
        tau_neg = srt[..., n_neg - 1][..., None]
        soft = soft * jax.nn.sigmoid((pc - tau_neg) / temp)
    # parenthesization is load-bearing: (soft - soft) is exactly 0.0
    # elementwise, so the forward value is bitwise `hard`
    return hard + (soft - jax.lax.stop_gradient(soft))


def score_map(
    routing: dict | None, q: jax.Array, k: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
) -> jax.Array:
    """THE routing-mode dispatch for full score maps: the learned scorer
    under routing_mode == "learned" (routing params required — missing
    ones fail loudly here, the single shared path), the pooled P_c
    otherwise. Every full-map consumer (compute_mask, plan_attention,
    drift measurement) scores through this."""
    check_routing_mode(cfg, routing)
    if cfg.routing_mode == "learned":
        return predict_routing(routing, q, k, cfg, scale)
    return predict_pc(q, k, cfg, scale)


def score_map_pooled(
    routing: dict | None, qp: jax.Array, kp: jax.Array, cfg: SLAConfig,
    scale: float | None = None,
) -> jax.Array:
    """`score_map` from already-pooled block features.

    qp: (B, H, Tm, D) / kp: (B, H, Tn, D) mean-pooled per-block features
    (what `pool_blocks` produces). Equals `score_map(routing, q, k, ...)`
    bitwise when the pools match `pool_blocks` of the same (q, k) —
    the chunked-prefill carry maintains exactly those pools, so a chunk
    can re-score the FULL map without holding raw q/k (DESIGN.md
    "Chunked admission prefill")."""
    check_routing_mode(cfg, routing)
    d = qp.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    qp = qp.astype(jnp.float32)
    kp = kp.astype(jnp.float32)
    if cfg.routing_mode == "learned":
        qp = jnp.einsum("bhmd,hde->bhme", qp,
                        routing["wq"].astype(jnp.float32))
        kp = jnp.einsum("bhnd,hde->bhne", kp,
                        routing["wk"].astype(jnp.float32))
    return _pooled_scores(qp, kp, cfg, scale)


def classify_blocks(pc: jax.Array, cfg: SLAConfig) -> jax.Array:
    """Three-way block classification M_c (Eq. 3). pc: (..., Tm, Tn) -> int8.

    +1 critical / 0 marginal / -1 negligible. Causal-invalid blocks are -1.
    The diagonal block is forced critical when cfg.force_diagonal (guarantees
    the sparse softmax of every row is well defined).
    """
    tm, tn = pc.shape[-2], pc.shape[-1]
    n_crit = cfg.num_critical(tn)
    n_neg = cfg.num_negligible(tn)

    score = pc
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, tm, tn)
        score = jnp.where(valid, score, -1.0)  # push invalid to the very bottom
    force_diag = cfg.force_diagonal or cfg.causal
    if cfg.causal:
        # The diagonal block is the only partially-valid causal block; it must
        # be critical so the linear branch only ever sees fully-past blocks.
        assert cfg.block_q == cfg.block_kv, "causal SLA requires b_q == b_kv"
    if force_diag and tm <= tn:
        # Give the (block-)diagonal an infinitely large score so TopK keeps it.
        diag = jnp.eye(tm, tn, k=0, dtype=bool)
        if cfg.block_q != cfg.block_kv:
            qi = jnp.arange(tm) * cfg.block_q // cfg.block_kv
            diag = jax.nn.one_hot(qi, tn, dtype=jnp.bool_)
        score = jnp.where(diag, 2.0, score)

    # Descending rank of every block within its row (stable; O(Tn log Tn)).
    order = jnp.argsort(-score, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)

    mc = jnp.zeros(pc.shape, jnp.int8)
    mc = jnp.where(rank < n_crit, jnp.int8(1), mc)
    if n_neg > 0:
        mc = jnp.where(rank >= tn - n_neg, jnp.int8(-1), mc)
    if cfg.causal or cfg.window:
        valid = block_valid(cfg, tm, tn)
        mc = jnp.where(valid, mc, jnp.int8(-1))
        # Rows near the start may have fewer valid blocks than n_crit; the
        # rank<n_crit rule already keeps all their valid blocks critical.

    if cfg.col_capacity_factor is not None:
        # TPU adaptation: enforce a static per-column critical budget so the
        # dK/dV backward kernel has a fixed-width column LUT (DESIGN.md §3).
        # Over-budget blocks demote to *marginal* (linear branch still covers
        # them). The boosted `score` keeps forced-diagonal blocks first.
        cap = cfg.col_capacity(tm, tn)
        is_crit = mc == 1
        col_key = jnp.where(is_crit, score, -2.0)
        col_order = jnp.argsort(-col_key, axis=-2, stable=True)
        col_rank = jnp.argsort(col_order, axis=-2, stable=True)
        demote = jnp.logical_and(is_crit, col_rank >= cap)
        mc = jnp.where(demote, jnp.int8(0), mc)
    return mc


# ---------------------------------------------------------------------------
# row-local classification (decode-time incremental plans; DESIGN.md
# "Decode-time SLA"). `row` may be a python int or a traced scalar, so the
# same code serves one-shot tests and the jitted decode step.
# ---------------------------------------------------------------------------
def row_valid(row, tn: int, cfg: SLAConfig) -> jax.Array:
    """Validity of one query-block row — the row `row` slice of
    `block_valid` (causal + window constraints).

    `row` may be a scalar (returns (tn,)) or an array of per-slot rows
    (continuous-batching decode); an array broadcasts against the block
    axis, returning row.shape + (tn,) — pass it shaped (B, 1) when the
    result must align with per-head (B, H, Tn) score rows."""
    j = jnp.arange(tn)
    r = jnp.asarray(row)[..., None]
    valid = jnp.ones(jnp.broadcast_shapes(r.shape, j.shape), bool)
    if cfg.causal:
        valid = jnp.logical_and(
            valid, (r + 1) * cfg.block_q - 1 >= j * cfg.block_kv)
    if cfg.window:
        dist = jnp.abs(r * cfg.block_q - j * cfg.block_kv)
        valid = jnp.logical_and(valid, dist < cfg.window + cfg.block_kv)
    return valid


def predict_pc_row(
    qpool_row: jax.Array, kpool: jax.Array, row, cfg: SLAConfig,
    scale: float | None = None,
) -> jax.Array:
    """One row of the compressed map P_c from already-pooled inputs.

    qpool_row: (..., D) mean-pooled q of block `row`; kpool: (..., Tn, D)
    mean-pooled k per KV block (entries of invalid blocks are ignored).
    Equals `predict_pc(q, k, cfg)[..., row, :]` when the pools match
    `pool_blocks` of the same (q, k)."""
    d = qpool_row.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("...d,...nd->...n", qpool_row.astype(jnp.float32),
                   kpool.astype(jnp.float32)) * scale
    if cfg.causal or cfg.window:
        s = jnp.where(row_valid(row, kpool.shape[-2], cfg), s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def predict_routing_row(
    routing: dict, qpool_row: jax.Array, kpool: jax.Array, row,
    cfg: SLAConfig, scale: float | None = None,
) -> jax.Array:
    """One row of the learned-routing map from already-pooled inputs.

    qpool_row: (B, H, D); kpool: (B, H, Tn, D) — the decode cache's
    per-head pooled features. Projects both through the routing head
    then defers to `predict_pc_row`, so at identity init this equals
    `predict_pc_row` bitwise and prefill/decode route identically
    (`classify_row` of this row == `classify_blocks(...)[row]`)."""
    qr = jnp.einsum("bhd,hde->bhe", qpool_row.astype(jnp.float32),
                    routing["wq"].astype(jnp.float32))
    kr = jnp.einsum("bhnd,hde->bhne", kpool.astype(jnp.float32),
                    routing["wk"].astype(jnp.float32))
    return predict_pc_row(qr, kr, row, cfg, scale)


def score_row(
    routing: dict | None, qpool_row: jax.Array, kpool: jax.Array, row,
    cfg: SLAConfig, scale: float | None = None,
) -> jax.Array:
    """Row-level counterpart of `score_map` (decode-time classification):
    the same dispatch + loud-failure contract, one row at a time."""
    check_routing_mode(cfg, routing)
    if cfg.routing_mode == "learned":
        return predict_routing_row(routing, qpool_row, kpool, row, cfg,
                                   scale)
    return predict_pc_row(qpool_row, kpool, row, cfg, scale)


def classify_row(pc_row: jax.Array, row, cfg: SLAConfig) -> jax.Array:
    """Classify one query-block row: `classify_blocks(pc, cfg)[..., row, :]`.

    pc_row: (..., Tn) f32 -> (..., Tn) int8. `row` is a scalar, or an
    array of per-slot rows broadcastable against pc_row's batch axes
    (shape it (B, 1) for (B, H, Tn) rows). Row classification is
    row-local only without the column-capacity pass, so this requires
    cfg.col_capacity_factor is None (use `SLAConfig.decode_plan_cfg`).
    """
    assert cfg.col_capacity_factor is None, (
        "classify_row is row-local; column capacity couples rows — "
        "classify with SLAConfig.decode_plan_cfg(...)")
    tn = pc_row.shape[-1]
    n_crit = cfg.num_critical(tn)
    n_neg = cfg.num_negligible(tn)
    valid = row_valid(row, tn, cfg)
    score = jnp.where(valid, pc_row, -1.0)
    if cfg.causal:
        assert cfg.block_q == cfg.block_kv, "causal SLA requires b_q == b_kv"
    if cfg.force_diagonal or cfg.causal:
        diag_col = jnp.asarray(row * cfg.block_q // cfg.block_kv)
        score = jnp.where(jnp.arange(tn) == diag_col[..., None], 2.0,
                          score)
    order = jnp.argsort(-score, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)
    mc = jnp.zeros(pc_row.shape, jnp.int8)
    mc = jnp.where(rank < n_crit, jnp.int8(1), mc)
    if n_neg > 0:
        mc = jnp.where(rank >= tn - n_neg, jnp.int8(-1), mc)
    return jnp.where(valid, mc, jnp.int8(-1))


def compute_mask(
    q: jax.Array, k: jax.Array, cfg: SLAConfig, scale: float | None = None,
    routing: dict | None = None,
) -> jax.Array:
    """Score-map prediction + classification. Gradient-stopped (the mask
    is a constant w.r.t. the loss, matching the paper: TopK is not
    differentiated). With cfg.routing_mode == "learned" the learned
    scorer ranks the blocks (`routing` required; see `routing_init`)."""
    pc = score_map(routing, jax.lax.stop_gradient(q),
                   jax.lax.stop_gradient(k), cfg, scale)
    return classify_blocks(pc, cfg)


def expand_mask(mc: jax.Array, block_q: int, block_kv: int) -> jax.Array:
    """Expand (..., Tm, Tn) block classification to (..., N, M) element level."""
    out = jnp.repeat(mc, block_q, axis=-2)
    return jnp.repeat(out, block_kv, axis=-1)


def sparsity_stats(mc: jax.Array) -> dict:
    """Fractions of critical / marginal / negligible blocks (over valid)."""
    total = mc.size
    crit = jnp.sum(mc == 1) / total
    marg = jnp.sum(mc == 0) / total
    neg = jnp.sum(mc == -1) / total
    return {
        "critical_frac": crit,
        "marginal_frac": marg,
        "negligible_frac": neg,
        "sparsity": 1.0 - crit,  # paper: sparsity = 1 - computed fraction
    }
