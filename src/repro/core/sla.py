"""SLA attention module — functional public API.

Usage:
    cfg = SLAConfig(kh_frac=0.05, kl_frac=0.10, phi="softmax")
    params = sla_init(rng, num_heads, head_dim, cfg)
    out = sla_attention(params, q, k, v, cfg)        # (B, H, N, D)

Modes (cfg.mode):
  "sla"          O = O^s + Proj(O^l)                      (paper, Eq. 6)
  "sparse_only"  O = O^s                                   (Table 2 baseline)
  "linear_only"  O = full linear attention                 (Table 2 baseline)
  "l_plus_s"     O = O^s + full-linear(O)                  (Table 2 baseline)
  "full"         exact softmax attention

Set use_kernel=True to run the fused Pallas TPU kernel (interpret mode on
CPU); False runs the pure-jnp reference path (autodiff-differentiable).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.masks import compute_mask
from repro.core.phi import phi
from repro.core import reference as ref

Params = Dict[str, jax.Array]


def sla_init(rng: jax.Array, num_heads: int, head_dim: int,
             cfg: SLAConfig, dtype=jnp.float32) -> Params:
    """Learnable parameters: the per-head d x d Proj on the linear branch."""
    if cfg.proj_init == "identity":
        proj = jnp.tile(jnp.eye(head_dim, dtype=dtype)[None], (num_heads, 1, 1))
    elif cfg.proj_init == "zeros":
        proj = jnp.zeros((num_heads, head_dim, head_dim), dtype)
    else:
        raise ValueError(cfg.proj_init)
    return {"proj": proj}


def _repeat_kv(x: jax.Array, num_q_heads: int) -> jax.Array:
    """GQA: broadcast KV heads to match Q heads. (B, Hkv, N, D) -> (B, H, N, D)."""
    hkv = x.shape[1]
    if hkv == num_q_heads:
        return x
    assert num_q_heads % hkv == 0
    return jnp.repeat(x, num_q_heads // hkv, axis=1)


def sla_attention(
    params: Optional[Params],
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: SLAConfig,
    scale: Optional[float] = None,
    use_kernel: bool = False,
    interpret: bool = True,
    impl: str = "reference",
) -> jax.Array:
    """SLA attention. q: (B, H, N, D); k, v: (B, Hkv, N, D) with Hkv | H.

    impl: "reference" (dense oracle) or "gather" (LUT-gather XLA path whose
    compiled FLOPs equal the true sparse cost — use for dry-run/training).
    use_kernel=True overrides impl with the fused Pallas kernel.

    Returns (B, H, N, D) in q.dtype.
    """
    in_dtype = q.dtype
    h = q.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)

    if cfg.mode == "full":
        return ref.full_attention(q, k, v, cfg.causal, scale).astype(in_dtype)

    if cfg.mode == "linear_only":
        qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)
        o = ref.full_linear(qp, kp, v)
        if params is not None:
            o = jnp.einsum("bhnd,hde->bhne", o, params["proj"].astype(jnp.float32))
        return o.astype(in_dtype)

    mc = compute_mask(q, k, cfg, scale)

    if cfg.mode == "sparse_only":
        o_s, _ = ref.sparse_component(q, k, v, mc, cfg, scale)
        return o_s.astype(in_dtype)

    qp, kp = phi(q, cfg.phi), phi(k, cfg.phi)

    if cfg.mode == "l_plus_s":
        o_s, _ = ref.sparse_component(q, k, v, mc, cfg, scale)
        o_l = ref.full_linear(qp, kp, v)
        return (o_s + o_l).astype(in_dtype)

    if cfg.mode != "sla":
        raise ValueError(f"unknown SLA mode {cfg.mode!r}")

    if use_kernel:
        from repro.kernels import ops as kops
        o_s, o_l = kops.sla_attention_core(q, k, v, qp, kp, mc, cfg,
                                           scale=scale, interpret=interpret)
    elif impl == "gather":
        from repro.core.block_sparse_xla import sla_forward_gather
        o_s, o_l = sla_forward_gather(q, k, v, qp, kp, mc, cfg, scale)
    else:
        o_s, o_l = ref.sla_forward_reference(q, k, v, qp, kp, mc, cfg, scale)

    proj = params["proj"].astype(jnp.float32)
    o = o_s + jnp.einsum("bhnd,hde->bhne", o_l, proj)
    return o.astype(in_dtype)
