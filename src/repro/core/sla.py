"""SLA attention module — functional public API (plan/execute wrapper).

Usage:
    cfg = SLAConfig(kh_frac=0.05, kl_frac=0.10, phi="softmax")
    params = sla_init(rng, num_heads, head_dim, cfg)
    out = sla_attention(params, q, k, v, cfg)                 # (B, H, N, D)

    # plan once, execute many times (cross-timestep reuse):
    plan = plan_attention(q, k, cfg)
    out = sla_attention(params, q, k, v, cfg, plan=plan)

    # drift-gated refresh (DESIGN.md "Plan lifetime & drift"): keep the
    # plan while it retains critical mass, rebuild when it decays:
    plan, retention, replanned = refresh_plan(plan, q, k, cfg,
                                              cfg.plan_drift_threshold)

Modes (cfg.mode):
  "sla"          O = O^s + Proj(O^l)                      (paper, Eq. 6)
  "sparse_only"  O = O^s                                   (Table 2 baseline)
  "linear_only"  O = full linear attention                 (Table 2 baseline)
  "l_plus_s"     O = O^s + full-linear(O)                  (Table 2 baseline)
  "full"         exact softmax attention

`backend` selects the execution path from the core.backends registry:
"reference" (dense oracle), "gather" (LUT-gather XLA — true sparse
compiled FLOPs), or "kernel" (fused Pallas; interpret mode off-TPU).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import backends
from repro.core.config import SLAConfig
from repro.core.plan import SLAPlan, refresh_plan  # noqa: F401 — re-export

Params = Dict[str, jax.Array]


def sla_init(rng: jax.Array, num_heads: int, head_dim: int,
             cfg: SLAConfig, dtype=jnp.float32) -> Params:
    """Learnable parameters: the per-head d x d Proj on the linear branch."""
    if cfg.proj_init == "identity":
        proj = jnp.tile(jnp.eye(head_dim, dtype=dtype)[None], (num_heads, 1, 1))
    elif cfg.proj_init == "zeros":
        proj = jnp.zeros((num_heads, head_dim, head_dim), dtype)
    else:
        raise ValueError(cfg.proj_init)
    return {"proj": proj}


def sla_attention(
    params: Optional[Params],
    q: jax.Array, k: jax.Array, v: jax.Array,
    cfg: SLAConfig,
    scale: Optional[float] = None,
    backend: str = "reference",
    plan: Optional[SLAPlan] = None,
    routing: Optional[Params] = None,
) -> jax.Array:
    """SLA attention. q: (B, H, N, D); k, v: (B, Hkv, N, D) with Hkv | H.

    `plan`: a precomputed SLAPlan (from `plan_attention`) — pass it to
    amortize planning across calls; None plans inline from (q, k).
    `routing`: learned-routing scorer parameters (`routing_init`) for
    inline planning when cfg.routing_mode == "learned".

    Returns (B, H, N, D) in q.dtype.
    """
    return backends.execute(plan, params, q, k, v, cfg,
                            scale=scale, backend=backend, routing=routing)
