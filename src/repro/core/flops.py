"""FLOPs accounting for attention variants (paper Tables 1-3 'FLOPs' column).

Convention: 1 multiply-accumulate = 2 FLOPs, matching XLA cost_analysis.
Counts are per (batch element x layer), summed over heads, forward only,
unless stated otherwise. `d` is the head dim, `h` heads, `n` tokens.
"""
from __future__ import annotations

from repro.core.config import SLAConfig


def full_attention_flops(n: int, d: int, h: int) -> float:
    """QK^T + PV: 2 matmuls of (n x d x n) each => 4 n^2 d per head."""
    return 4.0 * n * n * d * h


def linear_attention_flops(n: int, d: int, h: int) -> float:
    """phi(K)^T V (2nd^2) + phi(Q) H (2nd^2) + normalizer (~2nd)."""
    return (4.0 * n * d * d + 2.0 * n * d) * h


def sla_flops(n: int, d: int, h: int, cfg: SLAConfig,
              include_overheads: bool = True) -> dict:
    """FLOPs breakdown of SLA at sequence length n.

    sparse   : 4 n^2 d * (critical fraction)
    linear   : h_j/z_j precompute + per-row phi(Q_i)H_i  (Eq. 5)
    mask     : pooled score map  pool(Q)pool(K)^T + softmax (Eq. 2)
    routing  : learned-routing head only (cfg.routing_mode == "learned"):
               per-head d x d projections of the pooled Q (Tm rows) and
               pooled K (Tn rows) block features; 0 under "threshold"
    aggregate: marginal-indicator matmul A @ h (TPU pre-aggregation form)
    proj     : learnable d x d on the linear output (Eq. 6)
    """
    tm, tn = n // cfg.block_q, n // cfg.block_kv
    crit_frac = cfg.num_critical(tn) / tn
    sparse = 4.0 * n * n * d * crit_frac * h
    linear = (4.0 * n * d * d) * h
    mask = (2.0 * tm * tn * d + 5.0 * tm * tn) * h
    routing = (2.0 * (tm + tn) * d * d * h
               if cfg.routing_mode == "learned" else 0.0)
    agg = (2.0 * tm * tn * (d * d + d)) * h if include_overheads else 0.0
    proj = 2.0 * n * d * d * h
    total = sparse + linear + mask + routing + agg + proj
    return {
        "sparse": sparse,
        "linear": linear,
        "mask": mask,
        "routing": routing,
        "aggregate": agg,
        "proj": proj,
        "total": total,
        "full": full_attention_flops(n, d, h),
        "reduction_x": full_attention_flops(n, d, h) / total,
        "sparsity": 1.0 - crit_frac,
    }


def dense_decode_flops(n: int, d: int, h: int) -> float:
    """Per-token dense masked decode: q K^T (2nd) + p V (2nd) per head —
    O(S) in the context length (the decode_* cells' old cost model)."""
    return 4.0 * n * d * h


def sla_decode_flops(n: int, d: int, h: int, cfg: SLAConfig,
                     num_critical: int | None = None) -> dict:
    """Per-token decode-SLA attention FLOPs (DESIGN.md "Decode-time SLA").

    sparse : attend the live row's K critical blocks (4 K b_kv d)
    state  : O(1) running-state update phi(k) v^T + totals (~4 d^2)
    linear : subtractive aggregation H - sum_crit h_j (2 K d^2) plus the
             phi(q) H / phi(q) Z apply (2 d^2 + 2 d)
    proj   : learned d x d merge (Eq. 6)
    plan   : amortized block-boundary row classification — one O(Tn d)
             pooled-score row + top-k every b_q tokens
    routing: learned-routing head only: projecting the pooled q row and
             the Tn pooled-k features at each block boundary, amortized
             like `plan`; 0 under "threshold"

    Everything except `plan`/`routing` is independent of the context
    length n: the O(S) dense term is replaced by critical-blocks + an
    O(1) linear term, with planning amortized to O(Tn / b_q) per token.
    """
    tn = max(1, n // cfg.block_kv)
    if num_critical is not None:
        k_sel = num_critical
    elif cfg.decode_budget is not None:
        k_sel = cfg.decode_budget  # the static decode budget
    else:
        k_sel = cfg.num_critical(tn)
    k_sel = max(1, min(k_sel, tn))
    sparse = 4.0 * k_sel * cfg.block_kv * d * h
    state = 4.0 * d * d * h
    linear = (2.0 * k_sel * d * d + 2.0 * d * d + 2.0 * d) * h
    proj = 2.0 * d * d * h
    plan = (2.0 * tn * d + 5.0 * tn) * h / cfg.block_q
    routing = (2.0 * (tn + 1) * d * d * h / cfg.block_q
               if cfg.routing_mode == "learned" else 0.0)
    total = sparse + state + linear + proj + plan + routing
    dense = dense_decode_flops(n, d, h)
    return {
        "sparse": sparse,
        "state": state,
        "linear": linear,
        "proj": proj,
        "plan": plan,
        "routing": routing,
        "total": total,
        "dense": dense,
        "reduction_x": dense / total,
    }


def sla_subtractive_agg_flops(n: int, d: int, h: int, cfg: SLAConfig) -> float:
    """Aggregation cost with the subtract-non-marginal optimization:
    H_i = H_total - sum_{crit+neg j} h_j   (paper App. A.3, gather form).
    """
    tm, tn = n // cfg.block_q, n // cfg.block_kv
    sub_frac = (cfg.num_critical(tn) + cfg.num_negligible(tn)) / tn
    return (2.0 * tm * tn * (d * d + d)) * sub_frac * h
