"""FLOPs accounting for attention variants (paper Tables 1-3 'FLOPs' column).

Convention: 1 multiply-accumulate = 2 FLOPs, matching XLA cost_analysis.
Counts are per (batch element x layer), summed over heads, forward only,
unless stated otherwise. `d` is the head dim, `h` heads, `n` tokens.
"""
from __future__ import annotations

from repro.core.config import SLAConfig


def full_attention_flops(n: int, d: int, h: int) -> float:
    """QK^T + PV: 2 matmuls of (n x d x n) each => 4 n^2 d per head."""
    return 4.0 * n * n * d * h


def linear_attention_flops(n: int, d: int, h: int) -> float:
    """phi(K)^T V (2nd^2) + phi(Q) H (2nd^2) + normalizer (~2nd)."""
    return (4.0 * n * d * d + 2.0 * n * d) * h


def sla_flops(n: int, d: int, h: int, cfg: SLAConfig,
              include_overheads: bool = True) -> dict:
    """FLOPs breakdown of SLA at sequence length n.

    sparse   : 4 n^2 d * (critical fraction)
    linear   : h_j/z_j precompute + per-row phi(Q_i)H_i  (Eq. 5)
    mask     : pooled score map  pool(Q)pool(K)^T + softmax (Eq. 2)
    aggregate: marginal-indicator matmul A @ h (TPU pre-aggregation form)
    proj     : learnable d x d on the linear output (Eq. 6)
    """
    tm, tn = n // cfg.block_q, n // cfg.block_kv
    crit_frac = cfg.num_critical(tn) / tn
    sparse = 4.0 * n * n * d * crit_frac * h
    linear = (4.0 * n * d * d) * h
    mask = (2.0 * tm * tn * d + 5.0 * tm * tn) * h
    agg = (2.0 * tm * tn * (d * d + d)) * h if include_overheads else 0.0
    proj = 2.0 * n * d * d * h
    total = sparse + linear + mask + agg + proj
    return {
        "sparse": sparse,
        "linear": linear,
        "mask": mask,
        "aggregate": agg,
        "proj": proj,
        "total": total,
        "full": full_attention_flops(n, d, h),
        "reduction_x": full_attention_flops(n, d, h) / total,
        "sparsity": 1.0 - crit_frac,
    }


def sla_subtractive_agg_flops(n: int, d: int, h: int, cfg: SLAConfig) -> float:
    """Aggregation cost with the subtract-non-marginal optimization:
    H_i = H_total - sum_{crit+neg j} h_j   (paper App. A.3, gather form).
    """
    tm, tn = n // cfg.block_q, n // cfg.block_kv
    sub_frac = (cfg.num_critical(tn) + cfg.num_negligible(tn)) / tn
    return (2.0 * tm * tn * (d * d + d)) * sub_frac * h
