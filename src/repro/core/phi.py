"""Feature maps phi(.) for the linear-attention branch of SLA.

The paper ablates softmax (best), elu+1, and hedgehog; we provide softmax,
elu+1 and relu. All maps produce non-negative features so the linear-branch
denominator phi(Q) . Z is positive whenever any marginal block exists.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def phi(x: jax.Array, kind: str) -> jax.Array:
    """Apply the feature map along the head dimension (last axis).

    Computed in f32 regardless of input dtype (returned in f32; callers cast).
    """
    x = x.astype(jnp.float32)
    if kind == "softmax":
        return jax.nn.softmax(x, axis=-1)
    if kind == "elu1":
        return jax.nn.elu(x) + 1.0
    if kind == "relu":
        return jax.nn.relu(x) + 1e-6
    raise ValueError(f"unknown phi kind: {kind!r}")


PHI_KINDS = ("softmax", "elu1", "relu")
